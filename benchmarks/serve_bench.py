"""Serving throughput: batched engine vs per-query execution.

Measures queries/sec and p50 latency for five execution modes of the
same mixed workload (aggregation / Boolean / ranked, paper Table I):

  per_query_scan  - legacy path: one query at a time, per-shard
                    operators rescan the flat token arrays (the
                    pre-postings serving path, kept via the *_scan
                    parity references)
  per_query       - one query at a time through the current
                    single-query entry points (postings-backed)
  batched         - ``QueryBatch``: one-pass batched scoring, shared
                    shard scans, per-shard postings
  batched_fused   - ``QueryBatch`` with doc-granular scoring enabled:
                    planning scores every query against every *doc*
                    and reduces to shards through the fused path
                    (shard-sorted ``np.add.reduceat`` on CPU; the
                    segment-sum Pallas kernels on TPU) — n_docs >>
                    n_shards of scoring work at batched-row throughput
  windowed        - ``BatchWindow`` frontend over the batched engine:
                    queries submitted one at a time in an open-loop
                    burst, windows closed by deadline (2 ms) or size.
                    This is a *saturated-throughput* row: its
                    ``p50_sojourn_ms`` includes dispatcher queue
                    backlog, so it is comparable run-to-run but is NOT
                    the lightly-loaded window latency (for that, see
                    examples/serve_queries.py, which paces arrivals)
  batched_hostsN  - (``--hosts N``; the smoke gate runs N=2) the
                    batched engine through a simulated N-host topology:
                    a blocked ``PlacementMap`` over the shards and a
                    ``HostGroupExecutor`` splitting every union plan by
                    residency, per-host shared scans, cross-host
                    gather.  Worker threads are held at the single-host
                    total so the row isolates placement overhead, not
                    parallelism.  Alongside the timing row the bench
                    emits a ``placement`` record and *hard-checks* the
                    locality contract: per-host scan counts must equal
                    the residency split of every union plan, and the
                    gathered results must be identical to the
                    single-executor path for all three query types.
                    Read the throughput ratio on the *smoke* config
                    (dispatch-dominated batches — the CI gate): there
                    it shows the no-cross-host-penalty property.  At
                    full-bench scale the simulation undercounts: both
                    hosts' scans are GIL-bound numpy on ONE machine,
                    so their "concurrent" halves partly serialize and
                    the ratio dips below 1.0 — contention a real pod,
                    with per-host cores, does not have (the same
                    effect already makes the single-host arm faster at
                    1 worker than 2 on this container)
  batched_lbN     - the *hot-host* arm (runs whenever ``--hosts`` is
                    active): same N-host topology, but host 0 is
                    artificially degraded (the injection hook sleeps
                    ``HOT_HOST_DELAY_S`` per resident shard before
                    each of its scans) and the executor runs with the
                    replica-aware balancer on.  The warm pass teaches
                    the load model that host 0 is hot; measured trials
                    then run the balanced split, which sheds host 0's
                    shard groups onto their ring replicas (scans stay
                    local — replicas hold the data).  Alongside the
                    timing row the bench emits a ``balance`` record
                    (estimated vs realized per-host makespan, shed
                    counts, primary-vs-balanced makespan) and
                    *hard-fails* unless (a) balanced results are
                    bit-for-bit the single-executor results, (b)
                    balanced and primary-only splits gather
                    identically, and (c) the balanced split reduces
                    the mean job makespan vs the primary-only split
                    under the same hot host
  batched_budget  - the error-budgeted engine: every query carries a
                    ``QueryBudget`` (error / latency SLOs), a
                    ``RatePlanner`` picks its per-query sampling rate,
                    and Boolean/ranked results gain bootstrap CIs.
                    The row prices budget planning + per-result CIs on
                    the batched hot path; it is floored by the
                    regression gate.  Alongside it (whenever
                    ``--hosts`` is active) a ``budget`` record runs
                    three *hard checks*: (1) a planner-bearing engine
                    serving unbudgeted queries is bit-for-bit the
                    plain engine, at the nominal rate and at the
                    precise rate-1.0 fast path; (2) on a deterministic
                    untimed pass (pinned rng, pressure 0 and fully
                    degraded) the count queries' 95% CIs cover the
                    exact full-scan answer for >= 90% of queries;
                    (3) under ~3x-capacity Poisson arrivals with the
                    hot host, the budget-aware window (degradation
                    ladder on) sheds strictly fewer queries than the
                    static-backpressure baseline on the same arrival
                    schedule and queue bound — and the baseline must
                    itself shed, or the arm failed to overload
  batched_chaos   - (``--chaos``; always on under ``--smoke``) the
                    batched engine through the 2-host topology under a
                    steady scripted ``FaultPlan``: every host uniformly
                    slowed ``CHAOS_SLOW_MS`` per shard scan (the row is
                    sleep-dominated, hence machine-stable and floorable
                    by the regression gate) and host 1 mildly flaky
                    (deterministic seeded task faults, cleared by the
                    executor's retry path).  Alongside it the bench
                    emits a ``chaos`` record: an untimed scripted
                    kill -> serve-degraded -> join -> recover -> drain
                    scenario through ``FleetManager`` that *hard-fails*
                    unless zero queries are lost, every batch (faulted
                    ones included) gathers bit-for-bit the
                    single-executor results, the post-join makespan
                    recovers to within 1.25x the pre-crash baseline,
                    the joiner was fully warmed before serving, and the
                    planned drain orphans nothing (``--chaos-only``
                    runs just this arm — the CI chaos-smoke job)
  batched_zipf    - (``--zipf``; always on under ``--smoke``) the plain
                    batched engine serving a Zipf-skewed stream (skew
                    ``ZIPF_SKEW``, 2x the distinct pool): repeated hot
                    queries pay full sampling + scan price every time.
                    The uncached baseline the cached row is read
                    against — same stream, so qps/p50 are over the
                    stream length, not ``n_queries``
  batched_cached  - the same Zipf stream through a
                    ``SemanticQueryCache``-enabled engine (built via
                    ``launch.serve_stack.build_serving_stack``, reused
                    across trials): the warm pass populates the cache,
                    measured trials serve mostly exact LSH-signature
                    hits that skip sampling, scanning, and the
                    executor entirely.  Floored by the regression
                    gate, and *hard-gated* in-run: cached p50 must be
                    strictly below ``batched_zipf`` p50.  Alongside
                    the rows a ``cache`` record runs two untimed hard
                    checks at Hamming radius 0 — (1) exact-hit
                    parity: a cold cached pass is bit-for-bit the
                    plain engine under the same seeds, and a warm
                    pass under different seeds resolves every query
                    from the cache with results bit-for-bit the cold
                    ones; (2) generation fencing: across scripted
                    ``FleetManager`` join and drain swaps ZERO cache
                    hits cross the placement-epoch bump, every entry
                    drops as ``stale_epoch``, and post-swap results
                    match a plain engine on the new topology
  batched_ingest  - (``--ingest``; always on under ``--smoke``) the
                    batched engine through an ingest-enabled serving
                    stack while a small ``Ingestor.step`` — corpus
                    append, frozen-model PV-DBOW inference, RCU
                    generation swap — races every call from a
                    background thread.  The row prices writer
                    contention on the serving hot path and is floored
                    by the regression gate.  Alongside it a
                    hard-gated ``ingest`` record appends 25% of the
                    corpus (sentinel-phrase docs) mid-serve and
                    fails unless: zero queries shed/lose shards
                    across the swap; every racing batch is
                    bit-for-bit either the pre-append or post-append
                    reference (never a torn world); the post-swap
                    sentinel census observes exactly the appended
                    docs at error bound 0 with the content
                    generation minted exactly once; serving p99 with
                    ingest active stays within 1.25x the no-ingest
                    p99; and a warm semantic cache serves ZERO hits
                    across the content bump, dropping every entry as
                    ``stale_epoch`` (the content-axis fence the old
                    placement-only epoch could not see)
  batched_mega    - the one-launch scan-over-shards megakernel row:
                    every query in the chunk scans the FULL fleet (the
                    high-shards-per-host regime), and the chunk's scan
                    fns come from one ``MegascanSpec``, so the executor
                    routes each chunk as ONE Pallas launch over the
                    packed multi-shard payload (double-buffered shard
                    prefetch on TPU) instead of one task per shard.
                    Floored by the regression gate — the row collapses
                    if the megakernel route stops engaging and the
                    scan falls back to per-shard dispatch.  Alongside
                    it a hard-gated ``megascan`` record checks
                    bit-for-bit group-vs-per-shard gather parity on
                    ragged plans (sum AND ranked modes, single- and
                    host-group executors), that the one-launch wall
                    beats the per-shard route's on the same plan, and
                    that the roofline dispatch share drops — the
                    dispatch-bound -> bandwidth-bound claim as a
                    rendered row (``python -m benchmarks.roofline
                    --serve BENCH_serve.json``)

Each mode runs ``trials`` times and the best wall time is reported
(the container CPU is shared; best-of filters scheduler noise).
Emits ``BENCH_serve.json`` (path overridable via ``BENCH_SERVE_JSON``)
so future PRs have a serving-perf trajectory to compare against.
NOTE: the trajectory *resets at PR 3* — retrieval queries now read
``ceil(rate * n_shards)`` distinct shards (``pps_sample_distinct``)
instead of a with-replacement multiset that often touched far fewer,
so every bool/ranked query in every arm does more scan work at the
same nominal rate; the ~35% drop in the ``batched``/``batched_fused``
rows vs PR 2 is that extra work, not a runtime regression.  Rows are
comparable from PR 3 onward.

``--sweep`` additionally drives a *load sweep*: Poisson arrivals
(exponential gaps, TextBenDS-style throughput emulation) at several
rates spanning light load to past dispatcher capacity, each served
three ways — the static (2 ms, fixed-size) window, the adaptive
``WindowController`` window, and the error-budgeted engine behind an
adaptive window with a bounded queue — and records per-rate p50/p99
sojourn rows under ``load_sweep`` in the JSON, each row carrying the
fraction of queries shed vs served degraded and the realized p90
relative error of its count queries against exact answers.  The
adaptive window must be no worse at both ends: at light load it
collapses the deadline (a lone query stops waiting out 2 ms), at heavy
load it grows the batch (amortization is what keeps the dispatcher
stable); the budget mode is where overload walks the
degrade-before-shed ladder instead of queueing without bound.

  PYTHONPATH=src python -m benchmarks.serve_bench [--smoke] [--sweep]

``--smoke`` runs a small corpus + short training in well under a
minute — the CI serving smoke job.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import threading
import time

import numpy as np

from benchmarks.common import csv_row, pick_query_words, text_setup

# per-resident-shard delay injected on host 0 in the hot-host arm:
# several times the real per-shard scan cost at *both* bench scales
# (sub-ms on the smoke corpus, ~2-8 ms/shard at full scale on a loaded
# container), so the hot/cold cost ratio clears the balancer's
# hysteresis band decisively everywhere — a marginal ratio would make
# the shed (and the makespan hard-check) flap with container noise —
# yet cheap enough that the whole arm stays in CI budget (the primary
# arm pays it on ~half the union per job; the balanced arm sheds it)
HOT_HOST_DELAY_S = 1e-2

# uniform per-shard sleep injected on EVERY host in the chaos arms
# (FaultPlan.slow): it makes job makespans sleep-dominated, so the
# kill/recover makespan ratios and the batched_chaos row's throughput
# are set by the scripted scenario, not by container CPU speed — the
# property that lets the regression gate floor the chaos row and the
# recovery hard-check run with a tight 1.25x bound
CHAOS_SLOW_MS = 3.0

# the Zipf/cached arms' traffic shape: skew 1.5 makes the top query
# ~10x the 10th-ranked one (a realistic hot-query head), and the
# stream runs 2x the distinct pool so the cached arm's measured trials
# serve mostly repeats — the regime the semantic cache is built for
ZIPF_SKEW = 1.5
ZIPF_STREAM_FACTOR = 2

# the live-ingest arms: the hard-gated record appends a mid-run batch
# of INGEST_FRACTION * n_docs sentinel-phrase docs through one
# Ingestor.step racing the serving loop, and the batched_ingest timed
# row serves the pool while a small INGEST_CHUNK_DOCS step runs
# concurrently on every call — the row prices writer/reader GIL
# contention on the hot path, and the regression gate floors it.
# INGEST_P99_MAX_RATIO is the freshness-vs-latency contract from the
# record: serving p99 with ingest active may not exceed 1.25x the
# no-ingest p99.  Both arms serve the pool INGEST_GATE_PASSES times
# per trial (best-of-trials) and the p99 is over per-query samples —
# enough mass that the statistic is a real tail quantile (on a few
# batches "p99" is just the max, i.e. pure scheduler noise) and the
# step's startup burst (one batch in ~130) sits past the cutoff, so
# the gate measures steady-state racing, not the single worst
# collision.
INGEST_FRACTION = 0.25
INGEST_CHUNK_DOCS = 8
INGEST_INFER_STEPS = 10
INGEST_P99_MAX_RATIO = 1.25
INGEST_GATE_PASSES = 32


def _hot_host_hook(host, shard_ids):
    """Degrade host 0 by HOT_HOST_DELAY_S per shard it is about to
    scan — the straggler the balancer exists to route around."""
    if host == 0:
        time.sleep(HOT_HOST_DELAY_S * len(shard_ids))


def _mixed_queries(corpus, n, rng):
    from repro.core.queries import BatchQuery, parse_boolean
    words = pick_query_words(corpus, 3 * n, rng)
    if len(words) < 3:
        raise ValueError("corpus has too few mid-frequency candidate words "
                         f"for the serve bench ({len(words)} < 3)")
    qs = []
    for i in range(n):
        # pick_query_words caps at the candidate-pool size; recycle by
        # modulo so large n_queries never indexes past the end
        w = [int(words[(3 * i + j) % len(words)]) for j in range(3)]
        kind = i % 3
        if kind == 0:
            qs.append(BatchQuery.count([w[0]]))
        elif kind == 1:
            qs.append(BatchQuery.boolean(
                parse_boolean([w[0], "or", w[1], "and", w[2]])))
        else:
            qs.append(BatchQuery.ranked(w, k=10))
    return qs


def _zipf_stream(queries, n_stream, skew, rng):
    """Power-law query stream over the distinct pool: the i-th query
    (rank i+1) is drawn with probability proportional to rank**-skew —
    the hot/near-duplicate traffic shape real serving sees, and what
    the semantic cache is for."""
    ranks = np.arange(1, len(queries) + 1, dtype=np.float64)
    p = ranks ** -float(skew)
    p /= p.sum()
    idx = rng.choice(len(queries), size=int(n_stream), p=p)
    return [queries[int(i)] for i in idx]


def _run_per_query(corpus, index, queries, rate, executor, seed):
    """Current single-query entry points, one query at a time."""
    from repro.core.queries import (boolean_query, phrase_count_query,
                                    ranked_query)
    rng = np.random.default_rng(seed)
    lat = []
    for q in queries:
        t0 = time.perf_counter()
        if q.kind == "count":
            phrase_count_query(corpus, index, q.phrase, rate, rng=rng,
                               executor=executor)
        elif q.kind == "bool":
            boolean_query(corpus, index, q.expr, rate, rng=rng,
                          executor=executor)
        else:
            ranked_query(corpus, index, q.words, rate, k=q.k, rng=rng,
                         executor=executor)
        lat.append(time.perf_counter() - t0)
    return lat


def _run_per_query_scan(corpus, index, queries, rate, executor, seed):
    """The pre-postings serving path: single-query planning + flat-scan
    per-shard operators (``*_scan`` parity references)."""
    from repro.core.queries.retrieval import (_expr_eval_docs_scan,
                                              _expr_shard_similarity,
                                              bm25_scores_for_shard_scan)
    from repro.core.sampling import (ht_estimate, pps_sample,
                                     pps_sample_distinct,
                                     similarity_probabilities, unique_shards)
    from repro.data.store import count_phrase_in_shard
    rng = np.random.default_rng(seed)
    lat = []
    for q in queries:
        t0 = time.perf_counter()
        if q.kind == "bool":
            sims = _expr_shard_similarity(q.expr, index)
            probs = similarity_probabilities(sims)
        else:
            probs = index.shard_probabilities(
                q.phrase if q.kind == "count" else q.words)
        # same kind-dependent samplers as the engine paths: retrieval
        # reads distinct shards, aggregation keeps the HH multiset
        if q.kind == "count":
            sample = pps_sample(probs, rate, rng)
        else:
            sample = pps_sample_distinct(probs, rate, rng)
        distinct = unique_shards(sample)
        if q.kind == "count":
            by = executor.map_shards(
                corpus, distinct,
                lambda s, q=q: count_phrase_in_shard(s, q.phrase))
            local = np.asarray([by[int(s)] for s in sample.shard_ids],
                               np.float64)
            ht_estimate(local, sample)
        elif q.kind == "bool":
            executor.map_shards(
                corpus, distinct,
                lambda s, q=q: s.doc_ids[_expr_eval_docs_scan(q.expr, s)])
        else:
            by = executor.map_shards(
                corpus, distinct,
                lambda s, q=q: (s.doc_ids, bm25_scores_for_shard_scan(
                    s, q.words, index.doc_freq, index.n_docs,
                    index.avg_doc_len)))
            sc = np.concatenate([by[int(s)][1] for s in distinct])
            np.argsort(-sc, kind="stable")[:q.k]
        lat.append(time.perf_counter() - t0)
    return lat


def _run_batched(corpus, index, queries, rate, executor, seed, batch_size,
                 engine=None):
    from repro.core.queries import QueryBatch
    if engine is None:
        engine = QueryBatch(corpus, index, executor=executor)
    rng = np.random.default_rng(seed)
    lat = []
    for i in range(0, len(queries), batch_size):
        chunk = queries[i:i + batch_size]
        t0 = time.perf_counter()
        engine.execute(chunk, rate, rng=rng)
        lat.append((time.perf_counter() - t0, len(chunk)))
    return lat


def _mega_chunks(corpus, index, n, batch_size, rng):
    """Pre-built ``(fns, plan)`` chunks for the batched_mega arm: every
    query is a similarity-mass scan over the FULL fleet (the
    high-shards-per-host regime the megakernel targets), and each
    chunk's fns come from one ``MegascanSpec`` — so the megakernel
    route runs the chunk as ONE launch (per host on a host-group
    executor) where the per-shard route pays ``n_shards`` tasks.
    Built once and reused across trials, like the budget/cache engines:
    the warm pass is where payload packing and jit land."""
    from repro.kernels.megascan import MegascanSpec
    words = pick_query_words(corpus, 3 * n, rng)
    all_shards = list(range(corpus.n_shards))
    chunks = []
    for i in range(0, n, batch_size):
        m = min(batch_size, n - i)
        triples = [[int(words[(3 * (i + j) + t) % len(words)])
                    for t in range(3)] for j in range(m)]
        spec = MegascanSpec(index, index.query_vectors(triples))
        chunks.append((spec.scan_fns(), [all_shards] * m))
    return chunks


def _run_mega(corpus, chunks, executor, seed):
    """The one-launch scan arm: each pre-built chunk goes through
    ``map_shard_batch(megakernel=True)``."""
    lat = []
    for fns, plan in chunks:
        t0 = time.perf_counter()
        executor.map_shard_batch(corpus, plan, fns, megakernel=True)
        lat.append((time.perf_counter() - t0, len(plan)))
    return lat


def _run_windowed(corpus, index, queries, rate, executor, seed, batch_size,
                  window_s=0.002):
    """BatchWindow frontend: queries arrive one by one; windows close by
    deadline or size.  Latency is per-query sojourn (submit -> done)."""
    from repro.core.queries import QueryBatch
    from repro.runtime import BatchWindow
    engine = QueryBatch(corpus, index, executor=executor)
    window = BatchWindow(engine, rate, max_batch=batch_size,
                         max_delay_s=window_s,
                         rng=np.random.default_rng(seed))
    done_at = [None] * len(queries)
    submit_at = [None] * len(queries)

    def on_done(i):
        def cb(_fut):
            done_at[i] = time.perf_counter()
        return cb

    futs = []
    for i, q in enumerate(queries):
        submit_at[i] = time.perf_counter()
        fut = window.submit(q)
        fut.add_done_callback(on_done(i))
        futs.append(fut)
    for f in futs:
        f.result()
    window.close()
    return [(d - s, 1) for s, d in zip(submit_at, done_at)]


def _run_paced_window(corpus, index, queries, rate, executor, seed,
                      arrival_qps, *, adaptive, static_delay_s,
                      static_batch, max_batch_bound, max_pending=None,
                      budget=False):
    """One load-sweep arm: Poisson arrivals at ``arrival_qps`` through a
    static or adaptive window; returns (sojourns, realized_qps, stats,
    mean_batch, extras).

    With ``max_pending`` the submit loop is *shed-tolerant*: a
    ``Backpressure`` drops that query on the floor (the open-loop source
    does not retry — offered load is the experiment variable) and the
    query's slot in ``extras['results']`` stays ``None``.  With
    ``budget=True`` the engine carries a ``RatePlanner`` wired to the
    window's controller (``ci=True``), so queries with ``QueryBudget``s
    plan their own rates and overload degrades before it sheds."""
    from repro.core.queries import QueryBatch
    from repro.runtime import (Backpressure, BatchWindow, ControllerConfig,
                               RatePlanner, WindowController)
    controller = None
    if adaptive or budget:
        controller = WindowController(ControllerConfig(
            min_delay_s=1e-4, max_delay_s=0.02,
            min_batch=1, max_batch=max_batch_bound))
    planner = (RatePlanner(corpus.n_shards, controller=controller)
               if budget else None)
    engine = QueryBatch(corpus, index, executor=executor,
                        planner=planner, ci=budget)
    window = BatchWindow(engine, rate,
                         max_batch=(max_batch_bound if adaptive or budget
                                    else static_batch),
                         max_delay_s=static_delay_s,
                         controller=controller,
                         max_pending=max_pending,
                         rng=np.random.default_rng(seed))
    gap_rng = np.random.default_rng(seed + 7)
    n = len(queries)
    submit_at = [None] * n
    done_at = [None] * n
    retry_hints = []

    def on_done(i):
        def cb(_fut):
            done_at[i] = time.perf_counter()
        return cb

    t0 = time.perf_counter()
    futs = [None] * n
    for i, q in enumerate(queries):
        submit_at[i] = time.perf_counter()
        try:
            fut = window.submit(q)
        except Backpressure as bp:
            if bp.retry_after_s is not None:
                retry_hints.append(bp.retry_after_s)
        else:
            fut.add_done_callback(on_done(i))
            futs[i] = fut
        gap = gap_rng.exponential(1.0 / arrival_qps)
        # spin for sub-ms gaps: time.sleep() overshoots by ~100 us,
        # which at heavy load would silently throttle the target rate
        if gap > 1e-3:
            time.sleep(gap)
        else:
            t_next = submit_at[i] + gap
            while time.perf_counter() < t_next:
                pass
    results = [f.result() if f is not None else None for f in futs]
    wall = time.perf_counter() - t0
    window.close()
    served = [i for i, f in enumerate(futs) if f is not None]
    sojourns = np.asarray([done_at[i] - submit_at[i] for i in served]
                          or [0.0])
    batches = max(window.stats["batches"], 1)
    extras = dict(offered=n, served=len(served),
                  shed=window.stats["shed"],
                  escalated=window.stats["escalated"],
                  degraded=window.stats["degraded"],
                  retry_hints=retry_hints, results=results,
                  last_budget=window.last_budget)
    return (sojourns, len(served) / wall, dict(window.stats),
            len(served) / batches, extras)


def _result_matches(q, got, want) -> bool:
    """Bit-for-bit result equality per query kind — the one parity
    predicate both the placement and balance smoke gates enforce."""
    if q.kind == "count":
        return (got.estimate.value == want.estimate.value
                and got.estimate.error_bound == want.estimate.error_bound)
    if q.kind == "bool":
        return bool(np.array_equal(got.doc_ids, want.doc_ids))
    return bool(np.array_equal(got.doc_ids, want.doc_ids)
                and np.array_equal(got.scores, want.scores))


def _gather_parity(queries, got, want) -> dict:
    """{kind: all-match} over a batch of (query, got, want) triples."""
    parity = {"count": True, "bool": True, "ranked": True}
    for q, g, w in zip(queries, got, want):
        parity[q.kind] &= _result_matches(q, g, w)
    return parity


def _mega_scan_equal(got, want) -> bool:
    """Bit-for-bit equality of one query's per-shard scan dict — python
    floats in sum mode, ``{doc_ids, values}`` arrays in ranked mode."""
    if got.keys() != want.keys():
        return False
    for s, g in got.items():
        w = want[s]
        if isinstance(g, dict):
            if not (np.array_equal(g["doc_ids"], w["doc_ids"])
                    and np.array_equal(g["values"], w["values"])):
                return False
        elif g != w:
            return False
    return True


def _megascan_report(corpus, index, n_hosts, workers, batch_size) -> dict:
    """The one-launch megascan record (hard-gated).

    Untimed parity checks plus a timed dispatch-amortization micro:

      1. group-vs-per-shard parity: ``map_shard_batch(megakernel=True)``
         must gather BIT-FOR-BIT what ``megakernel=False`` (the
         per-shard fused path) gathers, on ragged plans — lone-shard
         queries, strict subsets, the full fleet — in sum mode AND
         ranked top-k mode.  The block-aligned payload pads every shard
         independently, so partials must not move across groupings.
      2. host-group parity: the same plans through an N-host
         ``HostGroupExecutor`` (one launch per host) must match too,
         and every host's executor must report megascan jobs — proof
         the route engaged rather than silently falling back.
      3. the roofline claim: per-shard (launches = n_shards) vs
         megascan (launches = 1) records through
         ``benchmarks.roofline.analyze_megascan`` — the megascan's
         dispatch share must drop, and its measured best-of-3 wall on
         the full-fleet plan must beat the per-shard route's.

    Returns the record, including ``roofline_records`` (rendered by
    ``python -m benchmarks.roofline --serve BENCH_serve.json``)."""
    from benchmarks.roofline import analyze_megascan
    from repro.kernels.megascan import MegascanSpec
    from repro.runtime import HostGroupExecutor, PlacementMap
    from repro.runtime.executor import ShardTaskExecutor

    rng = np.random.default_rng(23)
    n_shards = corpus.n_shards
    b = max(4, min(12, batch_size))
    words = pick_query_words(corpus, 3 * b, rng)
    triples = [[int(words[(3 * i + j) % len(words)]) for j in range(3)]
               for i in range(b)]
    vecs = index.query_vectors(triples)
    plans = []
    for i in range(b):
        if i % 4 == 0:
            plans.append([int(rng.integers(n_shards))])
        elif i % 4 == 1:
            sub = rng.choice(n_shards, size=max(2, n_shards // 2),
                             replace=False)
            plans.append(sorted(int(s) for s in sub))
        else:
            plans.append(list(range(n_shards)))

    ex = ShardTaskExecutor(workers=workers)
    sum_spec = MegascanSpec(index, vecs)
    sum_fns = sum_spec.scan_fns()
    ranked_fns = MegascanSpec(index, vecs, ranked_k=10).scan_fns()
    parity = {}
    for label, fns in (("sum", sum_fns), ("ranked", ranked_fns)):
        mega = ex.map_shard_batch(corpus, plans, fns, megakernel=True)
        per = ex.map_shard_batch(corpus, plans, fns, megakernel=False)
        parity[label] = all(
            _mega_scan_equal(m, p) for m, p in zip(mega, per))
        if not parity[label]:
            raise RuntimeError(
                f"megascan {label}-mode group scan does not match the "
                f"per-shard fused path bit-for-bit on ragged plans")

    host_launches = None
    if n_hosts >= 2:
        hg = HostGroupExecutor(
            PlacementMap.blocked(n_shards, n_hosts, n_replicas=1),
            workers_per_host=max(1, workers // n_hosts))
        hmega = hg.map_shard_batch(corpus, plans, sum_fns)
        per = ex.map_shard_batch(corpus, plans, sum_fns, megakernel=False)
        if not all(_mega_scan_equal(m, p) for m, p in zip(hmega, per)):
            raise RuntimeError(
                "megascan host-group gather does not match the "
                "per-shard fused path bit-for-bit")
        host_launches = {h: hx.stats["megascan_jobs"]
                         for h, hx in hg.hosts.items()}
        if not all(v > 0 for v in host_launches.values()):
            raise RuntimeError(
                f"megakernel route did not engage on every host: "
                f"{host_launches}")
        hg.close()

    full = [list(range(n_shards))] * b

    def best_of(megakernel):
        best = None
        for _ in range(3):
            t0 = time.perf_counter()
            ex.map_shard_batch(corpus, full, sum_fns,
                               megakernel=megakernel)
            dt = time.perf_counter() - t0
            best = dt if best is None or dt < best else best
        return best

    ex.map_shard_batch(corpus, full, sum_fns, megakernel=True)  # warm
    t_mega = best_of(True)
    rec_mega = dict(sum_spec.last_record, name="megascan_one_launch",
                    measured_wall_s=t_mega)
    t_per = best_of(False)
    # same payload, same flops, n_shards launches, no cross-shard
    # prefetch — slightly flatters the per-shard route (it actually
    # repeats the query projection per launch), which only makes the
    # dispatch-share gate harder to pass
    rec_per = dict(rec_mega, name="megascan_per_shard",
                   launches=n_shards, double_buffer=False,
                   measured_wall_s=t_per, wall_s=t_per)
    row_mega = analyze_megascan(rec_mega)
    row_per = analyze_megascan(rec_per)
    if row_mega["dispatch_share"] >= row_per["dispatch_share"]:
        raise RuntimeError(
            f"megascan dispatch share {row_mega['dispatch_share']:.3f} "
            f"did not drop below the per-shard route's "
            f"{row_per['dispatch_share']:.3f}")
    if t_mega >= t_per:
        raise RuntimeError(
            f"megascan one-launch wall {t_mega:.4f}s is not below the "
            f"per-shard route's {t_per:.4f}s on the full-fleet plan")
    ex.close()
    return dict(
        parity=parity,
        host_group_parity=n_hosts >= 2,
        host_megascan_jobs=host_launches,
        shards=n_shards, queries=b,
        launches=dict(mega=1, per_shard=n_shards),
        measured=dict(mega_s=t_mega, per_shard_s=t_per,
                      win=t_per / t_mega),
        dispatch_share=dict(mega=row_mega["dispatch_share"],
                            per_shard=row_per["dispatch_share"]),
        dominant=dict(mega=row_mega["dominant"],
                      per_shard=row_per["dominant"]),
        spec_stats=dict(sum_spec.stats),
        roofline_records=[rec_per, rec_mega],
    )


def _placement_report(corpus, index, queries, rate, executor, n_hosts,
                      workers, batch_size) -> dict:
    """The simulated-topology record: parity + residency verification
    (one untimed pass with fresh executors so the scan accounting is
    exact) and a per-host stats snapshot.  Raises on any violation —
    this runs under the CI smoke gate."""
    from repro.core.queries import QueryBatch
    from repro.runtime import HostGroupExecutor, PlacementMap
    placement = PlacementMap.blocked(corpus.n_shards, n_hosts, n_replicas=1)
    hosts = HostGroupExecutor(placement,
                              workers_per_host=max(1, workers // n_hosts))
    engine = QueryBatch(corpus, index, executor=hosts)
    parity = {"count": True, "bool": True, "ranked": True}
    expected_scans = np.zeros(n_hosts, np.int64)
    for i in range(0, len(queries), batch_size):
        chunk = queries[i:i + batch_size]
        seed = 1000 + i
        got = engine.execute(chunk, rate, rng=np.random.default_rng(seed))
        want = QueryBatch(corpus, index, executor=executor).execute(
            chunk, rate, rng=np.random.default_rng(seed))
        for kind, ok in _gather_parity(chunk, got, want).items():
            parity[kind] &= ok
        for h, c in hosts.residency_split(engine.last_plan).items():
            expected_scans[h] += c
    observed = np.asarray(hosts.stats["scans_per_host"], np.int64)
    record = dict(
        hosts=n_hosts, policy="blocked", n_replicas=1,
        scans_per_host=observed.tolist(),
        expected_scans_per_host=expected_scans.tolist(),
        residency_match=bool((observed == expected_scans).all()),
        parity={"count": parity["count"], "bool": parity["bool"],
                "ranked": parity["ranked"]},
        host_stats={k: v for k, v in hosts.stats.items()
                    if k != "scans_per_host"},
    )
    hosts.close()
    if not record["residency_match"]:
        raise RuntimeError(
            f"placement residency violated: per-host scans {observed} "
            f"!= union-plan split {expected_scans}")
    if not all(parity.values()):
        raise RuntimeError(f"cross-host gather parity violated: {parity}")
    return record


def _balance_report(corpus, index, queries, rate, executor, n_hosts,
                    replicas, workers, batch_size) -> dict:
    """The hot-host record: one untimed pass each through the
    primary-only and the balanced split, both with host 0 degraded by
    ``_hot_host_hook``, against the single-executor reference.  Hard
    checks (this runs under the CI smoke gate): balanced results must
    be bit-for-bit the single-executor results, balanced and
    primary-only gathers must match each other, and the balanced split
    must reduce the mean per-job makespan."""
    from repro.core.queries import QueryBatch
    from repro.runtime import HostGroupExecutor, PlacementMap
    wph = max(1, workers // n_hosts)

    def run_arm(balanced):
        pm = PlacementMap.blocked(corpus.n_shards, n_hosts,
                                  n_replicas=replicas)
        hg = HostGroupExecutor(pm, workers_per_host=wph, balanced=balanced,
                               host_fault_hook=_hot_host_hook)
        engine = QueryBatch(corpus, index, executor=hg)
        # warm pass: thread pools and, for the balanced arm, the load
        # model's first look at the hot host (the seeded count-balanced
        # split runs once; measured batches run the learned split)
        engine.execute(queries[:batch_size], rate,
                       rng=np.random.default_rng(99))
        results, makespans = [], []
        for i in range(0, len(queries), batch_size):
            got = engine.execute(queries[i:i + batch_size], rate,
                                 rng=np.random.default_rng(2000 + i))
            results.extend(got)
            makespans.append(max(
                hg.last_job["per_host_wall_s"].values(), default=0.0))
        audit, stats = engine.last_audit, dict(hg.stats)
        stats.pop("scans_per_host", None)
        hg.close()
        return results, float(np.mean(makespans)), audit, stats

    primary_res, primary_ms, _, _ = run_arm(balanced=False)
    bal_res, bal_ms, audit, bal_stats = run_arm(balanced=True)
    ref = QueryBatch(corpus, index, executor=executor)
    want = []
    for i in range(0, len(queries), batch_size):
        want.extend(ref.execute(queries[i:i + batch_size], rate,
                                rng=np.random.default_rng(2000 + i)))

    parity = _gather_parity(queries, bal_res, want)
    parity_vs_primary = _gather_parity(queries, bal_res, primary_res)
    record = dict(
        hosts=n_hosts, policy="blocked", n_replicas=replicas,
        hot_host=0, hot_delay_ms_per_shard=HOT_HOST_DELAY_S * 1e3,
        primary_mean_makespan_ms=primary_ms * 1e3,
        balanced_mean_makespan_ms=bal_ms * 1e3,
        makespan_reduction=primary_ms / max(bal_ms, 1e-12),
        shed_shards=bal_stats.get("shed_shards", 0),
        last_audit=audit,
        parity=parity, parity_vs_primary=parity_vs_primary,
        host_stats=bal_stats,
    )
    if not all(parity.values()):
        raise RuntimeError(
            f"balanced gather diverged from the single executor: {parity}")
    if not all(parity_vs_primary.values()):
        raise RuntimeError(
            f"balanced gather diverged from the primary-only split: "
            f"{parity_vs_primary}")
    if bal_ms >= primary_ms:
        raise RuntimeError(
            f"balanced split did not reduce the hot-host makespan: "
            f"balanced {bal_ms * 1e3:.2f} ms >= primary "
            f"{primary_ms * 1e3:.2f} ms")
    return record


def _budgeted_queries(queries, floor_rate=0.1):
    """The same mixed workload with per-query SLOs attached: counts ask
    for a relative-error budget (the closed-form Eq-2 inversion), bools
    a looser one (bootstrap CI width), ranked a latency budget with an
    error cap (best accuracy that fits ~50 ms p99).  ``floor_rate`` is
    every query's graceful-degradation floor."""
    import dataclasses as _dc

    from repro.runtime import QueryBudget
    out = []
    for q in queries:
        if q.kind == "count":
            b = QueryBudget(max_rel_error=0.5, floor_rate=floor_rate)
        elif q.kind == "bool":
            b = QueryBudget(max_rel_error=0.6, floor_rate=floor_rate)
        else:
            b = QueryBudget(max_rel_error=0.6, max_latency_s=0.05,
                            floor_rate=floor_rate)
        out.append(_dc.replace(q, budget=b))
    return out


def _count_err_stats(queries, results, truths):
    """(p90 relative error, CI-coverage fraction) of the served count
    queries in ``results`` (``None`` slots are shed) against the exact
    full-scan ``truths``."""
    errs, covered, total = [], 0, 0
    for q, res, truth in zip(queries, results, truths):
        if q.kind != "count" or res is None:
            continue
        total += 1
        if res.estimate.covers(truth):
            covered += 1
        if truth:
            errs.append(abs(res.estimate.value - truth) / truth)
    p90 = float(np.percentile(errs, 90)) if errs else 0.0
    return p90, (covered / total if total else 1.0), total


def _budget_report(corpus, index, queries, rate, executor, n_hosts,
                   workers, batch_size) -> dict:
    """The error-budgeted-serving record — three hard gates (this runs
    under the CI smoke job):

      1. *Parity*: a planner-bearing engine serving UNBUDGETED queries
         must be bit-for-bit the plain engine, at the nominal rate and
         at the precise rate-1.0 fast path.
      2. *Calibration*: with budgets attached, the count queries' 95%
         CIs must cover the exact full-scan answer for >= 90% of
         queries — measured on a deterministic untimed pass (pinned
         rng, pressure 0 and pressure 1), not inside the
         timing-dependent overload arm.
      3. *Degrade-before-shed*: under ~3x-capacity Poisson arrivals on
         a 2-host topology with a hot host, the budget-aware window
         (degradation ladder on) must shed strictly fewer queries than
         the PR 3-style static-backpressure baseline under the same
         arrival schedule and queue bound (and the baseline must
         actually shed, or the arm failed to overload).
    """
    from repro.core.queries import QueryBatch
    from repro.runtime import (HostGroupExecutor, PlacementMap, RatePlanner,
                               WindowController)
    plain = QueryBatch(corpus, index, executor=executor)
    budgeted = _budgeted_queries(queries)

    # -- gate 1: unbudgeted parity through the planner ----------------
    planner_engine = QueryBatch(corpus, index, executor=executor,
                                planner=RatePlanner(corpus.n_shards),
                                ci=True)
    parity = {}
    for label, r in (("nominal", rate), ("precise", 1.0)):
        got = planner_engine.execute(queries, r,
                                     rng=np.random.default_rng(31))
        want = plain.execute(queries, r, rng=np.random.default_rng(31))
        parity[label] = _gather_parity(queries, got, want)
        if not all(parity[label].values()):
            raise RuntimeError(
                f"planner engine diverged from the plain engine on "
                f"unbudgeted queries at {label} rate: {parity[label]}")

    # -- gate 2: count-CI coverage, deterministic pass ----------------
    truths = [res.estimate.value if q.kind == "count" else None
              for q, res in zip(queries, plain.execute(
                  queries, 1.0, rng=np.random.default_rng(32)))]
    # warm the planner's error curves off served unbudgeted batches so
    # the budgeted pass plans from fitted dispersion, not the
    # pessimistic cold seed (which would plan a census and make the
    # coverage check vacuous)
    for s in (33, 34):
        planner_engine.execute(queries, rate, rng=np.random.default_rng(s))
    coverage = {}
    audits = {}
    for label, pressure, seeds in (("planned", 0.0, (40, 41)),
                                   ("degraded", 1.0, (42, 43))):
        res_all, q_all, t_all = [], [], []
        for s in seeds:
            res_all.extend(planner_engine.execute(
                budgeted, rate, rng=np.random.default_rng(s),
                pressure=pressure))
            q_all.extend(budgeted)
            t_all.extend(truths)
        p90, cov, n_counts = _count_err_stats(q_all, res_all, t_all)
        coverage[label] = dict(ci_coverage=cov, p90_rel_err=p90,
                               n_count_queries=n_counts)
        audits[label] = planner_engine.last_budget
    for label in ("planned", "degraded"):
        if coverage[label]["ci_coverage"] < 0.9:
            raise RuntimeError(
                f"count 95% CIs cover the exact answer for only "
                f"{coverage[label]['ci_coverage']:.0%} of queries on the "
                f"{label} pass (floor 90%)")

    # -- gate 3: overload — static shedding vs degrade-first ----------
    wph = max(1, workers // n_hosts)

    def hot_exec():
        return HostGroupExecutor(
            PlacementMap.blocked(corpus.n_shards, n_hosts, n_replicas=1),
            workers_per_host=wph, host_fault_hook=_hot_host_hook)

    # capacity probe at the static arm's operating point (hot host
    # included): one warmed batch through a plain engine
    probe_exec = hot_exec()
    probe_engine = QueryBatch(corpus, index, executor=probe_exec)
    probe = budgeted[:batch_size]
    probe_engine.execute(probe, rate, rng=np.random.default_rng(50))
    t0 = time.perf_counter()
    probe_engine.execute(probe, rate, rng=np.random.default_rng(51))
    capacity_qps = len(probe) / (time.perf_counter() - t0)
    probe_exec.close()
    offered = 3.0 * capacity_qps
    overload_queries = (budgeted * ((10 * batch_size) // len(budgeted) + 1)
                        )[:10 * batch_size]

    arms = {}
    for mode, is_budget in (("static", False), ("budget", True)):
        ex = hot_exec()
        sojourns, served_qps, stats, mean_batch, extras = _run_paced_window(
            corpus, index, overload_queries, rate, ex, seed=60,
            arrival_qps=offered, adaptive=is_budget,
            static_delay_s=0.002, static_batch=batch_size,
            max_batch_bound=8 * batch_size, max_pending=4 * batch_size,
            budget=is_budget)
        ex.close()
        p90, cov, n_counts = _count_err_stats(
            overload_queries, extras["results"],
            (truths * ((10 * batch_size) // len(truths) + 1)
             )[:10 * batch_size])
        arms[mode] = dict(
            offered_qps=offered, served_qps=served_qps,
            shed=extras["shed"], served=extras["served"],
            shed_frac=extras["shed"] / extras["offered"],
            degraded_frac=extras["degraded"] / max(extras["served"], 1),
            escalated=extras["escalated"],
            p99_sojourn_ms=float(np.percentile(sojourns, 99)) * 1e3,
            mean_batch=mean_batch,
            p90_rel_err=p90, ci_coverage=cov,
            mean_retry_after_ms=(float(np.mean(extras["retry_hints"]))
                                 * 1e3 if extras["retry_hints"] else None),
            last_budget=extras["last_budget"])
    if arms["static"]["shed"] == 0:
        raise RuntimeError(
            "overload arm failed to overload: the static-backpressure "
            f"baseline shed nothing at {offered:.0f} q/s offered")
    if arms["budget"]["shed"] >= arms["static"]["shed"]:
        raise RuntimeError(
            f"budget-aware serving did not shed strictly fewer than the "
            f"static baseline: {arms['budget']['shed']} >= "
            f"{arms['static']['shed']}")

    return dict(
        hosts=n_hosts, hot_host=0,
        hot_delay_ms_per_shard=HOT_HOST_DELAY_S * 1e3,
        capacity_qps=capacity_qps,
        parity=parity, coverage=coverage,
        planned_audit=audits["planned"], degraded_audit=audits["degraded"],
        overload=arms)


def _chaos_report(corpus, index, queries, rate, executor, n_hosts,
                  workers, batch_size) -> dict:
    """The elastic-fleet chaos record: one scripted, untimed
    kill -> serve-degraded -> join -> recover -> drain scenario driven
    by a seeded ``FaultPlan`` against a ``FleetManager``-managed
    2-host topology, checked batch-by-batch against the
    single-executor reference.  Hard gates (this runs under the CI
    chaos-smoke job):

      1. *Zero lost queries*: every query of every phase returns a
         full-sample result — no partial estimates, no lost shards —
         because one replica survives every scripted failure.
      2. *Gather parity*: every batch, including the one that
         discovers the kill mid-job and requeues on replicas, is
         bit-for-bit the single-executor result (for counts that
         equality covers the CI — so the planned drain provably never
         widens an error bound).
      3. *The kill landed*: the scripted crash fired and the
         single-survivor phase's makespan degraded >= 1.3x the healthy
         baseline (sleep-dominated, so the ratio is deterministic).
      4. *Recovery*: after a warmed replacement host joins, mean job
         makespan returns to within 1.25x the pre-crash baseline, and
         every shard the joiner owns was streamed to it (``warm_fn``)
         before residency swapped.
      5. *Clean drain*: the planned departure moves every shard to a
         live replica (nothing orphaned) and serving continues.
    """
    from repro.core.queries import QueryBatch
    from repro.runtime import (FaultPlan, FleetManager, HostGroupExecutor,
                               PlacementMap)
    hg = HostGroupExecutor(
        PlacementMap.blocked(corpus.n_shards, n_hosts, n_replicas=1),
        workers_per_host=max(1, workers // n_hosts), allow_partial=True)
    plan = FaultPlan(seed=7)
    for h in range(n_hosts + 1):     # + the replacement host joined below
        plan.slow(h, ms_per_shard=CHAOS_SLOW_MS)
    plan.install(hg)
    streamed = []
    fleet = FleetManager(
        hg, warm_fn=lambda sid, src, dst:
        streamed.append([int(sid), int(src), int(dst)]))
    engine = QueryBatch(corpus, index, executor=hg)
    ref = QueryBatch(corpus, index, executor=executor)
    chunks = [queries[i:i + batch_size]
              for i in range(0, len(queries), batch_size)]
    parity = {"count": True, "bool": True, "ranked": True}
    lost_queries = 0
    job_i = 0
    phase_ms = {}

    def run_phase(name, n_batches):
        nonlocal job_i, lost_queries
        makespans = []
        for _ in range(n_batches):
            chunk = chunks[job_i % len(chunks)]
            seed = 3000 + job_i
            got = engine.execute(chunk, rate,
                                 rng=np.random.default_rng(seed))
            want = ref.execute(chunk, rate,
                               rng=np.random.default_rng(seed))
            for kind, ok in _gather_parity(chunk, got, want).items():
                parity[kind] &= ok
            if engine.last_degraded is not None:
                lost_queries += engine.last_degraded["degraded_queries"]
            makespans.append(max(
                hg.last_job["per_host_wall_s"].values(), default=0.0))
            job_i += 1
        # best-of over the phase's batches, same reason the throughput
        # arms take best-of wall time: the sleeps make the true value
        # deterministic, and a container scheduler stall only ever adds
        phase_ms[name] = float(np.min(makespans)) * 1e3

    engine.execute(chunks[0], rate, rng=np.random.default_rng(2999))  # warm
    run_phase("healthy", 2)
    # the kill: host 1 dies NOW (every group job from here on raises);
    # the next batch discovers it mid-job and requeues on replicas
    plan.crash(1, at_job=int(hg.stats["jobs"]))
    run_phase("kill", 1)
    fleet.crash(1)                  # the failure detector catches up
    run_phase("degraded", 2)
    # replacement host (fresh id — the dead slot stays scripted-dead):
    # shards stream to it via warm_fn, then the generation swaps
    join_ev = fleet.join(n_hosts)
    run_phase("recovered", 2)
    # planned departure of the replacement: metadata-only handoff back
    # to live replicas before it leaves rotation
    drain_ev = fleet.drain(n_hosts)
    run_phase("drained", 1)

    record = dict(
        hosts=n_hosts, n_replicas=1, slow_ms_per_shard=CHAOS_SLOW_MS,
        phase_makespan_ms=phase_ms,
        degradation_ratio=phase_ms["degraded"] / max(phase_ms["healthy"],
                                                     1e-9),
        recovery_ratio=phase_ms["recovered"] / max(phase_ms["healthy"],
                                                   1e-9),
        parity=parity, lost_queries=lost_queries,
        lost_shards=int(hg.stats["lost_shards"]),
        warmed_shards=len(streamed), streamed=streamed,
        join=join_ev, drain=drain_ev,
        fleet=fleet.record(), faults=plan.record(),
    )
    hg.close()
    if lost_queries or record["lost_shards"]:
        raise RuntimeError(
            f"chaos scenario lost work: {lost_queries} degraded queries, "
            f"{record['lost_shards']} lost shards (every scripted failure "
            f"leaves a live replica — nothing may be lost)")
    if not all(parity.values()):
        raise RuntimeError(f"chaos gather parity violated: {parity}")
    if plan.fired["crash"] < 1:
        raise RuntimeError("the scripted kill never fired — the scenario "
                           "did not exercise the requeue path")
    if record["degradation_ratio"] < 1.3:
        raise RuntimeError(
            f"single-survivor makespan did not degrade: "
            f"{phase_ms['degraded']:.1f} ms vs healthy "
            f"{phase_ms['healthy']:.1f} ms — the kill did not land")
    if record["recovery_ratio"] > 1.25:
        raise RuntimeError(
            f"post-join makespan did not recover: {phase_ms['recovered']:.1f}"
            f" ms vs healthy {phase_ms['healthy']:.1f} ms "
            f"(> 1.25x)")
    if not streamed or join_ev["warmed_shards"] != len(streamed):
        raise RuntimeError(
            f"join warm-up mismatch: audit says {join_ev['warmed_shards']} "
            f"warmed, warm_fn saw {len(streamed)}")
    if drain_ev["orphaned_shards"] or not drain_ev["planned"]:
        raise RuntimeError(f"drain was not clean: {drain_ev}")
    return record


def _cache_report(corpus, index, queries, rate, executor, n_hosts,
                  workers, batch_size) -> dict:
    """Semantic-cache correctness record, hard-gated.

    Two scenarios, both run at Hamming radius 0 so every reuse is an
    *exact-signature* hit (the bit-for-bit contract; near-hit
    statistics are property-tested in tests/test_qcache.py, not
    benched):

    1. **Exact-hit parity** (single-host, the shared ``executor``):
       a cold pass through a cache-enabled engine must be bit-for-bit
       the plain engine's results under the same rng seeds (the cache
       may not perturb the miss path), and a warm pass under
       *different* seeds must resolve every distinct query from the
       cache with results bit-for-bit equal to the cold pass (hits
       consume no rng and return the memoized estimates verbatim).

    2. **Generation fencing** (``n_hosts`` group + ``FleetManager``):
       populate the cache at one placement epoch, then ``join`` a
       host (RCU generation swap) and re-serve — ZERO cache hits may
       cross the swap, every entry must drop as ``stale_epoch``, and
       the re-served results must match a plain engine on the same
       post-join topology.  Repopulate, ``drain`` the host, and check
       the same again.  A control re-serve *before* the join proves
       the warm cache would have hit, so the zero is the fence and
       not an accident.

    Any violation raises — these are serving-correctness contracts,
    not performance numbers.
    """
    from repro.core.queries import QueryBatch
    from repro.runtime import FleetManager, HostGroupExecutor, PlacementMap
    from repro.runtime.qcache import (QueryCacheConfig, SemanticQueryCache,
                                      query_key)

    # dedupe the pool: _mixed_queries can recycle words on tiny corpora
    # and a duplicate would hit mid-cold-pass, skewing the counts below
    seen, pool = set(), []
    for q in queries:
        k = query_key(q)
        if k not in seen:
            seen.add(k)
            pool.append(q)

    def cache_cfg():
        return QueryCacheConfig(max_entries=4 * len(pool), ttl_s=3600.0,
                                hamming_radius=0)

    def serve(engine, seed_base):
        out = []
        for i in range(0, len(pool), batch_size):
            out.extend(engine.execute(
                pool[i:i + batch_size], rate,
                rng=np.random.default_rng(seed_base + i)))
        return out

    # --- gate 1: exact-hit parity on the single-host executor --------
    cache = SemanticQueryCache(cache_cfg())
    cached_engine = QueryBatch(corpus, index, executor=executor,
                               cache=cache)
    plain = QueryBatch(corpus, index, executor=executor)
    want = serve(plain, 500)
    got_cold = serve(cached_engine, 500)      # same seeds -> same draws
    cold_parity = _gather_parity(pool, got_cold, want)
    if not all(cold_parity.values()):
        raise RuntimeError(
            f"cache MISS path diverged from the uncached engine under "
            f"identical seeds: {cold_parity} — attaching a cold cache "
            f"must be a no-op")
    if cache.stats["hits"] or cache.stats["near_hits"]:
        raise RuntimeError(
            f"cold pass over {len(pool)} distinct queries reported "
            f"{cache.stats['hits']} hits / {cache.stats['near_hits']} "
            f"near-hits — the pool dedup or the keying is broken")
    got_warm = serve(cached_engine, 900)      # different seeds on purpose
    if cache.stats["hits"] != len(pool):
        raise RuntimeError(
            f"warm pass resolved {cache.stats['hits']}/{len(pool)} "
            f"queries from the cache — exact re-asks must all hit")
    warm_parity = _gather_parity(pool, got_warm, want)
    if not all(warm_parity.values()):
        raise RuntimeError(
            f"exact-hit results differ from the uncached execution: "
            f"{warm_parity} — hits must be bit-for-bit the memoized "
            f"result, rng-independent")
    single_host = dict(pool=len(pool), cold_parity=cold_parity,
                       warm_parity=warm_parity, stats=cache.record())

    # --- gate 2: zero hits across fleet generation swaps -------------
    hg = HostGroupExecutor(
        PlacementMap.blocked(corpus.n_shards, n_hosts, n_replicas=1),
        workers_per_host=max(1, workers // n_hosts))
    fleet = FleetManager(hg, warm_fn=lambda sid, src, dst: None)
    fcache = SemanticQueryCache(cache_cfg())
    feng = QueryBatch(corpus, index, executor=hg, cache=fcache)
    fref = QueryBatch(corpus, index, executor=hg)

    serve(feng, 100)                          # populate at epoch e0
    serve(feng, 140)                          # control: warm cache hits
    control_hits = fcache.stats["hits"]
    if control_hits != len(pool):
        raise RuntimeError(
            f"pre-join control re-serve hit {control_hits}/{len(pool)} "
            f"— the warm cache is not actually warm, the join gate "
            f"below would pass vacuously")

    def swap_and_check(event_name, swap):
        ev = swap()
        hits0 = fcache.stats["hits"]
        stale0 = fcache.stats["stale_epoch"]
        got = serve(feng, 180)                # every entry is now stale
        want = serve(fref, 180)               # same seeds, same topology
        stale_hits = fcache.stats["hits"] - hits0
        staled = fcache.stats["stale_epoch"] - stale0
        if stale_hits:
            raise RuntimeError(
                f"{stale_hits} cache hits served across the {event_name} "
                f"generation swap — stale-epoch entries must never hit")
        if staled < len(pool):
            raise RuntimeError(
                f"only {staled}/{len(pool)} entries dropped as "
                f"stale_epoch across {event_name} — the epoch fence "
                f"is not covering the cache")
        parity = _gather_parity(pool, got, want)
        if not all(parity.values()):
            raise RuntimeError(
                f"post-{event_name} re-serve diverged from the plain "
                f"engine on the same topology: {parity}")
        return dict(event=ev, stale_dropped=staled, parity=parity)

    join_rec = swap_and_check("join", lambda: fleet.join(n_hosts))
    # serve(feng, 180) above repopulated at the post-join epoch; the
    # drain swap must fence those entries just the same
    drain_rec = swap_and_check("drain", lambda: fleet.drain(n_hosts))
    fleet_rec = dict(hosts=n_hosts, control_hits=control_hits,
                     join=join_rec, drain=drain_rec,
                     stats=fcache.record(), fleet=fleet.record())
    hg.close()
    return dict(hamming_radius=0, single_host=single_host,
                fleet=fleet_rec)


def _ingest_report(corpus, index, model, pv_cfg, queries, rate, n_hosts,
                   workers, batch_size) -> dict:
    """Live-ingest correctness record, hard-gated.

    One ``Ingestor.step`` appends ``INGEST_FRACTION`` of the corpus —
    every appended doc opens with a sentinel phrase the base corpus
    cannot contain — while the serving loop keeps executing.  The
    gates (any violation raises):

      (a) **zero loss** — no query sheds, degrades, or loses shards
          across the swap (every result carries ``lost_shards == 0``).
      (b) **old-generation parity** — every batch served while the
          swap races is bit-for-bit EITHER the no-ingest reference
          (same seeds, pre-append world) OR the post-append reference
          (same seeds, appended world built sequentially off to the
          side): the RCU capture never hands a batch a torn world.
      (c) **freshness** — after the swap, a precise count of the
          sentinel phrase observes exactly ``n_new`` more matches
          than before, at error bound 0, and the content generation
          advanced exactly once (placement only if shards spilled).
      (d) **zero pause** — serving p99 with the ingest step racing
          stays within ``INGEST_P99_MAX_RATIO`` of the no-ingest p99
          on the same pool: ``INGEST_GATE_PASSES`` pool passes per
          trial, identical seeds and symmetric warmup on both arms,
          p99 over per-query samples, best-of-3 trials each.

    A cache sub-check re-runs the fence contract on the content axis:
    a warm cache must serve ZERO hits across the step, drop every
    entry as ``stale_epoch``, and re-serve bit-for-bit a plain engine
    on the appended world — the ``attach_corpus``/ingest gap the
    placement-only epoch could not see.
    """
    from repro.core.index import refresh_appended
    from repro.core.queries import BatchQuery, QueryBatch
    from repro.launch.serve_stack import build_serving_stack
    from repro.runtime.qcache import QueryCacheConfig, SemanticQueryCache

    rng = np.random.default_rng(71)
    vocab = corpus.vocab_size
    phrase = (vocab - 2, vocab - 1)
    n_new = int(np.ceil(INGEST_FRACTION * corpus.n_docs))
    # sentinel docs: the phrase once at position 0, body drawn below
    # vocab-2 so no other occurrence can form
    new_docs = [np.concatenate([
        np.asarray(phrase, np.int32),
        rng.integers(0, vocab - 2,
                     size=int(rng.integers(10, 50))).astype(np.int32)])
        for _ in range(n_new)]
    fresh_q = [BatchQuery.count(phrase)]
    chunks = [queries[i:i + batch_size]
              for i in range(0, len(queries), batch_size)]

    def serve(engine):
        return [engine.execute(c, rate, rng=np.random.default_rng(7000 + j))
                for j, c in enumerate(chunks)]

    def batch_equal(j, got, want):
        return all(_result_matches(q, g, w)
                   for q, g, w in zip(chunks[j], got, want))

    def no_loss(rounds):
        return all(r.lost_shards == 0 for got in rounds for r in got)

    def stack_kw(**extra):
        return dict(hosts=n_hosts, workers=workers, **extra)

    # --- reference worlds, computed sequentially ---------------------
    with build_serving_stack(corpus, index, **stack_kw()) as ref:
        want_old = serve(ref.engine)
        c0 = ref.engine.execute(fresh_q, 1.0)[0].estimate.value
    grown, _, affected = corpus.append_documents(new_docs)
    post_index = refresh_appended(index, grown, model, pv_cfg, new_docs,
                                  affected, infer_steps=INGEST_INFER_STEPS)
    with build_serving_stack(grown, post_index, **stack_kw()) as ref:
        want_new = serve(ref.engine)

    # --- gates (a)-(c): the racing swap ------------------------------
    ingest_kw = stack_kw(ingest=True, ingest_model=model,
                         ingest_pv_cfg=pv_cfg,
                         ingest_infer_steps=INGEST_INFER_STEPS)
    with build_serving_stack(corpus, index, **ingest_kw) as stack:
        pre = serve(stack.engine)
        for j, (got, want) in enumerate(zip(pre, want_old)):
            if not batch_equal(j, got, want):
                raise RuntimeError(
                    f"batch {j}: an idle attached Ingestor perturbed "
                    f"serving — pre-swap results must be bit-for-bit "
                    f"the plain stack's")
        started = threading.Event()
        step_rec = {}

        def writer():
            started.wait()
            step_rec.update(stack.ingestor.step(new_docs))

        t = threading.Thread(target=writer)
        t.start()
        during = []
        started.set()
        while t.is_alive() and len(during) < 64:
            during.append(serve(stack.engine))
        t.join()
        after = serve(stack.engine)
        served_during = sum(len(r) for r in during)
        old_batches = new_batches = 0
        for rounds in during:
            if not no_loss(rounds):
                raise RuntimeError("a query lost shards during the "
                                   "ingest swap — gate (a)")
            for j, got in enumerate(rounds):
                if batch_equal(j, got, want_old[j]):
                    old_batches += 1
                elif batch_equal(j, got, want_new[j]):
                    new_batches += 1
                else:
                    raise RuntimeError(
                        f"batch {j} served during the swap matches "
                        f"NEITHER the pre-append nor the post-append "
                        f"reference bit-for-bit — torn world, gate (b)")
        for j, got in enumerate(after):
            if not batch_equal(j, got, want_new[j]):
                raise RuntimeError(
                    f"batch {j} after the swap diverged from the "
                    f"post-append reference — the swap did not land "
                    f"cleanly, gate (b)")
        if not (no_loss(pre) and no_loss(after)):
            raise RuntimeError("shard loss outside the swap window — "
                               "gate (a)")
        fres = stack.engine.execute(fresh_q, 1.0)[0]
        if fres.estimate.value != c0 + n_new:
            raise RuntimeError(
                f"freshness: post-swap sentinel count "
                f"{fres.estimate.value} != {c0} + {n_new} appended — "
                f"new docs are not (all) visible, gate (c)")
        if fres.estimate.error_bound != 0.0:
            raise RuntimeError("freshness count was not a precise "
                               "census — gate (c)")
        gen = stack.generation
        if gen.content != 1:
            raise RuntimeError(
                f"content generation is {gen.content} after exactly "
                f"one swap — must mint exactly once, gate (c)")
        want_placement = 1 if step_rec.get("new_shards", 0) else 0
        if n_hosts >= 2 and gen.placement != want_placement:
            raise RuntimeError(
                f"placement generation {gen.placement} != "
                f"{want_placement} (new_shards="
                f"{step_rec.get('new_shards')}) — gate (c)")
        swap_rec = dict(
            n_new=n_new, step=step_rec, served_during_swap=served_during,
            old_generation_batches=old_batches,
            new_generation_batches=new_batches,
            freshness=dict(before=float(c0), after=float(
                fres.estimate.value)),
            generation=gen.record())

    # --- gate (d): p99 with ingest racing vs without -----------------
    # On a few batches "p99" degenerates to the max batch — pure
    # scheduler noise on a shared box, and the one batch colliding
    # with the step's startup burst.  Both arms therefore serve the
    # pool INGEST_GATE_PASSES times per trial with identical seeds,
    # warm symmetrically (a cold pass-set each, so lazily-built
    # postings/jit caches don't masquerade as ingest contention), and
    # the p99 is over per-query amortized samples — the startup-burst
    # batch is < 1% of the mass, so the statistic reflects the
    # steady-state cost of the racing, GIL-paced writer.
    def p99(lat):
        s = np.concatenate([[t / n] * n for t, n in lat])
        return float(np.percentile(s, 99))

    def pool_passes(stack, seed0):
        lat = []
        for r in range(INGEST_GATE_PASSES):
            lat += _run_batched(corpus, index, queries, rate,
                                stack.executor, seed0 + r, batch_size,
                                engine=stack.engine)
        return lat

    with build_serving_stack(corpus, index, **stack_kw()) as plain:
        pool_passes(plain, 0)  # warm
        base = min(p99(pool_passes(plain, 100 * (1 + t)))
                   for t in range(3))
    feed = np.random.default_rng(83)
    with build_serving_stack(corpus, index, **ingest_kw) as stack:
        pool_passes(stack, 0)  # warm
        stack.ingestor.step([feed.integers(0, vocab - 2, 30)
                             .astype(np.int32)])  # warm inference jit
        active = []
        for t in range(3):
            chunk = [feed.integers(0, vocab - 2, 30).astype(np.int32)
                     for _ in range(INGEST_CHUNK_DOCS)]
            th = threading.Thread(target=stack.ingestor.step,
                                  args=(chunk,))
            th.start()
            active.append(p99(pool_passes(stack, 100 * (1 + t))))
            th.join()
        ratio = min(active) / max(base, 1e-9)
        if ratio > INGEST_P99_MAX_RATIO:
            raise RuntimeError(
                f"serving p99 with ingest active is {ratio:.2f}x the "
                f"no-ingest p99 (> {INGEST_P99_MAX_RATIO}x) — the "
                f"append path is pausing serving, gate (d)")
        latency_rec = dict(no_ingest_p99_ms=base * 1e3,
                           ingest_p99_ms=min(active) * 1e3,
                           ratio=ratio, bound=INGEST_P99_MAX_RATIO,
                           passes=INGEST_GATE_PASSES,
                           ingest_steps=stack.ingestor.stats["steps"])

    # --- cache sub-check: the content-axis fence ---------------------
    cache_kw = dict(ingest_kw, cache=True,
                    cache_config=QueryCacheConfig(
                        max_entries=4 * len(queries), ttl_s=3600.0,
                        hamming_radius=0))
    # dedupe the pool the way the cache record does, so hit counts are
    # exact
    from repro.runtime.qcache import query_key
    seen, pool = set(), []
    for q in queries:
        k = query_key(q)
        if k not in seen:
            seen.add(k)
            pool.append(q)
    pool_chunks = [pool[i:i + batch_size]
                   for i in range(0, len(pool), batch_size)]

    def serve_pool(engine, seed_base):
        out = []
        for j, c in enumerate(pool_chunks):
            out.extend(engine.execute(
                c, rate, rng=np.random.default_rng(seed_base + j)))
        return out

    with build_serving_stack(corpus, index, **cache_kw) as stack:
        serve_pool(stack.engine, 100)             # populate
        serve_pool(stack.engine, 140)             # control: warm hits
        control_hits = stack.cache.stats["hits"]
        if control_hits != len(pool):
            raise RuntimeError(
                f"pre-ingest control re-serve hit {control_hits}/"
                f"{len(pool)} — the fence check below would pass "
                f"vacuously")
        stack.ingestor.step(new_docs)             # content bump
        hits0 = stack.cache.stats["hits"]
        stale0 = stack.cache.stats["stale_epoch"]
        got = serve_pool(stack.engine, 180)
        stale_hits = stack.cache.stats["hits"] - hits0
        staled = stack.cache.stats["stale_epoch"] - stale0
        if stale_hits:
            raise RuntimeError(
                f"{stale_hits} cache hits served across the ingest "
                f"content swap — stale entries must never hit")
        if staled < len(pool):
            raise RuntimeError(
                f"only {staled}/{len(pool)} entries dropped as "
                f"stale_epoch across the ingest swap — the content "
                f"axis is not fencing the cache")
        ref_engine = QueryBatch(stack.corpus, stack.index,
                                executor=stack.executor)
        want = serve_pool(ref_engine, 180)
        parity = _gather_parity(pool, got, want)
        if not all(parity.values()):
            raise RuntimeError(
                f"post-ingest re-serve diverged from a plain engine "
                f"on the appended world: {parity}")
        cache_rec = dict(pool=len(pool), control_hits=control_hits,
                         stale_dropped=staled, parity=parity,
                         stats=stack.cache.record())

    return dict(fraction=INGEST_FRACTION, swap=swap_rec,
                latency=latency_rec, cache_fence=cache_rec)


def run_sweep(corpus, index, queries, rate, executor, batch_size) -> list:
    """Static-vs-adaptive window sojourn across arrival rates.

    Rates are anchored to two measured capacities so the sweep spans
    the same regimes on any machine: the *light* end drives 0.1x the
    single-query service rate (windows should serve singles
    immediately — the static 2 ms deadline is pure added latency
    there; the wide margin matters because paced-serving cost runs
    several times the back-to-back probe estimate), and the mid/heavy
    ends drive 0.5x / 1.5x / 3x the *batched* dispatcher capacity
    (where amortization is what keeps the dispatcher stable).

    Three modes per load level: ``static`` and ``adaptive`` windows
    serving the unbudgeted stream (unbounded queue, as before), and
    ``budget`` — the error-budgeted engine behind an adaptive window
    with a bounded queue, so overload exercises the degrade-then-shed
    ladder.  Every row reports the fraction of queries shed vs served
    degraded and the realized p90 relative error of its count queries
    against exact full-scan answers."""
    from repro.core.queries import QueryBatch
    engine = QueryBatch(corpus, index, executor=executor)
    probe = queries[:batch_size]
    engine.execute(probe, rate, rng=np.random.default_rng(5))  # warm
    t0 = time.perf_counter()
    engine.execute(probe, rate, rng=np.random.default_rng(6))
    capacity_qps = len(probe) / (time.perf_counter() - t0)
    t0 = time.perf_counter()
    for i in range(4):
        engine.execute(queries[i:i + 1], rate, rng=np.random.default_rng(7))
    single_qps = 4 / (time.perf_counter() - t0)
    truths = [res.estimate.value if q.kind == "count" else None
              for q, res in zip(queries, engine.execute(
                  queries, 1.0, rng=np.random.default_rng(8)))]
    # percentile stability: each arm serves ~5 windows' worth of queries
    reps = (5 * batch_size) // len(queries) + 1
    sweep_queries = (queries * reps)[:5 * batch_size]
    budget_queries = (_budgeted_queries(queries) * reps)[:5 * batch_size]
    sweep_truths = (truths * reps)[:5 * batch_size]
    arms = [("light", 0.1 * single_qps), ("mid", 0.5 * capacity_qps),
            ("heavy", 1.5 * capacity_qps), ("overload", 3.0 * capacity_qps)]
    rows = []
    for li, (label, arrival_qps) in enumerate(arms):
        arrival_qps = max(arrival_qps, 1.0)
        for mode in ("static", "adaptive", "budget"):
            is_budget = mode == "budget"
            # best-of-3 on p99, same reason the throughput arms take
            # best-of wall time: one scheduler stall in the shared
            # container lands in somebody's tail
            row = None
            for trial in range(3):
                sojourns, realized, stats, mean_batch, extras = \
                    _run_paced_window(
                        corpus, index,
                        budget_queries if is_budget else sweep_queries,
                        rate, executor,
                        seed=10 + li + 100 * trial, arrival_qps=arrival_qps,
                        adaptive=(mode == "adaptive"),
                        static_delay_s=0.002, static_batch=batch_size,
                        max_batch_bound=4 * batch_size,
                        max_pending=(2 * batch_size if is_budget else None),
                        budget=is_budget)
                p90, _, _ = _count_err_stats(
                    budget_queries if is_budget else sweep_queries,
                    extras["results"], sweep_truths)
                cand = dict(
                    load=label, mode=mode,
                    arrival_qps_target=arrival_qps,
                    served_qps=realized,
                    p50_sojourn_ms=float(np.percentile(sojourns, 50)) * 1e3,
                    p99_sojourn_ms=float(np.percentile(sojourns, 99)) * 1e3,
                    windows=stats["batches"], mean_batch=mean_batch,
                    shed_frac=extras["shed"] / extras["offered"],
                    degraded_frac=(extras["degraded"]
                                   / max(extras["served"], 1)),
                    p90_rel_err=p90)
                if row is None or cand["p99_sojourn_ms"] < row["p99_sojourn_ms"]:
                    row = cand
            rows.append(row)
            csv_row(f"serve_sweep_{mode}_{label}",
                    row["p99_sojourn_ms"] * 1e3,
                    f"p99={row['p99_sojourn_ms']:.2f}ms "
                    f"qps={row['served_qps']:.0f}")
    return rows


def run(n_queries: int = 96, rate: float = 0.15, batch_size: int = 48,
        workers: int = 2, trials: int = 3, out_path: str = None,
        smoke: bool = False, sweep: bool = False, hosts: int = 0,
        replicas: int = 1, chaos: bool = False,
        chaos_only: bool = False, zipf: bool = False,
        ingest: bool = False) -> dict:
    chaos = chaos or chaos_only
    zipf = (zipf or smoke) and not chaos_only
    ingest = (ingest or smoke) and not chaos_only
    if smoke:
        # CI budget: tiny corpus, short PV training.  The arms
        # themselves cost milliseconds next to the setup, so 5 trials
        # buy the bench-regression gate a stable best-of measurement
        # for free.  The smoke run always carries the 2-host simulated
        # topology — its row is floored by the regression gate and its
        # parity/residency checks are hard failures — and the chaos
        # arm (scripted kill/join/drain scenario + the batched_chaos
        # row the gate also floors).
        setup = text_setup(tag="smoke", n_docs=400, vocab=2048, topics=8,
                           dim=24, steps=150, bits=128)
        n_queries, batch_size, trials = 48, 12, 5
        hosts = hosts or 2
        chaos = True
    else:
        setup = text_setup()
    if chaos and hosts < 2:
        hosts = 2
    corpus, index = setup["corpus"], setup["index"]
    # doc-granular variant of the same index: planning scores against
    # every doc and reduces to shards through the fused path — the
    # segment-sum Pallas kernels on TPU; on CPU interpret-mode Pallas
    # would swamp the measurement, so the kernels stay off and the
    # fused route is the shard-sorted np.add.reduceat
    from repro.kernels.common import on_tpu
    index_doc = dataclasses.replace(
        index, granularity="doc",
        use_kernel=on_tpu()).attach_corpus(corpus)
    from repro.runtime.executor import ShardTaskExecutor
    executor = ShardTaskExecutor(workers=workers)
    rng = np.random.default_rng(11)
    queries = _mixed_queries(corpus, n_queries, rng)

    arms = {} if chaos_only else {
        "per_query_scan": lambda seed: _run_per_query_scan(
            corpus, index, queries, rate, executor, seed),
        "per_query": lambda seed: _run_per_query(
            corpus, index, queries, rate, executor, seed),
        "batched": lambda seed: _run_batched(
            corpus, index, queries, rate, executor, seed, batch_size),
        "batched_fused": lambda seed: _run_batched(
            corpus, index_doc, queries, rate, executor, seed, batch_size),
        "windowed": lambda seed: _run_windowed(
            corpus, index, queries, rate, executor, seed, batch_size),
    }
    if not chaos_only:
        # the error-budgeted engine: per-query SLOs through a
        # RatePlanner, bootstrap CIs on (one engine reused across
        # trials, like the balanced arm, so the warm pass is where the
        # error curves fit and measured trials run the learned plans)
        from repro.core.queries import QueryBatch
        from repro.runtime import RatePlanner
        budget_engine = QueryBatch(corpus, index, executor=executor,
                                   planner=RatePlanner(corpus.n_shards),
                                   ci=True)
        budget_queries = _budgeted_queries(queries)
        arms["batched_budget"] = lambda seed: _run_batched(
            corpus, index, budget_queries, rate, executor, seed, batch_size,
            engine=budget_engine)
        # the one-launch scan arm: chunks prebuilt (spec + payload
        # reused across trials), every query scanning the full fleet —
        # one megakernel launch per chunk vs n_shards tasks per chunk
        # on the per-shard route
        mega_chunks = _mega_chunks(corpus, index_doc, n_queries,
                                   batch_size, np.random.default_rng(29))
        arms["batched_mega"] = lambda seed: _run_mega(
            corpus, mega_chunks, executor, seed)
    arm_n = {}                      # per-arm served-query count override
    zipf_stream = cache_stack = None
    if zipf:
        # the semantic-cache arms: the SAME Zipf-skewed stream (2x the
        # distinct pool, hot head) through the plain batched engine
        # (batched_zipf — repeats pay full price) and through a
        # cache-enabled engine reused across trials (batched_cached —
        # the warm pass populates, measured trials serve mostly exact
        # hits that skip sampling, scanning, and the executor).  Both
        # rows are qps/p50 over the stream length, not n_queries.
        from repro.launch.serve_stack import build_serving_stack
        from repro.runtime.qcache import QueryCacheConfig
        zipf_stream = _zipf_stream(queries, ZIPF_STREAM_FACTOR * n_queries,
                                   ZIPF_SKEW, np.random.default_rng(17))
        arms["batched_zipf"] = lambda seed: _run_batched(
            corpus, index, zipf_stream, rate, executor, seed, batch_size)
        cache_stack = build_serving_stack(
            corpus, index, cache=True, workers=workers,
            cache_config=QueryCacheConfig(max_entries=4 * n_queries,
                                          ttl_s=3600.0))
        arms["batched_cached"] = lambda seed: _run_batched(
            corpus, index, zipf_stream, rate, cache_stack.executor, seed,
            batch_size, engine=cache_stack.engine)
        arm_n["batched_zipf"] = arm_n["batched_cached"] = len(zipf_stream)
    ingest_stack = None
    if ingest:
        # the live-ingest arm: the batched pool served through an
        # ingest-enabled stack while a small Ingestor.step (append +
        # frozen-model inference + RCU swap) races each call from a
        # background thread — the row prices writer contention on the
        # serving hot path and is floored by the regression gate.  The
        # corpus grows a little every call (INGEST_CHUNK_DOCS docs),
        # which is the point: ingest-concurrent serving, not a frozen
        # world.
        from repro.launch.serve_stack import build_serving_stack
        # yield_s=0: the timed row prices RAW writer/reader contention
        # (the default cooperative pacing would make it a sleep
        # benchmark); the hard-gated latency record measures the paced
        # configuration instead.
        ingest_stack = build_serving_stack(
            corpus, index, workers=workers, ingest=True,
            ingest_model=setup["model"], ingest_pv_cfg=setup["pv_cfg"],
            ingest_infer_steps=INGEST_INFER_STEPS, ingest_yield_s=0.0)
        ingest_feed = np.random.default_rng(83)

        def _ingest_arm(seed):
            chunk = [ingest_feed.integers(0, corpus.vocab_size - 2, 30)
                     .astype(np.int32) for _ in range(INGEST_CHUNK_DOCS)]
            th = threading.Thread(target=ingest_stack.ingestor.step,
                                  args=(chunk,))
            th.start()
            lat = _run_batched(corpus, index, queries, rate,
                               ingest_stack.executor, seed, batch_size,
                               engine=ingest_stack.engine)
            th.join()
            return lat

        arms["batched_ingest"] = _ingest_arm
    chaos_exec = chaos_plan = None
    if chaos:
        # the chaos-hardened topology under a steady scripted fault
        # load: every host uniformly slowed (sleep-dominated, so the
        # row is machine-stable) and host 1 mildly flaky, so the row
        # prices the injection seams + the deterministic retry path on
        # the batched hot path.  Floored by the regression gate — it
        # collapses if fault handling grows a serialization point.
        from repro.runtime import (FaultPlan, HostGroupExecutor,
                                   PlacementMap)
        chaos_exec = HostGroupExecutor(
            PlacementMap.blocked(corpus.n_shards, hosts,
                                 n_replicas=max(1, replicas)),
            workers_per_host=max(1, workers // hosts))
        chaos_plan = FaultPlan(seed=11).flaky(1, error_rate=0.05)
        for h in range(hosts):
            chaos_plan.slow(h, ms_per_shard=CHAOS_SLOW_MS)
        chaos_plan.install(chaos_exec)
        arms["batched_chaos"] = lambda seed: _run_batched(
            corpus, index, queries, rate, chaos_exec, seed, batch_size)
    host_exec = lb_exec = None
    if hosts >= 2 and not chaos_only:
        from repro.runtime import HostGroupExecutor, PlacementMap
        # same total worker threads as the single-host arms: the row
        # measures placement overhead, not extra parallelism
        host_exec = HostGroupExecutor(
            PlacementMap.blocked(corpus.n_shards, hosts,
                                 n_replicas=replicas),
            workers_per_host=max(1, workers // hosts))
        arms[f"batched_hosts{hosts}"] = lambda seed: _run_batched(
            corpus, index, queries, rate, host_exec, seed, batch_size)
        if replicas >= 1:
            # the hot-host arm: host 0 degraded, balancer on.  The warm
            # pass (arm(0) below) is where the load model learns the
            # heat; measured trials run the learned, shed split
            lb_exec = HostGroupExecutor(
                PlacementMap.blocked(corpus.n_shards, hosts,
                                     n_replicas=replicas),
                workers_per_host=max(1, workers // hosts),
                balanced=True, host_fault_hook=_hot_host_hook)
            arms[f"batched_lb{hosts}"] = lambda seed: _run_batched(
                corpus, index, queries, rate, lb_exec, seed, batch_size)
        else:
            print("NOTE: --replicas 0 — the balanced hot-host arm needs "
                  "at least one replica to shed onto; skipping it")
    per_query_arms = {"per_query_scan", "per_query", "windowed"}
    report = {}
    for name, arm in arms.items():
        arm(0)  # warm (postings caches, jit, thread pools)
        best, best_lat = None, None
        for t in range(trials):
            t0 = time.perf_counter()
            lat = arm(1 + t)
            dt = time.perf_counter() - t0
            if best is None or dt < best:
                best, best_lat = dt, lat
        if name in per_query_arms:
            p50 = float(np.percentile(
                [t if np.isscalar(t) else t[0] for t in best_lat], 50))
        else:
            p50 = float(np.percentile([t / n for t, n in best_lat], 50))
        n_served = arm_n.get(name, n_queries)
        if name == "windowed":
            # open-loop burst: sojourn includes queue backlog behind the
            # single dispatcher, so label it as such instead of p50_ms
            report[name] = dict(qps=n_served / best,
                                p50_sojourn_ms=p50 * 1e3, wall_s=best,
                                note="saturated open-loop burst; sojourn "
                                     "includes dispatcher queue backlog")
        else:
            report[name] = dict(qps=n_served / best, p50_ms=p50 * 1e3,
                                wall_s=best)
        csv_row(f"serve_{name}", 1e6 * best / n_served,
                f"qps={report[name]['qps']:.1f}")

    if chaos:
        report["chaos"] = _chaos_report(
            corpus, index, queries, rate, executor, hosts, workers,
            batch_size)
        report["chaos"]["timed_row_faults"] = chaos_plan.record()
        chaos_exec.close()
        csv_row(f"serve_chaos_hosts{hosts}", 0.0,
                f"recovery {report['chaos']['recovery_ratio']:.2f}x, "
                f"lost {report['chaos']['lost_queries']}, "
                f"warmed {report['chaos']['warmed_shards']}")

    if zipf:
        report["cache"] = _cache_report(
            corpus, index, queries, rate, executor, max(hosts, 2),
            workers, batch_size)
        cached_p50 = report["batched_cached"]["p50_ms"]
        uncached_p50 = report["batched_zipf"]["p50_ms"]
        report["cache"]["zipf"] = dict(
            skew=ZIPF_SKEW, pool=n_queries, stream=len(zipf_stream),
            uncached_p50_ms=uncached_p50, cached_p50_ms=cached_p50,
            p50_collapse=uncached_p50 / max(cached_p50, 1e-9),
            stats=cache_stack.cache.record())
        cache_stack.close()
        # the latency contract: under skewed traffic the cached arm's
        # p50 must be STRICTLY below the uncached arm on the same
        # stream — a cache that hits but does not win latency is
        # overhead, and a regression here means hits stopped skipping
        # the sampling/scan path
        if cached_p50 >= uncached_p50:
            raise RuntimeError(
                f"cached p50 {cached_p50:.3f} ms >= uncached "
                f"{uncached_p50:.3f} ms on the Zipf stream "
                f"(skew {ZIPF_SKEW}) — exact hits are not bypassing "
                f"execution")
        csv_row("serve_cache", 0.0,
                f"p50 collapse "
                f"{report['cache']['zipf']['p50_collapse']:.1f}x, "
                f"hits {report['cache']['zipf']['stats']['hits']}")

    if ingest:
        report["ingest"] = _ingest_report(
            corpus, index, setup["model"], setup["pv_cfg"], queries,
            rate, hosts, workers, batch_size)
        report["ingest"]["timed_row"] = ingest_stack.ingestor.record()
        ingest_stack.close()
        sw = report["ingest"]["swap"]
        csv_row("serve_ingest", 0.0,
                f"+{sw['n_new']} docs, p99 ratio "
                f"{report['ingest']['latency']['ratio']:.2f}x, "
                f"stale dropped "
                f"{report['ingest']['cache_fence']['stale_dropped']}")

    if hosts >= 2 and not chaos_only:
        report["placement"] = _placement_report(
            corpus, index, queries, rate, executor, hosts, workers,
            batch_size)
        ratio = (report[f"batched_hosts{hosts}"]["qps"]
                 / report["batched"]["qps"])
        report["placement"]["qps_ratio_vs_single_host"] = ratio
        csv_row(f"serve_placement_hosts{hosts}", 0.0,
                f"{ratio:.2f}x of single-host")
        host_exec.close()
        if lb_exec is not None:
            report["balance"] = _balance_report(
                corpus, index, queries, rate, executor, hosts, replicas,
                workers, batch_size)
            csv_row(f"serve_balance_hosts{hosts}", 0.0,
                    f"makespan {report['balance']['makespan_reduction']:.2f}x"
                    f" down, shed {report['balance']['shed_shards']}")
            lb_exec.close()
        report["budget"] = _budget_report(
            corpus, index, queries, rate, executor, hosts, workers,
            batch_size)
        ov = report["budget"]["overload"]
        csv_row(f"serve_budget_hosts{hosts}", 0.0,
                f"shed static {ov['static']['shed']} -> budget "
                f"{ov['budget']['shed']}, CI coverage "
                f"{report['budget']['coverage']['planned']['ci_coverage']:.0%}")

    if sweep:
        report["load_sweep"] = run_sweep(corpus, index, queries, rate,
                                         executor, batch_size)

    if not chaos_only:
        report["megascan"] = _megascan_report(
            corpus, index_doc, hosts, workers, batch_size)
        mg = report["megascan"]
        csv_row("serve_megascan", 0.0,
                f"win {mg['measured']['win']:.2f}x over per-shard, "
                f"dispatch share "
                f"{mg['dispatch_share']['per_shard']:.2f} -> "
                f"{mg['dispatch_share']['mega']:.2f}, "
                f"dominant {mg['dominant']['per_shard']} -> "
                f"{mg['dominant']['mega']}")
        report["speedup_batched_vs_per_query"] = (
            report["per_query"]["wall_s"] / report["batched"]["wall_s"])
        report["speedup_batched_vs_scan"] = (
            report["per_query_scan"]["wall_s"] / report["batched"]["wall_s"])
        report["speedup_fused_vs_per_query"] = (
            report["per_query"]["wall_s"]
            / report["batched_fused"]["wall_s"])
        csv_row("serve_speedup_batched_vs_per_query", 0.0,
                f"{report['speedup_batched_vs_per_query']:.2f}x")
        csv_row("serve_speedup_batched_vs_scan", 0.0,
                f"{report['speedup_batched_vs_scan']:.2f}x")
        csv_row("serve_speedup_fused_vs_per_query", 0.0,
                f"{report['speedup_fused_vs_per_query']:.2f}x")
    report["config"] = dict(n_queries=n_queries, rate=rate,
                            batch_size=batch_size, workers=workers,
                            trials=trials, n_shards=corpus.n_shards,
                            n_docs=corpus.n_docs, smoke=smoke,
                            hosts=hosts, replicas=replicas,
                            chaos=chaos, chaos_only=chaos_only,
                            zipf=zipf, zipf_skew=ZIPF_SKEW,
                            ingest=ingest,
                            executor_stats=dict(executor.stats))
    executor.close()

    out_path = out_path or os.environ.get("BENCH_SERVE_JSON",
                                          "BENCH_serve.json")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus + 1 trial; finishes in <60 s "
                         "(the CI serving smoke job)")
    ap.add_argument("--sweep", action="store_true",
                    help="add the static-vs-adaptive window load sweep "
                         "(Poisson arrivals at several rates)")
    ap.add_argument("--hosts", type=int, default=0,
                    help="add a simulated N-host placement arm "
                         "(batched_hostsN row + placement parity/"
                         "residency record, plus the balanced hot-host "
                         "batched_lbN row + balance record; --smoke "
                         "defaults to 2)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="ring replicas per shard in the placement arms "
                         "(the balanced hot-host arm needs >= 1)")
    ap.add_argument("--chaos", action="store_true",
                    help="add the elastic-fleet chaos arm: the scripted "
                         "kill/join/drain scenario record (hard-gated) "
                         "plus the batched_chaos throughput row "
                         "(--smoke always includes it)")
    ap.add_argument("--zipf", action="store_true",
                    help="add the semantic-cache arms: batched_zipf / "
                         "batched_cached rows on a Zipf-skewed stream "
                         "plus the hard-gated cache correctness record "
                         "(exact-hit parity, zero stale-generation "
                         "hits; --smoke always includes them)")
    ap.add_argument("--ingest", action="store_true",
                    help="add the live-ingest arm: the batched_ingest "
                         "row (serving with an Ingestor.step racing "
                         "each call) plus the hard-gated ingest record "
                         "(zero loss, torn-world parity, sentinel "
                         "freshness, p99 bound, content-axis cache "
                         "fence; --smoke always includes it)")
    ap.add_argument("--chaos-only", action="store_true",
                    help="run ONLY the chaos arm (the CI chaos-smoke "
                         "job): scenario record + batched_chaos row, "
                         "skipping every other arm")
    ap.add_argument("--out", default=None, help="output json path")
    args = ap.parse_args()
    run(smoke=args.smoke, sweep=args.sweep, hosts=args.hosts,
        replicas=args.replicas, chaos=args.chaos,
        chaos_only=args.chaos_only, zipf=args.zipf,
        ingest=args.ingest, out_path=args.out)
