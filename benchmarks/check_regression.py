"""Serving-bench regression gate (the CI serve-smoke floor).

Compares a freshly produced ``BENCH_serve.json`` against the committed
baseline and fails (exit 1) when any floored row's throughput drops
more than ``--tolerance`` (default 25%) below it.  Eight rows are
floored: ``batched_fused`` (the single-host fused batched path),
``batched_hosts2`` (the simulated 2-host placement path — locality
split, per-host shared scans, cross-host gather), ``batched_lb2``
(the balanced hot-host path: host 0 degraded, the replica-aware
balancer sheds its shard groups onto ring replicas — this row's
throughput collapses if the balancer stops shedding, because the
injected per-shard delay then lands back on the critical path),
``batched_budget`` (the planner-attached CI-carrying path: every
query's rate planned from its error budget, every count answered with
a Hansen-Hurwitz interval — this row's throughput collapses if
planning or interval construction grows a per-query serialization
point), ``batched_chaos`` (the 2-host topology under a steady
scripted ``FaultPlan``: uniform per-shard slowdowns plus a mildly
flaky host — sleep-dominated, hence machine-stable, and it collapses
if the injection seams grow per-task overhead or retries stop
clearing transient faults), and ``batched_cached`` (the semantic-
cache path serving the Zipf-skewed stream: most queries resolve as
exact LSH-signature hits that skip sampling, scanning, and the
executor — this row's throughput collapses if hits stop bypassing
execution or the probe itself grows a per-query serialization
point; its baseline sits far below the measured hit-path qps
because the floor only needs to catch that collapse), and
``batched_mega`` (the one-launch scan-over-shards megakernel path:
every chunk of full-fleet similarity scans routed as ONE Pallas
launch over the packed multi-shard payload instead of one task per
shard — this row's throughput collapses if the megakernel route stops
engaging and the scan silently falls back to per-shard dispatch; its
baseline sits at roughly half the measured qps because the fallback
costs ~3x, so the floor catches the collapse without flapping on
container noise), and ``batched_ingest`` (the live-ingest-concurrent
serving path: the batched pool served through an ingest-enabled stack
while an unpaced ``Ingestor.step`` — append, frozen-model inference,
RCU generation swap — races every call from a writer thread; this
row's throughput collapses if the append path grows a read-side lock
or the post-swap engine starts rebuilding caches per batch; its
baseline sits well below the measured qps because writer/reader
timesharing is the noisiest thing the suite floors).  The
wide tolerance absorbs runner-to-runner CPU variance while still
catching the real regressions this gate exists for: a serialization
point sneaking back into the batched scoring path, postings caches
being rebuilt per batch, the fused reduction silently falling back to
per-query execution, the placement layer paying a cross-host penalty
on local data, or the balancer losing its shed.

The bench itself hard-fails (before this gate runs) on any
balanced-vs-primary or balanced-vs-single-executor gather mismatch and
on a balanced split that fails to reduce the hot-host makespan — the
same pattern as the placement record's residency/parity checks.

  PYTHONPATH=src python -m benchmarks.check_regression /tmp/bench.json

When the hardware generation of the CI runners changes legitimately,
re-run ``python -m benchmarks.serve_bench --smoke`` on the new runners
and refresh ``benchmarks/baselines/serve_smoke.json`` (every CI run
uploads its JSON as a workflow artifact to make that painless).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                                "serve_smoke.json")
DEFAULT_KEYS = ("batched_fused,batched_hosts2,batched_lb2,"
                "batched_budget,batched_chaos,batched_cached,"
                "batched_mega,batched_ingest")


def check_key(current: dict, baseline: dict, key: str,
              tolerance: float, current_path: str,
              baseline_path: str) -> int:
    try:
        cur_qps = float(current[key]["qps"])
    except KeyError:
        print(f"FAIL: {current_path} has no '{key}' row — the serving "
              f"bench did not exercise that path")
        return 1
    try:
        base_qps = float(baseline[key]["qps"])
    except KeyError:
        print(f"FAIL: baseline {baseline_path} has no '{key}' row — "
              f"refresh it from a full smoke run")
        return 1
    floor = (1.0 - tolerance) * base_qps
    ok = cur_qps >= floor
    print(f"{'OK' if ok else 'FAIL'}: {key} {cur_qps:.1f} q/s vs "
          f"baseline {base_qps:.1f} q/s (floor {floor:.1f}, "
          f"tolerance {tolerance:.0%})")
    return 0 if ok else 1


def check(current_path: str, baseline_path: str = DEFAULT_BASELINE,
          keys: str = DEFAULT_KEYS, tolerance: float = 0.25) -> int:
    with open(current_path) as f:
        current = json.load(f)
    with open(baseline_path) as f:
        baseline = json.load(f)
    rc = 0
    for key in [k.strip() for k in keys.split(",") if k.strip()]:
        rc |= check_key(current, baseline, key, tolerance,
                        current_path, baseline_path)
    return rc


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="BENCH_serve.json produced by this run")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--keys", default=DEFAULT_KEYS,
                    help="comma-separated rows to floor")
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get(
                        "BENCH_REGRESSION_TOLERANCE", "0.25")))
    args = ap.parse_args()
    sys.exit(check(args.current, args.baseline, args.keys, args.tolerance))
