"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract).

  PYTHONPATH=src python -m benchmarks.run             # everything
  PYTHONPATH=src python -m benchmarks.run --only fig3 # one family
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter: fig3/fig5/fig7/fig8/tab2/roofline")
    ap.add_argument("--fast", action="store_true",
                    help="smaller query counts (CI mode)")
    args = ap.parse_args()

    from benchmarks import (aggregation_bench, dir_bench, index_bench,
                            recsys_bench, roofline, sensitivity_bench,
                            serve_bench)

    suites = [
        ("serve_batched_engine",
         lambda: serve_bench.run(n_queries=48 if args.fast else 96,
                                 trials=2 if args.fast else 3)),
        ("fig3_fig4_aggregation",
         lambda: aggregation_bench.run(n_queries=20 if args.fast else 60,
                                       trials=1 if args.fast else 2)),
        ("fig5_fig6_dir",
         lambda: dir_bench.run(n_queries=10 if args.fast else 30)),
        ("fig7_recsys",
         lambda: recsys_bench.run(n_test_users=15 if args.fast else 40)),
        ("fig8_fig9_sensitivity", sensitivity_bench.run),
        ("tab2_index", index_bench.run),
        ("roofline", roofline.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        print(f"# --- {name} ---")
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED: {e}", file=sys.stderr)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
