"""Paper Fig. 8 + Fig. 9: sensitivity to lambda1 (PV-DBOW dim),
lambda2 (LSH bits), k (k-means clusters) — plus our beta (scoring
temperature) as the beyond-paper knob."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, pick_query_words, text_setup


def _agg_error(corpus, index, words, rate, rng, trials=2):
    from repro.core.queries.aggregation import (
        phrase_count_query, precise_phrase_count)
    errs = []
    for w in words:
        true = precise_phrase_count(corpus, [int(w)])
        if true == 0:
            continue
        for _ in range(trials):
            r = phrase_count_query(corpus, index, [int(w)], rate, rng=rng)
            errs.append(abs(r.estimate.value - true) / true)
    return float(np.mean(errs))


def run(verbose=True):
    from repro.core.index import build_index
    from repro.core.lsh import LSHConfig

    rng = np.random.default_rng(31)

    # fig8a/b: PV-DBOW dimension (lambda1)
    for dim in (16, 32, 64, 100):
        setup = text_setup(tag=f"dim{dim}", dim=dim, steps=1200,
                           n_docs=2000)
        corpus, index = setup["corpus"], setup["index"]
        words = pick_query_words(corpus, 12, rng)
        err = _agg_error(corpus, index, words, 0.10, rng)
        csv_row(f"fig8a_dim{dim}", 0.0, f"agg_rel_err@10%={err:.3f}")

    # fig8c/d: LSH bits (lambda2), same model, re-hash only
    setup = text_setup(tag="wiki")
    corpus, model, beta = setup["corpus"], setup["model"], \
        setup["pv_cfg"].temperature
    words = pick_query_words(corpus, 12, rng)
    real_idx = build_index(corpus, model, LSHConfig(bits=256),
                           use_lsh=False, temperature=beta)
    err_real = _agg_error(corpus, real_idx, words, 0.10, rng)
    csv_row("fig8c_realvalued", 0.0, f"agg_rel_err@10%={err_real:.3f}")
    for bits in (32, 64, 128, 256, 512):
        for mode in ("sym", "asym"):
            idx = build_index(corpus, model, LSHConfig(bits=bits),
                              temperature=beta, lsh_mode=mode)
            err = _agg_error(corpus, idx, words, 0.10, rng)
            csv_row(f"fig8c_bits{bits}_{mode}", 0.0,
                    f"agg_rel_err@10%={err:.3f}")

    # beyond-paper: scoring temperature beta
    for beta_s in (1.0, 4.0, 8.0, 12.0):
        idx = build_index(corpus, model, LSHConfig(bits=256),
                          temperature=beta_s)
        err = _agg_error(corpus, idx, words, 0.10, rng)
        csv_row(f"fig8x_beta{beta_s}", 0.0, f"agg_rel_err@10%={err:.3f}")

    # fig9: number of k-means clusters (ranked retrieval P@10)
    from repro.core.allocation import KMeansConfig, spherical_kmeans
    from repro.core.queries.retrieval import precision_at_k, ranked_query
    setup_nk = text_setup(tag="wiki", kmeans=False)
    corpus0, model0 = setup_nk["corpus"], setup_nk["model"]
    pre = build_index(corpus0, model0, LSHConfig(bits=256),
                      use_lsh=False, temperature=beta)
    n_shards = corpus0.n_shards
    for frac in (0.25, 0.5, 1.0):
        k = max(2, int(n_shards * frac))
        assign, _ = spherical_kmeans(pre.doc_vecs, KMeansConfig(n_clusters=k))
        # map k clusters onto n_shards shards round-robin
        corpus_k = corpus0.reallocate(assign % n_shards, n_shards)
        idx = build_index(corpus_k, model0, LSHConfig(bits=256),
                          temperature=beta)
        word_sets = [pick_query_words(corpus_k, 3, rng).tolist()
                     for _ in range(10)]
        precs = []
        for ws in word_sets:
            full = ranked_query(corpus_k, idx, ws, 1.0, k=10).doc_ids
            r = ranked_query(corpus_k, idx, ws, 0.25, k=10, rng=rng)
            precs.append(precision_at_k(r.doc_ids, full, 10))
        csv_row(f"fig9_kfrac{frac}", 0.0,
                f"ranked_p10@25%={np.mean(precs):.3f};k={k}")


if __name__ == "__main__":
    run()
