"""Paper Fig. 3 + Fig. 4: phrase-occurrence estimation.

Fig 3: CDFs of estimated relative error at 1/2.5/5/10% sampling,
EmApprox vs SRCS.  Fig 4: speedup (data fraction + wall time) and
estimated-vs-actual error.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row, pick_query_phrases, text_setup


def run(n_queries=60, trials=2, rates=(0.01, 0.025, 0.05, 0.10),
        verbose=True):
    from repro.core.queries.aggregation import (
        phrase_count_query, precise_phrase_count)

    setup = text_setup(tag="wiki")
    corpus, index = setup["corpus"], setup["index"]
    rng = np.random.default_rng(42)
    phrases = pick_query_phrases(corpus, n_queries, rng)

    truths = {}
    t0 = time.perf_counter()
    for i, ph in enumerate(phrases):
        truths[i] = precise_phrase_count(corpus, ph)
    precise_s = (time.perf_counter() - t0) / max(len(phrases), 1)

    results = {}
    for rate in rates:
        rows = {"em": {"est_rel": [], "act_rel": [], "t": [], "frac": []},
                "srcs": {"est_rel": [], "act_rel": [], "t": [], "frac": []}}
        for i, ph in enumerate(phrases):
            true = truths[i]
            if true == 0:
                continue
            for _ in range(trials):
                for method, key in (("emapprox", "em"), ("srcs", "srcs")):
                    r = phrase_count_query(corpus, index if method ==
                                           "emapprox" else None,
                                           ph, rate, method=method, rng=rng)
                    est_rel = min(r.estimate.relative_error, 10.0)
                    act_rel = abs(r.estimate.value - true) / true
                    rows[key]["est_rel"].append(est_rel)
                    rows[key]["act_rel"].append(act_rel)
                    rows[key]["t"].append(r.elapsed_s)
                    rows[key]["frac"].append(r.data_fraction)
        results[rate] = rows

    # ------- report (one CSV row per figure panel) --------------------
    for rate, rows in results.items():
        for key in ("em", "srcs"):
            r = rows[key]
            est = np.asarray(r["est_rel"])
            act = np.asarray(r["act_rel"])
            us = np.mean(r["t"]) * 1e6
            p50, p90 = np.percentile(est, [50, 90])
            csv_row(f"fig3_cdf_{key}_rate{rate}", us,
                    f"est_rel_p50={p50:.3f};est_rel_p90={p90:.3f}")
            speedup = precise_s / max(np.mean(r["t"]), 1e-9)
            csv_row(f"fig4_{key}_rate{rate}", us,
                    f"speedup={speedup:.1f}x;data_frac={np.mean(r['frac']):.3f};"
                    f"est_rel={est.mean():.3f};act_rel={act.mean():.3f}")
    # headline: data-equivalence factor (paper: SRCS needs ~4x data)
    em25 = np.mean(results[0.025]["em"]["act_rel"]) if 0.025 in results else None
    sr10 = np.mean(results[0.10]["srcs"]["act_rel"]) if 0.10 in results else None
    if em25 is not None and sr10 is not None:
        csv_row("fig4_data_equivalence", 0.0,
                f"em@2.5%={em25:.3f};srcs@10%={sr10:.3f};"
                f"claim_holds={bool(em25 <= sr10 * 1.2)}")
    return results


if __name__ == "__main__":
    run()
