"""Paper Table II analogue: index sizes, PV-DBOW training throughput,
query-time index-lookup cost (the XOR-Hamming hot path) for both the
jnp reference and the Pallas kernel (interpret mode on CPU)."""
from __future__ import annotations

import time


from benchmarks.common import csv_row, text_setup


def _time(fn, *args, reps=20, warmup=3):
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run(verbose=True):
    import jax.numpy as jnp
    from repro.core import lsh as lsh_mod
    from repro.core.pv_dbow import corpus_pairs
    from repro.kernels.hamming import ops as hops

    setup = text_setup(tag="wiki")
    corpus, index, model = setup["corpus"], setup["index"], setup["model"]

    corpus_bytes = sum(s.tokens.nbytes for s in corpus.shards)
    raw_vec_bytes = (index.word_vecs.nbytes + index.doc_vecs.nbytes +
                     index.shard_vecs.nbytes)
    csv_row("tab2_index_size", 0.0,
            f"corpus_MB={corpus_bytes/2**20:.1f};"
            f"raw_vectors_MB={raw_vec_bytes/2**20:.2f};"
            f"lsh_index_MB={index.nbytes()/2**20:.2f};"
            f"compression_vs_raw={raw_vec_bytes/max(index.nbytes(),1):.1f}x")
    csv_row("tab2_train_time", setup["train_s"] * 1e6,
            f"pv_dbow_train_s={setup['train_s']:.1f}")

    # PV-DBOW step throughput (pairs/s), jnp vs fused-kernel path
    import jax
    from repro.core.pv_dbow import sgns_step
    from repro.kernels.negsamp.ops import negsamp_step
    pairs = corpus_pairs(corpus)
    cdf = jnp.asarray(pairs.noise_cdf)
    key = jax.random.PRNGKey(0)
    doc_ids = jnp.asarray(pairs.doc_of_token[:4096])
    word_ids = jnp.asarray(pairs.word_of_token[:4096])
    kw = dict(negatives=5, lr=0.01, unit_norm=True, temperature=8.0)
    us_ref = _time(lambda: sgns_step(model, key, doc_ids, word_ids, cdf,
                                     **kw)[1], reps=10)
    us_ker = _time(lambda: negsamp_step(model, key, doc_ids, word_ids, cdf,
                                        **kw)[1], reps=10)
    csv_row("tab2_sgns_step_jnp", us_ref,
            f"pairs_per_s={4096/(us_ref/1e6):,.0f}")
    csv_row("tab2_sgns_step_kernel_interpret", us_ker,
            f"pairs_per_s={4096/(us_ker/1e6):,.0f}")

    # query-time similarity: Hamming over shard signatures
    q = index.shard_sig[:1]
    db = index.shard_sig
    us_jnp = _time(lambda: lsh_mod.hamming_similarity(
        jnp.asarray(q), jnp.asarray(db), index.bits, 8.0))
    us_kernel = _time(lambda: hops.hamming_similarity(
        jnp.asarray(q), jnp.asarray(db), index.bits, temperature=8.0))
    csv_row("query_similarity_jnp", us_jnp, f"n_shards={db.shape[0]}")
    csv_row("query_similarity_kernel_interpret", us_kernel,
            f"n_shards={db.shape[0]}")


if __name__ == "__main__":
    run()
