"""Paper Fig. 7: approximate user-centric collaborative filtering.

Per the paper's protocol: hold out 20% of each test user's ratings,
predict them from a sampled neighborhood, report MSE and P@10 vs the
precise (rate=1.0) execution, EmApprox vs SRCS.
"""
from __future__ import annotations


import numpy as np

from benchmarks.common import csv_row, review_setup


def run(n_test_users=40, rates=(0.10, 0.25, 0.50), verbose=True):
    from repro.core.queries.recommend import (
        mse as rec_mse, precision_at_k, recommend_query)

    setup = review_setup()
    data, corpus, index = setup["data"], setup["corpus"], setup["index"]
    rng = np.random.default_rng(17)
    users = rng.choice(data.user_topics.shape[0], n_test_users,
                       replace=False)

    # hold out 20% of each user's ratings as test
    holdout = {}
    for u in users:
        mask = data.user_of == u
        items = data.item_of[mask]
        ratings = data.ratings[mask]
        k = max(1, int(0.2 * len(items)))
        sel = rng.choice(len(items), k, replace=False)
        holdout[u] = (items[sel], ratings[sel], items)

    def evaluate(rate, method):
        mses, precs, ts = [], [], []
        for u in users:
            t_items, t_ratings, bought = holdout[u]
            r = recommend_query(corpus, index, data, int(u), rate,
                                k=10, method=method, rng=rng,
                                exclude_items=np.setdiff1d(bought, t_items))
            mses.append(rec_mse(r.predictions, t_items, t_ratings))
            precs.append(precision_at_k(r.top_k, t_items, 10))
            ts.append(r.elapsed_s)
        return float(np.nanmean(mses)), float(np.mean(precs)), np.mean(ts)

    m_p, p_p, t_p = evaluate(1.0, "emapprox")
    csv_row("fig7_precise", t_p * 1e6, f"mse={m_p:.3f};p_at_10={p_p:.3f}")
    for rate in rates:
        for method in ("emapprox", "srcs"):
            m, p, t = evaluate(rate, method)
            csv_row(f"fig7_{method}_rate{rate}", t * 1e6,
                    f"mse={m:.3f};p_at_10={p:.3f};"
                    f"speedup={t_p/max(t,1e-9):.2f}x")


if __name__ == "__main__":
    run()
