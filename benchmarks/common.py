"""Shared benchmark fixtures: corpora, models, indices — disk-cached so
``python -m benchmarks.run`` is resumable and re-runs are fast."""
from __future__ import annotations

import os
import pickle
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

CACHE = os.environ.get("BENCH_CACHE", "results/bench_cache")


def cached(name, builder):
    os.makedirs(CACHE, exist_ok=True)
    path = os.path.join(CACHE, name + ".pkl")
    if os.path.exists(path):
        with open(path, "rb") as f:
            return pickle.load(f)
    obj = builder()
    with open(path, "wb") as f:
        pickle.dump(obj, f)
    return obj


def text_setup(tag="wiki", n_docs=3200, vocab=4096, topics=16, seed=0,
               dim=64, steps=2000, beta=8.0, bits=256, kmeans=True):
    """Corpus + trained PV-DBOW + (optionally) k-means allocation +
    index.  The 'wiki'/'ccnews' tags mirror the paper's two text data
    sets (different seeds -> different topic structure)."""
    def build():
        from repro.core.allocation import allocate_corpus
        from repro.core.index import build_index
        from repro.core.lsh import LSHConfig
        from repro.core.pv_dbow import PVDBOWConfig, train_pv_dbow
        from repro.data.corpus import SyntheticCorpusConfig, generate_text_corpus

        ccfg = SyntheticCorpusConfig(n_docs=n_docs, vocab_size=vocab,
                                     n_topics=topics, seed=seed)
        docs, _ = generate_text_corpus(ccfg)
        from repro.data.store import ShardedCorpus
        corpus = ShardedCorpus.from_documents(docs, vocab, shard_tokens=4096)
        pcfg = PVDBOWConfig(dim=dim, steps=steps, batch_pairs=4096,
                            lr=0.01, temperature=beta, seed=seed)
        t0 = time.time()
        model = train_pv_dbow(corpus, pcfg)
        train_s = time.time() - t0
        if kmeans:
            pre = build_index(corpus, model, LSHConfig(bits=bits),
                              use_lsh=False, temperature=beta)
            corpus = allocate_corpus(corpus, pre.doc_vecs)
        index = build_index(corpus, model, LSHConfig(bits=bits),
                            temperature=beta)
        return dict(corpus=corpus, model=model, index=index,
                    train_s=train_s, pv_cfg=pcfg)
    return cached(f"text_{tag}_{n_docs}_{vocab}_{dim}_{steps}_{bits}"
                  f"_{int(kmeans)}_{seed}", build)


def review_setup(n_users=400, n_items=200, vocab=4096, topics=12, seed=1,
                 dim=48, steps=1500, beta=8.0, bits=256):
    """Amazon-reviews analogue for the recommendation workload."""
    def build():
        from repro.core.allocation import allocate_corpus
        from repro.core.index import build_index
        from repro.core.lsh import LSHConfig
        from repro.core.pv_dbow import PVDBOWConfig, train_pv_dbow
        from repro.data.corpus import ReviewCorpusConfig, generate_review_corpus
        from repro.data.store import ShardedCorpus

        data = generate_review_corpus(ReviewCorpusConfig(
            n_users=n_users, n_items=n_items, vocab_size=vocab,
            n_topics=topics, seed=seed))
        corpus = ShardedCorpus.from_documents(data.user_docs, vocab,
                                              shard_tokens=2048)
        pcfg = PVDBOWConfig(dim=dim, steps=steps, batch_pairs=4096,
                            lr=0.01, temperature=beta, seed=seed)
        model = train_pv_dbow(corpus, pcfg)
        pre = build_index(corpus, model, LSHConfig(bits=bits),
                          use_lsh=False, temperature=beta)
        corpus_km = allocate_corpus(corpus, pre.doc_vecs)
        index = build_index(corpus_km, model, LSHConfig(bits=bits),
                            temperature=beta)
        return dict(data=data, corpus=corpus_km, model=model, index=index,
                    pv_cfg=pcfg)
    return cached(f"review_{n_users}_{n_items}_{dim}_{steps}_{bits}_{seed}",
                  build)


def pick_query_words(corpus, n, rng, lo=50, hi=1200):
    counts = np.bincount(
        np.concatenate([s.tokens for s in corpus.shards]),
        minlength=corpus.vocab_size)
    cand = np.nonzero((counts > lo) & (counts < hi))[0]
    return rng.choice(cand, min(n, len(cand)), replace=False).astype(int)


def pick_query_phrases(corpus, n, rng, mean_len=2.0, std_len=1.0,
                       min_count=20):
    """Paper Sec. VII-A: random phrases, length ~ N(2, 1) clipped >= 1,
    drawn from actual corpus positions so they exist.

    ``min_count`` filters out near-singleton phrases: at 62 GB corpus
    scale the paper's random 2-word phrases occur thousands of times; at
    our ~13 MB synthetic scale they are often singletons, which turns
    relative error into a coin flip for EVERY sampling method.  The
    filter keeps the estimator regime comparable to the paper's."""
    phrases = []
    shards = corpus.shards
    attempts = 0
    while len(phrases) < n and attempts < n * 30:
        attempts += 1
        k = max(1, int(round(rng.normal(mean_len, std_len))))
        s = shards[rng.integers(len(shards))]
        if s.n_tokens < k + 1:
            continue
        start = rng.integers(0, s.n_tokens - k)
        doc = np.searchsorted(s.offsets, start, side="right") - 1
        if start + k > s.offsets[doc + 1]:
            continue  # don't cross doc boundary
        ph = s.tokens[start:start + k].tolist()
        if min_count and corpus.count_phrase(ph) < min_count:
            continue
        phrases.append(ph)
    return phrases


def csv_row(name, us_per_call, derived):
    print(f"{name},{us_per_call:.1f},{derived}")
