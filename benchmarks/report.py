"""Generate EXPERIMENTS.md sections from dry-run/benchmark artifacts.

    PYTHONPATH=src python -m benchmarks.report [--v1 results/dryrun]
        [--v2 results/dryrun_v2] [--serve BENCH_serve.json]
        [--out EXPERIMENTS.md]

The perf story is v1 (baseline) -> v2 (optimized): both sweeps are kept
so every before/after claim in §Perf is reproducible from artifacts.
``--serve`` additionally renders the serving benchmark (BENCH_serve.json
from benchmarks/serve_bench.py) — the execution-mode throughput table
plus, when present, the ``load_sweep`` (static vs adaptive window
sojourn across arrival rates), ``placement`` (simulated multi-host
topology: residency split, gather parity, relative throughput) and
``balance`` (replica-aware hot-host balancing: primary vs balanced
makespan, estimated vs realized per-host walls, shed counts) and
``chaos`` (the elastic-fleet scenario: scripted kill/join/drain phase
makespans, parity and zero-loss gates, membership audit) records, and
the speedup scalars.  A record kind this report has no renderer
for prints a one-line shape summary instead of vanishing — earlier
report versions silently dropped unknown kinds.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict

from benchmarks.roofline import (
    HBM_BW,
    LINK_BW,
    PEAK_FLOPS,
    analyze_record,
    markdown_table,
)


def load_recs(d: str) -> Dict[str, Dict]:
    out = {}
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        r = json.load(open(p))
        out[f"{r['arch']}|{r['shape']}|{r['mesh']}"] = r
    return out


def mem_gib(rec) -> float:
    m = rec.get("memory", {})
    # donated buffers alias args; live footprint ~ args + temp
    return (m.get("argument_bytes", 0) + m.get("temp_bytes", 0)) / 2 ** 30


def dryrun_section(recs: Dict[str, Dict]) -> str:
    lines = [
        "## §Dry-run",
        "",
        "Single-pod mesh (16,16)=256 chips and multi-pod (2,16,16)=512",
        "chips; every cell is `jit(step).lower(**abstract).compile()` on",
        "512 placeholder host devices — no allocation, shardings fully",
        "validated by the SPMD partitioner.  Per-device live memory =",
        "argument + temp bytes from `compiled.memory_analysis()` (outputs",
        "alias donated inputs).  Budget: 16 GiB (v5e).",
        "",
        "| arch | shape | mesh | status | live GiB | fits | HLO flops/dev (probe) | collective B/dev | compile s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    n_ok = n_skip = n_over = 0
    for key in sorted(recs):
        r = recs[key]
        if r["status"] == "skipped":
            n_skip += 1
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"SKIP (policy) | — | — | — | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR | — | — | — | — | — |")
            continue
        n_ok += 1
        g = mem_gib(r)
        fits = "yes" if g <= 16.0 else "NO"
        if g > 16.0:
            n_over += 1
        probe = r.get("probe", {})
        fl = probe.get("flops_total", r.get("flops", 0))
        cb = probe.get("coll_bytes_total", r.get("collective_bytes_total", 0))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{g:.1f} | {fits} | {fl:.2e} | {cb:.2e} | "
            f"{r.get('compile_s', 0):.0f} |")
    lines += ["",
              f"**{n_ok} compiled ok, {n_skip} policy skips "
              f"(long_500k x full-attention archs, DESIGN.md §6), "
              f"{n_over} over the 16 GiB budget.**", ""]
    return "\n".join(lines)


def roofline_section(recs: Dict[str, Dict]) -> str:
    rows = [analyze_record(r) for r in recs.values()]
    rows = [r for r in rows if r]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    lines = [
        "## §Roofline",
        "",
        f"Terms per device (the compiled SPMD module is the per-device "
        f"program): compute = HLO_FLOPs/{PEAK_FLOPS:.0e}, memory = "
        f"HLO_bytes/{HBM_BW:.0e}, collective = coll_bytes/{LINK_BW:.0e}.",
        "HLO totals come from the two-point depth probe (unrolled 1- and",
        "2-layer compiles) because XLA cost_analysis counts while-loop",
        "bodies once.  Notes: (1) the CPU backend's HLO is less fused",
        "than TPU's, so the memory term is an upper bound; (2)",
        "MODEL_FLOPS = 6·N·D (train) / 2·N_active·D (serve).",
        "",
        markdown_table(rows),
        "",
    ]
    return "\n".join(lines)


def perf_compare_section(v1: Dict[str, Dict], v2: Dict[str, Dict]) -> str:
    lines = [
        "### v1 -> v2 per-cell effect (single-pod)",
        "",
        "| arch | shape | live GiB v1 | v2 | coll B v1 | v2 |",
        "|---|---|---|---|---|---|",
    ]
    for key in sorted(v2):
        if not key.endswith("|single"):
            continue
        r2 = v2[key]
        r1 = v1.get(key)
        if not r1 or r1["status"] != "ok" or r2["status"] != "ok":
            continue
        c1 = r1.get("probe", {}).get("coll_bytes_total",
                                     r1.get("collective_bytes_total", 0))
        c2 = r2.get("probe", {}).get("coll_bytes_total",
                                     r2.get("collective_bytes_total", 0))
        lines.append(f"| {r2['arch']} | {r2['shape']} | {mem_gib(r1):.1f} | "
                     f"**{mem_gib(r2):.1f}** | {c1:.2e} | {c2:.2e} |")
    return "\n".join(lines) + "\n"


def _summarize_record(value) -> str:
    """One-line shape summary for a record kind this report has no
    renderer for — unknown kinds must never vanish silently."""
    if isinstance(value, dict):
        keys = ", ".join(list(value)[:6])
        more = ", …" if len(value) > 6 else ""
        return f"dict with keys {keys}{more}"
    if isinstance(value, (list, tuple)):
        return f"list of {len(value)} entries"
    return repr(value)


def serve_section(serve: Dict) -> str:
    """§Serving from a BENCH_serve.json: execution-mode table +
    load_sweep / placement / balance records + speedup scalars; any
    record kind without a renderer still prints a one-line summary
    (nothing in the JSON is dropped on the floor)."""
    rendered = {"config", "load_sweep", "placement", "balance", "budget",
                "chaos", "cache", "ingest"}
    lines = ["## §Serving", ""]
    cfg = serve.get("config", {})
    if cfg:
        lines += [f"{cfg.get('n_queries', '?')} mixed queries "
                  f"(agg/bool/ranked) at rate {cfg.get('rate', '?')}, "
                  f"batch {cfg.get('batch_size', '?')}, "
                  f"{cfg.get('n_shards', '?')} shards"
                  + (", smoke corpus" if cfg.get("smoke") else ""), ""]
    lines += ["| mode | q/s | p50 ms |", "|---|---|---|"]
    for mode, rec in serve.items():
        if not (isinstance(rec, dict) and "qps" in rec):
            continue
        rendered.add(mode)
        p50 = rec.get("p50_ms", rec.get("p50_sojourn_ms"))
        p50s = f"{p50:.2f}" if p50 is not None else "—"
        note = " (sojourn)" if "p50_sojourn_ms" in rec else ""
        lines.append(f"| {mode} | {rec['qps']:.0f} | {p50s}{note} |")
    lines.append("")
    speedups = [(k, v) for k, v in serve.items()
                if k.startswith("speedup_") and isinstance(v, (int, float))]
    if speedups:
        rendered.update(k for k, _ in speedups)
        lines += ["Speedups: " + ", ".join(
            f"{k[len('speedup_'):].replace('_', ' ')} **{v:.2f}x**"
            for k, v in speedups), ""]

    sweep = serve.get("load_sweep")
    if sweep:
        lines += ["### Load sweep (static vs adaptive vs budget window)",
                  "",
                  "| load | mode | target q/s | served q/s | p50 ms | "
                  "p99 ms | mean batch | shed | degraded | p90 rel err |",
                  "|---|---|---|---|---|---|---|---|---|---|"]
        def _pct(v):
            return f"{v:.0%}" if isinstance(v, (int, float)) else "—"

        def _err(v):
            # NaN (no count queries served) renders as a dash
            return f"{v:.2f}" if isinstance(v, (int, float)) and v == v \
                else "—"

        for row in sweep:
            lines.append(
                f"| {row['load']} | {row['mode']} | "
                f"{row['arrival_qps_target']:.0f} | "
                f"{row['served_qps']:.0f} | "
                f"{row['p50_sojourn_ms']:.2f} | "
                f"{row['p99_sojourn_ms']:.2f} | "
                f"{row['mean_batch']:.1f} | "
                f"{_pct(row.get('shed_frac'))} | "
                f"{_pct(row.get('degraded_frac'))} | "
                f"{_err(row.get('p90_rel_err'))} |")
        lines.append("")

    pl = serve.get("placement")
    if pl:
        parity = pl.get("parity", {})
        lines += [
            f"### Placement ({pl.get('hosts', '?')} hosts, "
            f"{pl.get('policy', '?')}, {pl.get('n_replicas', 0)} replica)",
            "",
            f"- per-host scans {pl.get('scans_per_host')} vs union-plan "
            f"residency split {pl.get('expected_scans_per_host')} — "
            f"match: **{pl.get('residency_match')}**",
            "- cross-host gather parity vs single executor: "
            + ", ".join(f"{k}={v}" for k, v in parity.items()),
            f"- throughput vs single-host: "
            f"**{pl.get('qps_ratio_vs_single_host', float('nan')):.2f}x**",
            "",
        ]

    bal = serve.get("balance")
    if bal:
        audit = bal.get("last_audit") or {}
        est = audit.get("est_cost_s") or []
        walls = audit.get("realized_wall_s") or []
        parity = bal.get("parity", {})
        lines += [
            f"### Replica-aware balance ({bal.get('hosts', '?')} hosts, "
            f"{bal.get('n_replicas', 0)} replica, host "
            f"{bal.get('hot_host', '?')} degraded "
            f"{bal.get('hot_delay_ms_per_shard', 0):.1f} ms/shard)",
            "",
            f"- mean job makespan: primary-only "
            f"{bal.get('primary_mean_makespan_ms', float('nan')):.2f} ms "
            f"-> balanced "
            f"{bal.get('balanced_mean_makespan_ms', float('nan')):.2f} ms "
            f"(**{bal.get('makespan_reduction', float('nan')):.2f}x** "
            f"down; {bal.get('shed_shards', 0)} shard scans shed to "
            f"replicas)",
        ]
        sizes = audit.get("group_sizes") or []
        if est and walls and sizes:
            per_host = ", ".join(
                f"h{h} est {1e3 * (c or 0) * n:.2f}/realized "
                f"{1e3 * w:.2f}"
                for h, (c, w, n) in enumerate(zip(est, walls, sizes)))
            lines.append(
                f"- last job per-host wall ms (est = cost x group vs "
                f"realized): {per_host}; split {sizes} vs "
                f"residency {audit.get('base_group_sizes')}")
        lines += [
            "- gather parity (vs single executor): "
            + ", ".join(f"{k}={v}" for k, v in parity.items())
            + "; vs primary-only split: "
            + ", ".join(f"{k}={v}"
                        for k, v in bal.get("parity_vs_primary",
                                            {}).items()),
            "",
        ]

    bud = serve.get("budget")
    if bud:
        cov = bud.get("coverage", {})
        parity = bud.get("parity", {})
        lines += [
            f"### Error-budgeted serving ({bud.get('hosts', '?')} hosts, "
            f"host {bud.get('hot_host', '?')} degraded "
            f"{bud.get('hot_delay_ms_per_shard', 0):.1f} ms/shard, "
            f"capacity {bud.get('capacity_qps', float('nan')):.0f} q/s)",
            "",
            "- planner parity (budget-free queries, planner engine vs "
            "plain): " + "; ".join(
                f"{lbl}: " + ", ".join(f"{k}={v}" for k, v in p.items())
                for lbl, p in parity.items()),
        ]
        for lbl, c in cov.items():
            lines.append(
                f"- {lbl} pass: count 95% CI coverage "
                f"**{c.get('ci_coverage', float('nan')):.0%}** over "
                f"{c.get('n_count_queries', '?')} queries, p90 realized "
                f"rel err {c.get('p90_rel_err', float('nan')):.2f}")
        for lbl in ("planned", "degraded"):
            a = bud.get(f"{lbl}_audit") or {}
            if a:
                lines.append(
                    f"- {lbl} audit: pressure {a.get('pressure', 0):.2f}, "
                    f"{a.get('degraded', 0)}/{a.get('budgeted', 0)} "
                    f"queries degraded, {a.get('at_floor', 0)} at floor")
        ov = bud.get("overload", {})
        if ov:
            lines += ["", "| overload arm | offered q/s | served q/s | "
                      "shed | degraded | mean batch | p99 ms | "
                      "CI coverage |",
                      "|---|---|---|---|---|---|---|---|"]
            for mode, arm in ov.items():
                covs = arm.get("ci_coverage")
                lines.append(
                    f"| {mode} | {arm['offered_qps']:.0f} | "
                    f"{arm['served_qps']:.0f} | "
                    f"{arm['shed']}/{arm['shed'] + arm['served']} | "
                    f"{arm['degraded_frac']:.0%} | "
                    f"{arm['mean_batch']:.1f} | "
                    f"{arm['p99_sojourn_ms']:.0f} | "
                    + (f"{covs:.0%} |" if isinstance(covs, (int, float))
                       and covs == covs else "— |"))
        lines.append("")

    ch = serve.get("chaos")
    if ch:
        parity = ch.get("parity", {})
        fleet = ch.get("fleet", {})
        fired = (ch.get("faults") or {}).get("fired", {})
        lines += [
            f"### Elastic-fleet chaos ({ch.get('hosts', '?')} hosts, "
            f"{ch.get('n_replicas', 0)} replica, every host slowed "
            f"{ch.get('slow_ms_per_shard', 0):.1f} ms/shard)",
            "",
            "Scripted kill -> serve-degraded -> join -> recover -> "
            "drain scenario (seeded FaultPlan through FleetManager; "
            "every gate below is a hard failure in CI):",
            "",
            "| phase | makespan ms |", "|---|---|"]
        for phase, ms in (ch.get("phase_makespan_ms") or {}).items():
            lines.append(f"| {phase} | {ms:.1f} |")
        lines += [
            "",
            f"- lost queries **{ch.get('lost_queries', '?')}**, lost "
            f"shards **{ch.get('lost_shards', '?')}** (floor: zero — "
            f"one replica survives every scripted failure)",
            "- gather parity vs single executor, all phases (kill "
            "batch included): "
            + ", ".join(f"{k}={v}" for k, v in parity.items()),
            f"- kill landed: degraded makespan "
            f"**{ch.get('degradation_ratio', float('nan')):.2f}x** "
            f"healthy (floor 1.3x); post-join recovery "
            f"**{ch.get('recovery_ratio', float('nan')):.2f}x** healthy "
            f"(ceiling 1.25x)",
            f"- joiner warmed **{ch.get('warmed_shards', '?')}** shards "
            f"before residency; drain moved "
            f"{(ch.get('drain') or {}).get('moved_shards', '?')} shards, "
            f"orphaned "
            f"{(ch.get('drain') or {}).get('orphaned_shards', '?')}",
            f"- membership: {fleet.get('joins', 0)} join / "
            f"{fleet.get('drains', 0)} drain / "
            f"{fleet.get('crashes', 0)} crash, "
            f"{fleet.get('placement_epoch', 0)} placement generations, "
            f"live hosts {fleet.get('live_hosts')}",
            "- faults fired (scenario): "
            + ", ".join(f"{k}={v}" for k, v in fired.items()),
            "",
        ]

    ca = serve.get("cache")
    if ca:
        z = ca.get("zipf") or {}
        zstats = z.get("stats") or {}
        sh = ca.get("single_host") or {}
        fl = ca.get("fleet") or {}
        cold = sh.get("cold_parity", {})
        warm = sh.get("warm_parity", {})
        lines += [
            "### Semantic query cache (LSH-signature keyed)",
            "",
            f"Zipf stream (skew {z.get('skew', '?')}): "
            f"{z.get('stream', '?')} queries over a "
            f"{z.get('pool', '?')}-query pool — cached p50 "
            f"**{z.get('cached_p50_ms', float('nan')):.3f} ms** vs "
            f"uncached {z.get('uncached_p50_ms', float('nan')):.3f} ms "
            f"(**{z.get('p50_collapse', float('nan')):.1f}x** collapse; "
            f"gate: cached must be strictly below), "
            f"{zstats.get('hits', '?')} hits / "
            f"{zstats.get('near_hits', '?')} near / "
            f"{zstats.get('misses', '?')} misses",
            "",
            "- exact-hit parity (radius 0): cold pass bit-for-bit the "
            "uncached engine "
            + ", ".join(f"{k}={v}" for k, v in cold.items())
            + "; warm pass all "
            f"{(sh.get('stats') or {}).get('hits', '?')} hits "
            "bit-for-bit the cold results "
            + ", ".join(f"{k}={v}" for k, v in warm.items()),
            f"- generation fencing ({fl.get('hosts', '?')} hosts): "
            f"join dropped "
            f"{(fl.get('join') or {}).get('stale_dropped', '?')} stale "
            f"entries, drain dropped "
            f"{(fl.get('drain') or {}).get('stale_dropped', '?')} — "
            f"zero cache hits crossed either swap (hard gate)",
            "",
        ]

    ing = serve.get("ingest")
    if ing:
        sw = ing.get("swap") or {}
        la = ing.get("latency") or {}
        cf = ing.get("cache_fence") or {}
        fr = sw.get("freshness") or {}
        gen = sw.get("generation") or {}
        tr = ing.get("timed_row") or {}
        lines += [
            "### Live ingest (append -> generation -> fence)",
            "",
            f"Mid-run append of **{sw.get('n_new', '?')}** sentinel "
            f"docs ({100 * ing.get('fraction', 0):.0f}% of the corpus) "
            f"racing the serving loop — "
            f"{sw.get('served_during_swap', '?')} batches served "
            f"during the swap ({sw.get('old_generation_batches', '?')} "
            f"old-generation, {sw.get('new_generation_batches', '?')} "
            f"new), every one bit-for-bit one of the two reference "
            f"worlds (hard gate: no torn reads, zero loss)",
            "",
            f"- freshness: sentinel-phrase count "
            f"{fr.get('before', '?'):.0f} -> "
            f"**{fr.get('after', '?'):.0f}** at error bound 0 after "
            f"the swap; generation "
            f"(placement={gen.get('placement', '?')}, "
            f"content={gen.get('content', '?')})",
            f"- zero pause: serving p99 with the paced writer racing "
            f"**{la.get('ingest_p99_ms', float('nan')):.3f} ms** vs "
            f"{la.get('no_ingest_p99_ms', float('nan')):.3f} ms "
            f"no-ingest — **{la.get('ratio', float('nan')):.2f}x** "
            f"(hard gate: <= {la.get('bound', '?')}x, "
            f"{la.get('passes', '?')} pool passes per trial)",
            f"- content-axis cache fence: "
            f"{cf.get('stale_dropped', '?')}/{cf.get('pool', '?')} "
            f"warm entries dropped as stale across the step, zero "
            f"stale hits, post-ingest re-serve bit-for-bit a plain "
            f"engine on the appended world (hard gate)",
            f"- timed arm: {tr.get('steps', '?')} steps, "
            f"{tr.get('docs_appended', '?')} docs appended, "
            f"{tr.get('swaps', '?')} swaps, "
            f"{tr.get('shards_added', '?')} shards added",
            "",
        ]

    mg = serve.get("megascan")
    if mg:
        from benchmarks.roofline import analyze_megascan, megascan_table
        rendered.add("megascan")
        meas = mg.get("measured") or {}
        ds = mg.get("dispatch_share") or {}
        launches = mg.get("launches") or {}
        jobs = mg.get("host_megascan_jobs")
        lines += [
            "### One-launch scan-over-shards megakernel",
            "",
            f"{mg.get('queries', '?')} full-fleet similarity scans over "
            f"{mg.get('shards', '?')} shards: "
            f"**{launches.get('mega', '?')}** launch vs "
            f"{launches.get('per_shard', '?')} per-shard launches — "
            f"measured **{meas.get('win', float('nan')):.2f}x** faster "
            f"({meas.get('mega_s', float('nan')):.4f}s vs "
            f"{meas.get('per_shard_s', float('nan')):.4f}s, hard gate: "
            f"one-launch must win), dispatch share "
            f"{ds.get('per_shard', float('nan')):.2f} -> "
            f"**{ds.get('mega', float('nan')):.2f}** (hard gate: must "
            f"drop)",
            "",
            "- group-vs-per-shard gather parity on ragged plans "
            "(bit-for-bit, hard gate): "
            + ", ".join(f"{k}={v}"
                        for k, v in (mg.get("parity") or {}).items())
            + (f"; host-group parity={mg['host_group_parity']}"
               if "host_group_parity" in mg else "")
            + (f", per-host launches {jobs}" if jobs else ""),
            "",
            megascan_table([analyze_megascan(r) for r in
                            mg.get("roofline_records", [])]),
            "",
        ]

    unknown = [k for k in serve if k not in rendered]
    for k in unknown:
        lines.append(f"- unrecognized record `{k}`: "
                     f"{_summarize_record(serve[k])}")
    if unknown:
        lines.append("")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--v1", default="results/dryrun")
    ap.add_argument("--v2", default=None,
                    help="optimized sweep dir (default: latest)")
    ap.add_argument("--serve", default="BENCH_serve.json",
                    help="serving bench JSON (skipped when absent)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    from benchmarks.roofline import default_dir
    v2_dir = args.v2 or default_dir()
    v1 = load_recs(args.v1)
    v2 = load_recs(v2_dir) if os.path.isdir(v2_dir) else v1
    text = dryrun_section(v2) + "\n" + roofline_section(v2) + "\n" + \
        perf_compare_section(v1, v2)
    if args.serve and os.path.exists(args.serve):
        text += "\n" + serve_section(json.load(open(args.serve)))
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    else:
        print(text)


if __name__ == "__main__":
    main()
