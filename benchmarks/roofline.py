"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e per assignment):
    peak 197 TFLOP/s bf16/chip, 819 GB/s HBM/chip, ~50 GB/s/link ICI.

All dry-run quantities are per-device (the compiled SPMD module is the
per-device program; probe totals reconstruct while-loop trip counts), so

    compute term    = flops_dev / 197e12
    memory term     = bytes_dev / 819e9
    collective term = coll_bytes_dev / 50e9

MODEL_FLOPS uses 6*N*D for training (N = params, dense; N_active for
MoE) and 2*N*D for single-token decode / prefill forward passes.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,          # one new token per sequence
    "long_500k": 1,
}


def model_flops(rec: Dict) -> float:
    n = rec.get("active_params_estimate") or rec.get("params_estimate")
    tokens = SHAPE_TOKENS[rec["shape"]]
    mult = 6.0 if rec["shape"].startswith("train") else 2.0
    return mult * n * tokens


def analyze_record(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    probe = rec.get("probe")
    if probe:
        flops_dev = probe["flops_total"]
        bytes_dev = probe["bytes_total"]
        coll_dev = probe["coll_bytes_total"]
    else:
        # multi-pod records have no probe; raw values undercount loops
        flops_dev = rec["flops"]
        bytes_dev = rec["bytes_accessed"]
        coll_dev = rec["collective_bytes_total"]
    n_dev = rec["n_devices"]
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec) / n_dev          # per-device useful flops
    useful_ratio = mf / flops_dev if flops_dev else 0.0
    # roofline fraction: useful work at peak vs modeled step time
    step_time = max(terms.values())
    roofline_frac = (mf / PEAK_FLOPS) / step_time if step_time else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": t_compute, "memory_s": t_memory,
        "collective_s": t_coll, "dominant": dominant,
        "model_flops_dev": mf, "hlo_flops_dev": flops_dev,
        "useful_ratio": useful_ratio, "roofline_frac": roofline_frac,
        "probe": bool(probe),
    }


def suggestion(row: Dict) -> str:
    d = row["dominant"]
    if d == "compute":
        if row["useful_ratio"] < 0.5:
            return ("compute-bound with low useful ratio: cut remat "
                    "recompute / padding waste (head-count or vocab "
                    "padding) before anything else")
        return "compute-bound and efficient: only larger chips help"
    if d == "memory":
        return ("memory-bound: fuse/batch HBM traffic — bigger decode "
                "batch per chip, bf16/int8 KV cache, flash attention")
    return ("collective-bound: overlap grad all-reduce with backprop, "
            "compress cross-pod gradients, or widen TP within pod")


def default_dir() -> str:
    """Latest sweep wins: v3 (optimized round 2) > v2 > v1 baseline."""
    for d in ("results/dryrun_v3", "results/dryrun_v2", "results/dryrun"):
        if os.path.isdir(d) and glob.glob(os.path.join(d, "*.json")):
            return d
    return "results/dryrun"


def load_rows(out_dir: Optional[str] = None) -> List[Dict]:
    out_dir = out_dir or default_dir()
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        row = analyze_record(rec)
        if row:
            rows.append(row)
    return rows


def run(out_dir: Optional[str] = None, verbose: bool = True):
    out_dir = out_dir or default_dir()
    rows = load_rows(out_dir)
    if verbose:
        for r in rows:
            if r["mesh"] != "single":
                continue
            print(f"roofline_{r['arch']}_{r['shape']},0.0,"
                  f"compute_s={r['compute_s']:.3e};"
                  f"memory_s={r['memory_s']:.3e};"
                  f"collective_s={r['collective_s']:.3e};"
                  f"dominant={r['dominant']};"
                  f"useful_ratio={r['useful_ratio']:.2f};"
                  f"roofline_frac={r['roofline_frac']:.2f}")
    return rows


def markdown_table(rows: List[Dict]) -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | MODEL/HLO | roofline frac | next lever |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != "single":
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.2f} | {suggestion(r)} |")
    return "\n".join(lines)


if __name__ == "__main__":
    run()
