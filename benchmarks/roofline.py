"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e per assignment):
    peak 197 TFLOP/s bf16/chip, 819 GB/s HBM/chip, ~50 GB/s/link ICI.

All dry-run quantities are per-device (the compiled SPMD module is the
per-device program; probe totals reconstruct while-loop trip counts), so

    compute term    = flops_dev / 197e12
    memory term     = bytes_dev / 819e9
    collective term = coll_bytes_dev / 50e9

MODEL_FLOPS uses 6*N*D for training (N = params, dense; N_active for
MoE) and 2*N*D for single-token decode / prefill forward passes.

Megascan records (``kind: "megascan"``, emitted by the serving bench's
one-launch scan arm) get their own model: the question there is not
FLOP efficiency but *dispatch share* — what fraction of the scan path
is per-launch overhead vs streaming/compute.  A per-shard launch
sequence pays ``launches * DISPATCH_S``; the megascan pays it once and
overlaps the HBM->VMEM block copies with MXU scoring (double-buffered
prefetch), so its modeled time is ``DISPATCH_S + max(memory, compute)``
and the dispatch-bound -> bandwidth-bound claim is the rendered
``dominant`` column flipping, not prose.
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
# Per-launch dispatch overhead model: host-side Pallas/XLA launch plus
# the HBM<->VMEM turnaround a fresh kernel pays before its pipeline
# fills.  ~8 us is the conventional small-kernel launch cost on current
# TPU runtimes; the absolute value only scales the dispatch column —
# the per-shard vs megascan *comparison* divides it out.
DISPATCH_S = 8e-6

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,          # one new token per sequence
    "long_500k": 1,
}


def model_flops(rec: Dict) -> float:
    n = rec.get("active_params_estimate") or rec.get("params_estimate")
    tokens = SHAPE_TOKENS[rec["shape"]]
    mult = 6.0 if rec["shape"].startswith("train") else 2.0
    return mult * n * tokens


def analyze_megascan(rec: Dict) -> Dict:
    """Roofline row for a megascan record (see kernels/megascan): the
    three terms are dispatch (launches * DISPATCH_S), memory (payload
    bytes streamed through VMEM once per launch set) and compute
    (scoring + one-hot reduction flops).  With double-buffered prefetch
    memory and compute overlap, so the modeled wall is
    ``dispatch + max(memory, compute)`` and ``overlap_ratio`` says how
    much of the smaller stream the prefetch hides."""
    launches = int(rec.get("launches", 1))
    t_dispatch = launches * DISPATCH_S
    t_memory = float(rec.get("bytes_streamed", 0)) / HBM_BW
    t_compute = float(rec.get("flops", 0)) / PEAK_FLOPS
    terms = {"dispatch": t_dispatch, "memory": t_memory,
             "compute": t_compute}
    dominant = max(terms, key=terms.get)
    stream = max(t_memory, t_compute)
    overlap = (min(t_memory, t_compute) / stream) if stream else 0.0
    modeled = t_dispatch + stream
    dispatch_share = t_dispatch / modeled if modeled else 0.0
    return {
        "kind": "megascan",
        "name": rec.get("name", f"megascan_x{launches}"),
        "launches": launches,
        "shards": int(rec.get("shards", 0)),
        "shards_per_launch": (rec.get("shards", 0) / launches
                              if launches else 0.0),
        "dispatch_s": t_dispatch, "memory_s": t_memory,
        "compute_s": t_compute, "dominant": dominant,
        "overlap_ratio": overlap, "modeled_s": modeled,
        "dispatch_share": dispatch_share,
        "bytes_streamed": int(rec.get("bytes_streamed", 0)),
        "measured_wall_s": rec.get("measured_wall_s"),
        "double_buffer": bool(rec.get("double_buffer", False)),
    }


def analyze_record(rec: Dict) -> Optional[Dict]:
    if rec.get("kind") == "megascan":
        return analyze_megascan(rec)
    if rec.get("status") != "ok":
        return None
    probe = rec.get("probe")
    if probe:
        flops_dev = probe["flops_total"]
        bytes_dev = probe["bytes_total"]
        coll_dev = probe["coll_bytes_total"]
    else:
        # multi-pod records have no probe; raw values undercount loops
        flops_dev = rec["flops"]
        bytes_dev = rec["bytes_accessed"]
        coll_dev = rec["collective_bytes_total"]
    n_dev = rec["n_devices"]
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec) / n_dev          # per-device useful flops
    useful_ratio = mf / flops_dev if flops_dev else 0.0
    # roofline fraction: useful work at peak vs modeled step time
    step_time = max(terms.values())
    roofline_frac = (mf / PEAK_FLOPS) / step_time if step_time else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": t_compute, "memory_s": t_memory,
        "collective_s": t_coll, "dominant": dominant,
        "model_flops_dev": mf, "hlo_flops_dev": flops_dev,
        "useful_ratio": useful_ratio, "roofline_frac": roofline_frac,
        "probe": bool(probe),
    }


def suggestion(row: Dict) -> str:
    if row.get("kind") == "megascan":
        d = row["dominant"]
        if d == "dispatch":
            return ("dispatch-bound: fuse more shards per launch "
                    "(megakernel route) — per-launch overhead dwarfs "
                    "the streamed payload")
        if d == "memory":
            if row["overlap_ratio"] < 0.5:
                return ("bandwidth-bound with idle MXU: raise bits or "
                        "batch more queries per launch to fill the "
                        "prefetch window")
            return ("bandwidth-bound and overlapped: the scan streams "
                    "at HBM speed — only narrower signatures (fewer "
                    "bits) or more chips help")
        return ("compute-bound: the one-hot reduction dominates — "
                "shrink the lane-padded slot axis or lower scoring "
                "precision")
    d = row["dominant"]
    if d == "compute":
        if row["useful_ratio"] < 0.5:
            return ("compute-bound with low useful ratio: cut remat "
                    "recompute / padding waste (head-count or vocab "
                    "padding) before anything else")
        return "compute-bound and efficient: only larger chips help"
    if d == "memory":
        return ("memory-bound: fuse/batch HBM traffic — bigger decode "
                "batch per chip, bf16/int8 KV cache, flash attention")
    return ("collective-bound: overlap grad all-reduce with backprop, "
            "compress cross-pod gradients, or widen TP within pod")


def default_dir() -> str:
    """Latest sweep wins: v3 (optimized round 2) > v2 > v1 baseline."""
    for d in ("results/dryrun_v3", "results/dryrun_v2", "results/dryrun"):
        if os.path.isdir(d) and glob.glob(os.path.join(d, "*.json")):
            return d
    return "results/dryrun"


def load_rows(out_dir: Optional[str] = None) -> List[Dict]:
    """Analyzed rows for every readable record in ``out_dir``.  A
    malformed / truncated / schema-incomplete JSON file (a dry-run
    killed mid-write, a partial artifact download) is *skipped with a
    warning* instead of failing the whole report — one bad record must
    not take down the table the good ones render."""
    out_dir = out_dir or default_dir()
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        try:
            with open(path) as f:
                rec = json.load(f)
            row = analyze_record(rec)
        except (json.JSONDecodeError, KeyError, TypeError,
                ValueError, OSError) as exc:
            print(f"roofline: skipping {path}: "
                  f"{type(exc).__name__}: {exc}", file=sys.stderr)
            continue
        if row:
            rows.append(row)
    return rows


def run(out_dir: Optional[str] = None, verbose: bool = True):
    out_dir = out_dir or default_dir()
    rows = load_rows(out_dir)
    if verbose:
        for r in rows:
            if r.get("kind") == "megascan" or r["mesh"] != "single":
                continue
            print(f"roofline_{r['arch']}_{r['shape']},0.0,"
                  f"compute_s={r['compute_s']:.3e};"
                  f"memory_s={r['memory_s']:.3e};"
                  f"collective_s={r['collective_s']:.3e};"
                  f"dominant={r['dominant']};"
                  f"useful_ratio={r['useful_ratio']:.2f};"
                  f"roofline_frac={r['roofline_frac']:.2f}")
    return rows


def markdown_table(rows: List[Dict]) -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | MODEL/HLO | roofline frac | next lever |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("kind") == "megascan" or r["mesh"] != "single":
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.2f} | {suggestion(r)} |")
    return "\n".join(lines)


def megascan_table(rows: List[Dict]) -> str:
    """The scan-path roofline: one row per megascan record, the
    dispatch-share column carrying the dispatch-bound vs
    bandwidth-bound claim."""
    lines = ["| scan | launches | shards/launch | dispatch s | "
             "memory s | compute s | dominant | dispatch share | "
             "overlap | measured s | next lever |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("kind") != "megascan":
            continue
        meas = (f"{r['measured_wall_s']:.2e}"
                if r.get("measured_wall_s") is not None else "-")
        lines.append(
            f"| {r['name']} | {r['launches']} | "
            f"{r['shards_per_launch']:.1f} | {r['dispatch_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['compute_s']:.2e} | "
            f"{r['dominant']} | {r['dispatch_share']:.2f} | "
            f"{r['overlap_ratio']:.2f} | {meas} | {suggestion(r)} |")
    return "\n".join(lines)


def serve_megascan_rows(serve_json: str) -> List[Dict]:
    """Analyzed megascan rows from a serve-bench report JSON (the
    ``megascan`` record's ``roofline_records`` list)."""
    with open(serve_json) as f:
        report = json.load(f)
    recs = (report.get("megascan") or {}).get("roofline_records", [])
    return [analyze_megascan(r) for r in recs]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dir", default=None,
                    help="dry-run artifact dir (default: latest sweep)")
    ap.add_argument("--serve", default=None,
                    help="serve-bench JSON: render its megascan records"
                         " instead of the dry-run artifacts")
    ap.add_argument("--out", default=None,
                    help="write the markdown table(s) to this path")
    args = ap.parse_args()
    if args.serve:
        rows = serve_megascan_rows(args.serve)
        table = megascan_table(rows)
    else:
        rows = run(args.dir)
        table = markdown_table(rows)
        mega = megascan_table(rows)
        if mega.count("\n") > 1:
            table = table + "\n\n" + mega
    if args.out:
        with open(args.out, "w") as f:
            f.write(table + "\n")
    print(table)
