"""Paper Fig. 5 + Fig. 6: distributed information retrieval.

Fig 5: Boolean-retrieval recall CDFs on two corpora (Wikipedia/CCNews
analogues).  Fig 6: speedups + mean recall at 25/50/75% and ranked
P@10 vs SRCS.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row, pick_query_words, text_setup


def _boolean_queries(corpus, n, rng):
    from repro.core.queries.retrieval import parse_boolean
    out = []
    for _ in range(n):
        k = max(2, int(round(rng.normal(3, 1))))
        words = pick_query_words(corpus, k, rng)
        tokens = [int(words[0])]
        for w in words[1:]:
            tokens.append("and" if rng.random() < 0.5 else "or")
            tokens.append(int(w))
        out.append(parse_boolean(tokens))
    return out


def run(n_queries=30, rates=(0.25, 0.50, 0.75), verbose=True):
    from repro.core.queries.retrieval import (
        boolean_query, precision_at_k, ranked_query, recall)

    for tag, seed in (("wiki", 0), ("ccnews", 7)):
        setup = text_setup(tag=tag, seed=seed)
        corpus, index = setup["corpus"], setup["index"]
        rng = np.random.default_rng(13 + seed)
        queries = _boolean_queries(corpus, n_queries, rng)

        full = {}
        t0 = time.perf_counter()
        for i, q in enumerate(queries):
            full[i] = boolean_query(corpus, index, q, 1.0).doc_ids
        precise_s = (time.perf_counter() - t0) / max(len(queries), 1)

        for rate in rates:
            for method in ("emapprox", "srcs"):
                recs, ts = [], []
                for i, q in enumerate(queries):
                    r = boolean_query(corpus, index, q, rate,
                                      method=method, rng=rng)
                    recs.append(recall(r.doc_ids, full[i]))
                    ts.append(r.elapsed_s)
                us = np.mean(ts) * 1e6
                p25, p50 = np.percentile(recs, [25, 50])
                csv_row(f"fig5_boolean_{tag}_{method}_rate{rate}", us,
                        f"recall_mean={np.mean(recs):.3f};"
                        f"recall_p25={p25:.3f};recall_p50={p50:.3f};"
                        f"speedup={precise_s/max(np.mean(ts),1e-9):.2f}x")

    # ranked retrieval (paper reports Wikipedia only)
    setup = text_setup(tag="wiki")
    corpus, index = setup["corpus"], setup["index"]
    rng = np.random.default_rng(29)
    from repro.core.queries.retrieval import precision_at_k, ranked_query
    word_sets = [pick_query_words(corpus, max(1, int(round(rng.normal(3, 1)))),
                                  rng).tolist() for _ in range(n_queries)]
    full = {i: ranked_query(corpus, index, ws, 1.0, k=10).doc_ids
            for i, ws in enumerate(word_sets)}
    for rate in rates:
        for method in ("emapprox", "srcs"):
            precs, ts = [], []
            for i, ws in enumerate(word_sets):
                r = ranked_query(corpus, index, ws, rate, k=10,
                                 method=method, rng=rng)
                precs.append(precision_at_k(r.doc_ids, full[i], 10))
                ts.append(r.elapsed_s)
            csv_row(f"fig6c_ranked_{method}_rate{rate}",
                    np.mean(ts) * 1e6,
                    f"p_at_10={np.mean(precs):.3f}")


if __name__ == "__main__":
    run()
