"""Recommendation example (paper Sec. IV-C): approximate user-centric CF
over an Amazon-reviews analogue, comparing sampling rates.

    PYTHONPATH=src python examples/recommend_user.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    from repro.core.allocation import allocate_corpus
    from repro.core.index import build_index
    from repro.core.lsh import LSHConfig
    from repro.core.pv_dbow import PVDBOWConfig, train_pv_dbow
    from repro.core.queries.recommend import mse, precision_at_k, recommend_query
    from repro.data.corpus import ReviewCorpusConfig, generate_review_corpus
    from repro.data.store import ShardedCorpus

    print("== generating review corpus (users x items x ratings) ==")
    data = generate_review_corpus(ReviewCorpusConfig(
        n_users=300, n_items=150, vocab_size=2048, n_topics=10))
    corpus = ShardedCorpus.from_documents(data.user_docs, 2048,
                                          shard_tokens=2048)
    print(f"   {len(data.ratings):,} ratings from "
          f"{data.user_topics.shape[0]} users over "
          f"{data.item_topics.shape[0]} items; {corpus.n_shards} shards")

    print("== training user vectors (PV-DBOW over review text) ==")
    pcfg = PVDBOWConfig(dim=32, steps=800, batch_pairs=4096, lr=0.01)
    model = train_pv_dbow(corpus, pcfg)
    pre = build_index(corpus, model, LSHConfig(bits=128), use_lsh=False,
                      temperature=pcfg.temperature)
    corpus = allocate_corpus(corpus, pre.doc_vecs)
    index = build_index(corpus, model, LSHConfig(bits=256),
                        temperature=pcfg.temperature)

    rng = np.random.default_rng(0)
    users = rng.choice(data.user_topics.shape[0], 20, replace=False)
    print("== predicting held-out ratings ==")
    for rate in (0.1, 0.25, 1.0):
        mses, precs = [], []
        for u in users:
            m = data.user_of == u
            items, ratings = data.item_of[m], data.ratings[m]
            k = max(1, len(items) // 5)
            sel = rng.choice(len(items), k, replace=False)
            res = recommend_query(corpus, index, data, int(u), rate,
                                  k=10, rng=rng)
            mses.append(mse(res.predictions, items[sel], ratings[sel]))
            precs.append(precision_at_k(res.top_k, items, 10))
        label = "precise" if rate == 1.0 else f"rate {rate:.2f}"
        print(f"   {label:10s}: MSE {np.nanmean(mses):.3f}  "
              f"P@10 {np.mean(precs):.3f}")


if __name__ == "__main__":
    main()
