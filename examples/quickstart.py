"""Quickstart: build an EmApprox index and run one query of each type.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.allocation import allocate_corpus
from repro.core.index import build_index
from repro.core.lsh import LSHConfig
from repro.core.pv_dbow import PVDBOWConfig, train_pv_dbow
from repro.core.queries.aggregation import phrase_count_query, precise_phrase_count
from repro.core.queries.retrieval import parse_boolean, boolean_query, ranked_query, recall
from repro.data.corpus import SyntheticCorpusConfig, generate_text_corpus
from repro.data.store import ShardedCorpus


def main():
    # 1. a corpus, partitioned into shards (the HDFS-block analogue)
    print("== generating corpus ==")
    ccfg = SyntheticCorpusConfig(n_docs=1500, vocab_size=2048, n_topics=12)
    docs, _ = generate_text_corpus(ccfg)
    corpus = ShardedCorpus.from_documents(docs, ccfg.vocab_size,
                                          shard_tokens=4096)
    print(f"   {corpus.n_docs} docs, {corpus.n_tokens:,} tokens, "
          f"{corpus.n_shards} shards")

    # 2. offline: learn PV-DBOW vectors, cluster, build the LSH index
    print("== training PV-DBOW index (offline, paper Fig 2 p1-p2) ==")
    pcfg = PVDBOWConfig(dim=32, steps=800, batch_pairs=4096, lr=0.01)
    model = train_pv_dbow(corpus, pcfg)
    pre = build_index(corpus, model, LSHConfig(bits=128), use_lsh=False,
                      temperature=pcfg.temperature)
    corpus = allocate_corpus(corpus, pre.doc_vecs)   # spherical k-means
    index = build_index(corpus, model, LSHConfig(bits=256),
                        temperature=pcfg.temperature)
    print(f"   index: {index.nbytes()/1024:.0f} KiB for "
          f"{corpus.n_tokens*4/1024:.0f} KiB of tokens")

    rng = np.random.default_rng(0)
    counts = np.bincount(np.concatenate([s.tokens for s in corpus.shards]),
                         minlength=ccfg.vocab_size)
    w1, w2 = np.argsort(-counts)[[60, 90]]

    # 3a. aggregation query with error bounds (paper Eq 1-2)
    print("== aggregation: phrase count at 10% sampling ==")
    res = phrase_count_query(corpus, index, [int(w1)], rate=0.10, rng=rng)
    true = precise_phrase_count(corpus, [int(w1)])
    print(f"   estimate {res.estimate.value:,.0f} ± {res.estimate.error_bound:,.0f} "
          f"(95% CI), true {true:,}, read {res.shards_read}/{res.n_shards} shards")

    # 3b. Boolean retrieval
    print("== boolean retrieval at 50% sampling ==")
    expr = parse_boolean([int(w1), "or", int(w2)])
    full = boolean_query(corpus, index, expr, 1.0)
    approx = boolean_query(corpus, index, expr, 0.5, rng=rng)
    print(f"   {len(approx.doc_ids)}/{len(full.doc_ids)} docs retrieved "
          f"(recall {recall(approx.doc_ids, full.doc_ids):.2f})")

    # 3c. ranked retrieval (BM25 over the sample)
    print("== ranked retrieval (BM25) at 50% sampling ==")
    fullr = ranked_query(corpus, index, [int(w1), int(w2)], 1.0, k=5)
    appr = ranked_query(corpus, index, [int(w1), int(w2)], 0.5, k=5, rng=rng)
    overlap = len(set(appr.doc_ids) & set(fullr.doc_ids))
    print(f"   top-5 overlap with precise execution: {overlap}/5")


if __name__ == "__main__":
    main()
