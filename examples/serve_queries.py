"""End-to-end driver (the paper's kind: approximate query serving).

Builds the offline index once, then serves a batched stream of mixed
queries — aggregation, Boolean, ranked, recommendation — through the
fault-tolerant shard executor, with injected worker faults and a
straggler, reporting per-class latency and accuracy.

    PYTHONPATH=src python examples/serve_queries.py [--queries 40]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=40)
    ap.add_argument("--rate", type=float, default=0.15)
    ap.add_argument("--workers", type=int, default=4)
    args = ap.parse_args()

    from repro.core.allocation import allocate_corpus
    from repro.core.index import build_index
    from repro.core.lsh import LSHConfig
    from repro.core.pv_dbow import PVDBOWConfig, train_pv_dbow
    from repro.core.queries.aggregation import (phrase_count_query,
                                                precise_phrase_count)
    from repro.core.queries.retrieval import (boolean_query, parse_boolean,
                                              ranked_query, recall,
                                              precision_at_k)
    from repro.data.corpus import SyntheticCorpusConfig, generate_text_corpus
    from repro.data.store import ShardedCorpus
    from repro.runtime.executor import ShardTaskExecutor

    print("== offline index build ==")
    ccfg = SyntheticCorpusConfig(n_docs=2400, vocab_size=4096, n_topics=16)
    docs, _ = generate_text_corpus(ccfg)
    corpus = ShardedCorpus.from_documents(docs, ccfg.vocab_size,
                                          shard_tokens=4096)
    pcfg = PVDBOWConfig(dim=48, steps=1200, batch_pairs=4096, lr=0.01)
    model = train_pv_dbow(corpus, pcfg)
    pre = build_index(corpus, model, LSHConfig(bits=128), use_lsh=False,
                      temperature=pcfg.temperature)
    corpus = allocate_corpus(corpus, pre.doc_vecs)
    index = build_index(corpus, model, LSHConfig(bits=256),
                        temperature=pcfg.temperature)
    print(f"   {corpus.n_shards} shards; index {index.nbytes()/1024:.0f} KiB")

    # fault injection: shard 3 fails once per attempt-1; executor retries
    faults = {"injected": 0}

    def fault_hook(sid, attempt):
        if sid == 3 and attempt == 1:
            faults["injected"] += 1
            raise RuntimeError("injected transient fault")

    executor = ShardTaskExecutor(workers=args.workers, max_retries=2,
                                 fault_hook=fault_hook)

    rng = np.random.default_rng(0)
    counts = np.bincount(np.concatenate([s.tokens for s in corpus.shards]),
                         minlength=ccfg.vocab_size)
    cand = np.nonzero((counts > 50) & (counts < 1200))[0]

    print(f"== serving {args.queries} mixed queries at rate {args.rate} ==")
    lat = {"agg": [], "bool": [], "ranked": []}
    acc = {"agg": [], "bool": [], "ranked": []}
    for i in range(args.queries):
        kind = ("agg", "bool", "ranked")[i % 3]
        words = rng.choice(cand, 3, replace=False).astype(int)
        t0 = time.perf_counter()
        if kind == "agg":
            r = phrase_count_query(corpus, index, [int(words[0])],
                                   args.rate, rng=rng, executor=executor)
            true = precise_phrase_count(corpus, [int(words[0])])
            if true:
                acc["agg"].append(abs(r.estimate.value - true) / true)
        elif kind == "bool":
            expr = parse_boolean([int(words[0]), "or",
                                  int(words[1]), "and", int(words[2])])
            full = boolean_query(corpus, index, expr, 1.0)
            r = boolean_query(corpus, index, expr, max(args.rate, 0.25),
                              rng=rng, executor=executor)
            acc["bool"].append(recall(r.doc_ids, full.doc_ids))
        else:
            full = ranked_query(corpus, index, words.tolist(), 1.0, k=10)
            r = ranked_query(corpus, index, words.tolist(),
                             max(args.rate, 0.25), k=10, rng=rng,
                             executor=executor)
            acc["ranked"].append(precision_at_k(r.doc_ids, full.doc_ids, 10))
        lat[kind].append(time.perf_counter() - t0)

    print(f"   injected faults survived: {faults['injected']} "
          f"(executor retries: {executor.stats['retries']})")
    for kind, metric in (("agg", "mean rel err"), ("bool", "mean recall"),
                         ("ranked", "mean P@10")):
        if lat[kind]:
            print(f"   {kind:7s}: p50 latency "
                  f"{np.percentile(lat[kind], 50)*1e3:7.1f} ms | "
                  f"{metric} {np.mean(acc[kind]):.3f}")


if __name__ == "__main__":
    main()
