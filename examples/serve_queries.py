"""End-to-end driver (the paper's kind: approximate query serving).

Builds the offline index once, then serves a stream of mixed queries —
aggregation, Boolean, ranked — through the *warm adaptive serving
runtime*: queries arrive one by one at a ``BatchWindow`` frontend
driven by the queueing-theory ``WindowController`` (each window opens
with the deadline/size pair currently estimated to minimize p99
sojourn; ``--static`` pins the fixed pair instead), with a bounded
pending queue that sheds via ``Backpressure`` if the dispatcher
saturates; each closed window runs through the batched execution
engine (``QueryBatch``) — one batched scoring pass, per-query pps
sampling, one shared scan over the union of sampled shards — on a
fault-tolerant executor whose thread pool stays warm across batches
(with injected worker faults surviving via retries).  Accuracy is
reported against precise answers computed with a rate-1.0 batch —
itself a single shared scan over all shards.

``--hosts N`` serves through a simulated N-host topology instead: a
blocked ``PlacementMap`` (``--replicas R`` ring replicas per shard)
assigns shard residency, and every window's shared scan splits across
per-host executors with a cross-host gather (the injected shard fault
then lands on whichever host owns the shard and is retried there;
per-host scan counts print at the end).  The replica-aware balancer is
on by default (``--no-balance`` pins the primary-only residency
split): per-host realized wall times feed a load model that sheds
shard groups from hot hosts onto their live replicas.
``--hot-host-ms M`` makes host 0 a straggler (M ms per resident shard
before each of its scans) so the shed is visible — the end-of-run
balance line shows estimated vs realized makespan and how many scans
moved.

``--budget-err E`` (and/or ``--budget-latency-ms L``) switches to
*error-budgeted serving*: every query carries a ``QueryBudget``
(relative error <= E at 95% confidence; p99 sojourn <= L ms;
degradation floor ``--budget-floor``), a ``RatePlanner`` wired to the
window controller inverts the paper's variance model to pick each
query's own sampling rate, and results come back with confidence
intervals (``ci=True``).  The precise reference pass always runs
through a plain engine so the accuracy lines compare against exact
answers.  Shed submits honor the ``Backpressure.retry_after_s`` hint
(back off one serving cycle instead of hot-retrying), and the
end-of-run budget line prints the planner's audit: planned vs realized
rates, degradation pressure, CI coverage of the exact counts.

``--cache`` attaches the semantic query cache (LSH-signature keyed,
``runtime/qcache``) and serves the stream *twice*: the first pass
populates (all misses, bit-for-bit the uncached results), the replay
resolves as exact hits with zero scoring/sampling/scan — the p50
collapse prints at the end alongside the hit/miss counters.

The whole stack — executor topology, planner, cache, controller,
window, fleet — is assembled through the one-call serving facade
(``repro.launch.serve_stack.build_serving_stack``); the flags below
are a thin argparse skin over its ``ServeConfig``.

    PYTHONPATH=src python examples/serve_queries.py [--queries 48]
        [--hosts 2] [--replicas 1] [--hot-host-ms 2] [--no-balance]
        [--budget-err 0.5] [--budget-latency-ms 50] [--cache]
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=48)
    ap.add_argument("--rate", type=float, default=0.25)
    ap.add_argument("--batch", type=int, default=12,
                    help="max queries per served window")
    ap.add_argument("--window-ms", type=float, default=2.0,
                    help="batch window deadline (ms)")
    ap.add_argument("--arrival-us", type=float, default=100.0,
                    help="mean inter-arrival gap of the synthetic "
                         "query stream (microseconds)")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--hosts", type=int, default=0,
                    help="serve through a simulated N-host placement "
                         "(locality-split scans + cross-host gather)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="ring replicas per shard in the placement "
                         "(shed targets for the balancer and failover)")
    ap.add_argument("--no-balance", action="store_true",
                    help="pin the primary-only residency split instead "
                         "of the replica-aware load balancer")
    ap.add_argument("--hot-host-ms", type=float, default=0.0,
                    help="degrade host 0 by this many ms per resident "
                         "shard before each scan (makes the balancer's "
                         "shed visible)")
    ap.add_argument("--static", action="store_true",
                    help="pin the fixed (deadline, batch) pair instead "
                         "of the adaptive window controller")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="pending-queue bound; submits shed with "
                         "Backpressure beyond it (default 8x batch)")
    ap.add_argument("--budget-err", type=float, default=None,
                    help="per-query error budget: max relative error "
                         "at 95%% confidence (e.g. 0.5); attaches a "
                         "RatePlanner and serves with CIs")
    ap.add_argument("--budget-latency-ms", type=float, default=None,
                    help="per-query latency budget: max estimated p99 "
                         "sojourn (ms); caps the planned rate")
    ap.add_argument("--budget-floor", type=float, default=0.1,
                    help="degradation floor rate — overload may "
                         "squeeze a budgeted query down to this rate, "
                         "never below")
    ap.add_argument("--cache", action="store_true",
                    help="attach the LSH-signature semantic query "
                         "cache and replay the stream once to show "
                         "the exact-hit p50 collapse")
    args = ap.parse_args()
    budget_on = (args.budget_err is not None
                 or args.budget_latency_ms is not None)

    from repro.core.allocation import allocate_corpus
    from repro.core.index import build_index
    from repro.core.lsh import LSHConfig
    from repro.core.pv_dbow import PVDBOWConfig, train_pv_dbow
    from repro.core.queries import (BatchQuery, QueryBatch, parse_boolean,
                                    precision_at_k, recall)
    from repro.data.corpus import SyntheticCorpusConfig, generate_text_corpus
    from repro.data.store import ShardedCorpus
    from repro.launch.serve_stack import ServeConfig, build_serving_stack
    from repro.runtime import Backpressure, ControllerConfig, QueryBudget

    print("== offline index build ==")
    ccfg = SyntheticCorpusConfig(n_docs=2400, vocab_size=4096, n_topics=16)
    docs, _ = generate_text_corpus(ccfg)
    corpus = ShardedCorpus.from_documents(docs, ccfg.vocab_size,
                                          shard_tokens=4096)
    pcfg = PVDBOWConfig(dim=48, steps=1200, batch_pairs=4096, lr=0.01)
    model = train_pv_dbow(corpus, pcfg)
    pre = build_index(corpus, model, LSHConfig(bits=128), use_lsh=False,
                      temperature=pcfg.temperature)
    corpus = allocate_corpus(corpus, pre.doc_vecs)
    index = build_index(corpus, model, LSHConfig(bits=256),
                        temperature=pcfg.temperature)
    print(f"   {corpus.n_shards} shards; index {index.nbytes()/1024:.0f} KiB")

    # fault injection: shard 3 fails once per attempt-1; executor retries
    faults = {"injected": 0}

    def fault_hook(sid, attempt):
        if sid == 3 and attempt == 1:
            faults["injected"] += 1
            raise RuntimeError("injected transient fault")

    host_hook = None
    if args.hosts >= 2 and args.hot_host_ms > 0:
        def host_hook(host, shard_ids):
            if host == 0:
                time.sleep(args.hot_host_ms * 1e-3 * len(shard_ids))
    balanced = (args.hosts >= 2 and not args.no_balance
                and args.replicas >= 1)
    max_pending = args.max_pending or 8 * args.batch
    controller_cfg = None
    if not args.static:
        controller_cfg = ControllerConfig(
            min_delay_s=1e-4, max_delay_s=args.window_ms / 1e3,
            min_batch=1, max_batch=args.batch)
    # one call wires executor topology, planner, cache, controller,
    # and window — the facade replaces the old hand-assembly here
    stack = build_serving_stack(corpus, index, ServeConfig(
        rate=args.rate,
        hosts=args.hosts if args.hosts >= 2 else 0,
        replicas=args.replicas, balanced=balanced,
        workers=args.workers, fault_hook=fault_hook,
        host_fault_hook=host_hook, adaptive_workers=True,
        planner=budget_on, ci=budget_on, cache=args.cache,
        window=True, adaptive=not args.static,
        max_batch=args.batch, max_delay_s=args.window_ms / 1e3,
        max_pending=max_pending, controller_config=controller_cfg,
        seed=1))
    executor, engine = stack.executor, stack.engine
    controller, window = stack.controller, stack.window
    if args.hosts >= 2:
        placement = executor.placement
        print(f"   placement: {args.hosts} hosts (blocked, "
              f"{placement.n_replicas} replica); shard residency "
              f"{[len(placement.shards_on(h)) for h in range(args.hosts)]}; "
              f"balancer {'on' if balanced else 'off'}"
              + (f"; host 0 degraded {args.hot_host_ms:.1f} ms/shard"
                 if host_hook else ""))

    rng = np.random.default_rng(0)
    counts = np.bincount(np.concatenate([s.tokens for s in corpus.shards]),
                         minlength=ccfg.vocab_size)
    cand = np.nonzero((counts > 50) & (counts < 1200))[0]

    queries = []
    for i in range(args.queries):
        words = rng.choice(cand, 3, replace=False).astype(int)
        kind = i % 3
        if kind == 0:
            queries.append(BatchQuery.count([int(words[0])]))
        elif kind == 1:
            queries.append(BatchQuery.boolean(parse_boolean(
                [int(words[0]), "or", int(words[1]), "and", int(words[2])])))
        else:
            queries.append(BatchQuery.ranked(words.tolist(), k=10))

    # precise reference answers: one rate-1.0 batch = one full shared
    # scan, always through the plain engine — in budget mode the
    # serving engine carries the planner, and the reference must stay
    # exact regardless of what the planner would do to budgeted queries
    print("== precise reference pass (rate 1.0, one shared scan) ==")
    # always a plain engine: the reference must stay exact (and out of
    # the cache) regardless of planner/cache on the serving engine
    ref_engine = QueryBatch(corpus, index, executor=executor)
    precise = ref_engine.execute(queries, 1.0)

    if budget_on:
        budget = QueryBudget(
            max_rel_error=args.budget_err,
            max_latency_s=(args.budget_latency_ms / 1e3
                           if args.budget_latency_ms is not None else None),
            floor_rate=args.budget_floor)
        queries = [dataclasses.replace(q, budget=budget) for q in queries]
        print(f"   budgets: rel err <= {args.budget_err}"
              + (f", p99 <= {args.budget_latency_ms:.0f} ms"
                 if args.budget_latency_ms is not None else "")
              + f", floor rate {args.budget_floor}; planner attached, "
              f"results carry confidence intervals")
    mode = ("static window" if args.static
            else "adaptive window (p99-sojourn controller)")
    print(f"== serving {args.queries} mixed queries at rate {args.rate} "
          f"through a {args.window_ms:.1f} ms / {args.batch}-query "
          f"{mode}, pending bound {max_pending} ==")
    # with --cache the stream is served twice: pass 1 populates the
    # cache (all misses), pass 2 replays the same queries as exact hits
    stream = list(range(len(queries)))
    if args.cache:
        stream = stream + stream
    arrival_rng = np.random.default_rng(2)
    done_at = {}
    t_submit = {}

    def on_done(i):
        def cb(_fut):
            done_at[i] = time.perf_counter()
        return cb

    t_serve = time.perf_counter()
    futs, shed, retry_hints = [], 0, []
    for i, qi in enumerate(stream):
        q = queries[qi]
        t_submit[i] = time.perf_counter()
        while True:
            try:
                fut = window.submit(q)
                break
            except Backpressure as bp:
                # a real frontend would divert to a replica; the
                # example backs off for the controller's estimated
                # capacity-recovery time (one serving cycle) and
                # retries.  The original t_submit stands — every
                # shed-and-wait penalty is part of the query's sojourn
                shed += 1
                if bp.retry_after_s is not None:
                    retry_hints.append(bp.retry_after_s)
                time.sleep(bp.retry_after_s or args.window_ms / 1e3)
        fut.add_done_callback(on_done(i))
        futs.append(fut)
        if args.arrival_us > 0:
            time.sleep(arrival_rng.exponential(args.arrival_us) / 1e6)
    results = [f.result() for f in futs]
    elapsed = time.perf_counter() - t_serve
    window.close()

    lat = {"agg": [], "bool": [], "ranked": []}
    acc = {"agg": [], "bool": [], "ranked": []}
    kind_of = {"count": "agg", "bool": "bool", "ranked": "ranked"}
    for i, (q, r, ref) in enumerate(zip(queries, results, precise)):
        # pass-1 results only: the replay (if any) repeats the same
        # queries and lands in the cache line below
        k = kind_of[q.kind]
        lat[k].append(done_at[i] - t_submit[i])
        if q.kind == "count":
            if ref.estimate.value:
                acc[k].append(abs(r.estimate.value - ref.estimate.value)
                              / ref.estimate.value)
        elif q.kind == "bool":
            acc[k].append(recall(r.doc_ids, ref.doc_ids))
        else:
            acc[k].append(precision_at_k(r.doc_ids, ref.doc_ids, 10))

    ws = window.stats
    sojourn = np.asarray([done_at[i] - t_submit[i]
                          for i in range(len(stream))])
    print(f"   throughput: {len(stream)/elapsed:8.1f} queries/sec "
          f"({len(stream)} queries in {elapsed:.2f}s)")
    print(f"   sojourn: p50 {np.percentile(sojourn, 50)*1e3:.2f} ms | "
          f"p99 {np.percentile(sojourn, 99)*1e3:.2f} ms")
    if args.cache:
        n = len(queries)
        p50_cold = np.percentile(sojourn[:n], 50) * 1e3
        p50_hot = np.percentile(sojourn[n:], 50) * 1e3
        rec = stack.cache.record()
        print(f"   cache: replay p50 {p50_hot:.2f} ms vs cold "
              f"{p50_cold:.2f} ms ({p50_cold / max(p50_hot, 1e-9):.1f}x); "
              f"{rec['hits']} hits / {rec['near_hits']} near / "
              f"{rec['misses']} misses / {rec['bypassed']} bypassed "
              f"({rec['size']} entries)")
    print(f"   windows: {ws['batches']} "
          f"(by size {ws['closed_by_size']}, "
          f"by deadline {ws['closed_by_deadline']}, "
          f"by flush {ws['closed_by_flush']}); "
          f"shed by backpressure: {shed}"
          + (f" (mean retry-after hint "
             f"{1e3 * sum(retry_hints) / len(retry_hints):.1f} ms)"
             if retry_hints else "")
          + (f"; pressure escalations: {ws['escalated']}, "
             f"served degraded: {ws['degraded']}"
             if ws.get("escalated") or ws.get("degraded") else ""))
    if controller is not None and controller.current_plan is not None:
        plan = controller.current_plan
        scan = controller.scan_fraction
        print(f"   controller: deadline {plan.delay_s*1e3:.2f} ms, "
              f"batch {plan.max_batch}, est p99 {plan.est_p99_s*1e3:.2f} ms, "
              f"utilization {plan.utilization:.2f}, "
              f"arrival rate {plan.arrival_rate:.0f}/s"
              + (f", scan share {scan:.0%}" if scan is not None else ""))
    if budget_on:
        cover, n_counts, rates = 0, 0, []
        for q, r, ref in zip(queries, results, precise):
            rates.append(r.achieved_rate)
            if q.kind == "count":
                n_counts += 1
                cover += int(r.estimate.covers(ref.estimate.value))
        print(f"   budget: count 95% CIs cover the exact answer "
              f"{cover}/{n_counts}; mean achieved rate "
              f"{np.mean(rates):.2f} (nominal {args.rate})")
        audit = window.last_budget
        if audit:
            print(f"   planner audit (last window): pressure "
                  f"{audit['pressure']:.2f}, {audit['degraded']}/"
                  f"{audit['budgeted']} degraded, {audit['at_floor']} at "
                  f"floor; planned rates p50 "
                  f"{np.median(audit['planned_rates']):.2f}, realized rel "
                  f"err p50 {np.median(audit['realized_rel_error']):.2f}")
    if args.hosts >= 2:
        retries = sum(ex.stats["retries"] for ex in executor.hosts.values())
        print(f"   injected faults survived: {faults['injected']} "
              f"(task retries across hosts: {retries}; host failures: "
              f"{executor.stats['host_failures']}; requeued shards: "
              f"{executor.stats['requeued_shards']})")
        print(f"   per-host scans: {executor.stats['scans_per_host']} "
              f"over {executor.stats['jobs']} gather jobs")
        audit = engine.last_audit
        if audit is not None:
            print(f"   balance: split {audit['group_sizes']} vs residency "
                  f"{audit['base_group_sizes']} "
                  f"({'shed ' + str(audit['shed']) if audit['balanced'] else 'held by hysteresis'}; "
                  f"{executor.stats['shed_shards']} scans shed total); "
                  f"last-job makespan est "
                  f"{audit['est_makespan_s'] * 1e3:.2f} ms / realized "
                  f"{audit['realized_makespan_s'] * 1e3:.2f} ms "
                  f"(residency split would est "
                  f"{audit['est_base_makespan_s'] * 1e3:.2f} ms)")
    else:
        print(f"   injected faults survived: {faults['injected']} "
              f"(executor retries: {executor.stats['retries']}; warm pool "
              f"rebuilds: {executor.stats['pool_rebuilds']} across "
              f"{executor.stats['jobs']} jobs)")
    for kind, metric in (("agg", "mean rel err"), ("bool", "mean recall"),
                         ("ranked", "mean P@10")):
        if lat[kind]:
            print(f"   {kind:7s}: p50 sojourn latency "
                  f"{np.percentile(lat[kind], 50)*1e3:7.2f} ms | "
                  f"{metric} {np.mean(acc[kind]):.3f}")
    stack.close()


if __name__ == "__main__":
    main()
