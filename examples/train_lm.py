"""LM-training example: drives the distributed training stack (sharded
params/optimizer, microbatching, checkpoint/restart) on any of the 10
assigned architectures.

On CPU use the smoke config; on a TPU slice drop --smoke and raise the
sizes — the same code path compiles to the production mesh.

    PYTHONPATH=src python examples/train_lm.py --arch smollm-360m \
        --steps 60 --batch 8 --seq 128 --ckpt /tmp/lm_ckpt

Kill it mid-run and re-run with the same --ckpt: it resumes from the
latest committed checkpoint (crash-consistent atomic rename).
"""
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--full-size", action="store_true",
                    help="use the full assigned config (TPU only)")
    args = ap.parse_args()

    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", args.arch,
           "--steps", str(args.steps),
           "--batch", str(args.batch),
           "--seq", str(args.seq),
           "--microbatches", "2",
           "--ckpt-dir", args.ckpt,
           "--ckpt-every", "20"]
    if not args.full_size:
        cmd.append("--smoke")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    print("+", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd, env=env))


if __name__ == "__main__":
    main()
