"""Fused in-kernel reductions: segment-sum and top-k variants of the
asym / hamming scoring kernels vs the unfused [B, M] + numpy/jnp
reduce references (interpret mode on CPU per the harness contract),
and the ApproxIndex-level fused routes vs their unfused parity paths."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lsh as lsh_mod
from repro.kernels.asym import ops as aops
from repro.kernels.asym import ref as aref
from repro.kernels.hamming import ops as hops
from repro.kernels.hamming import ref as href

QUERIES = [[3, 5, 9], [2], [10, 11], [7, 4, 5, 6]]


def _asym_setup(b, m, dim, bits, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, dim)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(m, dim)).astype(np.float32))
    planes = lsh_mod.hyperplanes(lsh_mod.LSHConfig(bits=bits), dim)
    db = lsh_mod.pack_bits(lsh_mod.signature_bits(x, planes))
    return rng, q, planes, db


# ----------------------------------------------------------------------
# kernel-level: fused segment sum vs unfused matrix + reduce
# ----------------------------------------------------------------------
@pytest.mark.parametrize("b,m,s,dim,bits,temp", [
    (1, 7, 3, 24, 128, 1.0),        # single query, tiny tile
    (5, 613, 37, 48, 128, 8.0),     # ragged M, many segments
    (9, 300, 128, 32, 64, 4.0),     # S == lane width exactly
    (3, 1000, 5, 48, 256, 8.0),     # M over several tiles
])
def test_asym_segment_sum_matches_unfused(b, m, s, dim, bits, temp):
    rng, q, planes, db = _asym_setup(b, m, dim, bits, seed=b * 100 + m)
    seg = np.sort(rng.integers(0, s, m)).astype(np.int32)
    got = aops.asym_exp_segment_sum(q, db, planes, bits, seg, s,
                                    temperature=temp)
    # unfused reference: full [B, M] matrix, then a numpy segment reduce
    sims = np.asarray(aref.asym_exp_similarity_ref(q, db, planes, bits, temp),
                      np.float64)
    want = np.stack([np.bincount(seg, weights=row, minlength=s)
                     for row in sims])
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4)


def test_asym_segment_sum_empty_and_unsorted_segments():
    rng, q, planes, db = _asym_setup(4, 200, 32, 128, seed=0)
    s = 16
    # all docs in one segment: every other slot must be exactly zero
    seg = np.full(200, 5, np.int32)
    got = np.asarray(aops.asym_exp_segment_sum(q, db, planes, 128, seg, s))
    assert (got[:, 5] > 0).all()
    mask = np.ones(s, bool)
    mask[5] = False
    np.testing.assert_array_equal(got[:, mask], 0.0)
    # correctness must not depend on segment-sorted doc order
    seg = rng.integers(0, s, 200).astype(np.int32)
    got = np.asarray(aops.asym_exp_segment_sum(q, db, planes, 128, seg, s))
    sims = np.asarray(aref.asym_exp_similarity_ref(q, db, planes, 128, 1.0),
                      np.float64)
    want = np.stack([np.bincount(seg, weights=row, minlength=s)
                     for row in sims])
    np.testing.assert_allclose(got, want, rtol=1e-4)


@pytest.mark.parametrize("n,m,s,bits,temp", [
    (4, 300, 37, 128, 8.0), (1, 64, 3, 64, 1.0), (8, 1000, 121, 256, 4.0),
])
def test_hamming_segment_sum_matches_unfused(n, m, s, bits, temp):
    rng = np.random.default_rng(n * 10 + m)
    w = bits // 32
    q = jnp.asarray(rng.integers(0, 2**32, (n, w), dtype=np.uint32))
    db = jnp.asarray(rng.integers(0, 2**32, (m, w), dtype=np.uint32))
    seg = np.sort(rng.integers(0, s, m)).astype(np.int32)
    got = hops.hamming_segment_similarity(q, db, bits, seg, s,
                                          temperature=temp)
    sims = np.asarray(href.hamming_similarity_ref(q, db, bits),
                      np.float64) ** temp
    want = np.stack([np.bincount(seg, weights=row, minlength=s)
                     for row in sims])
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4)


# ----------------------------------------------------------------------
# kernel-level: fused top-k vs argsort over the unfused matrix
# ----------------------------------------------------------------------
@pytest.mark.parametrize("b,m,k,dim,bits,temp", [
    (3, 257, 10, 48, 128, 8.0),     # k << tile, ragged M
    (5, 100, 100, 32, 64, 4.0),     # k == M (full sort)
    (2, 700, 300, 24, 128, 1.0),    # k > default tile width
    (2, 50, 7, 24, 64, 2.0),        # tiny M, k far below lane width
])
def test_asym_topk_matches_argsort(b, m, k, dim, bits, temp):
    _, q, planes, db = _asym_setup(b, m, dim, bits, seed=b + m + k)
    idx, vals = aops.asym_exp_topk(q, db, planes, bits, k, temperature=temp)
    sims = np.asarray(aref.asym_exp_similarity_ref(q, db, planes, bits, temp))
    order = np.argsort(-sims, axis=1, kind="stable")[:, :k]
    want_vals = np.take_along_axis(sims, order, axis=1)
    # values must agree; indices may differ only where values tie
    np.testing.assert_allclose(np.asarray(vals), want_vals, rtol=1e-4)
    picked = np.take_along_axis(sims, np.asarray(idx), axis=1)
    np.testing.assert_allclose(picked, np.asarray(vals), rtol=1e-5)
    # rows sorted descending
    v = np.asarray(vals)
    assert (np.diff(v, axis=1) <= 1e-6).all()


@pytest.mark.parametrize("b,m,k", [
    (3, 257, 10),                   # kp = 128 within the tile
    (2, 50, 7),                     # kp = 128 exceeds M entirely
])
def test_asym_topk_lane_padding_is_invisible(b, m, k):
    """The TPU lane-pad path (K -> multiple of 128; off by default in
    interpret mode) must return exactly what the unpadded path does —
    padding only widens the per-tile candidate sets."""
    _, q, planes, db = _asym_setup(b, m, 32, 64, seed=b * m + k)
    idx_p, vals_p = aops.asym_exp_topk(q, db, planes, 64, k,
                                       temperature=4.0, pad_lanes=True)
    idx_u, vals_u = aops.asym_exp_topk(q, db, planes, 64, k,
                                       temperature=4.0, pad_lanes=False)
    np.testing.assert_allclose(np.asarray(vals_p), np.asarray(vals_u),
                               rtol=1e-6)
    sims = np.asarray(aref.asym_exp_similarity_ref(q, db, planes, 64, 4.0))
    picked = np.take_along_axis(sims, np.asarray(idx_p), axis=1)
    np.testing.assert_allclose(picked, np.asarray(vals_p), rtol=1e-5)


# ----------------------------------------------------------------------
# index-level: fused routes vs unfused parity paths
# ----------------------------------------------------------------------
def _doc_kernel_index(built_index, corpus, lsh_mode):
    return dataclasses.replace(
        built_index, granularity="doc", use_kernel=True,
        lsh_mode=lsh_mode).attach_corpus(corpus)


@pytest.mark.parametrize("lsh_mode", ["asym", "sym"])
def test_index_fused_shard_sims_match_unfused(small_corpus, built_index,
                                              lsh_mode):
    idx = _doc_kernel_index(built_index, small_corpus, lsh_mode)
    fused = idx.shard_similarities_batch(QUERIES, fused=True)
    unfused = idx.shard_similarities_batch(QUERIES, fused=False)
    assert fused.shape == (len(QUERIES), small_corpus.n_shards)
    np.testing.assert_allclose(fused, unfused, rtol=1e-4)


def test_index_fused_matches_single_query_loop(small_corpus, built_index):
    idx = _doc_kernel_index(built_index, small_corpus, "asym")
    fused = idx.shard_similarities_batch(QUERIES, fused=True)
    singles = np.stack([idx.shard_similarities(q) for q in QUERIES])
    np.testing.assert_allclose(fused, singles, rtol=1e-4)


def test_index_topk_fused_matches_argsort(small_corpus, built_index):
    idx = _doc_kernel_index(built_index, small_corpus, "asym")
    ids_f, vals_f = idx.topk_doc_similarities_batch(QUERIES, k=9, fused=True)
    ids_r, vals_r = idx.topk_doc_similarities_batch(QUERIES, k=9, fused=False)
    assert ids_f.shape == vals_f.shape == (len(QUERIES), 9)
    np.testing.assert_allclose(vals_f, vals_r, rtol=1e-4)
    # fused picks must carry their true similarity values
    sims = idx._exp_sim_batch(idx.query_vectors(QUERIES), idx.doc_sig,
                              idx.doc_vecs, "doc")
    picked = np.take_along_axis(sims, ids_f, axis=1)
    np.testing.assert_allclose(picked, vals_f, rtol=1e-4)


def test_index_topk_requires_doc_vectors(built_index):
    idx = dataclasses.replace(built_index, doc_sig=None, doc_vecs=None)
    with pytest.raises(ValueError):
        idx.topk_doc_similarities_batch(QUERIES, k=3)


def test_sum_docs_to_shards_batch_vectorized_matches_bincount(
        small_corpus, built_index):
    """The reduceat rewrite is exactly the per-row bincount it replaced
    (incl. rows of zeros and the B=1 edge)."""
    idx = dataclasses.replace(built_index,
                              granularity="doc").attach_corpus(small_corpus)
    rng = np.random.default_rng(7)
    vals = rng.uniform(0.0, 5.0, (6, small_corpus.n_docs))
    vals[2] = 0.0
    got = idx._sum_docs_to_shards_batch(vals)
    want = np.stack([np.bincount(idx._doc_shard_ids, weights=row,
                                 minlength=small_corpus.n_shards)
                     for row in vals])
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-12)
    one = idx._sum_docs_to_shards_batch(vals[:1])
    np.testing.assert_allclose(one, want[:1], rtol=1e-10, atol=1e-12)


def test_sum_docs_to_shards_batch_trailing_empty_shards(built_index):
    """Regression: a trailing empty shard (possible after reallocate
    leaves a k-means cluster empty) must not truncate the last
    non-empty shard's sum."""
    idx = dataclasses.replace(built_index)
    idx.shard_vecs = idx.shard_vecs[:4]
    # 2 docs both in shard 0; shards 1..3 empty (incl. the tail)
    idx._doc_shard_ids = np.asarray([0, 0], np.int64)
    got = idx._sum_docs_to_shards_batch(np.asarray([[1.0, 2.0]]))
    np.testing.assert_allclose(got, [[3.0, 0.0, 0.0, 0.0]])
    # empty shard sandwiched between non-empty ones
    idx._shard_sort = None              # drop the cached sort structures
    idx._doc_shard_ids = np.asarray([0, 0, 2, 3], np.int64)
    got = idx._sum_docs_to_shards_batch(
        np.asarray([[1.0, 2.0, 4.0, 8.0], [1.0, 1.0, 1.0, 1.0]]))
    np.testing.assert_allclose(got, [[3.0, 0.0, 4.0, 8.0],
                                     [2.0, 0.0, 1.0, 1.0]])
