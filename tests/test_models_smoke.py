"""Per-architecture smoke tests: reduced configs, one forward/train step
on CPU, shape + finiteness assertions, decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import model as M
from repro.models.config import DTypePolicy

ARCHS = list_archs()
FP32 = DTypePolicy(params="float32", compute="float32", kv_cache="float32")


def _batch(cfg, b=2, s=24, key=jax.random.PRNGKey(0)):
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "mask": jnp.ones((b, s), jnp.float32),
    }
    if cfg.is_encdec:
        batch["enc_inputs"] = jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model))
    elif cfg.family == "vlm":
        batch["enc_inputs"] = jax.random.normal(
            key, (b, cfg.vision_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = M.forward(params, batch["tokens"], cfg,
                       enc_inputs=batch.get("enc_inputs"))
    assert logits.shape == (2, 24, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_runs(arch):
    from repro.launch.steps import make_train_step
    from repro.optimizer.adamw import AdamWConfig, adamw_init
    cfg = get_config(arch, smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3)
    opt_state = adamw_init(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    batch = _batch(cfg)
    params, opt_state, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(opt_state.step) == 1


@pytest.mark.parametrize("arch,lr", [("smollm_360m", 5e-3),
                                     ("qwen2_5_14b", 5e-3),
                                     ("mamba2_780m", 1e-3),
                                     ("hymba_1_5b", 1e-3),
                                     ("llama4_scout_17b_a16e", 5e-3)])
def test_loss_decreases(arch, lr):
    # SSM archs get a smaller lr: the SSD recurrence is sensitive to
    # dt/a_log early in training and 5e-3 can overshoot in 8 steps.
    from repro.launch.steps import make_train_step
    from repro.optimizer.adamw import AdamWConfig, adamw_init
    cfg = get_config(arch, smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=lr)
    opt_state = adamw_init(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, warmup_steps=1))
    batch = _batch(cfg, b=4, s=32)
    losses = []
    for _ in range(10):
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert min(losses[1:]) < losses[0]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Teacher-forced forward == incremental decode (fp32 cache).

    MoE needs drop-free capacity here: capacity is computed per dispatch
    group, so decode (1-token groups) and full forward (S-token groups)
    drop different tokens under a tight capacity factor — that is
    expected behaviour, not a bug, so we remove dropping from the
    equation."""
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtypes=FP32)
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    b, s = 2, 12
    key = jax.random.PRNGKey(2)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    enc_in = None
    enc_state = None
    if cfg.is_encdec:
        enc_in = jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model))
        enc_state = M.encode(params, enc_in, cfg)
    elif cfg.family == "vlm":
        enc_in = jax.random.normal(key, (b, cfg.vision_tokens, cfg.d_model))
        enc_state = enc_in
    full = np.asarray(M.forward(params, toks, cfg, enc_inputs=enc_in))
    state = M.init_decode_state(cfg, b, 32, enc=enc_state)
    _, state = M.prefill(params, toks[:, :s - 1], cfg, state)
    dec, state = M.decode_step(params, toks[:, s - 1:s], cfg, state)
    scale = np.max(np.abs(full[:, -1])) + 1e-9
    assert np.max(np.abs(np.asarray(dec) - full[:, -1])) / scale < 5e-3


@pytest.mark.slow
def test_sliding_window_ring_buffer():
    """Hymba ring cache: decoding past the window stays consistent with
    a windowed full forward (~20 s: 20 per-token decode_step compiles)."""
    cfg = dataclasses.replace(get_config("hymba_1_5b", smoke=True),
                              dtypes=FP32)
    # tiny window so we wrap quickly
    cfg = dataclasses.replace(cfg, sliding_window=8)
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    b, s = 1, 20
    toks = jax.random.randint(jax.random.PRNGKey(4), (b, s), 0,
                              cfg.vocab_size)
    full = np.asarray(M.forward(params, toks, cfg))
    state = M.init_decode_state(cfg, b, 64)
    errs = []
    for t in range(s):
        lg, state = M.decode_step(params, toks[:, t:t + 1], cfg, state)
        errs.append(np.max(np.abs(np.asarray(lg) - full[:, t])))
    assert max(errs) / (np.max(np.abs(full)) + 1e-9) < 5e-3


def test_param_counts_match_assignment():
    """Full-size analytic param counts are in the advertised ballpark."""
    expect = {
        "smollm_360m": (0.25e9, 0.6e9),
        "qwen2_5_14b": (12e9, 16e9),
        "starcoder2_3b": (2.5e9, 4.5e9),  # SwiGLU vs 2-mat MLP (DESIGN.md)
        "internlm2_20b": (17e9, 23e9),
        "mamba2_780m": (0.6e9, 1.0e9),
        "hymba_1_5b": (1.0e9, 2.0e9),
        "llama4_scout_17b_a16e": (90e9, 115e9),
        "llama4_maverick_400b_a17b": (350e9, 450e9),
        "llama_3_2_vision_11b": (8e9, 13e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count_estimate()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B not in [{lo/1e9},{hi/1e9}]"


def test_long_context_support_flags():
    assert get_config("mamba2_780m").supports_long_context
    assert get_config("hymba_1_5b").supports_long_context
    for arch in ("smollm_360m", "qwen2_5_14b", "llama4_scout_17b_a16e",
                 "whisper_small", "llama_3_2_vision_11b"):
        assert not get_config(arch).supports_long_context
