"""Deterministic chaos harness: FaultPlan injection (seeded flaky /
slow / stall / crash), the executor's bounded-backoff retries,
per-job deadlines with graceful partial results, the job-epoch guard
against zombie completions, and property-style scenario sweeps
(scripted faults x join/drain timing) pinning gather parity and
zero lost queries against the single-executor reference."""
import time

import numpy as np
import pytest

from repro.core.queries import BatchQuery, QueryBatch, parse_boolean
from repro.runtime import (
    FaultPlan,
    FleetManager,
    HostGroupExecutor,
    PlacementMap,
    ShardTaskExecutor,
)
from repro.runtime.chaos import ChaosCrash, ChaosFault
from repro.runtime.executor import ShardTaskError


class _FakeShard:
    def __init__(self, i):
        self.shard_id = i


class _FakeCorpus:
    def __init__(self, n):
        self.shards = [_FakeShard(i) for i in range(n)]


# ----------------------------------------------------------------------
# FaultPlan determinism
# ----------------------------------------------------------------------
def test_flaky_faults_are_deterministic_and_cleared_by_retries():
    corpus = _FakeCorpus(24)

    def one_run():
        plan = FaultPlan(seed=3).flaky(0, error_rate=0.25)
        with ShardTaskExecutor(workers=4, max_retries=6) as ex:
            plan.install(ex)
            out = ex.map_shards(corpus, range(24),
                                lambda s: s.shard_id * 2)
            return out, plan.fired["flaky"], ex.stats["retries"]

    out1, fired1, retries1 = one_run()
    out2, fired2, retries2 = one_run()
    assert out1 == out2 == {i: i * 2 for i in range(24)}
    # decisions are a pure function of (seed, host, shard, job,
    # attempt) — identical across runs regardless of thread timing
    assert fired1 == fired2 > 0
    assert retries1 == retries2 == fired1   # every fault retried clear


def test_flaky_decision_is_coordinate_keyed_not_stream_keyed():
    plan_a = FaultPlan(seed=5).flaky(0, error_rate=0.5)
    plan_b = FaultPlan(seed=6).flaky(0, error_rate=0.5)
    hook_a, hook_b = plan_a._task_hook_for(0), plan_b._task_hook_for(0)

    def decisions(hook, plan):
        out = []
        plan._advance(0)
        for sid in range(40):
            try:
                hook(sid, 0, 0)
                out.append(False)
            except ChaosFault:
                out.append(True)
        return out

    da, db = decisions(hook_a, plan_a), decisions(hook_b, plan_b)
    assert da == decisions(hook_a, plan_a)   # replay-identical
    assert da != db                          # the seed is load-bearing


def test_crash_persists_and_stall_sleeps():
    plan = FaultPlan(seed=0).crash(1, at_job=2).stall(0, s=0.03, jobs=[1])
    plan._advance(1)
    t0 = time.perf_counter()
    plan._host_hook(0, [1, 2])               # stalls
    assert time.perf_counter() - t0 >= 0.025
    plan._host_hook(1, [3])                  # job 1 < at_job 2: alive
    plan._advance(2)
    with pytest.raises(ChaosCrash):
        plan._host_hook(1, [3])
    plan._advance(7)
    with pytest.raises(ChaosCrash):          # dead stays dead
        plan._host_hook(1, [3])
    assert plan.fired["crash"] == 2 and plan.fired["stall"] == 1
    rec = plan.record()
    assert rec["scripted"]["crashes"] == [[1, 2]]
    assert rec["fired"]["crash"] == 2


# ----------------------------------------------------------------------
# executor hardening: backoff, deadline, epoch guard
# ----------------------------------------------------------------------
def test_retry_backoff_delays_resubmission():
    corpus = _FakeCorpus(4)
    failed = set()

    def flake_once(sid, attempt, job):
        # attempts are 1-based: the first run of a shard is attempt 1
        if attempt == 1 and sid == 2 and 2 not in failed:
            failed.add(2)
            raise ChaosFault("one transient fault")

    with ShardTaskExecutor(workers=2, task_hook=flake_once,
                           retry_backoff_s=0.08) as ex:
        t0 = time.perf_counter()
        out = ex.map_shards(corpus, range(4), lambda s: s.shard_id)
        dt = time.perf_counter() - t0
    assert out == {i: i for i in range(4)}
    assert ex.stats["retries"] == 1
    assert dt >= 0.06            # the retry waited out the backoff


def test_backoff_is_bounded_by_cap():
    corpus = _FakeCorpus(1)

    def always_fail(sid, attempt, job):
        raise ChaosFault(f"attempt {attempt}")

    with ShardTaskExecutor(workers=1, max_retries=3,
                           task_hook=always_fail,
                           retry_backoff_s=0.01,
                           retry_backoff_cap_s=0.02) as ex:
        t0 = time.perf_counter()
        with pytest.raises(ShardTaskError):
            ex.map_shards(corpus, [0], lambda s: s.shard_id)
        dt = time.perf_counter() - t0
    # 3 retries at 0.01 / 0.02 / 0.02 (capped, not 0.04): well under
    # the uncapped geometric sum's wall
    assert ex.stats["retries"] == 3
    assert 0.04 <= dt < 0.5


def test_job_deadline_returns_partial_when_allowed():
    corpus = _FakeCorpus(6)

    def slow_tail(sid, attempt, job):
        if sid >= 4:
            time.sleep(0.5)

    with ShardTaskExecutor(workers=2, task_hook=slow_tail,
                           job_deadline_s=0.15,
                           allow_partial=True) as ex:
        out = ex.map_shards(corpus, range(6), lambda s: s.shard_id)
        # the fast shards landed; the stalled tail was abandoned at
        # the deadline instead of holding the job open
        assert set(out) == {0, 1, 2, 3}
        assert ex.stats["lost_shards"] == 2
        assert ex.last_job["lost_shards"] == 2.0


def test_job_deadline_raises_without_allow_partial():
    corpus = _FakeCorpus(2)

    def stall_all(sid, attempt, job):
        time.sleep(0.5)

    with ShardTaskExecutor(workers=2, task_hook=stall_all,
                           job_deadline_s=0.05) as ex:
        with pytest.raises(ShardTaskError, match="deadline"):
            ex.map_shards(corpus, range(2), lambda s: s.shard_id)


def test_zombie_completion_from_abandoned_job_is_dropped():
    corpus = _FakeCorpus(2)
    stall_first_job = {"on": True}

    def hook(sid, attempt, job):
        if stall_first_job["on"]:
            time.sleep(0.3)

    with ShardTaskExecutor(workers=1, task_hook=hook,
                           job_deadline_s=0.05,
                           allow_partial=True) as ex:
        out1 = ex.map_shards(corpus, [0], lambda s: s.shard_id)
        assert out1 == {}                    # abandoned at the deadline
        stall_first_job["on"] = False
        time.sleep(0.5)                      # zombie finishes, enqueues
        # the next job must not see the stale epoch's completion
        out2 = ex.map_shards(corpus, [1], lambda s: s.shard_id + 10)
        assert out2 == {1: 11}
        assert ex.stats["stale_completions"] >= 1


# ----------------------------------------------------------------------
# property-style scenario sweep: scripted faults x membership timing,
# pinned invariants — gather parity vs the single executor on every
# batch and zero lost queries (a replica survives every scenario)
# ----------------------------------------------------------------------
def _mixed_queries():
    return [
        BatchQuery.count([3]),
        BatchQuery.boolean(parse_boolean([3, "or", 5, "and", 9])),
        BatchQuery.ranked([7, 4, 5], k=10),
        BatchQuery.count([11]),
    ]


def _assert_results_identical(got, want):
    for g, w in zip(got, want):
        assert type(g) is type(w)
        if hasattr(g, "doc_ids"):
            np.testing.assert_array_equal(g.doc_ids, w.doc_ids)
            if hasattr(g, "scores"):
                np.testing.assert_array_equal(g.scores, w.scores)
        else:
            assert g.estimate.value == w.estimate.value
            assert g.estimate.error_bound == w.estimate.error_bound


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("scenario",
                         ["crash_then_join", "drain_mid_stream",
                          "flaky_everywhere", "stall_and_slow"])
def test_chaos_scenarios_preserve_parity_and_lose_nothing(
        small_corpus, built_index, scenario, seed):
    queries = _mixed_queries()
    pm = PlacementMap.blocked(small_corpus.n_shards, 2, n_replicas=1)
    with ShardTaskExecutor(workers=2) as single, \
            HostGroupExecutor(pm, workers_per_host=1, max_retries=6,
                              allow_partial=True) as hg:
        ref = QueryBatch(small_corpus, built_index, executor=single)
        engine = QueryBatch(small_corpus, built_index, executor=hg)
        plan = FaultPlan(seed=seed)
        fleet = FleetManager(hg)
        # membership ops keyed on batch index: fired between batches,
        # mimicking a failure detector / operator acting mid-stream
        ops = {}
        if scenario == "crash_then_join":
            plan.crash(1, at_job=1)          # batch 1 discovers it live
            ops[1] = lambda: fleet.crash(1)  # detector catches up after
            ops[2] = lambda: fleet.join(2)   # replacement host joins
        elif scenario == "drain_mid_stream":
            ops[1] = lambda: fleet.drain(0)
        elif scenario == "flaky_everywhere":
            plan.flaky(0, error_rate=0.2).flaky(1, error_rate=0.2)
        else:
            plan.stall(0, s=0.02, jobs=[1]).slow(1, ms_per_shard=1.0)
        plan.install(hg)
        for batch in range(4):
            rng_seed = 100 * seed + batch
            got = engine.execute(queries, 0.5,
                                 rng=np.random.default_rng(rng_seed))
            want = ref.execute(queries, 0.5,
                               rng=np.random.default_rng(rng_seed))
            _assert_results_identical(got, want)
            assert engine.last_degraded is None
            if batch in ops:
                ops[batch]()
        assert hg.stats["lost_shards"] == 0
