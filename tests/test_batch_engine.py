"""Batched query engine: batched-vs-single score parity across every
LSH mode, postings-vs-scan parity, shared-scan executor correctness
(incl. under injected faults), end-to-end QueryBatch equivalence, and
index save/load round-trip fidelity."""
import dataclasses
import threading

import numpy as np
import pytest

from repro.core.index import ApproxIndex
from repro.core.queries import (
    BatchQuery,
    QueryBatch,
    boolean_query,
    parse_boolean,
    phrase_count_query,
    ranked_query,
)
from repro.core.queries.retrieval import (
    _expr_eval_docs,
    _expr_eval_docs_scan,
    bm25_scores_for_shard,
    bm25_scores_for_shard_scan,
)
from repro.data.store import (
    docs_matching_all,
    docs_matching_all_scan,
    shard_postings,
)
from repro.runtime.executor import ShardTaskExecutor

QUERIES = [[3, 5, 9], [2], [10, 11], [7, 4, 5, 6]]


# ----------------------------------------------------------------------
# batched vs single-query scoring parity (all index modes)
# ----------------------------------------------------------------------
def _variants(index, corpus):
    yield "asym", index
    yield "asym+kernel", dataclasses.replace(index, use_kernel=True)
    yield "sym", dataclasses.replace(index, lsh_mode="sym")
    yield "sym+kernel", dataclasses.replace(index, lsh_mode="sym",
                                            use_kernel=True)
    yield "real", dataclasses.replace(index, use_lsh=False)
    yield "doc-granular", dataclasses.replace(
        index, granularity="doc").attach_corpus(corpus)


def test_batched_scores_match_single(small_corpus, built_index):
    for name, idx in _variants(built_index, small_corpus):
        batch = idx.shard_similarities_batch(QUERIES)
        singles = np.stack([idx.shard_similarities(q) for q in QUERIES])
        assert batch.shape == (len(QUERIES), small_corpus.n_shards)
        np.testing.assert_allclose(batch, singles, rtol=1e-5,
                                   err_msg=f"variant {name}")


def test_batched_word_scores_match_single(built_index):
    words = [3, 7, 9, 1500]
    batch = built_index.word_shard_similarities_batch(words)
    singles = np.stack([built_index.word_shard_similarity(w) for w in words])
    np.testing.assert_allclose(batch, singles, rtol=1e-5)


def test_signs_cache_keyed_by_role(built_index):
    built_index.shard_similarities([1, 2])
    built_index.word_shard_similarity(3)
    cache = getattr(built_index, "_signs")
    assert set(cache) <= {"shard", "doc", "word"}
    assert cache["shard"].shape == (built_index.shard_sig.shape[0],
                                    built_index.bits)


# ----------------------------------------------------------------------
# postings vs flat-scan parity
# ----------------------------------------------------------------------
def test_postings_bm25_matches_scan(small_corpus, built_index):
    rng = np.random.default_rng(0)
    df = built_index.doc_freq
    # out-of-vocab probes need a df entry too
    df_ext = np.concatenate([df, np.ones(64, np.int64)])
    for shard in small_corpus.shards[:6]:
        words = rng.integers(0, small_corpus.vocab_size + 60, 5).tolist()
        a = bm25_scores_for_shard(shard, words, df_ext, built_index.n_docs,
                                  built_index.avg_doc_len)
        b = bm25_scores_for_shard_scan(shard, words, df_ext,
                                       built_index.n_docs,
                                       built_index.avg_doc_len)
        np.testing.assert_array_equal(a, b)


def test_postings_boolean_matches_scan(small_corpus):
    rng = np.random.default_rng(1)
    for shard in small_corpus.shards[:6]:
        w = rng.integers(0, small_corpus.vocab_size + 60, 3)
        expr = parse_boolean([int(w[0]), "or", int(w[1]), "and", int(w[2])])
        np.testing.assert_array_equal(_expr_eval_docs(expr, shard),
                                      _expr_eval_docs_scan(expr, shard))
        np.testing.assert_array_equal(
            docs_matching_all(shard, w[:2].tolist()),
            docs_matching_all_scan(shard, w[:2].tolist()))


def test_postings_cached_and_counts(small_corpus):
    shard = small_corpus.shards[0]
    post = shard_postings(shard)
    assert post is shard_postings(shard)  # lazily built once, reused
    for w in (0, 5, 10**6):
        assert post.word_count(w) == int(np.count_nonzero(shard.tokens == w))


# ----------------------------------------------------------------------
# shared-scan executor
# ----------------------------------------------------------------------
class _FakeShard:
    def __init__(self, i):
        self.shard_id = i


class _FakeCorpus:
    def __init__(self, n):
        self.shards = [_FakeShard(i) for i in range(n)]


def test_map_shard_batch_matches_per_query_map_shards():
    corpus = _FakeCorpus(12)
    plan = [[0, 3, 5], [3, 5, 7, 9], [1], []]
    fns = [lambda s, k=k: (k, s.shard_id) for k in range(len(plan))]
    ex = ShardTaskExecutor(workers=3)
    got = ex.map_shard_batch(corpus, plan, fns)
    for qi, (ids, fn) in enumerate(zip(plan, fns)):
        want = ShardTaskExecutor(workers=3).map_shards(corpus, ids, fn)
        assert got[qi] == want


def test_map_shard_batch_visits_union_once():
    corpus = _FakeCorpus(10)
    visits = []
    lock = threading.Lock()

    def track(qi):
        def fn(shard):
            with lock:
                visits.append((qi, shard.shard_id))
            return shard.shard_id
        return fn

    plan = [[0, 1, 2, 3], [2, 3, 4, 5], [3, 4, 5, 6]]
    ex = ShardTaskExecutor(workers=1)  # no speculation -> exact visit count
    ex.map_shard_batch(corpus, plan, [track(q) for q in range(3)])
    # every (query, shard) pair evaluated exactly once; the underlying
    # shard visit count equals the union, not the sum of plan sizes
    assert sorted(visits) == sorted(
        (qi, s) for qi, ids in enumerate(plan) for s in ids)


def test_map_shard_batch_retries_faults():
    corpus = _FakeCorpus(8)
    fails = {"n": 0}

    def hook(sid, attempt):
        if sid == 2 and attempt == 1:
            fails["n"] += 1
            raise RuntimeError("injected")

    ex = ShardTaskExecutor(workers=2, max_retries=2, fault_hook=hook)
    plan = [[0, 2, 4], [2, 6]]
    got = ex.map_shard_batch(corpus, plan,
                             [lambda s: s.shard_id * 10,
                              lambda s: s.shard_id + 1])
    assert fails["n"] == 1 and ex.stats["retries"] == 1
    assert got[0] == {0: 0, 2: 20, 4: 40}
    assert got[1] == {2: 3, 6: 7}


def test_map_shard_batch_length_mismatch():
    with pytest.raises(ValueError):
        ShardTaskExecutor().map_shard_batch(_FakeCorpus(2), [[0]], [])


# ----------------------------------------------------------------------
# QueryBatch end-to-end
# ----------------------------------------------------------------------
def _mixed_queries():
    return [BatchQuery.count([5]),
            BatchQuery.ranked([3, 8, 11], k=5),
            BatchQuery.boolean(parse_boolean([4, "or", 9, "and", 12])),
            BatchQuery.count([7, 2]),
            BatchQuery.ranked([1, 2], k=8)]


@pytest.mark.parametrize("use_executor", [False, True])
def test_query_batch_matches_single_query_loop(small_corpus, built_index,
                                               use_executor):
    ex = ShardTaskExecutor(workers=3) if use_executor else None
    queries = _mixed_queries()
    got = QueryBatch(small_corpus, built_index, executor=ex).execute(
        queries, 0.3, rng=np.random.default_rng(42))
    rng = np.random.default_rng(42)
    want = [phrase_count_query(small_corpus, built_index, [5], 0.3, rng=rng),
            ranked_query(small_corpus, built_index, [3, 8, 11], 0.3, k=5,
                         rng=rng),
            boolean_query(small_corpus, built_index,
                          parse_boolean([4, "or", 9, "and", 12]), 0.3,
                          rng=rng),
            phrase_count_query(small_corpus, built_index, [7, 2], 0.3,
                               rng=rng),
            ranked_query(small_corpus, built_index, [1, 2], 0.3, k=8,
                         rng=rng)]
    np.testing.assert_allclose(got[0].estimate.value, want[0].estimate.value,
                               rtol=1e-6)
    np.testing.assert_allclose(got[3].estimate.value, want[3].estimate.value,
                               rtol=1e-6)
    np.testing.assert_array_equal(got[1].doc_ids, want[1].doc_ids)
    np.testing.assert_allclose(got[1].scores, want[1].scores, rtol=1e-12)
    np.testing.assert_array_equal(got[2].doc_ids, want[2].doc_ids)
    np.testing.assert_array_equal(got[4].doc_ids, want[4].doc_ids)
    for g, w in zip(got, want):
        assert g.shards_read == w.shards_read


def test_query_batch_precise_and_srcs(small_corpus, built_index):
    queries = _mixed_queries()
    precise = QueryBatch(small_corpus, built_index).execute(queries, 1.0)
    assert precise[0].estimate.error_bound == 0.0
    assert precise[0].estimate.value == small_corpus.count_phrase([5])
    assert precise[0].shards_read == small_corpus.n_shards
    # srcs needs no index at all
    srcs = QueryBatch(small_corpus, None, method="srcs").execute(
        queries, 0.3, rng=np.random.default_rng(3))
    assert len(srcs) == len(queries)
    with pytest.raises(ValueError):
        QueryBatch(small_corpus, None)          # emapprox requires index
    with pytest.raises(ValueError):
        QueryBatch(small_corpus, built_index, method="nope")


def test_query_batch_under_faults(small_corpus, built_index):
    fails = {"n": 0}

    def hook(sid, attempt):
        if sid in (0, 1) and attempt == 1:
            fails["n"] += 1
            raise RuntimeError("injected")

    ex = ShardTaskExecutor(workers=3, max_retries=2, fault_hook=hook)
    got = QueryBatch(small_corpus, built_index, executor=ex).execute(
        _mixed_queries(), 1.0)
    assert fails["n"] == 2 and ex.stats["retries"] == 2
    assert got[0].estimate.value == small_corpus.count_phrase([5])


# ----------------------------------------------------------------------
# save/load round-trip (granularity / use_kernel / doc->shard map)
# ----------------------------------------------------------------------
def test_save_load_preserves_execution_config(tmp_path, small_corpus,
                                              built_index):
    idx = dataclasses.replace(built_index, granularity="doc",
                              use_kernel=True).attach_corpus(small_corpus)
    p = str(tmp_path / "index.npz")
    idx.save(p)
    loaded = ApproxIndex.load(p)
    assert loaded.granularity == "doc"
    assert loaded.use_kernel is True
    assert loaded.lsh_mode == idx.lsh_mode
    np.testing.assert_array_equal(loaded._doc_shard_ids, idx._doc_shard_ids)
    # a persisted doc-granular index must score doc-granular after load
    np.testing.assert_allclose(loaded.shard_similarities([3, 5]),
                               idx.shard_similarities([3, 5]), rtol=1e-6)


# ----------------------------------------------------------------------
# error-budgeted execution (runtime.budget.RatePlanner integration)
# ----------------------------------------------------------------------
def test_planner_engine_parity_for_unbudgeted_queries(small_corpus,
                                                      built_index):
    """A planner on the engine must be bit-for-bit invisible to queries
    that carry no budget — including the precise rate-1.0 fast path and
    with CI construction on (the bootstrap never touches the sampling
    rng)."""
    from repro.runtime import RatePlanner
    queries = _mixed_queries()
    for rate in (0.3, 1.0):
        plain = QueryBatch(small_corpus, built_index).execute(
            queries, rate, rng=np.random.default_rng(21))
        planned = QueryBatch(
            small_corpus, built_index,
            planner=RatePlanner(small_corpus.n_shards),
            ci=True).execute(queries, rate, rng=np.random.default_rng(21))
        for q, a, b in zip(queries, plain, planned):
            if q.kind == "count":
                assert b.estimate.value == a.estimate.value
                assert b.estimate.error_bound == a.estimate.error_bound
            else:
                np.testing.assert_array_equal(b.doc_ids, a.doc_ids)
                if hasattr(a, "scores"):
                    np.testing.assert_array_equal(b.scores, a.scores)
            assert b.shards_read == a.shards_read


def test_budgeted_queries_plan_their_own_rates(small_corpus, built_index):
    from repro.runtime import QueryBudget, RatePlanner
    planner = RatePlanner(small_corpus.n_shards)
    engine = QueryBatch(small_corpus, built_index, planner=planner,
                        ci=True)
    assert engine.accepts_pressure
    budget = QueryBudget(max_rel_error=0.6, floor_rate=0.25)
    queries = [dataclasses.replace(q, budget=budget)
               for q in _mixed_queries()]
    res = engine.execute(queries, 0.3, rng=np.random.default_rng(5))
    audit = engine.last_budget
    assert audit is not None
    assert audit["budgeted"] == len(queries)
    assert audit["pressure"] == 0.0 and audit["degraded"] == 0
    n = small_corpus.n_shards
    for q, r, planned in zip(queries, res, audit["planned_rates"]):
        assert 0.25 <= planned <= 1.0
        n_req = int(np.ceil(planned * n))
        if q.kind == "count":
            # with-replacement draws match the plan; *distinct* shards
            # physically read may be fewer (duplicates dedup in I/O)
            assert r.estimate.n == n_req
            assert r.shards_read <= n_req
        else:
            # retrieval samples distinct shards: achieved rate is the
            # ceil-quantized planned rate exactly
            assert r.shards_read == min(n, n_req)
        assert r.estimate is not None          # every kind carries a CI
    assert len(audit["realized_rel_error"]) == len(queries)
    # the loop closed: realized errors fed the per-kind curves
    assert planner.curve("count").count >= 1


def test_budget_pressure_degrades_to_floor(small_corpus, built_index):
    """pressure=1.0 squeezes every budgeted query to its floor and the
    audit lands on the executor's last_job (the balance-audit
    pattern)."""
    from repro.runtime import QueryBudget, RatePlanner
    budget = QueryBudget(max_rel_error=0.5, floor_rate=0.25)
    queries = [dataclasses.replace(q, budget=budget)
               for q in _mixed_queries()]
    ex = ShardTaskExecutor(workers=2)
    engine = QueryBatch(small_corpus, built_index, executor=ex,
                        planner=RatePlanner(small_corpus.n_shards),
                        ci=True)
    res = engine.execute(queries, 0.3, rng=np.random.default_rng(6),
                         pressure=1.0)
    audit = engine.last_budget
    assert audit["pressure"] == 1.0
    assert audit["at_floor"] == len(queries)
    assert all(r == pytest.approx(0.25) for r in audit["planned_rates"])
    n = small_corpus.n_shards
    for r in res:
        assert r.shards_read <= int(np.ceil(0.25 * n))
    assert ex.last_job["budget"] == audit
    # a degraded count still reports an honest interval: possibly
    # infinite (collapsed sample), never NaN
    for q, r in zip(queries, res):
        if q.kind == "count":
            assert not np.isnan(r.estimate.error_bound)
    ex.close()


def test_ci_flag_adds_intervals_to_retrieval(small_corpus, built_index):
    """ci=True: boolean results carry a bootstrap count estimate,
    ranked results a top-k stability score in [0, 1]; ci=False leaves
    the estimate slot empty (legacy shape)."""
    queries = _mixed_queries()
    on = QueryBatch(small_corpus, built_index, ci=True).execute(
        queries, 0.4, rng=np.random.default_rng(9))
    off = QueryBatch(small_corpus, built_index).execute(
        queries, 0.4, rng=np.random.default_rng(9))
    for q, r_on, r_off in zip(queries, on, off):
        if q.kind == "bool":
            assert r_on.estimate is not None
            assert r_on.estimate.value >= 0.0
            assert r_off.estimate is None
        elif q.kind == "ranked":
            assert r_on.estimate is not None
            assert 0.0 <= r_on.estimate.value <= 1.0
            assert r_off.estimate is None
