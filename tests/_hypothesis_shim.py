"""Import indirection for ``hypothesis`` so the suite degrades gracefully.

This container has no network access and ``hypothesis`` is not baked
into the image, so a bare ``from hypothesis import given`` aborts the
whole pytest collection (4 modules' worth of non-property tests were
being lost with it).  Import ``given``/``settings``/``st`` from this
module instead: when hypothesis is available they are the real thing;
when it is missing, ``@given`` rewrites the test into a zero-argument
stub that calls ``pytest.skip`` so property tests skip cleanly while
every example-based test in the same module still runs.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: every strategy
        constructor (``st.integers(...)``, ``st.floats(...)``, ...)
        returns an inert placeholder — ``@given`` below never calls
        into it."""

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None

            return _strategy

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco

    def given(*args, **kwargs):
        def deco(fn):
            # Zero-arg replacement: pytest must not try to resolve the
            # property arguments (x, seed, ...) as fixtures.
            def skipper():
                pytest.skip("hypothesis not installed")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco
