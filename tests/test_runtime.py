"""Fault-tolerance runtime: executor retries, speculation, checkpoints,
optimizer state compression, pipeline."""
import os
import threading
import time

import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.runtime.executor import ShardTaskError, ShardTaskExecutor


class _FakeShard:
    def __init__(self, i):
        self.shard_id = i


class _FakeCorpus:
    def __init__(self, n):
        self.shards = [_FakeShard(i) for i in range(n)]


def test_executor_basic():
    ex = ShardTaskExecutor(workers=4)
    out = ex.map_shards(_FakeCorpus(10), range(10), lambda s: s.shard_id * 2)
    assert out == {i: i * 2 for i in range(10)}


def test_executor_retries_transient_failures():
    fails = {3: 2, 7: 1}   # shard -> number of failures before success

    def hook(sid, attempt):
        if fails.get(sid, 0) >= attempt:
            raise RuntimeError(f"injected fault on {sid}")

    ex = ShardTaskExecutor(workers=4, max_retries=3, fault_hook=hook)
    out = ex.map_shards(_FakeCorpus(10), range(10), lambda s: s.shard_id)
    assert out == {i: i for i in range(10)}
    assert ex.stats["retries"] >= 3


def test_executor_permanent_failure_raises():
    def hook(sid, attempt):
        if sid == 5:
            raise RuntimeError("dead shard")

    ex = ShardTaskExecutor(workers=2, max_retries=1, fault_hook=hook)
    with pytest.raises(ShardTaskError):
        ex.map_shards(_FakeCorpus(8), range(8), lambda s: s.shard_id)


def test_executor_straggler_speculation():
    slow_once = {9}
    seen = {}
    lock = threading.Lock()

    def work(shard):
        with lock:
            n = seen.get(shard.shard_id, 0)
            seen[shard.shard_id] = n + 1
        if shard.shard_id in slow_once and n == 0:
            time.sleep(1.5)    # straggler on first attempt
        else:
            time.sleep(0.01)
        return shard.shard_id

    ex = ShardTaskExecutor(workers=4, straggler_factor=3.0,
                           min_completed_for_speculation=4)
    out = ex.map_shards(_FakeCorpus(10), range(10), work)
    assert out[9] == 9
    assert ex.stats["speculative"] >= 1
    # the duplicate attempt actually ran (n >= 2 for the straggler)
    assert seen[9] >= 2


def test_executor_elastic_resize():
    ex = ShardTaskExecutor(workers=2)
    ex.resize(8)
    out = ex.map_shards(_FakeCorpus(20), range(20), lambda s: 1)
    assert len(out) == 20


def test_executor_failure_drains_in_flight_tasks():
    """Regression: ShardTaskError must not escape while sibling tasks
    are still running on the shared warm pool (the old per-job pool
    guaranteed quiescence via its `with` shutdown)."""
    running = {"n": 0}
    lock = threading.Lock()

    def work(shard):
        if shard.shard_id == 5:
            raise RuntimeError("dead shard")
        with lock:
            running["n"] += 1
        time.sleep(0.15)
        with lock:
            running["n"] -= 1
        return shard.shard_id

    ex = ShardTaskExecutor(workers=4, max_retries=0)
    with pytest.raises(ShardTaskError):
        ex.map_shards(_FakeCorpus(8), range(8), work)
    assert running["n"] == 0          # no zombie tasks past the raise
    ex.close()


def test_executor_warm_pool_persists_across_jobs():
    ex = ShardTaskExecutor(workers=3)
    ex.map_shards(_FakeCorpus(10), range(10), lambda s: s.shard_id)
    pool = ex._pool
    assert pool is not None
    ex.map_shards(_FakeCorpus(6), range(6), lambda s: s.shard_id)
    assert ex._pool is pool                   # no per-job construction
    assert ex.stats["pool_rebuilds"] == 1
    assert ex.stats["jobs"] == 2
    ex.resize(5)                              # swap happens on next job
    assert ex._pool is pool
    ex.map_shards(_FakeCorpus(4), range(4), lambda s: 1)
    assert ex._pool is not pool and ex._pool_size == 5
    assert ex.stats["pool_rebuilds"] == 2
    ex.close()
    assert ex._pool is None
    ex.close()                                # idempotent


def test_executor_adaptive_workers_by_task_granularity():
    # generous floor so ~us numpy-ish tasks are unambiguously "tiny"
    ex = ShardTaskExecutor(workers=8, adaptive_workers=True,
                           gil_floor_s=0.02)
    assert ex.target_workers() == 8           # no evidence yet
    ex.map_shards(_FakeCorpus(16), range(16), lambda s: s.shard_id)
    assert ex.target_workers() == 2           # GIL-bound tasks -> shrink
    ex.map_shards(_FakeCorpus(4), range(4),
                  lambda s: time.sleep(0.1) or s.shard_id)
    assert ex.target_workers() == 8           # long tasks -> widen back
    ex.close()


def test_executor_context_manager_closes_pool():
    with ShardTaskExecutor(workers=2) as ex:
        ex.map_shards(_FakeCorpus(4), range(4), lambda s: 1)
        assert ex._pool is not None
    assert ex._pool is None


# ----------------------------------------------------------------------
# checkpointing
# ----------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    import jax.numpy as jnp
    from repro.checkpoint import (
        CheckpointManager, restore_checkpoint, save_checkpoint, latest_step)
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4))}}
    save_checkpoint(str(tmp_path), 5, tree)
    assert latest_step(str(tmp_path)) == 5
    restored = restore_checkpoint(str(tmp_path), 5, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_manager_async_and_gc(tmp_path):
    import jax.numpy as jnp
    from repro.checkpoint import CheckpointManager, latest_step
    m = CheckpointManager(str(tmp_path), keep=2, async_write=True)
    tree = {"w": jnp.zeros((64,))}
    for step in (1, 2, 3, 4):
        m.save(step, tree)
    m.wait()
    assert latest_step(str(tmp_path)) == 4
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 2


def test_checkpoint_chunked_large_leaf(tmp_path):
    import jax.numpy as jnp
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    big = jnp.arange(2 << 20, dtype=jnp.float32).reshape(1 << 11, -1)
    save_checkpoint(str(tmp_path), 1, {"big": big}, chunk_elems=1 << 18)
    r = restore_checkpoint(str(tmp_path), 1, {"big": big})
    np.testing.assert_array_equal(np.asarray(r["big"]), np.asarray(big))
    files = os.listdir(os.path.join(tmp_path, "step_1"))
    assert sum(1 for f in files if "chunk" in f) > 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    import jax.numpy as jnp
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    save_checkpoint(str(tmp_path), 1, {"a": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 1, {"a": jnp.zeros((5,))})


# ----------------------------------------------------------------------
# optimizer / compression
# ----------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.integers(1, 2000))
def test_q8_roundtrip_error_bounded(seed, n):
    from repro.optimizer.quantized import q8_dequantize, q8_quantize
    rng = np.random.default_rng(seed)
    x = rng.normal(size=n).astype(np.float32) * rng.uniform(0.01, 100)
    q = q8_quantize(x)
    back = np.asarray(q8_dequantize(q, x.shape))
    # per-block error <= absmax/254 (half a code)
    err = np.abs(back - x)
    assert err.max() <= np.abs(x).max() / 127.0 + 1e-6


def test_adamw_converges_quadratic():
    import jax
    import jax.numpy as jnp
    from repro.optimizer.adamw import AdamWConfig, adamw_init, adamw_update
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params, cfg)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        params, opt, _ = adamw_update(params, grads, opt, cfg)
    assert np.abs(np.asarray(params["x"])).max() < 0.05


@pytest.mark.parametrize("state_dtype", ["bfloat16", "q8"])
def test_adamw_compressed_states(state_dtype):
    import jax
    import jax.numpy as jnp
    from repro.optimizer.adamw import AdamWConfig, adamw_init, adamw_update
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, state_dtype=state_dtype)
    params = {"w": jnp.ones((300,)) * 4.0}
    opt = adamw_init(params, cfg)
    for _ in range(150):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, _ = adamw_update(params, grads, opt, cfg)
    assert np.abs(np.asarray(params["w"])).max() < 0.3


def test_compressed_psum_error_feedback():
    """Quantize-roundtrip residual is carried, so the *sum over steps*
    of compressed gradients tracks the true sum (error feedback)."""
    from repro.distributed.compression import quantize_roundtrip
    rng = np.random.default_rng(0)
    total_true = np.zeros(512, np.float32)
    total_sent = np.zeros(512, np.float32)
    err = np.zeros(512, np.float32)
    import jax.numpy as jnp
    for _ in range(30):
        g = rng.normal(size=512).astype(np.float32)
        total_true += g
        approx, new_err = quantize_roundtrip(jnp.asarray(g + err))
        total_sent += np.asarray(approx)
        err = np.asarray(new_err)
    drift = np.abs(total_sent + err - total_true).max()
    assert drift < 1e-3


# ----------------------------------------------------------------------
# data pipeline
# ----------------------------------------------------------------------
def test_lm_pipeline_batches(small_corpus):
    from repro.data.pipeline import LMBatchPipeline
    p = LMBatchPipeline(small_corpus, batch_size=4, seq_len=64)
    batches = list(p.iter_epoch(0))
    assert len(batches) > 3
    for b in batches:
        assert b["tokens"].shape == (4, 64)
        assert b["labels"].shape == (4, 64)
        # labels are next-token shifted wherever mask is on
        m = b["mask"][:, :-1] * b["mask"][:, 1:]
        np.testing.assert_array_equal(
            (b["labels"][:, :-1] * m).astype(np.int64),
            (b["tokens"][:, 1:] * m).astype(np.int64))


def test_prefetch_iterator():
    from repro.data.pipeline import PrefetchIterator
    it = PrefetchIterator(iter(range(100)), depth=4)
    assert list(it) == list(range(100))


def test_prefetch_propagates_errors():
    from repro.data.pipeline import PrefetchIterator

    def gen():
        yield 1
        raise ValueError("boom")

    it = PrefetchIterator(gen())
    assert next(it) == 1
    with pytest.raises(ValueError):
        list(it)


def test_similarity_sampler():
    from repro.data.pipeline import SimilaritySampler
    p = np.asarray([0.7, 0.1, 0.1, 0.1])
    s = SimilaritySampler(p, seed=0)
    draws = s.draw_epoch_order(4000)
    frac = (draws == 0).mean()
    assert 0.6 < frac < 0.8
