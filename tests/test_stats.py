"""t critical values vs tabulated references."""
import pytest

from repro.utils.stats import t_critical_value

# (df, 95% two-sided critical value) from standard t tables
TABLE_95 = [
    (1, 12.706), (2, 4.303), (3, 3.182), (4, 2.776), (5, 2.571),
    (10, 2.228), (20, 2.086), (30, 2.042), (60, 2.000), (120, 1.980),
]

TABLE_99 = [(5, 4.032), (10, 3.169), (30, 2.750), (120, 2.617)]


@pytest.mark.parametrize("df,expected", TABLE_95)
def test_t95(df, expected):
    assert t_critical_value(df, 0.95) == pytest.approx(expected, abs=5e-3)


@pytest.mark.parametrize("df,expected", TABLE_99)
def test_t99(df, expected):
    assert t_critical_value(df, 0.99) == pytest.approx(expected, abs=1e-2)


def test_monotone_in_confidence():
    assert t_critical_value(10, 0.99) > t_critical_value(10, 0.95)


def test_limits_to_normal():
    assert t_critical_value(10000, 0.95) == pytest.approx(1.96, abs=1e-2)


def test_invalid():
    with pytest.raises(ValueError):
        t_critical_value(0)
