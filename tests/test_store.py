"""Document store: phrase counting oracle, reallocation, boundaries."""
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.data.store import (
    DocShard,
    Document,
    ShardedCorpus,
    count_phrase_in_shard,
    docs_matching_all,
)


def naive_count(docs, phrase):
    total = 0
    k = len(phrase)
    for d in docs:
        t = d.tokens.tolist()
        total += sum(1 for i in range(len(t) - k + 1)
                     if t[i:i + k] == list(phrase))
    return total


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 5000),
    n_docs=st.integers(1, 12),
    vocab=st.integers(2, 6),
    k=st.integers(1, 3),
)
def test_count_phrase_matches_naive(seed, n_docs, vocab, k):
    """Property: vectorized n-gram counting == naive scan, never
    crossing document boundaries."""
    rng = np.random.default_rng(seed)
    docs = [Document(i, rng.integers(0, vocab, rng.integers(0, 20)).astype(np.int32))
            for i in range(n_docs)]
    shard = DocShard.from_documents(0, docs)
    phrase = rng.integers(0, vocab, k).tolist()
    assert count_phrase_in_shard(shard, phrase) == naive_count(docs, phrase)


def test_phrase_never_crosses_boundary():
    docs = [Document(0, np.asarray([1, 2], np.int32)),
            Document(1, np.asarray([3, 4], np.int32))]
    shard = DocShard.from_documents(0, docs)
    assert count_phrase_in_shard(shard, [2, 3]) == 0
    assert count_phrase_in_shard(shard, [1, 2]) == 1


def test_reallocate_preserves_documents(small_corpus):
    n = small_corpus.n_docs
    rng = np.random.default_rng(0)
    assign = rng.integers(0, 7, n)
    new = small_corpus.reallocate(assign, 7)
    assert new.n_docs == n
    assert new.n_tokens == small_corpus.n_tokens
    # every doc in its assigned shard
    m = new.doc_shard_map()
    np.testing.assert_array_equal(m, assign)


def test_segment_sum_trailing_empty_doc():
    """Regression: an empty doc at the end must not truncate the last
    non-empty doc's sum (reduceat start-clamping folded it away)."""
    from repro.data.store import segment_sum_by_offsets
    vals = np.asarray([1.0, 2.0, 4.0])
    offsets = np.asarray([0, 1, 3, 3])          # docs: [1], [2,4], []
    np.testing.assert_allclose(segment_sum_by_offsets(vals, offsets),
                               [1.0, 6.0, 0.0])
    offsets = np.asarray([0, 0, 3, 3])          # empty at both ends
    np.testing.assert_allclose(segment_sum_by_offsets(vals, offsets),
                               [0.0, 7.0, 0.0])


def test_docs_matching_all():
    docs = [Document(0, np.asarray([1, 2, 3], np.int32)),
            Document(1, np.asarray([1, 1], np.int32)),
            Document(2, np.asarray([], np.int32))]
    shard = DocShard.from_documents(5, docs)
    np.testing.assert_array_equal(docs_matching_all(shard, [1, 2]), [0])
    np.testing.assert_array_equal(docs_matching_all(shard, [1]), [0, 1])


def test_corpus_shard_budget(small_corpus):
    # sequential allocation: every shard except the last near the budget
    sizes = small_corpus.shard_token_counts()
    assert (sizes[:-1] >= 4096).all()


# ----------------------------------------------------------------------
# persistence: shard payload + postings round-trip
# ----------------------------------------------------------------------
def _tiny_corpus(seed=0, n_docs=40, vocab=50):
    rng = np.random.default_rng(seed)
    docs = [Document(i, rng.integers(0, vocab, rng.integers(1, 30))
                     .astype(np.int32)) for i in range(n_docs)]
    return ShardedCorpus.from_documents(docs, vocab, shard_tokens=100)


def test_corpus_save_load_roundtrip(tmp_path):
    from repro.data.store import shard_postings
    corpus = _tiny_corpus()
    path = str(tmp_path / "corpus.npz")
    corpus.save(path)
    loaded = ShardedCorpus.load(path)
    assert loaded.n_shards == corpus.n_shards
    assert loaded.n_docs == corpus.n_docs
    assert loaded.vocab_size == corpus.vocab_size
    for s, s2 in zip(corpus.shards, loaded.shards):
        np.testing.assert_array_equal(s.tokens, s2.tokens)
        np.testing.assert_array_equal(s.offsets, s2.offsets)
        np.testing.assert_array_equal(s.doc_ids, s2.doc_ids)
    assert loaded.count_phrase([3]) == corpus.count_phrase([3])
    np.testing.assert_array_equal(loaded.doc_shard_map(),
                                  corpus.doc_shard_map())


def test_corpus_save_persists_postings(tmp_path):
    """Postings ride along with the payload: a cold open serves its
    first query from the persisted CSR, no lazy rebuild."""
    from repro.data.store import build_postings, shard_postings
    corpus = _tiny_corpus(seed=1)
    path = str(tmp_path / "corpus.npz")
    corpus.save(path)                        # builds + persists postings
    loaded = ShardedCorpus.load(path)
    for shard in loaded.shards:
        pre_attached = getattr(shard, "_postings", None)
        assert pre_attached is not None      # cache hit from query one
        assert shard_postings(shard) is pre_attached
        fresh = build_postings(shard)
        np.testing.assert_array_equal(pre_attached.indptr, fresh.indptr)
        np.testing.assert_array_equal(pre_attached.doc_idx, fresh.doc_idx)
        np.testing.assert_array_equal(pre_attached.tf, fresh.tf)


def test_corpus_save_without_postings_stays_lazy(tmp_path):
    corpus = _tiny_corpus(seed=2)
    path = str(tmp_path / "raw.npz")
    corpus.save(path, include_postings=False)
    loaded = ShardedCorpus.load(path)
    assert all(getattr(s, "_postings", None) is None for s in loaded.shards)
    # lazily built on demand, exactly as before persistence existed
    w = int(loaded.shards[0].tokens[0])
    from repro.data.store import shard_postings
    assert shard_postings(loaded.shards[0]).word_count(w) > 0
