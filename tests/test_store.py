"""Document store: phrase counting oracle, reallocation, boundaries."""
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.data.store import (
    DocShard,
    Document,
    ShardedCorpus,
    count_phrase_in_shard,
    docs_matching_all,
)


def naive_count(docs, phrase):
    total = 0
    k = len(phrase)
    for d in docs:
        t = d.tokens.tolist()
        total += sum(1 for i in range(len(t) - k + 1)
                     if t[i:i + k] == list(phrase))
    return total


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 5000),
    n_docs=st.integers(1, 12),
    vocab=st.integers(2, 6),
    k=st.integers(1, 3),
)
def test_count_phrase_matches_naive(seed, n_docs, vocab, k):
    """Property: vectorized n-gram counting == naive scan, never
    crossing document boundaries."""
    rng = np.random.default_rng(seed)
    docs = [Document(i, rng.integers(0, vocab, rng.integers(0, 20)).astype(np.int32))
            for i in range(n_docs)]
    shard = DocShard.from_documents(0, docs)
    phrase = rng.integers(0, vocab, k).tolist()
    assert count_phrase_in_shard(shard, phrase) == naive_count(docs, phrase)


def test_phrase_never_crosses_boundary():
    docs = [Document(0, np.asarray([1, 2], np.int32)),
            Document(1, np.asarray([3, 4], np.int32))]
    shard = DocShard.from_documents(0, docs)
    assert count_phrase_in_shard(shard, [2, 3]) == 0
    assert count_phrase_in_shard(shard, [1, 2]) == 1


def test_reallocate_preserves_documents(small_corpus):
    n = small_corpus.n_docs
    rng = np.random.default_rng(0)
    assign = rng.integers(0, 7, n)
    new = small_corpus.reallocate(assign, 7)
    assert new.n_docs == n
    assert new.n_tokens == small_corpus.n_tokens
    # every doc in its assigned shard
    m = new.doc_shard_map()
    np.testing.assert_array_equal(m, assign)


def test_docs_matching_all():
    docs = [Document(0, np.asarray([1, 2, 3], np.int32)),
            Document(1, np.asarray([1, 1], np.int32)),
            Document(2, np.asarray([], np.int32))]
    shard = DocShard.from_documents(5, docs)
    np.testing.assert_array_equal(docs_matching_all(shard, [1, 2]), [0])
    np.testing.assert_array_equal(docs_matching_all(shard, [1]), [0, 1])


def test_corpus_shard_budget(small_corpus):
    # sequential allocation: every shard except the last near the budget
    sizes = small_corpus.shard_token_counts()
    assert (sizes[:-1] >= 4096).all()
