"""Warm adaptive serving runtime: BatchWindow deadline/size/flush close
behavior under synthetic arrival traces, error delivery, and the
end-to-end window -> QueryBatch -> warm executor path."""
import threading
import time

import pytest

from repro.runtime import BatchWindow, ShardTaskExecutor


class _RecordingEngine:
    """Stands in for QueryBatch: records every executed batch."""

    def __init__(self, delay_s: float = 0.0):
        self.batches = []
        self.delay_s = delay_s
        self._lock = threading.Lock()

    def execute(self, queries, rate, rng=None):
        if self.delay_s:
            time.sleep(self.delay_s)
        with self._lock:
            self.batches.append(list(queries))
        return [("done", q, rate) for q in queries]


def test_window_closes_by_size():
    eng = _RecordingEngine()
    with BatchWindow(eng, 0.5, max_batch=4, max_delay_s=30.0) as win:
        futs = [win.submit(i) for i in range(8)]
        results = [f.result(timeout=10) for f in futs]
    assert results == [("done", i, 0.5) for i in range(8)]
    assert win.stats["closed_by_size"] == 2
    assert win.stats["closed_by_deadline"] == 0
    assert win.stats["served"] == 8
    assert [len(b) for b in eng.batches] == [4, 4]


def test_window_closes_by_deadline():
    eng = _RecordingEngine()
    win = BatchWindow(eng, 0.5, max_batch=100, max_delay_s=0.05)
    t0 = time.perf_counter()
    futs = [win.submit(i) for i in range(3)]
    results = [f.result(timeout=10) for f in futs]
    waited = time.perf_counter() - t0
    win.close()
    assert [r[1] for r in results] == [0, 1, 2]
    assert win.stats["closed_by_deadline"] == 1
    assert win.stats["closed_by_size"] == 0
    # the batch waited for the deadline, not for max_batch arrivals
    assert 0.04 <= waited < 5.0
    assert eng.batches == [[0, 1, 2]]


def test_window_synthetic_trace_mixes_close_reasons():
    """A burst (size close) followed by a trickle (deadline close)."""
    eng = _RecordingEngine()
    win = BatchWindow(eng, 1.0, max_batch=5, max_delay_s=0.05)
    futs = [win.submit(i) for i in range(5)]          # burst: exactly one
    [f.result(timeout=10) for f in futs]              # full window
    late = win.submit(99)                             # lone straggler
    assert late.result(timeout=10)[1] == 99
    win.close()
    assert win.stats["closed_by_size"] == 1
    assert win.stats["closed_by_deadline"] == 1
    assert win.stats["batches"] == 2


def test_window_flush_and_close_drain():
    eng = _RecordingEngine()
    win = BatchWindow(eng, 1.0, max_batch=100, max_delay_s=30.0)
    f1 = win.submit("a")
    win.flush()
    assert f1.result(timeout=10)[1] == "a"
    assert win.stats["closed_by_flush"] == 1
    f2 = win.submit("b")
    win.close()                        # close() must drain the open window
    assert f2.result(timeout=10)[1] == "b"
    assert win.stats["served"] == 2
    with pytest.raises(RuntimeError):
        win.submit("c")


def test_window_survives_cancelled_futures():
    """Regression: a caller cancelling a pending future must not kill
    the dispatcher (set_result on a cancelled future raises)."""
    eng = _RecordingEngine()
    win = BatchWindow(eng, 1.0, max_batch=100, max_delay_s=0.05)
    doomed = win.submit("doomed")
    assert doomed.cancel()
    ok = win.submit("ok")
    assert ok.result(timeout=10)[1] == "ok"      # dispatcher still alive
    later = win.submit("later")
    assert later.result(timeout=10)[1] == "later"
    win.close()
    assert win.stats["cancelled"] == 1
    assert win.stats["served"] == 2
    assert all("doomed" not in b for b in eng.batches)


def test_window_delivers_engine_failures():
    class Boom:
        def execute(self, queries, rate, rng=None):
            raise RuntimeError("engine exploded")

    win = BatchWindow(Boom(), 1.0, max_batch=2, max_delay_s=0.01)
    f1, f2 = win.submit(1), win.submit(2)
    for f in (f1, f2):
        with pytest.raises(RuntimeError):
            f.result(timeout=10)
    win.close()


def test_window_rejects_bad_config():
    eng = _RecordingEngine()
    with pytest.raises(ValueError):
        BatchWindow(eng, 1.0, max_batch=0)
    with pytest.raises(ValueError):
        BatchWindow(eng, 1.0, max_delay_s=-1.0)


def test_window_end_to_end_precise(small_corpus, built_index):
    """Window -> QueryBatch -> warm executor at rate 1.0: the precise
    answers must be independent of how arrivals were windowed."""
    from repro.core.queries import BatchQuery, QueryBatch, parse_boolean
    ex = ShardTaskExecutor(workers=2)
    engine = QueryBatch(small_corpus, built_index, executor=ex)
    queries = [BatchQuery.count([5]),
               BatchQuery.boolean(parse_boolean([4, "or", 9])),
               BatchQuery.count([7, 2]),
               BatchQuery.ranked([3, 8], k=5)]
    with BatchWindow(engine, 1.0, max_batch=3, max_delay_s=0.02) as win:
        futs = [win.submit(q) for q in queries]
        results = [f.result(timeout=60) for f in futs]
    assert results[0].estimate.value == small_corpus.count_phrase([5])
    assert results[2].estimate.value == small_corpus.count_phrase([7, 2])
    assert results[0].shards_read == small_corpus.n_shards
    # warm pool was reused across windows, not rebuilt per batch
    assert ex.stats["jobs"] >= 2
    assert ex.stats["pool_rebuilds"] == 1
    ex.close()
