"""Semantic query cache: exact-hit bit-for-bit parity, near-hit
estimator unbiasedness, placement-epoch fencing through FleetManager,
LRU/TTL eviction, and the fidelity fences (degraded / budgeted /
pressured answers never cached)."""
import json

import numpy as np
import pytest

from repro.core.queries import BatchQuery, QueryBatch, parse_boolean
from repro.runtime import (
    FleetManager,
    HostGroupExecutor,
    PlacementMap,
    WindowController,
)
from repro.runtime.budget import QueryBudget, RatePlanner
from repro.runtime.qcache import (
    QueryCacheConfig,
    SemanticQueryCache,
    query_key,
    sampler_class,
)

RATE = 0.4


def _queries():
    return [BatchQuery.count([5]),
            BatchQuery.boolean(parse_boolean([3, "and", 8])),
            BatchQuery.ranked([3, 8, 11], k=5),
            BatchQuery.count([2, 7])]


def _cfg(**kw):
    kw.setdefault("max_entries", 64)
    kw.setdefault("ttl_s", 3600.0)
    kw.setdefault("hamming_radius", 0)
    return QueryCacheConfig(**kw)


def _strip_elapsed(res):
    return res._replace(elapsed_s=0.0)


def _same_result(a, b):
    return repr(_strip_elapsed(a)) == repr(_strip_elapsed(b))


# ----------------------------------------------------------------------
# unit: keys, config, LRU / TTL / epoch mechanics (no engine)
# ----------------------------------------------------------------------
def _sig(*bits):
    """A 128-bit packed signature with the given bit positions set."""
    words = np.zeros(4, np.uint32)
    for b in bits:
        words[b // 32] |= np.uint32(1) << np.uint32(b % 32)
    return words


def test_query_key_distinguishes_kinds_and_structure():
    keys = {query_key(q) for q in _queries()}
    assert len(keys) == 4
    # same words, different k -> different identity
    assert (query_key(BatchQuery.ranked([1, 2], k=5))
            != query_key(BatchQuery.ranked([1, 2], k=7)))
    # AND vs OR over the same words -> different identity
    assert (query_key(BatchQuery.boolean(parse_boolean([1, "and", 2])))
            != query_key(BatchQuery.boolean(parse_boolean([1, "or", 2]))))
    assert sampler_class("count") == "hh"
    assert sampler_class("bool") == sampler_class("ranked") == "distinct"


def test_config_validation():
    with pytest.raises(ValueError):
        QueryCacheConfig(max_entries=0)
    with pytest.raises(ValueError):
        QueryCacheConfig(ttl_s=0.0)
    with pytest.raises(ValueError):
        QueryCacheConfig(hamming_radius=-1)


def test_exact_hit_requires_key_and_rate():
    c = SemanticQueryCache(_cfg())
    k = ("count", (5,))
    c.insert(_sig(3), k, "hh", 0.4, probs=None, sample="S", plan="P",
             result="R", epoch=0)
    kind, e = c.lookup(_sig(3), k, "hh", 0.4, 0)
    assert kind == "hit" and e.result == "R"
    # different rate -> miss even with an identical signature
    assert c.lookup(_sig(3), k, "hh", 0.5, 0)[0] == "miss"
    # different query at the same signature -> NOT a full hit: it may
    # only borrow the plan (a radius-0 "near"), never the result
    kind, e = c.lookup(_sig(3), ("count", (6,)), "hh", 0.4, 0)
    assert kind == "near" and e.plan == "P"
    assert c.stats == dict(hits=1, near_hits=1, misses=1, bypassed=0,
                           insertions=1, evictions=0, expired=0,
                           stale_epoch=0)


def test_near_hit_within_radius_same_class_same_rate():
    c = SemanticQueryCache(_cfg(hamming_radius=2))
    c.insert(_sig(3, 64), ("count", (5,)), "hh", 0.4, probs=None,
             sample="S", plan="P", result="R", epoch=0)
    # 1 bit away, same class/rate: borrows the plan
    kind, e = c.lookup(_sig(3, 64, 99), ("count", (6,)), "hh", 0.4, 0)
    assert kind == "near" and e.plan == "P"
    # 3 bits away: outside the radius
    assert c.lookup(_sig(3, 64, 97, 98, 99), ("count", (6,)),
                    "hh", 0.4, 0)[0] == "miss"
    # same signature, wrong sampler class or rate: never near
    assert c.lookup(_sig(3, 64), ("ranked", (5,), 10), "distinct",
                    0.4, 0)[0] == "miss"
    assert c.lookup(_sig(3, 64), ("count", (6,)), "hh", 0.3, 0)[0] == "miss"


def test_lru_eviction_bound():
    c = SemanticQueryCache(_cfg(max_entries=3))
    for i in range(5):
        c.insert(_sig(i), ("count", (i,)), "hh", 0.4, probs=None,
                 sample=None, plan=None, result=i, epoch=0)
    assert len(c) == 3 and c.stats["evictions"] == 2
    # oldest two are gone, newest three live
    assert c.lookup(_sig(0), ("count", (0,)), "hh", 0.4, 0)[0] == "miss"
    assert c.lookup(_sig(4), ("count", (4,)), "hh", 0.4, 0)[0] == "hit"
    # a hit refreshes recency: 2 survives the next two insertions
    c.lookup(_sig(2), ("count", (2,)), "hh", 0.4, 0)
    for i in range(5, 7):
        c.insert(_sig(i), ("count", (i,)), "hh", 0.4, probs=None,
                 sample=None, plan=None, result=i, epoch=0)
    assert c.lookup(_sig(2), ("count", (2,)), "hh", 0.4, 0)[0] == "hit"


def test_ttl_expiry_with_injected_clock():
    t = [0.0]
    c = SemanticQueryCache(_cfg(ttl_s=10.0), clock=lambda: t[0])
    c.insert(_sig(1), ("count", (1,)), "hh", 0.4, probs=None,
             sample=None, plan=None, result="R", epoch=0)
    t[0] = 9.0
    assert c.lookup(_sig(1), ("count", (1,)), "hh", 0.4, 0)[0] == "hit"
    t[0] = 11.0
    assert c.lookup(_sig(1), ("count", (1,)), "hh", 0.4, 0)[0] == "miss"
    assert c.stats["expired"] == 1 and len(c) == 0


def test_epoch_fences_entries():
    c = SemanticQueryCache(_cfg())
    c.insert(_sig(1), ("count", (1,)), "hh", 0.4, probs=None,
             sample=None, plan=None, result="R", epoch=3)
    assert c.lookup(_sig(1), ("count", (1,)), "hh", 0.4, 4)[0] == "miss"
    assert c.stats["stale_epoch"] == 1 and len(c) == 0


def test_purge_and_record():
    t = [0.0]
    c = SemanticQueryCache(_cfg(ttl_s=10.0), clock=lambda: t[0])
    c.insert(_sig(1), ("count", (1,)), "hh", 0.4, probs=None,
             sample=None, plan=None, result="R", epoch=0)
    c.insert(_sig(2), ("count", (2,)), "hh", 0.4, probs=None,
             sample=None, plan=None, result="R", epoch=1)
    t[0] = 11.0
    t2 = [0.0]
    c._clock = lambda: t2[0]  # keep the epoch-1 entry fresh
    assert c.purge(epoch=1) == 1          # the epoch-0 entry
    assert len(c) == 1
    rec = json.loads(json.dumps(c.record()))
    assert rec["size"] == 1 and rec["stale_epoch"] == 1


# ----------------------------------------------------------------------
# engine integration: parity, rng independence, near-hit statistics
# ----------------------------------------------------------------------
def test_cold_cache_is_bit_for_bit_uncached(small_corpus, built_index):
    qs = _queries()
    plain = QueryBatch(small_corpus, built_index)
    cached = QueryBatch(small_corpus, built_index,
                        cache=SemanticQueryCache(_cfg()))
    want = plain.execute(qs, RATE, rng=np.random.default_rng(7))
    got = cached.execute(qs, RATE, rng=np.random.default_rng(7))
    assert all(_same_result(g, w) for g, w in zip(got, want))
    assert cached.cache.stats["misses"] == len(qs)
    assert cached.cache.stats["hits"] == 0


def test_exact_hits_bit_for_bit_and_rng_independent(small_corpus,
                                                    built_index):
    qs = _queries()
    cache = SemanticQueryCache(_cfg())
    eng = QueryBatch(small_corpus, built_index, cache=cache)
    first = eng.execute(qs, RATE, rng=np.random.default_rng(7))
    # a DIFFERENT generator: hits consume no rng, so the results must
    # still be the memoized ones, verbatim
    again = eng.execute(qs, RATE, rng=np.random.default_rng(12345))
    assert cache.stats["hits"] == len(qs)
    assert all(_same_result(a, f) for a, f in zip(again, first))
    # the executed plan for a hit is empty — nothing was scanned
    assert all(len(p) == 0 for p in eng.last_report.plan)
    assert eng.last_report.cache == dict(hits=4, near_hits=0, misses=0,
                                         bypassed=0)


def test_mixed_batch_misses_draw_as_if_alone(small_corpus, built_index):
    """Hits consume no rng: the remaining misses must draw exactly what
    they would draw in a batch of their own."""
    qs = _queries()
    cache = SemanticQueryCache(_cfg())
    eng = QueryBatch(small_corpus, built_index, cache=cache)
    eng.execute(qs[:2], RATE, rng=np.random.default_rng(7))  # populate 2
    mixed = eng.execute(qs, RATE, rng=np.random.default_rng(9))
    alone = QueryBatch(small_corpus, built_index).execute(
        qs[2:], RATE, rng=np.random.default_rng(9))
    assert cache.stats["hits"] == 2
    assert all(_same_result(m, a) for m, a in zip(mixed[2:], alone))


def test_near_hit_borrows_plan_and_stays_unbiased(small_corpus,
                                                  built_index):
    """Hansen-Hurwitz is unbiased for ANY full-support sampling
    distribution, so a count served off a *neighbor's* cached plan must
    agree with the exact answer in expectation.  Radius = all bits so
    the neighbor always qualifies."""
    qa, qb = BatchQuery.count([5]), BatchQuery.count([9])
    exact = QueryBatch(small_corpus, built_index).execute(
        [qb], 1.0)[0].estimate.value
    vals = []
    for seed in range(250):
        cache = SemanticQueryCache(_cfg(hamming_radius=built_index.bits))
        eng = QueryBatch(small_corpus, built_index, cache=cache)
        eng.execute([qa], RATE, rng=np.random.default_rng(seed))
        res = eng.execute([qb], RATE,
                          rng=np.random.default_rng(seed + 10_000))[0]
        assert cache.stats["near_hits"] == 1, "neighbor did not qualify"
        # the borrowed plan executed a real scan (not a memoized result)
        assert len(eng.last_report.plan[0]) > 0
        vals.append(res.estimate.value)
    mean = float(np.mean(vals))
    sem = float(np.std(vals, ddof=1) / np.sqrt(len(vals)))
    assert abs(mean - exact) <= 4.0 * sem + 1e-9, (
        f"near-hit estimator biased: mean {mean:.2f} vs exact "
        f"{exact:.2f} (sem {sem:.2f})")


def test_near_hit_inserts_own_entry(small_corpus, built_index):
    """A near-hit runs a real reduce, so its full-fidelity result is
    cacheable: the next identical ask is an exact hit."""
    qa, qb = BatchQuery.count([5]), BatchQuery.count([9])
    cache = SemanticQueryCache(_cfg(hamming_radius=built_index.bits))
    eng = QueryBatch(small_corpus, built_index, cache=cache)
    eng.execute([qa], RATE, rng=np.random.default_rng(0))
    eng.execute([qb], RATE, rng=np.random.default_rng(1))
    assert cache.stats["near_hits"] == 1
    res = eng.execute([qb], RATE, rng=np.random.default_rng(2))[0]
    assert cache.stats["hits"] == 1
    assert res.estimate is not None


# ----------------------------------------------------------------------
# placement-epoch fencing through the fleet
# ----------------------------------------------------------------------
def _fleet_stack(small_corpus, built_index, n_replicas=1, **hg_kw):
    hg = HostGroupExecutor(
        PlacementMap.blocked(small_corpus.n_shards, 2,
                             n_replicas=n_replicas),
        workers_per_host=1, **hg_kw)
    cache = SemanticQueryCache(_cfg())
    eng = QueryBatch(small_corpus, built_index, executor=hg, cache=cache)
    return hg, FleetManager(hg, warm_fn=lambda sid, src, dst: None), \
        cache, eng


@pytest.mark.parametrize("swap", ["join", "drain", "crash"])
def test_fleet_swap_invalidates_cached_plans(small_corpus, built_index,
                                             swap):
    qs = _queries()
    hg, fleet, cache, eng = _fleet_stack(small_corpus, built_index)
    with hg:
        eng.execute(qs, RATE, rng=np.random.default_rng(7))   # populate
        eng.execute(qs, RATE, rng=np.random.default_rng(8))
        assert cache.stats["hits"] == len(qs)                 # warm
        if swap == "join":
            fleet.join(2)
        elif swap == "drain":
            fleet.drain(1)
        else:
            fleet.crash(1)
        got = eng.execute(qs, RATE, rng=np.random.default_rng(9))
        # zero hits crossed the generation swap; every entry dropped
        assert cache.stats["hits"] == len(qs)
        assert cache.stats["stale_epoch"] == len(qs)
        # and the re-served results match a plain engine on the same
        # post-swap topology under the same seeds
        want = QueryBatch(small_corpus, built_index, executor=hg).execute(
            qs, RATE, rng=np.random.default_rng(9))
        assert all(_same_result(g, w) for g, w in zip(got, want))
        # repopulated at the new epoch: warm again
        eng.execute(qs, RATE, rng=np.random.default_rng(10))
        assert cache.stats["hits"] == 2 * len(qs)


# ----------------------------------------------------------------------
# fidelity fences: degraded / budgeted / pressured never cached
# ----------------------------------------------------------------------
def test_degraded_results_never_cached(small_corpus, built_index):
    hg, fleet, cache, eng = _fleet_stack(small_corpus, built_index,
                                         n_replicas=0, allow_partial=True)
    with hg:
        fleet.crash(1)      # no replicas: host 1's shards are orphaned
        res = eng.execute([BatchQuery.count([5])], 1.0,
                          rng=np.random.default_rng(0))[0]
        assert res.lost_shards > 0
        assert cache.stats["insertions"] == 0 and len(cache) == 0


def test_budgeted_queries_bypass_cache(small_corpus, built_index):
    cache = SemanticQueryCache(_cfg())
    eng = QueryBatch(small_corpus, built_index,
                     planner=RatePlanner(small_corpus.n_shards),
                     cache=cache)
    budgeted = BatchQuery.count([5], budget=QueryBudget(max_rel_error=0.5))
    plain = BatchQuery.count([9])
    for seed in (0, 1):
        eng.execute([budgeted, plain], RATE,
                    rng=np.random.default_rng(seed))
    # the budgeted query never probed nor populated; the plain one hit
    assert cache.stats["bypassed"] == 2
    assert cache.stats["insertions"] == 1
    assert cache.stats["hits"] == 1
    assert eng.last_report.cache["bypassed"] == 1


def test_pressure_bypasses_cache_both_directions(small_corpus,
                                                 built_index):
    cache = SemanticQueryCache(_cfg())
    eng = QueryBatch(small_corpus, built_index,
                     planner=RatePlanner(small_corpus.n_shards),
                     cache=cache)
    qs = [BatchQuery.count([5])]
    eng.execute(qs, RATE, rng=np.random.default_rng(0))   # populate
    eng.execute(qs, RATE, rng=np.random.default_rng(1), pressure=0.7)
    # the degraded batch neither read the warm entry nor replaced it
    assert cache.stats["hits"] == 0 and cache.stats["bypassed"] == 1
    assert cache.stats["insertions"] == 1


# ----------------------------------------------------------------------
# controller: cached queries stay out of the batch cost fit
# ----------------------------------------------------------------------
def test_controller_excludes_cached_from_cost_fit():
    c = WindowController()
    c.observe_batch(4, 0.01, cached=4)        # all-cached: dropped
    assert c._n_batches == 0
    c.observe_batch(4, 0.01, cached=2)        # fits as a 2-query batch
    assert c._n_batches == 1
