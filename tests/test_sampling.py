"""Estimator properties: unbiasedness, coverage, pps variance reduction."""
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.sampling import (
    ht_estimate,
    mean_estimate,
    pps_sample,
    similarity_probabilities,
    srcs_sample,
)


@settings(max_examples=25, deadline=None)
@given(
    n_shards=st.integers(4, 40),
    seed=st.integers(0, 10_000),
    skew=st.floats(2.5, 6.0),   # pareto alpha > 2: finite variance, so
                                # 400 trials actually concentrate
)
def test_ht_estimator_unbiased(n_shards, seed, skew):
    """E[tau_hat] == tau under pps sampling for any positive phi."""
    rng = np.random.default_rng(seed)
    tau_s = rng.pareto(skew, n_shards) * 100
    tau = tau_s.sum()
    phi = similarity_probabilities(rng.random(n_shards) + 0.1)
    est = []
    for _ in range(400):
        s = pps_sample(phi, 0.3, rng)
        est.append(ht_estimate(tau_s[s.shard_ids], s).value)
    assert np.mean(est) == pytest.approx(tau, rel=0.2)


def test_pps_beats_uniform_when_phi_matches_tau():
    """phi proportional to tau_s drives variance toward zero (paper
    Sec. II-B: optimal pps)."""
    rng = np.random.default_rng(0)
    tau_s = np.concatenate([np.full(5, 1000.0), np.full(45, 1.0)])
    phi_opt = tau_s / tau_s.sum()
    uni, opt = [], []
    for _ in range(300):
        s1 = srcs_sample(50, 0.2, rng)
        uni.append(ht_estimate(tau_s[s1.shard_ids], s1).value)
        s2 = pps_sample(phi_opt, 0.2, rng)
        opt.append(ht_estimate(tau_s[s2.shard_ids], s2).value)
    assert np.std(opt) < 0.2 * np.std(uni)


def test_error_bound_coverage():
    """95% interval should cover the truth ~>=85% of the time (t-based
    bounds are approximate for skewed small samples)."""
    rng = np.random.default_rng(1)
    tau_s = rng.gamma(2.0, 50.0, 64)
    tau = tau_s.sum()
    phi = similarity_probabilities(tau_s + rng.random(64) * 50)
    cover = 0
    trials = 300
    for _ in range(trials):
        s = pps_sample(phi, 0.25, rng)
        e = ht_estimate(tau_s[s.shard_ids], s)
        lo, hi = e.interval
        cover += (lo <= tau <= hi)
    assert cover / trials >= 0.85


def test_mean_estimate_ratio():
    rng = np.random.default_rng(2)
    sums = rng.random(30) * 100
    counts = np.maximum(rng.poisson(20, 30), 1).astype(float)
    true_mean = sums.sum() / counts.sum()
    vals = []
    for _ in range(200):
        s = srcs_sample(30, 0.4, rng)
        vals.append(mean_estimate(sums[s.shard_ids], counts[s.shard_ids], s).value)
    assert np.mean(vals) == pytest.approx(true_mean, rel=0.05)


@given(st.integers(2, 100), st.floats(0.01, 1.0))
@settings(max_examples=30, deadline=None)
def test_sample_sizes(n_shards, rate):
    rng = np.random.default_rng(0)
    s = srcs_sample(n_shards, rate, rng)
    assert 1 <= len(s.shard_ids) == int(np.ceil(rate * n_shards))
    assert s.probabilities.sum() == pytest.approx(1.0)


def test_similarity_probabilities_floor():
    p = similarity_probabilities(np.array([0.0, 0.0, 1.0]))
    assert (p > 0).all() and p.sum() == pytest.approx(1.0)
    assert p[2] > p[0]


# ----------------------------------------------------------------------
# degenerate hardening (error-budgeted serving relies on these corners:
# degraded rates draw tiny with-replacement samples that can collapse
# onto one hot shard, and the planner orders queries by relative error)
# ----------------------------------------------------------------------

def test_estimate_degenerate_relative_error_and_interval():
    from repro.core.sampling import Estimate
    inf = float("inf")
    assert Estimate(10.0, inf, 0.95, 1).relative_error == inf
    assert Estimate(10.0, float("nan"), 0.95, 2).relative_error == inf
    assert Estimate(0.0, 3.0, 0.95, 2).relative_error == inf
    assert Estimate(0.0, 0.0, 0.95, 2).relative_error == 0.0
    assert Estimate(10.0, 2.0, 0.95, 4).relative_error == pytest.approx(0.2)
    lo, hi = Estimate(5.0, inf, 0.95, 1).interval
    assert (lo, hi) == (-inf, inf)
    assert Estimate(5.0, inf, 0.95, 1).covers(1e300)
    assert Estimate(5.0, 1.0, 0.95, 4).covers(5.5)
    assert not Estimate(5.0, 1.0, 0.95, 4).covers(6.5)


def test_ht_estimate_single_distinct_shard_has_infinite_bound():
    """All draws landing on one shard carries zero variance *information*
    — the naive formula's zero-width CI around that shard's scaled value
    would be confidently wrong, so the bound must go infinite (the value
    itself stays the HH mean)."""
    from repro.core.sampling import SampleResult
    phi = np.array([0.9, 0.05, 0.05])
    s = SampleResult(np.array([0, 0, 0, 0]), phi, 0.5)
    est = ht_estimate(np.array([7.0, 7.0, 7.0, 7.0]), s)
    assert est.value == pytest.approx(7.0 / 0.9)
    assert est.error_bound == float("inf")
    m = mean_estimate(np.array([7.0] * 4), np.array([2.0] * 4), s)
    assert m.error_bound == float("inf")


def test_ht_estimate_df_uses_distinct_draws():
    """Duplicate with-replacement draws are not independent evidence:
    the t quantile's df comes from the distinct-shard count, so a
    near-collapsed sample reports a *wider* interval than the naive
    n-1 df would."""
    from repro.core.sampling import SampleResult
    from repro.utils.stats import t_critical_value
    phi = np.full(8, 1.0 / 8)
    ids = np.array([0, 0, 0, 0, 0, 1])          # 6 draws, 2 distinct
    tau = np.array([10.0, 10.0, 10.0, 10.0, 10.0, 30.0])
    s = SampleResult(ids, phi, 0.75)
    est = ht_estimate(tau, s)
    scaled = tau / phi[ids]
    var = np.sum((scaled - scaled.mean()) ** 2) / (6 * 5)
    naive = t_critical_value(5, 0.95) * np.sqrt(var)
    hardened = t_critical_value(1, 0.95) * np.sqrt(var)
    assert est.error_bound == pytest.approx(hardened)
    assert est.error_bound > naive


def test_hh_zero_variance_on_optimal_phi():
    """With phi exactly proportional to tau every scaled draw equals the
    total, so a multi-shard sample gives a zero-width interval around
    the *exact* answer — the paper's optimal-pps limit."""
    rng = np.random.default_rng(5)
    tau_s = np.array([4.0, 16.0, 60.0, 20.0])
    phi = tau_s / tau_s.sum()
    for _ in range(20):
        s = pps_sample(phi, 0.9, rng)
        if len(np.unique(s.shard_ids)) < 2:
            continue
        est = ht_estimate(tau_s[s.shard_ids], s)
        assert est.value == pytest.approx(tau_s.sum())
        assert est.error_bound == pytest.approx(0.0)


@pytest.mark.parametrize("rate", [1e-9, 1.0, 2.5])
def test_samplers_extreme_rates(rate):
    """Rates at/below the one-shard limit and at/above census must stay
    well-formed: sizes clamp, distinct sampling never repeats."""
    from repro.core.sampling import pps_sample_distinct
    rng = np.random.default_rng(3)
    phi = similarity_probabilities(np.arange(6, dtype=float))
    n_expect = max(1, int(np.ceil(rate * 6)))
    s = pps_sample(phi, rate, rng)
    assert len(s.shard_ids) == n_expect
    d = pps_sample_distinct(phi, rate, rng)
    assert len(d.shard_ids) == min(6, n_expect)
    assert len(np.unique(d.shard_ids)) == len(d.shard_ids)
    u = srcs_sample(6, rate, rng)
    assert len(u.shard_ids) == n_expect


def test_bootstrap_estimate_deterministic_and_degenerate():
    from repro.core.sampling import bootstrap_estimate, SampleResult
    phi = np.full(8, 1.0 / 8)
    ids = np.array([0, 2, 4, 6])
    vals = np.array([3.0, 9.0, 1.0, 5.0])
    s = SampleResult(ids, phi, 0.5)
    e1 = bootstrap_estimate(vals, s, rng=np.random.default_rng(7))
    e2 = bootstrap_estimate(vals, s, rng=np.random.default_rng(7))
    assert e1 == e2
    assert e1.value == pytest.approx((vals / phi[ids]).mean())
    assert np.isfinite(e1.error_bound) and e1.error_bound >= 0
    one = bootstrap_estimate(np.array([3.0]),
                             SampleResult(ids[:1], phi, 0.125), 0.95)
    assert one.error_bound == float("inf")


def test_bootstrap_topk_stability_bounds():
    from repro.core.sampling import bootstrap_topk_stability
    rng = np.random.default_rng(9)
    # identical per-shard rankings: every resample reproduces the top-k
    part = (np.array([5, 6, 7]), np.array([3.0, 2.0, 1.0]))
    est = bootstrap_topk_stability([part, part, part], k=3, rng=rng)
    assert est.value == pytest.approx(1.0)
    # disjoint per-shard contributions: stability drops below 1
    parts = [(np.array([i * 10, i * 10 + 1]), np.array([2.0, 1.0]))
             for i in range(4)]
    est2 = bootstrap_topk_stability(parts, k=3,
                                    rng=np.random.default_rng(11))
    assert 0.0 <= est2.value < 1.0
    assert bootstrap_topk_stability([], 3).error_bound == float("inf")
