"""Estimator properties: unbiasedness, coverage, pps variance reduction."""
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.sampling import (
    ht_estimate,
    mean_estimate,
    pps_sample,
    similarity_probabilities,
    srcs_sample,
)


@settings(max_examples=25, deadline=None)
@given(
    n_shards=st.integers(4, 40),
    seed=st.integers(0, 10_000),
    skew=st.floats(2.5, 6.0),   # pareto alpha > 2: finite variance, so
                                # 400 trials actually concentrate
)
def test_ht_estimator_unbiased(n_shards, seed, skew):
    """E[tau_hat] == tau under pps sampling for any positive phi."""
    rng = np.random.default_rng(seed)
    tau_s = rng.pareto(skew, n_shards) * 100
    tau = tau_s.sum()
    phi = similarity_probabilities(rng.random(n_shards) + 0.1)
    est = []
    for _ in range(400):
        s = pps_sample(phi, 0.3, rng)
        est.append(ht_estimate(tau_s[s.shard_ids], s).value)
    assert np.mean(est) == pytest.approx(tau, rel=0.2)


def test_pps_beats_uniform_when_phi_matches_tau():
    """phi proportional to tau_s drives variance toward zero (paper
    Sec. II-B: optimal pps)."""
    rng = np.random.default_rng(0)
    tau_s = np.concatenate([np.full(5, 1000.0), np.full(45, 1.0)])
    phi_opt = tau_s / tau_s.sum()
    uni, opt = [], []
    for _ in range(300):
        s1 = srcs_sample(50, 0.2, rng)
        uni.append(ht_estimate(tau_s[s1.shard_ids], s1).value)
        s2 = pps_sample(phi_opt, 0.2, rng)
        opt.append(ht_estimate(tau_s[s2.shard_ids], s2).value)
    assert np.std(opt) < 0.2 * np.std(uni)


def test_error_bound_coverage():
    """95% interval should cover the truth ~>=85% of the time (t-based
    bounds are approximate for skewed small samples)."""
    rng = np.random.default_rng(1)
    tau_s = rng.gamma(2.0, 50.0, 64)
    tau = tau_s.sum()
    phi = similarity_probabilities(tau_s + rng.random(64) * 50)
    cover = 0
    trials = 300
    for _ in range(trials):
        s = pps_sample(phi, 0.25, rng)
        e = ht_estimate(tau_s[s.shard_ids], s)
        lo, hi = e.interval
        cover += (lo <= tau <= hi)
    assert cover / trials >= 0.85


def test_mean_estimate_ratio():
    rng = np.random.default_rng(2)
    sums = rng.random(30) * 100
    counts = np.maximum(rng.poisson(20, 30), 1).astype(float)
    true_mean = sums.sum() / counts.sum()
    vals = []
    for _ in range(200):
        s = srcs_sample(30, 0.4, rng)
        vals.append(mean_estimate(sums[s.shard_ids], counts[s.shard_ids], s).value)
    assert np.mean(vals) == pytest.approx(true_mean, rel=0.05)


@given(st.integers(2, 100), st.floats(0.01, 1.0))
@settings(max_examples=30, deadline=None)
def test_sample_sizes(n_shards, rate):
    rng = np.random.default_rng(0)
    s = srcs_sample(n_shards, rate, rng)
    assert 1 <= len(s.shard_ids) == int(np.ceil(rate * n_shards))
    assert s.probabilities.sum() == pytest.approx(1.0)


def test_similarity_probabilities_floor():
    p = similarity_probabilities(np.array([0.0, 0.0, 1.0]))
    assert (p > 0).all() and p.sum() == pytest.approx(1.0)
    assert p[2] > p[0]
