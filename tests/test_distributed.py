"""Sharding rules + multi-device pjit correctness (8 fake CPU devices in
a subprocess so the main test process keeps its single real device)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.distributed.sharding import logical_to_mesh_spec, set_rules


class _FakeMesh:
    def __init__(self, names):
        self.axis_names = tuple(names)


def test_logical_mapping_drops_missing_axes():
    spec = logical_to_mesh_spec(("batch", None, "d_ff"),
                                _FakeMesh(["data", "model"]))
    assert spec == __import__("jax").sharding.PartitionSpec(
        ("data",), None, "model")


def test_logical_mapping_multi_axis_batch():
    spec = logical_to_mesh_spec(("batch", "d_ff"),
                                _FakeMesh(["pod", "data", "model"]))
    assert spec[0] == ("pod", "data")
    assert spec[1] == "model"


def test_rules_override_scoped():
    mesh = _FakeMesh(["data", "model"])
    with set_rules({"seq": "model"}):
        spec = logical_to_mesh_spec(("batch", "seq"), mesh)
        assert spec[1] == "model"
    spec2 = logical_to_mesh_spec(("batch", "seq"), mesh)
    assert spec2[1] is None


def test_no_duplicate_mesh_axes():
    """The same mesh axis must never appear twice in one spec."""
    mesh = _FakeMesh(["data", "model"])
    with set_rules({"seq": "data"}):   # batch also wants data
        spec = logical_to_mesh_spec(("batch", "seq"), mesh)
    used = []
    for s in spec:
        if s is None:
            continue
        used.extend([s] if isinstance(s, str) else list(s))
    assert len(used) == len(set(used))


_SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.launch.steps import (make_train_step, params_shardings,
                                    opt_state_shardings, batch_shardings)
    from repro.models import model as M
    from repro.optimizer.adamw import AdamWConfig, adamw_init

    cfg = get_config("smollm_360m", smoke=True)
    opt_cfg = AdamWConfig(lr=1e-3)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    key = jax.random.PRNGKey(0)
    batch = {
        "tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
        "mask": jnp.ones((8, 32), jnp.float32),
    }
    # single-device reference
    params = M.init_params(cfg, key)
    opt_state = adamw_init(params, opt_cfg)
    step = make_train_step(cfg, opt_cfg)
    p1, o1, m1 = jax.jit(step)(params, opt_state, batch)

    # sharded execution on the 4x2 mesh
    with mesh:
        p_sh = params_shardings(cfg, mesh)
        o_sh = opt_state_shardings(cfg, mesh)
        b_sh = batch_shardings(cfg, mesh, 8, False)
        sharded = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                          out_shardings=(p_sh, o_sh, None))
        params_s = jax.device_put(params, p_sh)
        opt_s = jax.device_put(opt_state, o_sh)
        batch_s = jax.device_put(batch, b_sh)
        p2, o2, m2 = sharded(params_s, opt_s, batch_s)

    diff = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                     b.astype(jnp.float32))))
               for a, b in zip(jax.tree_util.tree_leaves(p1),
                               jax.tree_util.tree_leaves(p2)))
    print(json.dumps({
        "loss_single": float(m1["loss"]),
        "loss_sharded": float(m2["loss"]),
        "max_param_diff": diff,
        "n_devices": jax.device_count(),
    }))
""")


@pytest.mark.slow
def test_pjit_matches_single_device():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_PROG],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n_devices"] == 8
    assert abs(res["loss_single"] - res["loss_sharded"]) < 2e-2
    assert res["max_param_diff"] < 2e-2
