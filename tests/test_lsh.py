"""LSH properties: packing roundtrip, cosine preservation, asym scoring."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.core.lsh import (
    LSHConfig,
    LSHIndex,
    asymmetric_cosine,
    hamming_distance,
    hamming_similarity,
    hyperplanes,
    pack_bits,
    popcount32,
    signature_bits,
    unpack_bits,
)


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=50, deadline=None)
def test_popcount_matches_python(x):
    got = int(popcount32(jnp.asarray([x], jnp.uint32))[0])
    assert got == bin(x).count("1")


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 8), words=st.integers(1, 4), seed=st.integers(0, 999))
def test_pack_unpack_roundtrip(n, words, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (n, words * 32)).astype(np.uint8)
    packed = pack_bits(jnp.asarray(bits))
    back = unpack_bits(packed, words * 32)
    np.testing.assert_array_equal(np.asarray(back), bits)


def test_hamming_distance_exact():
    a = pack_bits(jnp.asarray(np.eye(4, 64, dtype=np.uint8)))
    d = hamming_distance(a, a)
    assert (np.diag(np.asarray(d)) == 0).all()
    off = np.asarray(d)[~np.eye(4, dtype=bool)]
    assert (off == 2).all()   # two differing one-hot bits


def test_cosine_preservation():
    """Hamming-angle estimate tracks true cosine (paper Sec. II-D)."""
    rng = np.random.default_rng(3)
    dim, bits = 48, 512
    x = rng.normal(size=(60, dim))
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    planes = hyperplanes(LSHConfig(bits=bits), dim)
    packed = pack_bits(signature_bits(jnp.asarray(x, jnp.float32), planes))
    m = np.asarray(hamming_distance(packed, packed)).astype(float)
    est_cos = np.cos(np.pi * m / bits)
    true_cos = x @ x.T
    err = np.abs(est_cos - true_cos)
    assert err.mean() < 0.06
    # max-error bound: per-pair std is at most pi*sqrt(0.25/bits) ~ 0.069
    # at 512 bits, so the expected max over 60*59 pairs is already
    # ~ 0.069*sqrt(2 ln 3540) ~ 0.28 — the old 0.25 bound sat below the
    # *expected* maximum and failed for typical seeds (this one: 0.295).
    assert err.max() < 0.35


def test_asymmetric_beats_symmetric():
    """Asym scoring quantizes one side only -> lower cos error."""
    rng = np.random.default_rng(4)
    dim, bits = 48, 128
    db = rng.normal(size=(200, dim))
    db /= np.linalg.norm(db, axis=1, keepdims=True)
    q = rng.normal(size=(dim,))
    q /= np.linalg.norm(q)
    planes = hyperplanes(LSHConfig(bits=bits), dim)
    db_packed = pack_bits(signature_bits(jnp.asarray(db, jnp.float32), planes))
    q_packed = pack_bits(signature_bits(jnp.asarray(q[None], jnp.float32), planes))
    true_cos = db @ q
    sym = np.cos(np.pi * np.asarray(
        hamming_distance(q_packed, db_packed))[0].astype(float) / bits)
    asym = np.asarray(asymmetric_cosine(
        jnp.asarray(q, jnp.float32), db_packed, planes, bits))
    assert np.abs(asym - true_cos).mean() < np.abs(sym - true_cos).mean()


def test_lsh_index_api():
    rng = np.random.default_rng(5)
    x = rng.normal(size=(32, 16)).astype(np.float32)
    idx = LSHIndex.build(jnp.asarray(x), LSHConfig(bits=64))
    sims = idx.similarities(jnp.asarray(x[0]))
    assert int(np.argmax(np.asarray(sims))) == 0


def test_temperature_sharpens():
    rng = np.random.default_rng(6)
    a = pack_bits(jnp.asarray(rng.integers(0, 2, (4, 128)).astype(np.uint8)))
    s1 = np.asarray(hamming_similarity(a, a, 128, temperature=1.0))
    s8 = np.asarray(hamming_similarity(a, a, 128, temperature=8.0))
    r1 = s1.max() / s1.min()
    r8 = s8.max() / s8.min()
    assert r8 > r1
