"""One-launch megascan (kernels/megascan): the block-aligned packed
payload, the streamed vs DMA double-buffered schedules, the bitonic
per-tile top-k epilogue, and the executor megakernel route — pinned
against the pure-jnp oracles, the PR-2 fused segment-sum kernels, and
(bit-for-bit) the per-shard fused path, in interpret mode on CPU per
the harness contract."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lsh as lsh_mod
from repro.data.store import plan_blocked_layout
from repro.kernels.asym import ops as aops
from repro.kernels.hamming import ops as hops
from repro.kernels.megascan import kernel as mker
from repro.kernels.megascan import ops as mops
from repro.kernels.megascan import ref as mref

# ragged shard census the payload must survive: partial last blocks,
# a single-doc shard, an EMPTY shard, and a shard count that is not a
# multiple of the prefetch depth (2)
RAGGED = (13, 8, 1, 0, 27, 64, 5)
QUERIES = [[3, 5, 9], [2], [10, 11], [7, 4, 5, 6]]


def _segments(counts, dim, bits, seed):
    """Per-shard (packed signatures, doc ids) with globally unique ids."""
    rng = np.random.default_rng(seed)
    planes = lsh_mod.hyperplanes(lsh_mod.LSHConfig(bits=bits), dim)
    segs, base = [], 0
    for c in counts:
        x = rng.normal(size=(c, dim)).astype(np.float32)
        sig = np.asarray(lsh_mod.pack_bits(lsh_mod.signature_bits(
            jnp.asarray(x), planes)))
        segs.append((sig, np.arange(base, base + c, dtype=np.int64)))
        base += c
    q = jnp.asarray(rng.normal(size=(5, dim)).astype(np.float32))
    return segs, q, planes


# ----------------------------------------------------------------------
# layout planning + payload packing
# ----------------------------------------------------------------------
def test_plan_blocked_layout_ragged():
    starts, blocks, total = plan_blocked_layout(
        np.array([3, 0, 5, 4]), 4)
    np.testing.assert_array_equal(blocks, [1, 0, 2, 1])
    np.testing.assert_array_equal(starts, [0, 4, 4, 12])
    assert total == 16
    with pytest.raises(ValueError):
        plan_blocked_layout(np.array([1]), 0)
    with pytest.raises(ValueError):
        plan_blocked_layout(np.array([-1]), 4)


def test_build_payload_block_alignment():
    tm = 8
    segs, _, _ = _segments(RAGGED, 16, 64, seed=3)
    pay = mops.build_payload(segs, tm=tm)
    assert pay.n_rows % tm == 0
    assert pay.n_blocks == sum(-(-c // tm) for c in RAGGED)
    slots = np.asarray(pay.slots).ravel()
    # every TM block belongs to exactly one slot (padding rows carry
    # the out-of-range slot_pad, which still "belongs" to the block)
    for j in range(pay.n_blocks):
        blk = slots[j * tm:(j + 1) * tm]
        real = blk[blk != pay.slot_pad]
        assert real.size > 0 and (real == pay.block_slot[j]).all()
    # padding rows are -1 docs; real rows keep their global ids
    np.testing.assert_array_equal(np.asarray(pay.counts), RAGGED)
    assert (pay.doc_idx[slots == pay.slot_pad] == -1).all()
    assert (pay.doc_idx[slots != pay.slot_pad] >= 0).all()
    with pytest.raises(ValueError):
        mops.build_payload(segs, tm=12)     # not a power of two
    with pytest.raises(ValueError):
        mops.build_payload([])


# ----------------------------------------------------------------------
# segment-sum kernels: oracle, fused-kernel, and schedule parity
# ----------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["asym", "hamming"])
def test_megascan_segsum_matches_oracle_and_fused(mode):
    bits, dim, tm = 64, 16, 8
    segs, q, planes = _segments(RAGGED, dim, bits, seed=7)
    pay = mops.build_payload(segs, tm=tm)
    if mode == "hamming":
        q = lsh_mod.pack_bits(lsh_mod.signature_bits(q, planes))
    got = mops.megascan_segment_sums(pay, q, planes, bits, mode=mode,
                                     temperature=4.0)
    want = mref.megascan_segment_sums_ref(pay, q, planes, bits,
                                          mode=mode, temperature=4.0)
    np.testing.assert_allclose(got, want, rtol=1e-4)
    # the PR-2 fused kernels on the real rows only (their own tiling)
    real = np.concatenate([s for s, _ in segs])
    seg_ids = np.concatenate([
        np.full(c, i, np.int32) for i, c in enumerate(RAGGED)])
    if mode == "asym":
        fused = aops.asym_exp_segment_sum(
            q, jnp.asarray(real), planes, bits, seg_ids, len(RAGGED),
            temperature=4.0)
    else:
        fused = hops.hamming_segment_similarity(
            q, jnp.asarray(real), bits, seg_ids, len(RAGGED),
            temperature=4.0)
    np.testing.assert_allclose(got, np.asarray(fused), rtol=1e-4)


@pytest.mark.parametrize("mode", ["asym", "hamming"])
def test_megascan_double_buffer_is_bitwise(mode):
    """The explicit DMA double-buffered schedule and the BlockSpec grid
    pipeline must be the SAME numbers, not merely close."""
    bits, dim, tm = 64, 16, 8
    segs, q, planes = _segments(RAGGED, dim, bits, seed=11)
    pay = mops.build_payload(segs, tm=tm)
    if mode == "hamming":
        q = lsh_mod.pack_bits(lsh_mod.signature_bits(q, planes))
    streamed = mops.megascan_segment_sums(
        pay, q, planes, bits, mode=mode, double_buffer=False)
    dbuf = mops.megascan_segment_sums(
        pay, q, planes, bits, mode=mode, double_buffer=True)
    np.testing.assert_array_equal(streamed, dbuf)


def test_megascan_group_vs_single_shard_bitwise():
    """The bit-for-bit packing claim: slot s of the group payload's
    output equals a single-shard payload's output for shard s — the
    same guarantee the executor's gather-parity gate rests on."""
    bits, dim, tm = 64, 16, 8
    segs, q, planes = _segments(RAGGED, dim, bits, seed=13)
    group = mops.megascan_segment_sums(
        mops.build_payload(segs, tm=tm), q, planes, bits)
    for s, seg in enumerate(segs):
        single = mops.megascan_segment_sums(
            mops.build_payload([seg], tm=tm), q, planes, bits)
        np.testing.assert_array_equal(group[:, s], single[:, 0])


def test_megascan_empty_payload_and_single_shard_host():
    segs, q, planes = _segments((0, 0), 16, 64, seed=1)
    pay = mops.build_payload(segs, tm=8)
    assert pay.n_rows == 0 and pay.n_blocks == 0
    out = mops.megascan_segment_sums(pay, q, planes, 64)
    np.testing.assert_array_equal(out, np.zeros((5, 2)))
    # a one-shard host is just the degenerate group
    segs, q, planes = _segments((9,), 16, 64, seed=2)
    pay = mops.build_payload(segs, tm=8)
    got = mops.megascan_segment_sums(pay, q, planes, 64)
    want = mref.megascan_segment_sums_ref(pay, q, planes, 64, mode="asym")
    np.testing.assert_allclose(got, want, rtol=1e-4)


# ----------------------------------------------------------------------
# bitonic per-tile top-k
# ----------------------------------------------------------------------
@pytest.mark.parametrize("tm", [8, 128, 256])
def test_bitonic_sort_desc_matches_lax_topk_with_ties(tm):
    rng = np.random.default_rng(tm)
    # quantized values force tie groups; top_k breaks ties by lowest
    # index, the exact order the sort network must reproduce
    vals = jnp.asarray(
        rng.integers(0, tm // 2, (6, tm)).astype(np.float32))
    idx = jax.lax.broadcasted_iota(jnp.int32, (6, tm), 1)
    sv, si = mker.bitonic_sort_desc(vals, idx)
    tv, ti = jax.lax.top_k(vals, tm)
    np.testing.assert_array_equal(np.asarray(sv), np.asarray(tv))
    np.testing.assert_array_equal(np.asarray(si), np.asarray(ti))


def test_bitonic_sort_rejects_non_power_of_two():
    vals = jnp.zeros((2, 12), jnp.float32)
    idx = jnp.zeros((2, 12), jnp.int32)
    with pytest.raises(AssertionError):
        mker.bitonic_sort_desc(vals, idx)


def test_megascan_topk_matches_oracle_and_schedules():
    bits, dim, tm, k = 64, 16, 16, 5
    segs, q, planes = _segments(RAGGED, dim, bits, seed=17)
    pay = mops.build_payload(segs, tm=tm)
    ids, vals = mops.megascan_topk(pay, q, planes, bits, k,
                                   temperature=4.0)
    rids, rvals = mref.megascan_topk_ref(pay, q, planes, bits, k,
                                         temperature=4.0)
    np.testing.assert_array_equal(ids, rids)
    finite = np.isfinite(rvals)
    np.testing.assert_allclose(vals[finite], rvals[finite], rtol=1e-4)
    np.testing.assert_array_equal(np.isfinite(vals), finite)
    # a slot with fewer than k docs pads with -1 / -inf (shard 2 has
    # one doc; shard 3 is empty)
    assert (ids[:, 2, 1:] == -1).all() and (ids[:, 3] == -1).all()
    # both data-movement schedules emit the same candidates
    ids_db, vals_db = mops.megascan_topk(pay, q, planes, bits, k,
                                         temperature=4.0,
                                         double_buffer=True)
    np.testing.assert_array_equal(ids, ids_db)
    np.testing.assert_array_equal(vals, vals_db)


def test_megascan_topk_lane_padding_is_invisible():
    """PR 4's rule carried over: lane-padding K (TPU path) only widens
    the per-tile candidate sets, never changes the answer."""
    bits, dim, tm, k = 64, 16, 256, 7
    segs, q, planes = _segments((300, 40, 9), dim, bits, seed=19)
    pay = mops.build_payload(segs, tm=tm)
    ids_u, vals_u = mops.megascan_topk(pay, q, planes, bits, k,
                                       pad_lanes=False)
    ids_p, vals_p = mops.megascan_topk(pay, q, planes, bits, k,
                                       pad_lanes=True)
    np.testing.assert_array_equal(ids_u, ids_p)
    np.testing.assert_array_equal(vals_u, vals_p)
    # lane-padded k beyond the tile is a hard error, not silence
    with pytest.raises(ValueError):
        mops.megascan_topk(mops.build_payload(segs, tm=8), q, planes,
                           bits, k, pad_lanes=True)


# ----------------------------------------------------------------------
# index payload cache + executor megakernel route
# ----------------------------------------------------------------------
def _doc_index(built_index, corpus):
    return dataclasses.replace(
        built_index, granularity="doc").attach_corpus(corpus)


def test_index_megascan_payload_cached_until_reattach(small_corpus,
                                                      built_index):
    idx = _doc_index(built_index, small_corpus)
    pay = idx.megascan_payload((0, 1, 2), tm=64)
    assert idx.megascan_payload((0, 1, 2), tm=64) is pay
    assert idx.megascan_payload((0, 1, 2), tm=128) is not pay
    assert pay.shard_ids == (0, 1, 2)
    fresh = idx.attach_corpus(small_corpus)
    assert fresh.megascan_payload((0, 1, 2), tm=64) is not pay
    bare = dataclasses.replace(built_index, doc_sig=None, doc_vecs=None)
    with pytest.raises(ValueError):
        bare.megascan_payload((0,))


def _ragged_plans(n_queries, n_shards, rng):
    plans = []
    for i in range(n_queries):
        if i % 3 == 0:
            plans.append([int(rng.integers(n_shards))])
        elif i % 3 == 1:
            sub = rng.choice(n_shards, size=max(2, n_shards // 2),
                             replace=False)
            plans.append(sorted(int(s) for s in sub))
        else:
            plans.append(list(range(n_shards)))
    return plans


def _scan_dicts_equal(got, want):
    for g, w in zip(got, want):
        assert g.keys() == w.keys()
        for s in g:
            if isinstance(g[s], dict):
                np.testing.assert_array_equal(g[s]["doc_ids"],
                                              w[s]["doc_ids"])
                np.testing.assert_array_equal(g[s]["values"],
                                              w[s]["values"])
            else:
                assert g[s] == w[s]


@pytest.mark.parametrize("ranked", [False, True])
def test_executor_megakernel_route_bitwise_parity(small_corpus,
                                                  built_index, ranked):
    from repro.kernels.megascan import MegascanSpec
    from repro.runtime.executor import ShardTaskExecutor
    idx = _doc_index(built_index, small_corpus)
    spec = MegascanSpec(idx, idx.query_vectors(QUERIES),
                        ranked_k=6 if ranked else None)
    fns = spec.scan_fns()
    plans = _ragged_plans(len(QUERIES), small_corpus.n_shards,
                          np.random.default_rng(5))
    ex = ShardTaskExecutor(workers=2)
    mega = ex.map_shard_batch(corpus=small_corpus, plan=plans, fns=fns,
                              megakernel=True)
    assert spec.stats["group_launches"] == 1
    assert ex.stats["megascan_jobs"] == 1
    assert "megascan" in ex.last_job
    per = ex.map_shard_batch(corpus=small_corpus, plan=plans, fns=fns,
                             megakernel=False)
    assert spec.stats["shard_launches"] > 0
    _scan_dicts_equal(mega, per)
    ex.close()


def test_executor_megakernel_retry_preserves_parity(small_corpus,
                                                    built_index):
    from repro.kernels.megascan import MegascanSpec
    from repro.runtime.executor import ShardTaskExecutor
    idx = _doc_index(built_index, small_corpus)
    spec = MegascanSpec(idx, idx.query_vectors(QUERIES))
    fns = spec.scan_fns()
    plans = [[0, 1, 2]] * len(QUERIES)
    failed = []

    def flaky(shard_id, attempt):
        if shard_id == 1 and attempt == 1:
            failed.append(shard_id)
            raise RuntimeError("injected")

    ex = ShardTaskExecutor(workers=2, fault_hook=flaky)
    mega = ex.map_shard_batch(corpus=small_corpus, plan=plans, fns=fns,
                              megakernel=True)
    assert failed == [1] and ex.stats["retries"] >= 1
    calm = ShardTaskExecutor(workers=2)
    per = calm.map_shard_batch(corpus=small_corpus, plan=plans, fns=fns,
                               megakernel=False)
    _scan_dicts_equal(mega, per)
    ex.close()
    calm.close()


def test_host_group_runs_one_launch_per_host(small_corpus, built_index):
    from repro.kernels.megascan import MegascanSpec
    from repro.runtime import HostGroupExecutor, PlacementMap
    from repro.runtime.executor import ShardTaskExecutor
    idx = _doc_index(built_index, small_corpus)
    spec = MegascanSpec(idx, idx.query_vectors(QUERIES))
    fns = spec.scan_fns()
    plans = [list(range(small_corpus.n_shards))] * len(QUERIES)
    hg = HostGroupExecutor(
        PlacementMap.blocked(small_corpus.n_shards, 2, n_replicas=1),
        workers_per_host=1)
    got = hg.map_shard_batch(small_corpus, plans, fns)
    for h, hex_ in hg.hosts.items():
        assert hex_.stats["megascan_jobs"] == 1, f"host {h} fell back"
    ex = ShardTaskExecutor(workers=2)
    want = ex.map_shard_batch(corpus=small_corpus, plan=plans, fns=fns,
                              megakernel=False)
    _scan_dicts_equal(got, want)
    hg.close()
    ex.close()


def test_run_shared_scan_megakernel_flag_validation(small_corpus):
    from repro.runtime.executor import ShardTaskExecutor
    ex = ShardTaskExecutor(workers=1)
    with pytest.raises(ValueError):
        ex.map_shard_batch(corpus=small_corpus, plan=[[0]],
                           fns=[lambda shard: 0.0], megakernel=True)
    ex.close()
