"""Query-layer behaviour over the shared small corpus + index."""
import numpy as np

from repro.core.queries.aggregation import phrase_count_query, precise_phrase_count
from repro.core.queries.recommend import mse as rec_mse, recommend_query
from repro.core.queries.retrieval import (
    boolean_query,
    parse_boolean,
    precision_at_k,
    ranked_query,
    recall,
)


def _frequent_word(corpus):
    counts = np.bincount(
        np.concatenate([s.tokens for s in corpus.shards]),
        minlength=corpus.vocab_size)
    return int(np.argsort(-counts)[40])   # frequent but not stopword-tier


def test_rate_one_is_exact(small_corpus, built_index):
    w = _frequent_word(small_corpus)
    res = phrase_count_query(small_corpus, built_index, [w], 1.0)
    assert res.estimate.value == precise_phrase_count(small_corpus, [w])
    assert res.estimate.error_bound == 0.0


def test_estimate_converges_with_rate(small_corpus, built_index):
    w = _frequent_word(small_corpus)
    true = precise_phrase_count(small_corpus, [w])
    rng = np.random.default_rng(0)
    errs = {}
    for rate in (0.2, 0.6):
        trials = [abs(phrase_count_query(
            small_corpus, built_index, [w], rate, rng=rng
        ).estimate.value - true) / true for _ in range(8)]
        errs[rate] = np.mean(trials)
    assert errs[0.6] <= errs[0.2] + 0.05


def test_estimated_bound_usually_covers(small_corpus, built_index):
    w = _frequent_word(small_corpus)
    true = precise_phrase_count(small_corpus, [w])
    rng = np.random.default_rng(1)
    cover = 0
    for _ in range(20):
        r = phrase_count_query(small_corpus, built_index, [w], 0.4, rng=rng)
        lo, hi = r.estimate.interval
        cover += (lo <= true <= hi)
    assert cover >= 14   # ~95% nominal, allow slack on 20 trials


def test_boolean_parse_and_eval(small_corpus, built_index):
    w1, w2 = 5, 9
    expr = parse_boolean([w1, "and", w2])
    full = boolean_query(small_corpus, built_index, expr, 1.0)
    approx = boolean_query(small_corpus, built_index, expr, 0.5)
    r = recall(approx.doc_ids, full.doc_ids)
    assert 0.0 <= r <= 1.0
    assert set(approx.doc_ids).issubset(set(full.doc_ids))


def test_boolean_parser_precedence():
    e = parse_boolean([1, "or", 2, "and", 3])
    assert e.op == "or"
    assert e.right.op == "and"
    e2 = parse_boolean(["(", 1, "or", 2, ")", "and", 3])
    assert e2.op == "and"


def test_ranked_retrieval_topk(small_corpus, built_index):
    words = [_frequent_word(small_corpus), 17]
    full = ranked_query(small_corpus, built_index, words, 1.0, k=10)
    assert len(full.doc_ids) == 10
    approx = ranked_query(small_corpus, built_index, words, 0.6, k=10)
    p = precision_at_k(approx.doc_ids, full.doc_ids, 10)
    assert p >= 0.3  # sampled BM25 should overlap substantially


def test_higher_rate_reads_more_shards(small_corpus, built_index):
    w = _frequent_word(small_corpus)
    rng = np.random.default_rng(2)
    lo = phrase_count_query(small_corpus, built_index, [w], 0.1, rng=rng)
    hi = phrase_count_query(small_corpus, built_index, [w], 0.5, rng=rng)
    assert hi.shards_read > lo.shards_read
    assert lo.data_fraction < 0.35


def test_recommend_pipeline():
    from repro.core.index import build_index
    from repro.core.lsh import LSHConfig
    from repro.core.pv_dbow import PVDBOWConfig, train_pv_dbow
    from repro.data.corpus import ReviewCorpusConfig, generate_review_corpus
    from repro.data.store import ShardedCorpus

    data = generate_review_corpus(ReviewCorpusConfig(
        n_users=120, n_items=60, vocab_size=1024, n_topics=6, seed=3))
    corpus = ShardedCorpus.from_documents(data.user_docs, 1024,
                                          shard_tokens=4096)
    pcfg = PVDBOWConfig(dim=16, steps=150, batch_pairs=1024)
    index = build_index(corpus, train_pv_dbow(corpus, pcfg),
                        LSHConfig(bits=64), temperature=pcfg.temperature)
    res = recommend_query(corpus, index, data, target_user=3, rate=0.5)
    assert res.predictions, "no predictions produced"
    for item, pred in res.predictions.items():
        assert 1.0 <= pred <= 5.0
    truth_mask = data.user_of == 3
    m = rec_mse(res.predictions, data.item_of[truth_mask],
                data.ratings[truth_mask])
    assert np.isfinite(m)
