"""Replica-aware load balancing: HostLoadModel telemetry, the
cost-aware ``PlacementMap.split`` replica paths (hot-primary shed,
hysteresis, dead-primary unification with the requeue path), and
end-to-end bit-for-bit parity of balanced execution with the
single-executor reduce under an injected slow host."""
import time

import numpy as np
import pytest

from repro.core.queries import BatchQuery, QueryBatch, parse_boolean
from repro.runtime import (
    BalanceConfig,
    HostFailure,
    HostGroupExecutor,
    HostLoadModel,
    PlacementMap,
    ShardTaskExecutor,
    plan_split,
)


class _FakeShard:
    def __init__(self, i):
        self.shard_id = i


class _FakeCorpus:
    def __init__(self, n):
        self.shards = [_FakeShard(i) for i in range(n)]


def _hot_model(hot_cost=0.2, cold_cost=0.01, n_hosts=2):
    m = HostLoadModel(n_hosts)
    m.observe(0, hot_cost * 4, 4)
    for h in range(1, n_hosts):
        m.observe(h, cold_cost * 4, 4)
    return m


# ----------------------------------------------------------------------
# HostLoadModel
# ----------------------------------------------------------------------
def test_load_model_seeds_uniform_before_telemetry():
    m = HostLoadModel(3)
    costs = [m.shard_cost(h) for h in range(3)]
    assert costs[0] == costs[1] == costs[2] > 0
    # uniform prior => estimated host load is just the shard count, so
    # the cold balanced split degenerates to count balancing
    pm = PlacementMap.blocked(12, 3, n_replicas=1)
    audit = plan_split(pm, range(12), m)
    assert audit.groups == pm.split(range(12))


def test_load_model_ewma_and_median_seeding():
    m = HostLoadModel(3, BalanceConfig(ewma_alpha=0.5))
    m.observe(0, 1.0, 10)                    # 100 ms/shard
    assert m.shard_cost(0) == pytest.approx(0.1)
    m.observe(0, 2.0, 10)                    # EWMA toward 200 ms/shard
    assert m.shard_cost(0) == pytest.approx(0.15)
    # a host without telemetry prices at the fleet median, not the seed
    assert m.shard_cost(1) == pytest.approx(m.shard_cost(0))
    m.observe(1, 0.1, 10)
    assert m.snapshot()[2] is None
    assert m.shard_cost(2) == pytest.approx(
        float(np.median([0.15, 0.01])))


def test_load_model_validation():
    with pytest.raises(ValueError):
        HostLoadModel(0)
    with pytest.raises(ValueError):
        BalanceConfig(ewma_alpha=0.0)
    with pytest.raises(ValueError):
        BalanceConfig(hysteresis=-0.1)
    m = HostLoadModel(2)
    m.observe(0, 1.0, 0)                     # no-op, not a crash
    assert m.snapshot() == [None, None]


# ----------------------------------------------------------------------
# cost-aware split: shed, hysteresis, dead-host unification
# ----------------------------------------------------------------------
def test_hot_primary_sheds_to_live_replica_and_preserves_residency():
    pm = PlacementMap.blocked(16, 2, n_replicas=1)
    m = _hot_model()
    audit = plan_split(pm, range(16), m)
    assert audit.balanced and audit.shed > 0
    # the hot host kept less than its residency half
    sizes = {h: len(g) for h, g in audit.groups.items()}
    assert sizes.get(0, 0) < 8
    # residency preserved: every shard landed on a host that holds it
    for h, g in audit.groups.items():
        for sid in g:
            assert h in pm.hosts_of(sid)
    # all shards assigned exactly once
    assert sorted(s for g in audit.groups.values() for s in g) == \
        list(range(16))
    # the balanced estimate beats the residency estimate
    assert audit.est_makespan_s < audit.est_base_makespan_s
    # split(load=...) is the same assignment
    assert pm.split(range(16), load=m) == audit.groups


def test_shed_lands_on_ring_replica_with_more_hosts():
    # 4 hosts, R=1: shards of host 0 may only go to 0 or its ring
    # replica 1 — never to 2 or 3, however cold those are
    pm = PlacementMap.blocked(16, 4, n_replicas=1)
    m = HostLoadModel(4)
    m.observe(0, 4.0, 4)                     # scorching
    for h in (1, 2, 3):
        m.observe(h, 0.04, 4)
    groups = pm.split(range(16), load=m)
    for h, g in groups.items():
        for sid in g:
            assert h in pm.hosts_of(sid)
    # the scorching host keeps nothing: its shards all shed to their
    # ring replica (host 1), which may cascade its own load onward —
    # but never onto a host that lacks the data
    assert len(groups.get(0, [])) == 0
    for sid in pm.shards_on(0):
        assert sid in groups[1]


def test_hysteresis_suppresses_flapping_under_near_equal_load():
    pm = PlacementMap.blocked(16, 2, n_replicas=1)
    m = HostLoadModel(2, BalanceConfig(hysteresis=0.25))
    m.observe(0, 0.44, 4)                    # 110 ms/shard
    m.observe(1, 0.40, 4)                    # 100 ms/shard: ~10% apart
    audits = [plan_split(pm, range(16), m) for _ in range(3)]
    for a in audits:
        assert not a.balanced and a.shed == 0
        assert a.groups == pm.split(range(16))
        assert a.est_makespan_s == a.est_base_makespan_s
    # widening the gap past the band flips it — the band, not the
    # model, was holding the split steady
    m2 = _hot_model(hot_cost=0.2, cold_cost=0.01)
    assert plan_split(pm, range(16), m2).balanced


def test_hysteresis_is_stateful_asymmetric_band():
    """Real hysteresis: the keep/shed decision depends on the previous
    decision.  A gap between the stay and enter thresholds keeps
    whatever split is already running — a fresh model at the same gap
    stays primary, a model already balanced stays balanced."""
    pm = PlacementMap.blocked(16, 2, n_replicas=1)
    cfg = BalanceConfig(hysteresis=0.25, stay_fraction=0.5, ewma_alpha=1.0)

    def observe_gap(m, ratio):
        m.observe(0, 0.1 * ratio * 4, 4)     # host 0 at ratio x host 1
        m.observe(1, 0.1 * 4, 4)

    # base makespan 8*c0, balanced ~ interleaves; pick a gross gap to
    # enter balanced mode first
    m = HostLoadModel(2, cfg)
    observe_gap(m, 20.0)
    assert plan_split(pm, range(16), m).balanced and m.balanced_mode
    # now hover between the stay (12.5%) and enter (25%) thresholds:
    # the balanced model keeps shedding ...
    observe_gap(m, 1.37)                     # est_base/est_bal ~ 1.2
    a_stay = plan_split(pm, range(16), m)
    assert a_stay.balanced and m.balanced_mode
    # ... while a fresh model at the identical load keeps the
    # residency split — same inputs, different (previous) state
    m2 = HostLoadModel(2, cfg)
    observe_gap(m2, 1.37)
    a_enter = plan_split(pm, range(16), m2)
    assert not a_enter.balanced and not m2.balanced_mode
    # dropping under the stay threshold exits balanced mode
    observe_gap(m, 1.0)
    assert not plan_split(pm, range(16), m).balanced
    assert not m.balanced_mode


def test_balanced_split_minimizes_churn():
    """Per-shard cost is host-uniform, so the balanced split should
    never *cross-move* shards: when the hot host keeps capacity worth
    using, it uses its own resident shards, not the cold host's."""
    pm = PlacementMap.blocked(16, 2, n_replicas=1)
    m = HostLoadModel(2, BalanceConfig(ewma_alpha=1.0))
    m.observe(0, 0.3 * 4, 4)                 # host 0 is 3x host 1
    m.observe(1, 0.1 * 4, 4)
    audit = plan_split(pm, range(16), m)
    assert audit.balanced
    sizes = {h: len(g) for h, g in audit.groups.items()}
    assert 0 < sizes[0] < 8                  # hot host still used
    base_host = {sid: h for h, g in audit.base_groups.items()
                 for sid in g}
    # no bidirectional churn: at most one direction of movement exists
    to_hot = [s for s in audit.groups[0] if base_host[s] == 1]
    off_hot = [s for s in audit.groups[1] if base_host[s] == 0]
    assert not (to_hot and off_hot)
    # shed equals the minimum possible for these group sizes
    assert audit.shed == abs(sizes[0] - len(audit.base_groups[0]))


def test_requeued_host_wall_accumulates_across_rounds():
    """A host that ran its own group and then absorbed a dead host's
    requeued group spent both walls — per_host_wall_s must report the
    sum, not just the last round (the audit the bench compares
    est-vs-realized against)."""
    pm = PlacementMap.blocked(10, 2, n_replicas=1)

    def hook(host, shard_ids):
        if host == 0:
            raise RuntimeError("host 0 down")
        time.sleep(0.01 * len(shard_ids))    # 10 ms per shard on host 1

    with HostGroupExecutor(pm, workers_per_host=1,
                           host_fault_hook=hook) as hg:
        out = hg.map_shards(_FakeCorpus(10), range(10), lambda s: 1)
    assert len(out) == 10
    # host 1 ran its own 5 shards, then host 0's requeued 5: >= 100 ms
    assert hg.last_job["per_host_wall_s"][1] >= 0.09


def test_dead_primary_requeue_and_balancer_shed_are_identical():
    """Failover is balancing with an infinite cost: for R=1 the
    balancer's dead-host split must equal the primary-only requeue
    split, whatever the load model says."""
    pm = PlacementMap.blocked(16, 2, n_replicas=1)
    ids = [3, 0, 9, 12, 5]
    dead = frozenset({0})
    want = pm.split(ids, dead)
    for model in (HostLoadModel(2), _hot_model(),
                  _hot_model(hot_cost=0.01, cold_cost=0.2)):
        assert pm.split(ids, dead, load=model) == want
    # both hosts dead: same HostFailure either way
    with pytest.raises(HostFailure):
        pm.split(ids, frozenset({0, 1}), load=_hot_model())


def test_balanced_executor_requeues_dead_host_like_primary_split():
    """End-to-end: an executor-killed host routes through the same
    balancer split — every shard re-runs on the surviving replica,
    exactly as the primary-only requeue does."""
    pm = PlacementMap.blocked(10, 2, n_replicas=1)

    def host_fault(host, shard_ids):
        if host == 0:
            raise RuntimeError("host 0 down")

    with HostGroupExecutor(pm, workers_per_host=1, balanced=True,
                           host_fault_hook=host_fault) as hg:
        out = hg.map_shards(_FakeCorpus(10), range(10),
                            lambda s: s.shard_id + 1)
    assert out == {i: i + 1 for i in range(10)}
    assert hg.stats["host_failures"] == 1
    assert hg.stats["scans_per_host"] == [0, 10]


def test_requeue_round_is_read_only_on_hysteresis_state():
    """A transient host death mid-job splits only the dead host's
    group; that degenerate subset must not flip ``balanced_mode`` (or
    inflate the planned-shed stat) — otherwise one blip resets the
    asymmetric band and the next planned split flaps."""
    pm = PlacementMap.blocked(16, 2, n_replicas=1)
    model = _hot_model()                     # host 0 hot: planned split
    assert plan_split(pm, range(16), model).balanced   # sheds to host 1
    assert model.balanced_mode

    died = []

    def fault(host, shard_ids):
        if host == 1 and not died:           # kill the cold host once
            died.append(host)
            raise RuntimeError("host 1 down")

    with HostGroupExecutor(pm, workers_per_host=1, balancer=model,
                           host_fault_hook=fault) as hg:
        out = hg.map_shards(_FakeCorpus(16), range(16), lambda s: 1)
        planned_shed = hg.last_job["balance"]["shed"]
    assert len(out) == 16 and died == [1]
    # the requeue (everything forced onto host 0, a no-choice split
    # whose base == balanced) left the hysteresis state alone ...
    assert model.balanced_mode
    # ... and the shed stat counts only the planned split's moves
    assert hg.stats["shed_shards"] == planned_shed


# ----------------------------------------------------------------------
# HostGroupExecutor with a balancer: telemetry, audit, convergence
# ----------------------------------------------------------------------
def test_balanced_executor_learns_and_sheds_hot_host():
    pm = PlacementMap.blocked(16, 2, n_replicas=1)

    def hot(host, shard_ids):                # host 0 is 5 ms/shard slower
        if host == 0:
            time.sleep(0.005 * len(shard_ids))

    with HostGroupExecutor(pm, workers_per_host=1, balanced=True,
                           host_fault_hook=hot) as hg:
        walls = []
        for _ in range(3):
            out = hg.map_shards(_FakeCorpus(16), range(16),
                                lambda s: s.shard_id)
            assert out == {i: i for i in range(16)}
            walls.append(hg.last_job["balance"]["realized_makespan_s"])
        rec = hg.last_job["balance"]
    # first job runs the seeded (count-balanced) split, later jobs shed
    assert hg.stats["shed_shards"] > 0
    assert rec["balanced"] and rec["shed"] > 0
    assert rec["group_sizes"][0] < rec["base_group_sizes"][0]
    assert sum(rec["realized_group_sizes"]) == 16
    # the balanced split beats the hot residency split's makespan
    assert walls[-1] < walls[0]
    assert rec["est_base_makespan_s"] > rec["est_makespan_s"]


def test_balance_record_absent_without_balancer():
    pm = PlacementMap.blocked(8, 2, n_replicas=1)
    with HostGroupExecutor(pm, workers_per_host=1) as hg:
        hg.map_shards(_FakeCorpus(8), range(8), lambda s: s.shard_id)
        assert "balance" not in hg.last_job
        assert hg.stats["shed_shards"] == 0


# ----------------------------------------------------------------------
# end-to-end: balanced QueryBatch bit-for-bit vs single executor
# ----------------------------------------------------------------------
def _mixed_queries():
    return [
        BatchQuery.count([3]),
        BatchQuery.boolean(parse_boolean([3, "or", 5, "and", 9])),
        BatchQuery.ranked([7, 4, 5], k=10),
        BatchQuery.count([11]),
        BatchQuery.ranked([2, 10], k=5),
        BatchQuery.boolean(parse_boolean([2, "and", 7])),
    ]


def _assert_results_identical(got, want):
    for g, w in zip(got, want):
        assert type(g) is type(w)
        if hasattr(g, "doc_ids"):                   # retrieval / ranked
            np.testing.assert_array_equal(g.doc_ids, w.doc_ids)
            if hasattr(g, "scores"):                # RankedResult
                np.testing.assert_array_equal(g.scores, w.scores)
        else:                                       # PhraseCountResult
            assert g.estimate.value == w.estimate.value
            assert g.estimate.error_bound == w.estimate.error_bound
        assert g.shards_read == w.shards_read


def test_balanced_query_batch_matches_single_executor_under_slow_host(
        small_corpus, built_index):
    """The satellite requirement: with an injected slow-host fault the
    balancer sheds work onto the replica, and the gathered reduces for
    all three query kinds stay bit-for-bit the single-executor
    results."""
    queries = _mixed_queries()
    with ShardTaskExecutor(workers=2) as single:
        ref_engine = QueryBatch(small_corpus, built_index, executor=single)
        wants = [ref_engine.execute(queries, 0.5,
                                    rng=np.random.default_rng(21 + j))
                 for j in range(3)]

    def slow_host(host, shard_ids):          # host 0 drags 5 ms/shard
        if host == 0:
            time.sleep(0.005 * len(shard_ids))

    pm = PlacementMap.blocked(small_corpus.n_shards, 2, n_replicas=1)
    with HostGroupExecutor(pm, workers_per_host=1, balanced=True,
                           host_fault_hook=slow_host) as hg:
        engine = QueryBatch(small_corpus, built_index, executor=hg)
        for j, want in enumerate(wants):
            got = engine.execute(queries, 0.5,
                                 rng=np.random.default_rng(21 + j))
            _assert_results_identical(got, want)
        audit = engine.last_audit
    # the slow host was actually detected and shed around
    assert hg.stats["shed_shards"] > 0
    # the executed split is audited on the engine
    assert audit is not None and audit["balanced"]
    assert audit["group_sizes"][0] < audit["base_group_sizes"][0]
