"""Locality-aware multi-host execution: PlacementMap residency and
failover, HostGroupExecutor per-host shared scans + cross-host gather
parity with the single-executor path (bit-for-bit, including under an
injected host fault with replica requeue), and per-host scan-count
accounting against the union plan's residency split."""
import numpy as np
import pytest

from repro.core.queries import BatchQuery, QueryBatch, parse_boolean
from repro.launch.mesh import make_placement_mesh
from repro.runtime import (
    HostFailure,
    HostGroupExecutor,
    PlacementMap,
    ShardTaskExecutor,
)
from repro.runtime.executor import invert_plan


class _FakeShard:
    def __init__(self, i):
        self.shard_id = i


class _FakeCorpus:
    def __init__(self, n):
        self.shards = [_FakeShard(i) for i in range(n)]


# ----------------------------------------------------------------------
# PlacementMap
# ----------------------------------------------------------------------
def test_blocked_placement_is_contiguous_and_covering():
    pm = PlacementMap.blocked(16, 4, n_replicas=1)
    assert pm.n_shards == 16 and pm.n_hosts == 4 and pm.n_replicas == 1
    # contiguous blocks, every host owns a quarter
    np.testing.assert_array_equal(pm.primary, np.repeat(np.arange(4), 4))
    for h in range(4):
        np.testing.assert_array_equal(pm.shards_on(h),
                                      np.arange(4 * h, 4 * h + 4))


def test_round_robin_placement_stripes():
    pm = PlacementMap.round_robin(10, 3, n_replicas=2)
    np.testing.assert_array_equal(pm.primary, np.arange(10) % 3)
    for sid in range(10):
        hosts = pm.hosts_of(sid)
        assert len(hosts) == 3                  # primary + 2 replicas
        assert len(set(hosts)) == 3             # all distinct


def test_replicas_capped_and_distinct_from_primary():
    pm = PlacementMap.blocked(8, 2, n_replicas=5)   # only 1 other host
    assert pm.n_replicas == 1
    assert (pm.replicas[:, 0] != pm.primary).all()
    none = PlacementMap.blocked(8, 2, n_replicas=0)
    assert none.n_replicas == 0


def test_split_by_residency_and_failover_order():
    pm = PlacementMap.blocked(8, 2, n_replicas=1)   # 0-3 on h0, 4-7 on h1
    groups = pm.split([0, 5, 2, 7])
    assert groups == {0: [0, 2], 1: [5, 7]}
    # dead primary: shards fail over to the replica host
    assert pm.split([0, 5], dead=frozenset({0})) == {1: [0, 5]}
    with pytest.raises(HostFailure):
        pm.split([0], dead=frozenset({0, 1}))
    with pytest.raises(HostFailure):
        PlacementMap.blocked(8, 2, n_replicas=0).split(
            [1], dead=frozenset({0}))


def test_from_mesh_reads_residency_axes():
    pm = PlacementMap.from_mesh(make_placement_mesh(4), 10)
    assert pm.n_hosts == 4
    assert len(np.unique(pm.primary)) == 4
    # pod x data both count as residency axes
    from jax.sharding import AbstractMesh
    mesh = AbstractMesh((("pod", 2), ("data", 3), ("model", 4)))
    assert PlacementMap.from_mesh(mesh, 12).n_hosts == 6


def test_placement_validation():
    with pytest.raises(ValueError):
        PlacementMap(np.asarray([0, 5]), np.zeros((2, 0), np.int64), 2)
    with pytest.raises(ValueError):                 # replica == primary
        PlacementMap(np.asarray([0, 1]), np.asarray([[0], [0]]), 2)
    with pytest.raises(ValueError):
        PlacementMap.blocked(4, 0)


# ----------------------------------------------------------------------
# HostGroupExecutor: gather parity + accounting
# ----------------------------------------------------------------------
def test_map_shards_matches_single_executor():
    pm = PlacementMap.blocked(12, 3, n_replicas=1)
    with HostGroupExecutor(pm, workers_per_host=2) as hg, \
            ShardTaskExecutor(workers=2) as single:
        corpus = _FakeCorpus(12)
        got = hg.map_shards(corpus, range(12), lambda s: s.shard_id * 3)
        want = single.map_shards(corpus, range(12), lambda s: s.shard_id * 3)
    assert got == want
    assert hg.stats["jobs"] == 1 and hg.stats["host_failures"] == 0


def test_shared_scan_splits_by_residency_and_gathers():
    pm = PlacementMap.blocked(8, 2, n_replicas=1)
    plan = [[0, 1, 6], [1, 6, 7], [2]]
    fns = [lambda s, q=q: (q, s.shard_id) for q in range(3)]
    with HostGroupExecutor(pm, workers_per_host=1) as hg, \
            ShardTaskExecutor(workers=2) as single:
        got = hg.map_shard_batch(_FakeCorpus(8), plan, fns)
        want = single.map_shard_batch(_FakeCorpus(8), plan, fns)
        assert got == want
        # per-host scans == the union plan's residency split, not the
        # sum of per-query plan sizes (5 union shards, 7 plan entries)
        union = sorted(invert_plan(plan))
        assert union == [0, 1, 2, 6, 7]
        assert hg.residency_split(plan) == {0: 3, 1: 2}
        assert hg.stats["scans_per_host"] == [3, 2]
        assert hg.last_job["tasks"] == 5.0 and hg.last_job["hosts"] == 2.0


def test_host_failure_requeues_on_replica():
    pm = PlacementMap.blocked(10, 2, n_replicas=1)
    downed = []

    def host_fault(host, shard_ids):
        if host == 0 and not downed:
            downed.append(list(shard_ids))
            raise RuntimeError("injected host fault")

    with HostGroupExecutor(pm, workers_per_host=1,
                           host_fault_hook=host_fault) as hg:
        out = hg.map_shards(_FakeCorpus(10), range(10),
                            lambda s: s.shard_id + 100)
    assert out == {i: i + 100 for i in range(10)}
    assert downed == [[0, 1, 2, 3, 4]]          # host 0's whole group died
    assert hg.stats["host_failures"] == 1
    assert hg.stats["requeued_shards"] == 5
    # every scan landed on the surviving replica host
    assert hg.stats["scans_per_host"] == [0, 10]


def test_host_failure_without_replica_raises():
    pm = PlacementMap.blocked(6, 2, n_replicas=0)

    def host_fault(host, shard_ids):
        if host == 1:
            raise RuntimeError("host 1 is gone")

    with HostGroupExecutor(pm, workers_per_host=1,
                           host_fault_hook=host_fault) as hg:
        with pytest.raises(HostFailure) as exc:
            hg.map_shards(_FakeCorpus(6), range(6), lambda s: s.shard_id)
    # the real host exception is chained, not swallowed — a bug in a
    # query fn must not masquerade as pure infrastructure loss
    assert isinstance(exc.value.__cause__, RuntimeError)
    assert "host 1 is gone" in str(exc.value.__cause__)


def test_task_fault_hook_forwards_to_host_executors():
    """Shard-granularity faults stay the per-host executor's business:
    retries absorb them without tripping host failover."""
    fails = {3: 1}

    def hook(sid, attempt):
        if fails.get(sid, 0) >= attempt:
            raise RuntimeError("transient task fault")

    pm = PlacementMap.blocked(8, 2, n_replicas=1)
    with HostGroupExecutor(pm, workers_per_host=2, max_retries=2,
                           fault_hook=hook) as hg:
        out = hg.map_shards(_FakeCorpus(8), range(8), lambda s: s.shard_id)
    assert out == {i: i for i in range(8)}
    assert hg.stats["host_failures"] == 0
    assert sum(ex.stats["retries"] for ex in hg.hosts.values()) >= 1


def test_close_is_idempotent():
    hg = HostGroupExecutor(PlacementMap.blocked(4, 2), workers_per_host=1)
    hg.map_shards(_FakeCorpus(4), range(4), lambda s: 1)
    hg.close()
    hg.close()
    assert all(ex._pool is None for ex in hg.hosts.values())


# ----------------------------------------------------------------------
# end-to-end: QueryBatch through a 2-host group, bit-for-bit vs single
# ----------------------------------------------------------------------
def _mixed_queries():
    return [
        BatchQuery.count([3]),
        BatchQuery.boolean(parse_boolean([3, "or", 5, "and", 9])),
        BatchQuery.ranked([7, 4, 5], k=10),
        BatchQuery.count([11]),
        BatchQuery.ranked([2, 10], k=5),
        BatchQuery.boolean(parse_boolean([2, "and", 7])),
    ]


def _assert_results_identical(got, want):
    for g, w in zip(got, want):
        assert type(g) is type(w)
        if hasattr(g, "doc_ids"):                   # retrieval / ranked
            np.testing.assert_array_equal(g.doc_ids, w.doc_ids)
            if hasattr(g, "scores"):                # RankedResult
                np.testing.assert_array_equal(g.scores, w.scores)
        else:                                       # PhraseCountResult
            assert g.estimate.value == w.estimate.value
            assert g.estimate.error_bound == w.estimate.error_bound
        assert g.shards_read == w.shards_read


@pytest.mark.parametrize("rate", [0.4, 1.0])
def test_query_batch_host_group_matches_single_executor(
        small_corpus, built_index, rate):
    queries = _mixed_queries()
    pm = PlacementMap.blocked(small_corpus.n_shards, 2, n_replicas=1)
    with ShardTaskExecutor(workers=2) as single, \
            HostGroupExecutor(pm, workers_per_host=1) as hg:
        want = QueryBatch(small_corpus, built_index, executor=single
                          ).execute(queries, rate,
                                    rng=np.random.default_rng(42))
        engine = QueryBatch(small_corpus, built_index, executor=hg)
        got = engine.execute(queries, rate, rng=np.random.default_rng(42))
        # the gathered reduce is bit-for-bit the single-executor reduce
        _assert_results_identical(got, want)
        # per-host scans match the residency split of the executed plan
        split = hg.residency_split(engine.last_plan)
        observed = {h: c for h, c in
                    enumerate(hg.stats["scans_per_host"]) if c}
        assert observed == split


def test_query_batch_survives_host_fault_bit_for_bit(small_corpus,
                                                     built_index):
    """The satellite requirement: a 2-host placement with an injected
    host fault re-executes that host's shards on the replica and the
    cross-host gathered reduce still matches the single-executor path
    bit-for-bit, for all three query types."""
    queries = _mixed_queries()
    with ShardTaskExecutor(workers=2) as single:
        want = QueryBatch(small_corpus, built_index, executor=single
                          ).execute(queries, 0.5,
                                    rng=np.random.default_rng(7))

    downed = []

    def host_fault(host, shard_ids):
        if host == 1 and not downed:
            downed.append(host)
            raise RuntimeError("host 1 down")

    pm = PlacementMap.blocked(small_corpus.n_shards, 2, n_replicas=1)
    with HostGroupExecutor(pm, workers_per_host=1,
                           host_fault_hook=host_fault) as hg:
        got = QueryBatch(small_corpus, built_index, executor=hg
                         ).execute(queries, 0.5,
                                   rng=np.random.default_rng(7))
    assert downed == [1]                        # the fault actually fired
    assert hg.stats["host_failures"] == 1
    assert hg.stats["requeued_shards"] > 0
    assert hg.stats["scans_per_host"][1] == 0   # replica took every scan
    _assert_results_identical(got, want)
