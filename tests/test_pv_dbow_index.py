"""PV-DBOW training + index behaviour."""
import os

import numpy as np
import pytest

from repro.core.index import ApproxIndex, build_index
from repro.core.lsh import LSHConfig
from repro.core.pv_dbow import (
    PVDBOWConfig,
    corpus_pairs,
    infer_doc_vector,
    sgns_loss,
    train_pv_dbow,
)


def test_training_reduces_loss(small_corpus):
    losses = []
    cfg = PVDBOWConfig(dim=16, steps=250, batch_pairs=2048, lr=0.01,
                       temperature=8.0)
    train_pv_dbow(small_corpus, cfg,
                  callback=lambda s, l: losses.append(l))
    assert losses[-1] < losses[0] * 0.85


def test_vectors_unit_norm(pv_model):
    model, _ = pv_model
    for t in (model.word_vecs, model.doc_vecs):
        norms = np.linalg.norm(np.asarray(t), axis=1)
        np.testing.assert_allclose(norms, 1.0, atol=1e-4)


def test_corpus_pairs_subsampling(small_corpus):
    full = corpus_pairs(small_corpus, subsample_t=0.0)
    sub = corpus_pairs(small_corpus, subsample_t=1e-3)
    assert sub.word_of_token.shape[0] < full.word_of_token.shape[0]
    assert sub.noise_cdf[-1] == pytest.approx(1.0, abs=1e-5)
    assert (np.diff(sub.noise_cdf) >= 0).all()


def test_infer_unseen_document(small_corpus, pv_model):
    """Inferred vector for an existing doc's tokens should land near
    that doc's trained vector (paper Sec. V)."""
    model, cfg = pv_model
    doc = small_corpus.shards[0].document(0)
    vec = np.asarray(infer_doc_vector(model, doc.tokens, cfg, steps=100))
    dv = np.asarray(model.doc_vecs)
    sims = dv @ vec
    rank = (sims > sims[doc.doc_id]).sum()
    assert rank < len(dv) * 0.25   # top quartile


def test_index_roundtrip(tmp_path, built_index):
    p = os.path.join(tmp_path, "idx.npz")
    built_index.save(p)
    loaded = ApproxIndex.load(p)
    np.testing.assert_array_equal(loaded.shard_sig, built_index.shard_sig)
    assert loaded.bits == built_index.bits
    assert loaded.temperature == built_index.temperature
    q = built_index.shard_probabilities([3, 5])
    q2 = loaded.shard_probabilities([3, 5])
    np.testing.assert_allclose(q, q2, rtol=1e-6)


def test_shard_probabilities_valid(built_index):
    p = built_index.shard_probabilities([1, 2, 3])
    assert p.sum() == pytest.approx(1.0)
    assert (p > 0).all()


def test_index_compression(built_index, small_corpus):
    """LSH index must be far smaller than raw fp32 vectors (paper
    Table II: ~64x)."""
    raw = (built_index.word_vecs.nbytes + built_index.doc_vecs.nbytes +
           built_index.shard_vecs.nbytes)
    packed = (built_index.word_sig.nbytes + built_index.doc_sig.nbytes +
              built_index.shard_sig.nbytes)
    assert packed * 4 < raw


def test_doc_granularity_scoring(small_corpus, pv_model):
    model, pcfg = pv_model
    idx = build_index(small_corpus, model, LSHConfig(bits=128),
                      temperature=pcfg.temperature, granularity="doc")
    p = idx.shard_probabilities([7])
    assert p.shape[0] == small_corpus.n_shards
    assert p.sum() == pytest.approx(1.0)
