"""Elastic fleet membership: FleetManager join/drain/crash over the
placement layer — RCU generation swaps, warm-before-serve joins,
orphan bookkeeping and revival, the cold-join load-model pricing, and
the all-replicas-dead degraded path through QueryBatch (the regression
pin for the former bare-HostFailure crash)."""
import json

import numpy as np
import pytest

from repro.core.queries import BatchQuery, QueryBatch, parse_boolean
from repro.runtime import (
    FleetManager,
    HostFailure,
    HostGroupExecutor,
    PlacementMap,
    ShardTaskExecutor,
)
from repro.runtime.balance import HostLoadModel


class _FakeShard:
    def __init__(self, i):
        self.shard_id = i


class _FakeCorpus:
    def __init__(self, n):
        self.shards = [_FakeShard(i) for i in range(n)]


def _ids(corpus, hg):
    return hg.map_shards(corpus, range(len(corpus.shards)),
                         lambda s: s.shard_id)


# ----------------------------------------------------------------------
# drain / crash: one transfer path, two orderings
# ----------------------------------------------------------------------
def test_drain_moves_residency_then_retires():
    corpus = _FakeCorpus(12)
    with HostGroupExecutor(PlacementMap.blocked(12, 3, n_replicas=1),
                           workers_per_host=1) as hg:
        fleet = FleetManager(hg)
        ev = fleet.drain(1)
        assert ev["op"] == "drain" and ev["planned"] is True
        assert ev["moved_shards"] == 4 and ev["orphaned_shards"] == 0
        assert 1 in hg.down
        assert not (hg.placement.primary == 1).any()
        assert hg.stats["placement_epoch"] == 1
        assert fleet.live_hosts() == [0, 2]
        # serving continues on the survivors, nothing lost
        out = _ids(corpus, hg)
        assert sorted(out) == list(range(12))
        assert hg.stats["lost_shards"] == 0


def test_crash_transfers_with_planned_false():
    corpus = _FakeCorpus(12)
    with HostGroupExecutor(PlacementMap.blocked(12, 3, n_replicas=1),
                           workers_per_host=1) as hg:
        fleet = FleetManager(hg)
        ev = fleet.crash(2)
        assert ev["op"] == "crash" and ev["planned"] is False
        assert ev["moved_shards"] == 4 and ev["orphaned_shards"] == 0
        assert sorted(_ids(corpus, hg)) == list(range(12))
        rec = fleet.record()
        assert rec["crashes"] == 1 and rec["joins"] == 0
        json.dumps(rec)                      # audit is JSON-ready


def test_join_grows_fleet_warm_before_residency():
    corpus = _FakeCorpus(12)
    with HostGroupExecutor(PlacementMap.blocked(12, 2, n_replicas=1),
                           workers_per_host=1) as hg:
        streamed = []

        def warm(sid, src, dst):
            # residency must not have swapped yet: the joiner owns
            # nothing while its shards are still streaming
            assert not (hg.placement.primary == dst).any()
            streamed.append((sid, src, dst))

        fleet = FleetManager(hg, warm_fn=warm)
        ev = fleet.join()
        assert ev["host"] == 2               # fleet grew by one id
        assert ev["warmed_shards"] == len(streamed) == 4
        counts = [int((hg.placement.primary == h).sum()) for h in range(3)]
        assert counts == [4, 4, 4]           # stolen down to even share
        assert sorted(_ids(corpus, hg)) == list(range(12))


def test_join_revives_down_slot_and_its_orphans():
    corpus = _FakeCorpus(8)
    # no replicas: a crash orphans the dead host's shards
    with HostGroupExecutor(PlacementMap.blocked(8, 2, n_replicas=0),
                           workers_per_host=1, allow_partial=True) as hg:
        fleet = FleetManager(hg)
        ev = fleet.crash(1)
        assert ev["orphaned_shards"] == 4 and ev["moved_shards"] == 0
        out = _ids(corpus, hg)
        assert sorted(out) == [0, 1, 2, 3]   # partial: orphans lost
        assert hg.stats["lost_shards"] == 4
        # default join revives the lowest down slot — and the orphaned
        # shards, which kept their dead primary, come back with it
        ev = fleet.join()
        assert ev["host"] == 1
        assert sorted(_ids(corpus, hg)) == list(range(8))


def test_fleet_lifecycle_epochs_and_audit():
    with HostGroupExecutor(PlacementMap.blocked(12, 2, n_replicas=1),
                           workers_per_host=1) as hg:
        fleet = FleetManager(hg)
        fleet.crash(1)
        fleet.join(2)
        fleet.drain(0)
        rec = fleet.record()
        assert [e["op"] for e in rec["events"]] == ["crash", "join",
                                                    "drain"]
        assert rec["placement_epoch"] == 3   # one generation per op
        assert rec["live_hosts"] == [2]


# ----------------------------------------------------------------------
# cold-join pricing in the load model
# ----------------------------------------------------------------------
def test_load_model_prices_cold_host_at_fleet_median():
    m = HostLoadModel(2)
    m.observe(0, wall_s=0.2, n_shards=2)     # 0.1 s/shard
    m.observe(1, wall_s=0.6, n_shards=2)     # 0.3 s/shard
    m.ensure_hosts(3)                        # joiner: no telemetry
    cold = m.shard_cost(2)
    assert cold == pytest.approx(np.median([0.1, 0.3]))
    m.forget_host(0)                         # departed: telemetry drops
    assert m.shard_cost(0) == pytest.approx(m.shard_cost(2))


# ----------------------------------------------------------------------
# all-replicas-dead: typed partial results, not a bare crash
# (regression pin for the former uncaught HostFailure)
# ----------------------------------------------------------------------
def _queries():
    return [
        BatchQuery.count([3]),
        BatchQuery.boolean(parse_boolean([3, "or", 5, "and", 9])),
        BatchQuery.ranked([7, 4, 5], k=10),
    ]


def test_all_replicas_dead_raises_typed_without_allow_partial(
        small_corpus, built_index):
    pm = PlacementMap.blocked(small_corpus.n_shards, 2, n_replicas=0)
    with HostGroupExecutor(pm, workers_per_host=1) as hg:
        FleetManager(hg).crash(1)
        engine = QueryBatch(small_corpus, built_index, executor=hg)
        with pytest.raises(HostFailure):
            engine.execute(_queries(), 0.9,
                           rng=np.random.default_rng(0))


def test_all_replicas_dead_degrades_to_partial_estimates(
        small_corpus, built_index):
    pm = PlacementMap.blocked(small_corpus.n_shards, 2, n_replicas=0)
    with ShardTaskExecutor(workers=2) as single, \
            HostGroupExecutor(pm, workers_per_host=1,
                              allow_partial=True) as hg:
        ref = QueryBatch(small_corpus, built_index, executor=single)
        want = ref.execute(_queries(), 0.9, rng=np.random.default_rng(1))
        engine = QueryBatch(small_corpus, built_index, executor=hg)
        FleetManager(hg).crash(1)
        got = engine.execute(_queries(), 0.9,
                             rng=np.random.default_rng(1))
        deg = engine.last_degraded
        assert deg is not None and deg["lost_shards"] > 0
        assert deg["degraded_queries"] >= 1
        # count: reduced over the surviving draws only — imprecise,
        # wider CI than the healthy reference, loss accounted
        count_got, count_want = got[0], want[0]
        assert count_got.lost_shards > 0
        assert count_got.estimate.error_bound > 0.0
        assert count_got.shards_read < count_want.shards_read
        # the estimator reduces over the surviving draws only (the CI
        # widens in expectation, not pointwise — variance is
        # data-dependent — so pin the sample shrink, not the bound)
        assert count_got.estimate.n < count_want.estimate.n
        # retrieval: served from surviving shards, loss surfaced
        assert got[1].lost_shards > 0 or got[2].lost_shards > 0
        for g in got[1:]:
            assert len(g.doc_ids) <= small_corpus.n_docs


def test_healthy_fleet_reports_no_degradation(small_corpus, built_index):
    pm = PlacementMap.blocked(small_corpus.n_shards, 2, n_replicas=1)
    with HostGroupExecutor(pm, workers_per_host=1,
                           allow_partial=True) as hg:
        engine = QueryBatch(small_corpus, built_index, executor=hg)
        got = engine.execute(_queries(), 0.9, rng=np.random.default_rng(2))
        assert engine.last_degraded is None
        assert all(g.lost_shards == 0 for g in got)
