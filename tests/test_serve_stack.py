"""Serving facade (launch/serve_stack) + ExecutionReport: one-call
construction parity vs hand-built stacks, layer wiring (topology /
planner / cache / window / fleet), config validation, and the typed
per-batch report with its deprecated attribute shims."""
import json

import numpy as np
import pytest

from repro.core.queries import (
    BatchQuery,
    ExecutionReport,
    QueryBatch,
    parse_boolean,
)
from repro.launch import ServeConfig, ServingStack, build_serving_stack
from repro.runtime import (
    BatchWindow,
    FleetManager,
    HostGroupExecutor,
    ShardTaskExecutor,
    WindowController,
)
from repro.runtime.budget import RatePlanner
from repro.runtime.qcache import QueryCacheConfig, SemanticQueryCache

QS = [BatchQuery.count([5]),
      BatchQuery.boolean(parse_boolean([3, "and", 8])),
      BatchQuery.ranked([3, 8, 11], k=5)]


def _same(a, b):
    return repr(a._replace(elapsed_s=0)) == repr(b._replace(elapsed_s=0))


# ----------------------------------------------------------------------
# construction + parity
# ----------------------------------------------------------------------
def test_default_stack_matches_hand_built_engine(small_corpus,
                                                 built_index):
    with build_serving_stack(small_corpus, built_index) as stack:
        assert isinstance(stack.executor, ShardTaskExecutor)
        assert stack.window is None and stack.planner is None
        assert stack.cache is None and stack.fleet is None
        got = stack.engine.execute(QS, 0.4, rng=np.random.default_rng(3))
    with ShardTaskExecutor(workers=2) as ex:
        want = QueryBatch(small_corpus, built_index, executor=ex).execute(
            QS, 0.4, rng=np.random.default_rng(3))
    assert all(_same(g, w) for g, w in zip(got, want))


def test_config_and_kwarg_overrides_compose(small_corpus, built_index):
    cfg = ServeConfig(rate=0.3, workers=1)
    with build_serving_stack(small_corpus, built_index, cfg,
                             ci=True) as stack:
        assert stack.config.rate == 0.3       # from the config
        assert stack.config.ci is True        # from the override
        assert stack.engine.ci is True
    assert cfg.ci is False                    # original untouched


def test_host_group_topology_and_fleet(small_corpus, built_index):
    with build_serving_stack(small_corpus, built_index, hosts=2,
                             replicas=1, fleet=True) as stack:
        assert isinstance(stack.executor, HostGroupExecutor)
        assert isinstance(stack.fleet, FleetManager)
        got = stack.engine.execute(QS, 0.4, rng=np.random.default_rng(3))
        assert len(got) == len(QS)
        # the fleet drives the SAME executor the engine serves from
        stack.fleet.drain(1)
        assert stack.executor.stats["placement_epoch"] == 1


def test_cache_wiring_serves_hits(small_corpus, built_index):
    with build_serving_stack(
            small_corpus, built_index, cache=True,
            cache_config=QueryCacheConfig(max_entries=8, ttl_s=3600.0,
                                          hamming_radius=0)) as stack:
        assert isinstance(stack.cache, SemanticQueryCache)
        assert stack.engine.cache is stack.cache
        first = stack.engine.execute(QS, 0.4,
                                     rng=np.random.default_rng(3))
        again = stack.engine.execute(QS, 0.4,
                                     rng=np.random.default_rng(99))
        assert stack.cache.stats["hits"] == len(QS)
        assert all(_same(a, f) for a, f in zip(again, first))


def test_planner_and_window_wiring(small_corpus, built_index):
    with build_serving_stack(small_corpus, built_index, planner=True,
                             ci=True, window=True, max_batch=4,
                             max_delay_s=0.001) as stack:
        assert isinstance(stack.planner, RatePlanner)
        assert isinstance(stack.controller, WindowController)
        assert isinstance(stack.window, BatchWindow)
        assert stack.window.controller is stack.controller
        assert stack.engine.accepts_pressure
        res = stack.window.submit(QS[0]).result(timeout=30)
        assert res.estimate is not None
    # context-manager exit closed the window: further submits refuse
    with pytest.raises(RuntimeError):
        stack.window.submit(QS[0])


def test_window_static_mode_has_no_controller(small_corpus, built_index):
    with build_serving_stack(small_corpus, built_index, window=True,
                             adaptive=False) as stack:
        assert stack.window is not None and stack.controller is None


def test_config_validation_errors(small_corpus, built_index):
    with pytest.raises(ValueError):
        ServeConfig(balanced=True)            # needs hosts >= 2
    with pytest.raises(ValueError):
        ServeConfig(fleet=True)
    with pytest.raises(ValueError):
        ServeConfig(host_fault_hook=lambda h, s: None)
    with pytest.raises(ValueError):
        ServeConfig(workers=0)
    with pytest.raises(ValueError):
        ServeConfig(hosts=-1)
    with pytest.raises(TypeError):            # unknown knob is a typo
        build_serving_stack(small_corpus, built_index, no_such_knob=1)


# ----------------------------------------------------------------------
# ExecutionReport: the typed per-batch record + deprecated shims
# ----------------------------------------------------------------------
def test_execution_report_contents_and_json(small_corpus, built_index):
    eng = QueryBatch(small_corpus, built_index)
    assert eng.last_report is None
    eng.execute(QS, 0.4, rng=np.random.default_rng(3))
    r = eng.last_report
    assert isinstance(r, ExecutionReport)
    assert r.n_queries == len(QS) and r.rate == 0.4
    assert len(r.rates) == len(r.plan) == len(QS)
    assert all(isinstance(p, np.ndarray) for p in r.plan)
    assert r.balance is None and r.budget is None
    assert r.degraded is None and r.cache is None
    rec = json.loads(json.dumps(r.record()))
    assert rec["n_queries"] == len(QS)
    assert all(isinstance(s, int) for p in rec["plan"] for s in p)


def test_deprecated_properties_read_through_report(small_corpus,
                                                   built_index):
    eng = QueryBatch(small_corpus, built_index)
    # all four are None before the first execute (legacy contract)
    assert eng.last_plan is None and eng.last_audit is None
    assert eng.last_budget is None and eng.last_degraded is None
    eng.execute(QS, 0.4, rng=np.random.default_rng(3))
    r = eng.last_report
    assert [list(p) for p in eng.last_plan] == [list(p) for p in r.plan]
    assert eng.last_audit is r.balance
    assert eng.last_budget is r.budget
    assert eng.last_degraded is r.degraded
    # read-only: the grab-bag attributes can no longer be assigned
    with pytest.raises(AttributeError):
        eng.last_plan = []
    # and the report itself is frozen
    with pytest.raises(Exception):
        r.n_queries = 0


def test_report_is_per_batch(small_corpus, built_index):
    eng = QueryBatch(small_corpus, built_index)
    eng.execute(QS, 0.4, rng=np.random.default_rng(3))
    first = eng.last_report
    eng.execute(QS[:1], 0.6, rng=np.random.default_rng(4))
    assert eng.last_report is not first
    assert eng.last_report.n_queries == 1
    assert eng.last_report.rate == 0.6


def test_stack_dataclass_shape(small_corpus, built_index):
    stack = build_serving_stack(small_corpus, built_index)
    try:
        assert isinstance(stack, ServingStack)
        assert stack.corpus is small_corpus
        assert stack.index is built_index
        assert isinstance(stack.config, ServeConfig)
    finally:
        stack.close()
        stack.close()      # idempotent
