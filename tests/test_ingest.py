"""Live ingest: the append path, the Generation API, and the
zero-pause RCU swap.

Pins the PR-10 contracts:

  * ``runtime.generation`` is the single authority — deprecated
    integer views (``stats["placement_epoch"]``, raw-int qcache
    epochs) are mirrors, never independently minted.
  * CSR postings appends are bit-for-bit a from-scratch rebuild.
  * Frozen-model batch inference is bit-for-bit the per-doc path.
  * ``refresh_appended`` leaves untouched rows byte-identical and
    makes touched rows match a full rebuild's ops.
  * The qcache fences on *content* changes (the ``attach_corpus``
    regression), not just placement.
  * A query racing an ingest swap returns bit-for-bit either the
    pre-append or the post-append answer — never a torn one.
"""
import threading

import numpy as np
import pytest

from repro.core.index import build_index, refresh_appended
from repro.core.lsh import LSHConfig
from repro.core.queries.batch import BatchQuery, QueryBatch
from repro.data.store import (
    DocShard,
    Document,
    ShardedCorpus,
    build_postings,
    merge_postings,
    shard_postings,
)
from repro.launch.serve_stack import (
    Ingestor,
    ServeConfig,
    build_serving_stack,
)
from repro.runtime.generation import Generation, GenerationClock
from repro.runtime.placement import HostGroupExecutor, PlacementMap
from repro.runtime.qcache import SemanticQueryCache


def _rand_docs(rng, n, vocab, mean_len=30):
    return [rng.integers(0, vocab, size=int(rng.integers(5, mean_len * 2)))
            .astype(np.int32) for _ in range(n)]


# ---------------------------------------------------------------------------
# the Generation API
# ---------------------------------------------------------------------------
def test_generation_clock_axes_are_independent():
    clock = GenerationClock()
    assert clock.current() == Generation(0, 0)
    assert clock.bump_placement() == Generation(1, 0)
    assert clock.bump_content() == Generation(1, 1)
    assert clock.bump_content() == Generation(1, 2)
    assert clock.current() == Generation(placement=1, content=2)
    assert clock.current().record() == dict(placement=1, content=2)


def test_generation_is_hashable_value_type():
    a, b = Generation(2, 3), Generation(2, 3)
    assert a == b and hash(a) == hash(b)
    assert Generation(2, 4) != a and Generation(3, 3) != a
    # never equal to the deprecated raw ints it replaced — a cache
    # entry stamped with an int can't accidentally validate against a
    # Generation probe (or vice versa)
    assert Generation(1, 0) != 1


def test_clock_mints_under_concurrency():
    clock = GenerationClock()

    def spin(bump, n=200):
        for _ in range(n):
            bump()

    threads = [threading.Thread(target=spin, args=(clock.bump_placement,)),
               threading.Thread(target=spin, args=(clock.bump_content,))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert clock.current() == Generation(200, 200)


def test_placement_epoch_is_a_mirror_of_the_clock():
    """The deprecated ``stats["placement_epoch"]`` int is a read-only
    view of the clock's placement axis — same values the pre-PR-10
    ``+= 1`` produced, but minted in exactly one place."""
    pm = PlacementMap.blocked(8, 2)
    ex = HostGroupExecutor(pm, workers_per_host=1)
    try:
        assert ex.stats["placement_epoch"] == 0
        assert ex.clock.current() == Generation(0, 0)
        ex.set_placement(PlacementMap.blocked(8, 2))
        assert ex.stats["placement_epoch"] == 1
        assert ex.clock.current() == Generation(1, 0)
    finally:
        ex.close()


def test_placement_extend():
    pm = PlacementMap.blocked(6, 2, n_replicas=1)
    grown = pm.extend(9)
    # old shards keep their primaries; new ones exist and are valid
    assert np.array_equal(grown.primary[:6], pm.primary[:6])
    assert grown.n_shards == 9 and grown.n_hosts == pm.n_hosts
    assert all(0 <= int(h) < pm.n_hosts for h in grown.primary)
    assert pm.extend(6) is pm
    with pytest.raises(ValueError):
        pm.extend(3)


# ---------------------------------------------------------------------------
# the store append path
# ---------------------------------------------------------------------------
def test_append_unbounded_grows_open_shard_bit_for_bit():
    rng = np.random.default_rng(0)
    base = _rand_docs(rng, 40, vocab=64)
    docs = [Document(i, t) for i, t in enumerate(base)]
    corpus = ShardedCorpus.from_documents(docs, 64, shard_tokens=512)
    # force postings to exist pre-append so the delta-merge path runs
    for s in corpus.shards:
        shard_postings(s)
    extra = _rand_docs(rng, 15, vocab=64)
    grown, new_ids, affected = corpus.append_documents(extra)

    assert grown.n_shards == corpus.n_shards
    assert affected == [corpus.n_shards - 1]
    assert np.array_equal(new_ids, np.arange(40, 55))
    # untouched shards are shared by reference (copy-on-write)
    for sid in range(corpus.n_shards - 1):
        assert grown.shards[sid] is corpus.shards[sid]
    # merged postings == from-scratch rebuild, bit for bit
    open_shard = grown.shards[-1]
    merged = open_shard._postings
    assert merged is not None, "delta merge should reuse the built CSR"
    rebuilt = build_postings(DocShard.from_documents(
        open_shard.shard_id, list(open_shard.iter_documents())))
    assert np.array_equal(merged.indptr, rebuilt.indptr)
    assert np.array_equal(merged.doc_idx, rebuilt.doc_idx)
    assert np.array_equal(merged.tf, rebuilt.tf)


def test_append_budgeted_spills_like_from_documents():
    rng = np.random.default_rng(1)
    base = _rand_docs(rng, 30, vocab=32)
    extra = _rand_docs(rng, 30, vocab=32)
    docs = [Document(i, t) for i, t in enumerate(base)]
    corpus = ShardedCorpus.from_documents(docs, 32, shard_tokens=256)
    grown, new_ids, affected = corpus.append_documents(
        extra, shard_tokens=256)
    # identical to building the whole corpus at once
    all_docs = [Document(i, t) for i, t in enumerate(base + extra)]
    oracle = ShardedCorpus.from_documents(all_docs, 32, shard_tokens=256)
    assert grown.n_shards == oracle.n_shards
    assert grown.n_docs == oracle.n_docs == 60
    for a, b in zip(grown.shards, oracle.shards):
        assert np.array_equal(a.doc_ids, b.doc_ids)
        assert np.array_equal(a.tokens, b.tokens)
        assert np.array_equal(a.offsets, b.offsets)
    assert affected  # the open shard changed, plus any spilled ones
    assert grown.n_shards > corpus.n_shards  # this budget does spill


def test_append_empty_is_identity():
    rng = np.random.default_rng(2)
    docs = [Document(i, t) for i, t in enumerate(_rand_docs(rng, 5, 16))]
    corpus = ShardedCorpus.from_documents(docs, 16, shard_tokens=128)
    same, ids, affected = corpus.append_documents([])
    assert same is corpus and len(ids) == 0 and affected == []


def test_merge_postings_handles_vocab_growth():
    """A delta whose max token exceeds the old shard's local vocab
    must widen the merged CSR, not truncate it."""
    old_docs = [Document(0, np.asarray([1, 1, 2], np.int32))]
    new_docs = [Document(1, np.asarray([5, 2], np.int32))]
    old = build_postings(DocShard.from_documents(0, old_docs))
    delta = build_postings(DocShard.from_documents(0, new_docs))
    merged = merge_postings(old, 1, delta)
    rebuilt = build_postings(DocShard.from_documents(0, old_docs + new_docs))
    assert np.array_equal(merged.indptr, rebuilt.indptr)
    assert np.array_equal(merged.doc_idx, rebuilt.doc_idx)
    assert np.array_equal(merged.tf, rebuilt.tf)


# ---------------------------------------------------------------------------
# frozen-model inference + incremental index refresh
# ---------------------------------------------------------------------------
def test_infer_doc_vectors_matches_per_doc_path(pv_model):
    from repro.core import pv_dbow as pv
    model, cfg = pv_model
    rng = np.random.default_rng(3)
    docs = _rand_docs(rng, 4, vocab=model.word_vecs.shape[0])
    batch = pv.infer_doc_vectors(model, docs, cfg, steps=6)
    assert batch.shape == (4, cfg.dim) and batch.dtype == np.float32
    for j, d in enumerate(docs):
        one = np.asarray(pv.infer_doc_vector(model, d, cfg, steps=6),
                         np.float32)
        assert np.array_equal(batch[j], one)
    empty = pv.infer_doc_vectors(model, [], cfg, steps=6)
    assert empty.shape == (0, cfg.dim)


def test_refresh_appended_incremental_vs_rebuild(small_corpus, pv_model,
                                                 built_index):
    model, pcfg = pv_model
    rng = np.random.default_rng(4)
    extra = _rand_docs(rng, 12, vocab=small_corpus.vocab_size)
    grown, new_ids, affected = small_corpus.append_documents(extra)
    new = refresh_appended(built_index, grown, model, pcfg, extra,
                           affected, infer_steps=5)
    # untouched shard rows byte-identical; old doc rows byte-identical
    untouched = [s for s in range(built_index.shard_vecs.shape[0])
                 if s not in set(affected)]
    assert np.array_equal(new.shard_vecs[untouched],
                          built_index.shard_vecs[untouched])
    assert np.array_equal(new.shard_sig[untouched],
                          built_index.shard_sig[untouched])
    assert np.array_equal(new.doc_vecs[:built_index.n_docs],
                          built_index.doc_vecs)
    # touched rows are the exact build op over the new membership
    for sid in affected:
        want = new.doc_vecs[grown.shards[sid].doc_ids].mean(axis=0)
        assert np.array_equal(new.shard_vecs[sid],
                              want.astype(np.float32))
    # exact integer stats deltas
    df = built_index.doc_freq.copy()
    for t in extra:
        df[np.unique(np.asarray(t, np.int64))] += 1
    assert np.array_equal(new.doc_freq, df)
    assert new.n_docs == grown.n_docs
    assert new.avg_doc_len == pytest.approx(
        grown.n_tokens / grown.n_docs)
    # generation continuity: same clock object, caller mints the bump
    assert new.clock is built_index.clock
    # and the old index object is untouched
    assert built_index.n_docs == small_corpus.n_docs


def test_refresh_appended_requires_doc_vectors(small_corpus, pv_model,
                                               built_index):
    import dataclasses as dc
    model, pcfg = pv_model
    stripped = dc.replace(built_index, doc_vecs=None, doc_sig=None)
    extra = [np.asarray([1, 2, 3], np.int32)]
    grown, _, affected = small_corpus.append_documents(extra)
    with pytest.raises(ValueError, match="keep_doc_vectors"):
        refresh_appended(stripped, grown, model, pcfg, extra, affected)
    with pytest.raises(ValueError, match="line up"):
        refresh_appended(built_index, grown, model, pcfg,
                         extra + extra, affected)


# ---------------------------------------------------------------------------
# the content-fence regression (the PR-10 bugfix)
# ---------------------------------------------------------------------------
def test_qcache_fences_on_content_change(small_corpus, built_index):
    """``attach_corpus`` changes what answers mean without touching
    placement — before the content axis existed, the cache kept
    serving estimates computed over the old corpus.  Now the engine's
    composite generation fences them."""
    index = built_index.use_clock(GenerationClock())
    cache = SemanticQueryCache()
    engine = QueryBatch(small_corpus, index, cache=cache)
    q = BatchQuery.count((3, 7))
    r0 = engine.execute([q], 0.5, np.random.default_rng(9))[0]
    r1 = engine.execute([q], 0.5, np.random.default_rng(10))[0]
    assert cache.stats["hits"] == 1
    assert r1.estimate.value == r0.estimate.value  # memoized

    index.attach_corpus(small_corpus)  # content bump, placement same
    engine.execute([q], 0.5, np.random.default_rng(11))
    # the cached entry was dropped as stale, not served
    assert cache.stats["hits"] == 1
    assert cache.stats["stale_epoch"] >= 1


def test_engine_generation_composes_both_axes(small_corpus, built_index):
    index = built_index.use_clock(GenerationClock())
    engine = QueryBatch(small_corpus, index,
                        cache=SemanticQueryCache())
    assert engine._generation() == Generation(0, 0)
    index.clock.bump_content()
    assert engine._generation() == Generation(0, 1)
    # no executor -> deprecated placement fallback reads 0
    assert engine._cache_epoch() == 0


# ---------------------------------------------------------------------------
# ServeConfig validation + Ingestor lifecycle
# ---------------------------------------------------------------------------
def test_serve_config_ingest_validation(pv_model):
    model, pcfg = pv_model
    with pytest.raises(ValueError, match="ingest_model"):
        ServeConfig(ingest=True)
    with pytest.raises(ValueError, match="ingest=False"):
        ServeConfig(ingest_model=model)
    with pytest.raises(ValueError, match="refresh_docs"):
        ServeConfig(ingest=True, ingest_model=model, ingest_pv_cfg=pcfg,
                    refresh_docs=0)
    with pytest.raises(ValueError, match="refresh_interval_s"):
        ServeConfig(ingest=True, ingest_model=model, ingest_pv_cfg=pcfg,
                    refresh_interval_s=0.0)
    with pytest.raises(ValueError, match="ingest_infer_steps"):
        ServeConfig(ingest=True, ingest_model=model, ingest_pv_cfg=pcfg,
                    ingest_infer_steps=0)
    with pytest.raises(ValueError, match="ingest_yield_s"):
        ServeConfig(ingest=True, ingest_model=model, ingest_pv_cfg=pcfg,
                    ingest_yield_s=-0.001)
    ok = ServeConfig(ingest=True, ingest_model=model, ingest_pv_cfg=pcfg)
    assert ok.ingest and ok.refresh_docs == 64
    # pacing may be disabled outright (throughput-first ingest)
    assert ServeConfig(ingest=True, ingest_model=model,
                       ingest_pv_cfg=pcfg, ingest_yield_s=0.0).ingest


def test_ingestor_step_swaps_and_bumps(small_corpus, pv_model,
                                       built_index):
    model, pcfg = pv_model
    phrase = (small_corpus.vocab_size - 2, small_corpus.vocab_size - 1)
    rng = np.random.default_rng(5)
    new_docs = [np.concatenate([
        np.asarray(phrase, np.int32),
        rng.integers(0, small_corpus.vocab_size - 2, 20).astype(np.int32)])
        for _ in range(10)]
    with build_serving_stack(
            small_corpus, built_index, cache=True, ingest=True,
            ingest_model=model, ingest_pv_cfg=pcfg,
            ingest_infer_steps=4) as stack:
        q = BatchQuery.count(phrase)
        c0 = stack.engine.execute([q], 1.0)[0].estimate.value
        assert stack.generation == Generation(0, 0)
        rec = stack.ingestor.step(new_docs)
        assert rec["appended"] == 10
        assert rec["generation"] == dict(placement=0, content=1)
        assert stack.generation == Generation(0, 1)
        c1 = stack.engine.execute([q], 1.0)[0].estimate.value
        assert c1 == c0 + 10  # freshness: new docs visible post-swap
        assert stack.corpus is stack.engine.corpus
        assert stack.index is stack.engine.index
        ing = stack.ingestor.record()
        assert ing["swaps"] == 1 and ing["docs_appended"] == 10
        # empty step: no swap, no bump
        rec2 = stack.ingestor.step([])
        assert rec2["appended"] == 0
        assert stack.generation == Generation(0, 1)


def test_ingestor_background_source(small_corpus, pv_model, built_index):
    model, pcfg = pv_model
    fed = threading.Event()
    rng = np.random.default_rng(6)

    def source(n):
        if fed.is_set():
            return []
        fed.set()
        return _rand_docs(rng, 5, small_corpus.vocab_size)

    with build_serving_stack(
            small_corpus, built_index, ingest=True,
            ingest_model=model, ingest_pv_cfg=pcfg,
            ingest_source=source, refresh_interval_s=0.01,
            ingest_infer_steps=2) as stack:
        assert stack.ingestor.running
        for _ in range(500):
            if stack.ingestor.stats["docs_appended"]:
                break
            threading.Event().wait(0.01)
        rec = stack.ingestor.record()
        assert rec["docs_appended"] == 5 and rec["errors"] == []
        stack.ingestor.close()
        assert not stack.ingestor.running
        stack.ingestor.close()  # idempotent
    # stack close after ingestor close is also fine (idempotent path)


# ---------------------------------------------------------------------------
# the RCU property: reads racing a swap are never torn
# ---------------------------------------------------------------------------
def test_read_during_swap_is_pre_or_post_never_torn(small_corpus,
                                                    pv_model,
                                                    built_index):
    """Property test: while ``step`` swaps the world, every concurrent
    *batch* returns bit-for-bit either the pre-append answer or the
    post-append answer (same seed, same rate) — never a mixture of
    the two worlds within one batch, and never an error."""
    model, pcfg = pv_model
    rng = np.random.default_rng(7)
    extra = _rand_docs(rng, 30, small_corpus.vocab_size)
    queries = [BatchQuery.count((3, 7)),
               BatchQuery.ranked((11, 23), k=5),
               BatchQuery.count((5,))]
    seeds = list(range(40, 46))

    def run_one(engine, s):
        res = engine.execute(queries, 0.5, np.random.default_rng(s))
        return tuple(
            (r.estimate.value if r.estimate is not None else None,
             tuple(np.asarray(getattr(r, "doc_ids", []), np.int64)
                   .tolist()))
            for r in res)

    def run_all(engine):
        return {s: run_one(engine, s) for s in seeds}

    # reference worlds, computed sequentially on throwaway stacks
    with build_serving_stack(small_corpus, built_index) as ref:
        pre = run_all(ref.engine)
    grown, _, affected = small_corpus.append_documents(extra)
    post_index = refresh_appended(built_index, grown, model, pcfg,
                                  extra, affected, infer_steps=3)
    with build_serving_stack(grown, post_index) as ref:
        post = run_all(ref.engine)

    with build_serving_stack(
            small_corpus, built_index, ingest=True, ingest_model=model,
            ingest_pv_cfg=pcfg, ingest_infer_steps=3) as stack:
        start = threading.Barrier(2)

        def writer():
            start.wait()
            stack.ingestor.step(extra)

        t = threading.Thread(target=writer)
        t.start()
        observed = []
        start.wait()
        for _ in range(20):
            for s in seeds:
                observed.append((s, run_one(stack.engine, s)))
        t.join()
        after = run_all(stack.engine)

    assert after == post  # the swap landed and serves fresh answers
    for s, got in observed:
        assert got == pre[s] or got == post[s], "torn batch during swap"
