"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU per the harness contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.asym import ops as aops
from repro.kernels.asym import ref as aref
from repro.kernels.hamming import ops as hops
from repro.kernels.hamming import ref as href
from repro.kernels.kmeans import ops as kops
from repro.kernels.kmeans import ref as kref
from repro.kernels.negsamp import ops as nops
from repro.kernels.negsamp import ref as nref


# ----------------------------------------------------------------------
# hamming
# ----------------------------------------------------------------------
@pytest.mark.parametrize("n,m,words", [
    (1, 7, 4), (3, 512, 4), (8, 513, 8), (5, 64, 2), (16, 1000, 1),
])
def test_hamming_distance_matches_ref(n, m, words):
    rng = np.random.default_rng(n * 100 + m)
    q = jnp.asarray(rng.integers(0, 2**32, (n, words), dtype=np.uint32))
    db = jnp.asarray(rng.integers(0, 2**32, (m, words), dtype=np.uint32))
    got = hops.hamming_distance(q, db)
    want = href.hamming_distance_ref(q, db)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bits,temp", [(128, 1.0), (128, 8.0), (256, 4.0)])
def test_hamming_similarity_matches_ref(bits, temp):
    rng = np.random.default_rng(bits)
    w = bits // 32
    q = jnp.asarray(rng.integers(0, 2**32, (4, w), dtype=np.uint32))
    db = jnp.asarray(rng.integers(0, 2**32, (300, w), dtype=np.uint32))
    got = hops.hamming_similarity(q, db, bits, temperature=temp)
    want = href.hamming_similarity_ref(q, db, bits) ** temp
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5)


# ----------------------------------------------------------------------
# asym (fused batched projection + sign-matmul + exp-cosine)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("b,m,dim,bits,temp", [
    (1, 7, 24, 128, 1.0), (5, 300, 48, 256, 8.0), (16, 1000, 32, 64, 4.0),
    (3, 257, 48, 128, 8.0), (9, 512, 64, 96, 2.0),
])
def test_asym_similarity_matches_ref(b, m, dim, bits, temp):
    from repro.core import lsh as lsh_mod
    rng = np.random.default_rng(b * 1000 + m)
    q = jnp.asarray(rng.normal(size=(b, dim)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(m, dim)).astype(np.float32))
    planes = lsh_mod.hyperplanes(lsh_mod.LSHConfig(bits=bits), dim)
    db = lsh_mod.pack_bits(lsh_mod.signature_bits(x, planes))
    got = aops.asym_exp_similarity(q, db, planes, bits, temperature=temp)
    want = aref.asym_exp_similarity_ref(q, db, planes, bits, temperature=temp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5)


def test_asym_kernel_matches_single_query_asymmetric_cosine():
    """The fused batch kernel row-matches core asymmetric_cosine."""
    from repro.core import lsh as lsh_mod
    rng = np.random.default_rng(7)
    dim, bits, temp = 48, 128, 8.0
    q = rng.normal(size=(4, dim)).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(200, dim)).astype(np.float32))
    planes = lsh_mod.hyperplanes(lsh_mod.LSHConfig(bits=bits), dim)
    db = lsh_mod.pack_bits(lsh_mod.signature_bits(x, planes))
    got = np.asarray(aops.asym_exp_similarity(
        jnp.asarray(q), db, planes, bits, temperature=temp))
    for i in range(q.shape[0]):
        cos = lsh_mod.asymmetric_cosine(jnp.asarray(q[i]), db, planes, bits)
        np.testing.assert_allclose(got[i], np.exp(temp * np.asarray(cos)),
                                   rtol=3e-5)


# ----------------------------------------------------------------------
# negsamp
# ----------------------------------------------------------------------
@pytest.mark.parametrize("b,dim,k,temp", [
    (16, 32, 5, 1.0), (100, 64, 3, 8.0), (256, 16, 1, 4.0), (7, 128, 8, 8.0),
])
def test_negsamp_grads_match_ref(b, dim, k, temp):
    rng = np.random.default_rng(b)
    d = jnp.asarray(rng.normal(size=(b, dim)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(b, dim)).astype(np.float32))
    wn = jnp.asarray(rng.normal(size=(b, k, dim)).astype(np.float32))
    got = nops.negsamp_grads(d, w, wn, temperature=temp)
    want = nref.negsamp_grads_ref(d, w, wn, temperature=temp)
    for g, r in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=1e-5, atol=1e-6)


def test_negsamp_grads_match_autodiff():
    """The fused manual gradients == jax.grad of the loss."""
    rng = np.random.default_rng(9)
    b, dim, k = 32, 24, 4
    d = jnp.asarray(rng.normal(size=(b, dim)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(b, dim)).astype(np.float32))
    wn = jnp.asarray(rng.normal(size=(b, k, dim)).astype(np.float32))

    def loss(d, w, wn):
        pos = jnp.sum(w * d, axis=-1)
        neg = jnp.einsum("bkd,bd->bk", wn, d)
        return (jax.nn.softplus(-pos) + jax.nn.softplus(neg).sum(-1)).sum()

    gd_ad, gw_ad, gwn_ad = jax.grad(loss, argnums=(0, 1, 2))(d, w, wn)
    _, gd, gw, gwn = nops.negsamp_grads(d, w, wn, temperature=1.0)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(gd_ad), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_ad), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gwn), np.asarray(gwn_ad), rtol=1e-4, atol=1e-5)


def test_negsamp_step_trains(small_corpus):
    """The kernel-backed step must behave like the reference step."""
    from repro.core.pv_dbow import PVDBOWConfig, train_pv_dbow
    cfg = PVDBOWConfig(dim=16, steps=60, batch_pairs=512, use_kernel=True)
    model = train_pv_dbow(small_corpus, cfg)
    assert np.isfinite(np.asarray(model.word_vecs)).all()
    norms = np.linalg.norm(np.asarray(model.word_vecs), axis=1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-4)


# ----------------------------------------------------------------------
# kmeans
# ----------------------------------------------------------------------
@pytest.mark.parametrize("n,k,dim", [(10, 3, 8), (513, 16, 32), (1000, 7, 64)])
def test_kmeans_assign_matches_ref(n, k, dim):
    rng = np.random.default_rng(n)
    x = rng.normal(size=(n, dim)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    c = rng.normal(size=(k, dim)).astype(np.float32)
    c /= np.linalg.norm(c, axis=1, keepdims=True)
    got = kops.assign(jnp.asarray(x), jnp.asarray(c))
    want, _ = kref.assign_ref(jnp.asarray(x), jnp.asarray(c))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
