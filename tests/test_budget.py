"""Budget/planner unit behavior: validation, the invertible error
curve, budget inversion, the latency cap, and the degradation ladder.
Integration with the batch engine lives in test_batch_engine.py; the
window's degrade-before-shed path in test_controller.py."""
import math

import pytest

from repro.runtime.budget import (
    BudgetAudit,
    PlannerConfig,
    QueryBudget,
    RatePlanner,
)
from repro.utils.stats import t_critical_value


class _Q:
    """Duck-typed query: the planner only reads .kind and .budget."""

    def __init__(self, kind="count", budget=None):
        self.kind = kind
        self.budget = budget


# ----------------------------------------------------------------------
# validation
# ----------------------------------------------------------------------

def test_budget_requires_some_budget():
    with pytest.raises(ValueError):
        QueryBudget()


@pytest.mark.parametrize("kwargs", [
    dict(max_rel_error=0.0),
    dict(max_rel_error=-0.1),
    dict(max_latency_s=0.0),
    dict(max_rel_error=0.1, confidence=0.0),
    dict(max_rel_error=0.1, confidence=1.0),
    dict(max_rel_error=0.1, floor_rate=0.0),
    dict(max_rel_error=0.1, floor_rate=1.5),
])
def test_budget_rejects_bad_fields(kwargs):
    with pytest.raises(ValueError):
        QueryBudget(**kwargs)


@pytest.mark.parametrize("kwargs", [
    dict(default_floor_rate=0.0),
    dict(default_floor_rate=1.5),
    dict(curve_alpha=0.0),
    dict(seed_rel_scale=0.0),
])
def test_planner_config_rejects_bad_fields(kwargs):
    with pytest.raises(ValueError):
        PlannerConfig(**kwargs)


def test_planner_rejects_bad_shard_count():
    with pytest.raises(ValueError):
        RatePlanner(0)


# ----------------------------------------------------------------------
# the error curve e(n) = t_{n-1} * s_rel / sqrt(n)
# ----------------------------------------------------------------------

def test_curve_seed_then_learn():
    planner = RatePlanner(16)
    curve = planner.curve("count")
    assert curve.scale() == planner.config.seed_rel_scale
    # a realized (n, e) pair teaches the exact scale that reproduces it
    n, e = 8, 0.3
    curve.observe(n, e)
    s_obs = e * math.sqrt(n) / t_critical_value(n - 1, 0.95)
    assert curve.scale() == pytest.approx(s_obs)
    assert curve.predict(n) == pytest.approx(e)


def test_curve_skips_degenerate_observations():
    curve = RatePlanner(16).curve("count")
    curve.observe(1, 0.5)            # n < 2: no variance estimate
    curve.observe(8, float("inf"))   # infinite error: no scale info
    curve.observe(8, 0.0)            # exact answer: no scale info
    assert curve.s_rel is None and curve.count == 0


def test_curve_predict_is_monotone_decreasing():
    curve = RatePlanner(64).curve("count")
    errs = [curve.predict(n) for n in range(2, 65)]
    assert all(a >= b for a, b in zip(errs, errs[1:]))
    assert curve.predict(1) == float("inf")


def test_required_n_inverts_predict():
    curve = RatePlanner(64).curve("count")
    for target in (0.3, 0.5, 0.9):
        n = curve.required_n(target, 0.95, 64)
        assert curve.predict(n) <= target
        if n > 2:
            assert curve.predict(n - 1) > target
    # a target below predict(n_max) is unmeetable: census fallback
    assert curve.required_n(1e-9, 0.95, 64) == 64


# ----------------------------------------------------------------------
# plan_rate: budget inversion + latency cap
# ----------------------------------------------------------------------

def test_plan_rate_without_budget_is_identity():
    planner = RatePlanner(16)
    for base in (0.05, 0.3, 1.0, 2.0):
        assert planner.plan_rate("count", None, base) == base


def test_plan_rate_error_budget_plans_smallest_sufficient():
    planner = RatePlanner(20)
    # teach the curve a known scale so required_n is deterministic
    planner.curve("count").observe(10, 0.2)
    budget = QueryBudget(max_rel_error=0.25, floor_rate=0.05)
    rate = planner.plan_rate("count", budget, 0.5)
    n_req = planner.curve("count").required_n(0.25, 0.95, 20)
    assert rate == pytest.approx(n_req / 20)
    # a tighter budget can only raise the planned rate
    tighter = planner.plan_rate(
        "count", QueryBudget(max_rel_error=0.1, floor_rate=0.05), 0.5)
    assert tighter >= rate
    # floor and ceiling clamp
    assert planner.plan_rate(
        "count", QueryBudget(max_rel_error=5.0, floor_rate=0.3), 0.5) >= 0.3
    assert planner.plan_rate(
        "count", QueryBudget(max_rel_error=1e-9), 0.5) <= 1.0


def test_plan_rate_latency_budget_without_controller_keeps_base():
    """No controller -> no cost model -> never degrade on a guess."""
    planner = RatePlanner(16)
    budget = QueryBudget(max_latency_s=0.01, floor_rate=0.05)
    assert planner.plan_rate("count", budget, 0.4) == 0.4


def test_plan_rate_latency_cap_scales_controller_p99():
    class _Plan:
        est_p99_s = 0.1

    class _Ctl:
        current_plan = _Plan()

    planner = RatePlanner(16, controller=_Ctl())
    planner._ref_rate = 0.4    # served rate that produced that p99
    # half the p99 affordable -> half the reference rate
    budget = QueryBudget(max_latency_s=0.05, floor_rate=0.01)
    assert planner.plan_rate("count", budget, 0.4) == pytest.approx(0.2)
    # combined budgets: the error plan is *capped* by the latency cap
    planner.curve("count").observe(16, 0.5)   # want many shards
    both = QueryBudget(max_rel_error=0.05, max_latency_s=0.05,
                       floor_rate=0.01)
    assert planner.plan_rate("count", both, 0.4) == pytest.approx(0.2)


# ----------------------------------------------------------------------
# plan_batch: the degradation ladder + audit
# ----------------------------------------------------------------------

def test_plan_batch_ladder_slides_toward_floor():
    planner = RatePlanner(16)
    budget = QueryBudget(max_rel_error=0.5, floor_rate=0.1)
    qs = [_Q("count", budget), _Q("bool")]
    r0, audit0 = planner.plan_batch(qs, 0.4, pressure=0.0)
    r_half, _ = planner.plan_batch(qs, 0.4, pressure=0.5)
    r_full, audit1 = planner.plan_batch(qs, 0.4, pressure=1.0)
    for i, floor in enumerate([0.1, planner.config.default_floor_rate]):
        assert r_half[i] == pytest.approx((r0[i] + floor) / 2)
        assert r_full[i] == pytest.approx(floor)
    assert audit0.pressure == 0.0 and audit0.degraded == 0
    assert audit0.budgeted == 1
    assert audit1.degraded == 2 and audit1.at_floor == 2


def test_plan_batch_pressure_is_clamped():
    planner = RatePlanner(16)
    qs = [_Q("count", QueryBudget(max_rel_error=0.5, floor_rate=0.1))]
    over, _ = planner.plan_batch(qs, 0.4, pressure=7.0)
    full, _ = planner.plan_batch(qs, 0.4, pressure=1.0)
    assert over == full
    under, _ = planner.plan_batch(qs, 0.4, pressure=-3.0)
    plain, _ = planner.plan_batch(qs, 0.4, pressure=0.0)
    assert under == plain


def test_audit_record_is_json_clean():
    planner = RatePlanner(4)   # tiny corpus: some est errors are inf
    qs = [_Q("count", QueryBudget(max_rel_error=0.5, floor_rate=0.3)),
          _Q("ranked")]
    _, audit = planner.plan_batch(qs, 0.25, pressure=0.25)
    assert isinstance(audit, BudgetAudit)
    rec = audit.record()
    assert rec["budgeted"] == 1 and rec["pressure"] == 0.25
    for xs in (rec["planned_rates"], rec["undegraded_rates"],
               rec["floors"], rec["est_rel_error"],
               rec["realized_rel_error"]):
        assert all(x is None or math.isfinite(x) for x in xs)


def test_observe_result_feeds_curve_and_ref_rate():
    planner = RatePlanner(16)
    planner.observe_result("count", 0.5, 8, 0.3)
    assert planner.curve("count").count == 1
    assert planner._ref_rate == pytest.approx(0.5)
    # degenerate feedback touches neither model
    planner.observe_result("count", 0.0, 1, float("inf"))
    assert planner.curve("count").count == 1
    assert planner._ref_rate == pytest.approx(0.5)
