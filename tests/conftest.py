import os
import sys

# Tests must see the real device count (1 CPU), never the dry-run's 512.
os.environ.pop("XLA_FLAGS", None)

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def small_corpus():
    from repro.data.corpus import SyntheticCorpusConfig, generate_text_corpus
    from repro.data.store import ShardedCorpus
    cfg = SyntheticCorpusConfig(n_docs=600, vocab_size=2048, n_topics=8,
                                seed=11)
    docs, topics = generate_text_corpus(cfg)
    corpus = ShardedCorpus.from_documents(docs, cfg.vocab_size,
                                          shard_tokens=4096)
    return corpus


@pytest.fixture(scope="session")
def pv_model(small_corpus):
    from repro.core.pv_dbow import PVDBOWConfig, train_pv_dbow
    cfg = PVDBOWConfig(dim=24, steps=400, batch_pairs=2048, lr=0.01,
                       temperature=8.0, seed=5)
    return train_pv_dbow(small_corpus, cfg), cfg


@pytest.fixture(scope="session")
def built_index(small_corpus, pv_model):
    from repro.core.index import build_index
    from repro.core.lsh import LSHConfig
    model, pcfg = pv_model
    return build_index(small_corpus, model, LSHConfig(bits=128),
                       temperature=pcfg.temperature)
