"""WindowController: deterministic synthetic arrival/service traces
(steady-light, steady-heavy, ramp, bursty) pinning the qualitative
control behavior — the window shrinks under light load, grows under
heavy load, pins (min delay, max batch) at saturation — plus service
model recovery, plan caching, and BatchWindow backpressure at the
pending-queue bound."""
import threading
import time

import numpy as np
import pytest

from repro.runtime import (
    Backpressure,
    BatchWindow,
    ControllerConfig,
    WindowController,
)

CFG = ControllerConfig(min_delay_s=1e-4, max_delay_s=0.02,
                       min_batch=1, max_batch=128)


def _drive(controller, gaps_s, batches=(), t0=0.0):
    """Feed a deterministic trace: arrivals separated by ``gaps_s``,
    then ``batches`` = (n, service_s) observations.  Returns the final
    synthetic timestamp."""
    t = t0
    controller.observe_arrival(t)
    for g in gaps_s:
        t += g
        controller.observe_arrival(t)
    for n, s in batches:
        controller.observe_batch(n, s)
    return t


def _steady(controller, gap_s, n_arrivals=300, **kw):
    return _drive(controller, [gap_s] * n_arrivals, **kw)


# ----------------------------------------------------------------------
# control behavior on synthetic traces
# ----------------------------------------------------------------------
def test_light_load_shrinks_window():
    """Steady trickle (20 qps, 1 ms singles): waiting out a deadline
    buys nothing, so the plan collapses to serve-immediately."""
    c = WindowController(CFG)
    t = _steady(c, 0.05, batches=[(1, 1e-3)] * 20)
    plan = c.plan(t)
    assert plan.max_batch == CFG.min_batch
    assert plan.delay_s == CFG.min_delay_s
    assert not plan.saturated
    assert plan.utilization < 0.1


def test_heavy_load_grows_window():
    """Steady 10k qps against a 0.5 ms + 50 us/query engine: only
    amortizing the per-window overhead keeps the dispatcher stable, so
    the chosen batch grows well past the light-load plan."""
    light = WindowController(CFG)
    t_l = _steady(light, 0.05, batches=[(1, 1e-3)] * 20)
    heavy = WindowController(CFG)
    t_h = _steady(heavy, 1e-4,
                  batches=[(n, 5e-4 + 5e-5 * n)
                           for n in (8, 16, 32, 64, 16, 8, 64, 32)] * 3)
    lp, hp = light.plan(t_l), heavy.plan(t_h)
    assert hp.max_batch > lp.max_batch
    assert hp.max_batch >= 8
    assert not hp.saturated
    assert 0.0 < hp.utilization < 1.0
    # and the heavy plan's window is still bounded by the config
    assert hp.max_batch <= CFG.max_batch
    assert CFG.min_delay_s <= hp.delay_s <= CFG.max_delay_s


def test_ramp_tracks_load_up_and_down():
    """Arrival gaps ramp 10 ms -> 0.1 ms -> 10 ms; the chosen batch
    must follow the load up and come back down."""
    c = WindowController(CFG)
    service = [(n, 5e-4 + 5e-5 * n) for n in (4, 8, 16, 32)] * 2
    t = _drive(c, np.geomspace(1e-2, 1e-4, 150), batches=service)
    mid = c.plan(t)
    t = _drive(c, np.geomspace(1e-4, 1e-2, 300), batches=service, t0=t)
    end = c.plan(t)
    start = WindowController(CFG)
    t_s = _steady(start, 1e-2, batches=service)
    assert mid.max_batch > start.plan(t_s).max_batch   # ramped up
    assert end.max_batch < mid.max_batch               # and back down
    assert end.delay_s <= mid.delay_s or end.max_batch == CFG.min_batch


def test_bursty_trace_stays_stable_and_bounded():
    """Bursts of 30 arrivals at 0.2 ms separated by 200 ms idle: the
    EWMA rate must land strictly between the burst and idle extremes
    and every plan must respect the configured bounds."""
    c = WindowController(CFG)
    t = 0.0
    for _ in range(20):
        t = _drive(c, [2e-4] * 30, t0=t)
        t += 0.2
        c.observe_batch(16, 2e-3)
    rate = c.arrival_rate
    assert 1.0 / 0.2 < rate < 1.0 / 2e-4
    plan = c.plan(t)
    assert CFG.min_batch <= plan.max_batch <= CFG.max_batch
    assert CFG.min_delay_s <= plan.delay_s <= CFG.max_delay_s


def test_saturation_pins_min_delay_max_batch():
    """100k qps against a 10 ms + 1 ms/query engine: no candidate is
    stable, so the plan serves immediately at max amortization and
    flags saturation (backpressure's cue)."""
    c = WindowController(CFG)
    t = _steady(c, 1e-5, batches=[(n, 1e-2 + 1e-3 * n)
                                  for n in (8, 32, 128)] * 3)
    plan = c.plan(t)
    assert plan.saturated
    assert plan.max_batch == CFG.max_batch
    assert plan.delay_s == CFG.min_delay_s
    assert plan.utilization >= 1.0


# ----------------------------------------------------------------------
# models
# ----------------------------------------------------------------------
def test_arrival_rate_ewma():
    c = WindowController(CFG)
    assert c.arrival_rate == 0.0          # no arrivals yet
    c.observe_arrival(0.0)
    assert c.arrival_rate == 0.0          # one arrival: no gap yet
    _steady(c, 0.01, n_arrivals=200)
    assert c.arrival_rate == pytest.approx(100.0, rel=0.05)


def test_service_model_recovers_cost_line():
    c = WindowController(CFG)
    for _ in range(40):
        for n in (1, 2, 4, 8, 16, 32):
            c.observe_batch(n, 2e-3 + 1e-4 * n)
    c0, c1 = c.service_model()
    assert c0 == pytest.approx(2e-3, rel=0.15)
    assert c1 == pytest.approx(1e-4, rel=0.15)


def test_service_model_degenerate_sizes():
    """All batches the same size: the covariance fit is undefined; the
    model must still return a finite, non-negative split."""
    c = WindowController(CFG)
    for _ in range(30):
        c.observe_batch(8, 4e-3)
    c0, c1 = c.service_model()
    assert c0 >= 0.0 and c1 >= 0.0
    assert c0 + 8 * c1 == pytest.approx(4e-3, rel=0.1)


def test_piecewise_cost_model_tracks_both_regimes():
    """Concave batch cost (tiny windows far cheaper than the pooled
    line's intercept): the small-n fit must price a 1-2 query window
    from small-n data, the large-n fit from large-n data, and the
    pooled line must be visibly wrong on the small side — the bug the
    piecewise model exists to fix."""
    c = WindowController(CFG)
    for _ in range(40):
        for n in (1, 2):                     # cheap singles
            c.observe_batch(n, 2e-4 + 2e-5 * n)
        for n in (16, 32, 64):               # scan-dominated batches
            c.observe_batch(n, 1.5e-3 + 2e-5 * n)
    assert c.service_cost(1) == pytest.approx(2.2e-4, rel=0.25)
    assert c.service_cost(32) == pytest.approx(2.14e-3, rel=0.25)
    c0, _ = c.service_model()
    # the pooled intercept (fitted mostly by the expensive large
    # batches) overcharges a lone query by several x
    assert c0 + c.service_model()[1] > 2.5 * c.service_cost(1)


def test_piecewise_regime_without_data_falls_back_to_pooled():
    c = WindowController(CFG)
    for _ in range(30):
        c.observe_batch(32, 2e-3)            # large-n data only
    c0, c1 = c.service_model()
    assert c.service_cost(2) == pytest.approx(c0 + 2 * c1)


def test_transition_band_prefers_short_deadline():
    """The mid-band regression (ROADMAP): with concave costs a pooled
    fit inflates small-window estimates and the planner flees to long
    deadlines.  On the same trace, the piecewise controller must plan
    a deadline no longer than a pooled-fit controller (pivot_batch=1
    routes everything into one regime) and no longer than the static
    2 ms pair it used to lose to."""
    pooled_cfg = ControllerConfig(min_delay_s=1e-4, max_delay_s=0.02,
                                  min_batch=1, max_batch=128,
                                  pivot_batch=1)
    piecewise, pooled = WindowController(CFG), WindowController(pooled_cfg)
    for c in (piecewise, pooled):
        # ~1.5k qps: windows of a handful of queries — the transition
        # band between single-query service and full batches
        t = _steady(c, 1 / 1500, n_arrivals=300)
        for _ in range(40):
            for n in (1, 2):
                c.observe_batch(n, 2e-4 + 2e-5 * n)
            for n in (16, 32, 64):
                c.observe_batch(n, 1.5e-3 + 2e-5 * n)
    pw, pl = piecewise.plan(t), pooled.plan(t)
    assert pw.delay_s <= pl.delay_s
    assert pw.delay_s <= 0.002               # beats/meets the static pair
    assert pw.est_p99_s <= pl.est_p99_s


def test_plan_cached_until_period_or_batch():
    c = WindowController(CFG)
    _steady(c, 1e-3, t0=0.0)
    d1, b1 = c.window_params(now=1000.0)
    assert c.current_plan is not None
    plan_obj = c.current_plan
    # within the control period: cached object returned
    c.window_params(now=1000.0 + CFG.control_period_s / 2)
    assert c.current_plan is plan_obj
    # a batch observation invalidates the cache immediately
    c.observe_batch(4, 1e-3)
    c.window_params(now=1000.0 + CFG.control_period_s / 2)
    assert c.current_plan is not plan_obj


def test_config_validation():
    with pytest.raises(ValueError):
        ControllerConfig(min_delay_s=0.01, max_delay_s=0.001)
    with pytest.raises(ValueError):
        ControllerConfig(min_batch=8, max_batch=4)
    with pytest.raises(ValueError):
        ControllerConfig(arrival_alpha=0.0)
    with pytest.raises(ValueError):
        ControllerConfig(service_alpha=1.5)


# ----------------------------------------------------------------------
# BatchWindow integration: controller params + backpressure
# ----------------------------------------------------------------------
class _GatedEngine:
    """Blocks inside execute() until released — deterministic way to
    hold the dispatcher busy while the pending queue fills."""

    def __init__(self):
        self.started = threading.Event()
        self.release = threading.Event()
        self.batches = []
        self._lock = threading.Lock()

    def execute(self, queries, rate, rng=None):
        self.started.set()
        assert self.release.wait(timeout=10)
        with self._lock:
            self.batches.append(list(queries))
        return [("done", q) for q in queries]


class _FixedController(WindowController):
    """Controller stub pinning window_params to a fixed pair while
    recording the observations BatchWindow feeds it."""

    def __init__(self, delay_s, max_batch):
        super().__init__(CFG)
        self._fixed = (delay_s, max_batch)
        self.arrivals = 0
        self.batches = []

    def window_params(self, now=None):
        return self._fixed

    def observe_arrival(self, now=None):
        self.arrivals += 1
        super().observe_arrival(now)

    def observe_batch(self, n, service_s, scan_s=None, cached=0):
        self.batches.append((n, service_s, scan_s))
        super().observe_batch(n, service_s, scan_s, cached=cached)


def test_backpressure_at_queue_bound():
    eng = _GatedEngine()
    win = BatchWindow(eng, 1.0, max_batch=1, max_delay_s=1e-4,
                      max_pending=3)
    first = win.submit("busy")           # claimed by the dispatcher
    assert eng.started.wait(timeout=10)
    queued = [win.submit(i) for i in range(3)]   # fills the bound
    with pytest.raises(Backpressure) as exc:
        win.submit("shed")
    assert exc.value.depth == 3
    assert win.stats["shed"] == 1
    eng.release.set()
    assert first.result(timeout=10)[1] == "busy"
    for f in queued:                      # queued work still completes
        assert f.result(timeout=10)[0] == "done"
    win.submit("after-drain").result(timeout=10)  # bound frees up
    win.close()
    assert win.stats["served"] == 5


def test_window_honors_controller_params_and_feeds_it():
    ctrl = _FixedController(delay_s=10.0, max_batch=2)
    eng = _GatedEngine()
    eng.release.set()                     # engine never blocks
    # static args say (100, 10 s) — the controller must override both
    win = BatchWindow(eng, 1.0, max_batch=100, max_delay_s=10.0,
                      controller=ctrl)
    futs = [win.submit(i) for i in range(6)]
    for f in futs:
        assert f.result(timeout=10)[0] == "done"
    win.close()
    assert all(len(b) <= 2 for b in eng.batches)
    assert win.stats["closed_by_size"] >= 2
    assert ctrl.arrivals == 6
    assert len(ctrl.batches) == win.stats["batches"]
    for n, service_s, _scan in ctrl.batches:
        assert 1 <= n <= 2
        assert service_s >= 0.0


def test_backpressure_carries_utilization():
    ctrl = _FixedController(delay_s=10.0, max_batch=1)
    _steady(ctrl, 1e-5, batches=[(1, 1e-2)] * 5)
    ctrl.plan(10.0)
    eng = _GatedEngine()
    win = BatchWindow(eng, 1.0, max_batch=1, controller=ctrl,
                      max_pending=1)
    win.submit("busy")
    assert eng.started.wait(timeout=10)
    win.submit("queued")
    with pytest.raises(Backpressure) as exc:
        win.submit("shed")
    assert exc.value.utilization is not None
    assert exc.value.utilization >= 1.0
    eng.release.set()
    win.close()


def test_adaptive_window_end_to_end_under_load():
    """Real controller, real (fast) engine: a burst of 60 queries must
    drain, windows stay within the controller's bounds, and the
    controller ends up with a live arrival-rate estimate."""

    class _FastEngine:
        def __init__(self):
            self.batches = []
            self._lock = threading.Lock()

        def execute(self, queries, rate, rng=None):
            time.sleep(2e-4)
            with self._lock:
                self.batches.append(len(queries))
            return [("done", q) for q in queries]

    cfg = ControllerConfig(min_delay_s=1e-4, max_delay_s=5e-3,
                           min_batch=1, max_batch=16,
                           control_period_s=1e-3)
    ctrl = WindowController(cfg)
    eng = _FastEngine()
    win = BatchWindow(eng, 1.0, controller=ctrl)
    futs = [win.submit(i) for i in range(60)]
    for f in futs:
        assert f.result(timeout=30)[0] == "done"
    win.close()
    assert sum(eng.batches) == 60
    assert all(1 <= n <= 16 for n in eng.batches)
    assert ctrl.arrival_rate > 0.0
    assert ctrl.current_plan is not None


# ----------------------------------------------------------------------
# degradation pressure: the ladder's state machine + degrade-before-shed
# ----------------------------------------------------------------------
def test_config_validation_degrade_knobs():
    for kw in (dict(degrade_exit_util=0.9),          # exit >= enter
               dict(degrade_enter_util=0.5, degrade_exit_util=0.5),
               dict(degrade_step=0.0),
               dict(degrade_step=1.5)):
        with pytest.raises(ValueError):
            ControllerConfig(**kw)


class _PinnedUtil(WindowController):
    """White-box stub pinning the estimated (p99, utilization) of every
    candidate so the ratchet sees an exact utilization."""

    def __init__(self, rho):
        super().__init__(CFG)
        self.rho = rho

    def _estimate_p99(self, lam, d, n):
        return (1e-3, self.rho)


def test_pressure_ratchets_up_with_hysteresis():
    c = _PinnedUtil(rho=0.9)             # above degrade_enter_util
    step = CFG.degrade_step
    for i in range(1, 4):
        c.plan(float(i))
        assert c.pressure == pytest.approx(min(1.0, i * step))
    for i in range(4, 8):                # saturates at 1.0
        c.plan(float(i))
    assert c.pressure == 1.0
    # inside the dead band (exit < rho < enter) pressure holds — the
    # hysteresis that keeps accuracy from flapping at the threshold
    c.rho = 0.7
    c.plan(10.0)
    assert c.pressure == 1.0
    # below the exit threshold it ratchets back down to zero
    c.rho = 0.3
    for i in range(4):
        c.plan(11.0 + i)
    assert c.pressure == 0.0
    c.plan(20.0)                         # and clamps at zero
    assert c.pressure == 0.0


def test_saturation_counts_as_over_threshold():
    """An unstable plan (infinite p99 at every candidate) must ratchet
    pressure even though the pinned fallback's rho may read < 1."""
    c = WindowController(CFG)
    t = _steady(c, 1e-5, batches=[(1, 1e-2)] * 5)
    plan = c.plan(t)
    assert plan.saturated
    assert c.pressure == pytest.approx(CFG.degrade_step)


def test_escalate_pressure_jumps_to_full():
    c = WindowController(CFG)
    assert c.pressure == 0.0
    assert c.escalate_pressure() == 1.0
    assert c.pressure == 1.0


def test_retry_after_hint():
    c = WindowController(CFG)
    assert c.retry_after_s() is None     # no plan yet
    t = _steady(c, 1e-3, batches=[(4, 1e-3)] * 8)
    plan = c.plan(t)
    hint = c.retry_after_s()
    assert hint == pytest.approx(
        plan.delay_s + c.service_cost(float(plan.max_batch)))
    assert hint > 0.0


def test_backpressure_carries_retry_after():
    ctrl = _FixedController(delay_s=10.0, max_batch=1)
    _steady(ctrl, 1e-5, batches=[(1, 1e-2)] * 5)
    ctrl.plan(10.0)
    eng = _GatedEngine()
    win = BatchWindow(eng, 1.0, max_batch=1, controller=ctrl,
                      max_pending=1)
    win.submit("busy")
    assert eng.started.wait(timeout=10)
    win.submit("queued")
    with pytest.raises(Backpressure) as exc:
        win.submit("shed")
    assert exc.value.retry_after_s is not None
    assert exc.value.retry_after_s == pytest.approx(ctrl.retry_after_s())
    eng.release.set()
    win.close()


class _ElasticGatedEngine(_GatedEngine):
    """Gated engine that advertises accuracy elasticity: the window may
    escalate pressure instead of shedding, and each batch records the
    pressure it was served at."""

    accepts_pressure = True

    def __init__(self):
        super().__init__()
        self.pressures = []

    def execute(self, queries, rate, rng=None, pressure=0.0):
        with self._lock:
            self.pressures.append(pressure)
        return super().execute(queries, rate, rng)


def test_window_degrades_before_shedding():
    """The ladder end to end: at the queue bound an accuracy-elastic
    engine absorbs overload via pressure escalation (queue stretches to
    2x the bound), and only past the hard cap does submit shed."""
    ctrl = _FixedController(delay_s=10.0, max_batch=1)
    eng = _ElasticGatedEngine()
    win = BatchWindow(eng, 0.5, max_batch=1, controller=ctrl,
                      max_pending=2)
    futs = [win.submit("busy")]
    assert eng.started.wait(timeout=10)      # dispatcher blocked in batch 1
    futs += [win.submit(i) for i in range(2)]     # fills the bound
    # bound hit, engine elastic -> escalate + enqueue, twice
    futs += [win.submit("deg1"), win.submit("deg2")]
    assert win.stats["escalated"] == 2
    assert win.stats["shed"] == 0
    assert ctrl.pressure == 1.0
    # queue now at the 2x hard cap: accuracy has nothing left to give
    with pytest.raises(Backpressure):
        win.submit("shed")
    assert win.stats["shed"] == 1
    eng.release.set()
    for f in futs:
        assert f.result(timeout=10)[0] == "done"
    win.close()
    # batch 1 was claimed before the escalation; every later batch ran
    # fully degraded and is counted in the degraded stat
    assert eng.pressures[0] == 0.0
    assert all(p == 1.0 for p in eng.pressures[1:])
    assert win.stats["degraded"] == len(futs) - 1


def test_window_without_elastic_engine_sheds_at_bound():
    """A controller alone is not enough: engines that cannot take
    pressure keep the legacy shed-at-bound contract."""
    ctrl = _FixedController(delay_s=10.0, max_batch=1)
    eng = _GatedEngine()
    win = BatchWindow(eng, 1.0, max_batch=1, controller=ctrl,
                      max_pending=1)
    win.submit("busy")
    assert eng.started.wait(timeout=10)
    win.submit("queued")
    with pytest.raises(Backpressure):
        win.submit("shed")
    assert win.stats["escalated"] == 0 and win.stats["shed"] == 1
    assert ctrl.pressure == 0.0
    eng.release.set()
    win.close()
