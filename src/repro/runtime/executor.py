"""Fault-tolerant shard-task executor (the query-side runtime).

This is the Spark-executor analogue for EmApprox query jobs: per-shard
tasks run on a worker pool with

  * retry on failure (transient worker faults),
  * straggler mitigation: when the slowest ~tail of tasks exceeds
    ``straggler_factor``x the median completion time, duplicates are
    speculatively launched and the first finisher wins (the classic
    MapReduce backup-task trick),
  * elastic worker count: pool size can change between jobs.

On a TPU cluster the same policy applies at pod granularity (a pod is a
worker; shards are its resident data) — the executor keeps that mapping
abstract by operating on shard ids.  Failure injection for tests is via
``fault_hook`` which may raise on chosen shards.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Any, Callable, Dict, Iterable, Optional, Sequence

import numpy as np


class ShardTaskError(RuntimeError):
    pass


class ShardTaskExecutor:
    def __init__(
        self,
        workers: int = 4,
        max_retries: int = 2,
        straggler_factor: float = 3.0,
        min_completed_for_speculation: int = 4,
        fault_hook: Optional[Callable[[int, int], None]] = None,
    ):
        self.workers = workers
        self.max_retries = max_retries
        self.straggler_factor = straggler_factor
        self.min_completed = min_completed_for_speculation
        self.fault_hook = fault_hook  # (shard_id, attempt) -> None or raise
        self.stats: Dict[str, int] = {"retries": 0, "speculative": 0}

    def resize(self, workers: int) -> None:
        """Elastic scaling between jobs."""
        self.workers = max(1, workers)

    def map_shards(
        self,
        corpus,
        shard_ids: Sequence[int],
        fn: Callable[[Any], Any],
    ) -> Dict[int, Any]:
        """Run ``fn(shard)`` for every id; returns {shard_id: result}."""
        ids = [int(s) for s in shard_ids]
        results: Dict[int, Any] = {}
        attempts: Dict[int, int] = {i: 0 for i in ids}
        lock = threading.Lock()

        def run_one(sid: int) -> Any:
            with lock:
                attempts[sid] += 1
                attempt = attempts[sid]
            if self.fault_hook is not None:
                self.fault_hook(sid, attempt)
            return fn(corpus.shards[sid])

        with ThreadPoolExecutor(max_workers=self.workers) as pool:
            future_of: Dict[Future, int] = {
                pool.submit(run_one, sid): sid for sid in ids}
            started = {sid: time.perf_counter() for sid in ids}
            durations: list = []
            speculated: set = set()
            pending = set(future_of)
            while pending:
                done, pending = wait(pending, timeout=0.05,
                                     return_when=FIRST_COMPLETED)
                now = time.perf_counter()
                for fut in done:
                    sid = future_of[fut]
                    try:
                        res = fut.result()
                        if sid not in results:
                            results[sid] = res
                            durations.append(now - started[sid])
                    except Exception:
                        if attempts[sid] <= self.max_retries:
                            self.stats["retries"] += 1
                            nf = pool.submit(run_one, sid)
                            future_of[nf] = sid
                            pending.add(nf)
                        elif sid not in results:
                            raise ShardTaskError(
                                f"shard {sid} failed after "
                                f"{attempts[sid]} attempts")
                # straggler speculation
                if (len(durations) >= self.min_completed and pending):
                    median = float(np.median(durations))
                    for fut in list(pending):
                        sid = future_of[fut]
                        if (sid not in results and sid not in speculated and
                                now - started[sid] >
                                self.straggler_factor * max(median, 1e-4)):
                            speculated.add(sid)
                            self.stats["speculative"] += 1
                            nf = pool.submit(run_one, sid)
                            future_of[nf] = sid
                            pending.add(nf)
        missing = [s for s in ids if s not in results]
        if missing:
            raise ShardTaskError(f"shards never completed: {missing}")
        return results
