"""Fault-tolerant shard-task executor (the query-side runtime).

This is the Spark-executor analogue for EmApprox query jobs: per-shard
tasks run on a worker pool with

  * retry on failure (transient worker faults) with *bounded
    exponential backoff*: the ``r``-th retry of a shard waits
    ``retry_backoff_s * 2**(r-1)`` (capped at ``retry_backoff_cap_s``)
    before resubmitting, so a flaky dependency is not hammered at
    queue speed,
  * a per-job deadline (``job_deadline_s``): a job that cannot finish
    in time stops retrying and — with ``allow_partial=True`` — returns
    the shards it *did* complete, recording the rest on
    ``last_job["lost_shards"]`` so the query layer can degrade to a
    partial-sample estimate with a widened CI instead of failing the
    whole batch (without ``allow_partial`` the deadline raises
    ``ShardTaskError`` exactly like exhausted retries),
  * straggler mitigation: when the slowest ~tail of tasks exceeds
    ``straggler_factor``x the median completion time, duplicates are
    speculatively launched and the first finisher wins (the classic
    MapReduce backup-task trick),
  * elastic worker count: pool size can change between jobs,
  * a *warm* pool: the thread pool is built lazily on the first job and
    kept alive across jobs (long-lived serving was paying a pool
    construction + teardown per batch), rebuilt only when the target
    worker count changes; ``close()`` (or the context manager) tears it
    down,
  * adaptive worker count by task granularity
    (``adaptive_workers=True``): tiny numpy tasks are GIL-bound — the
    lock convoy makes 4+ workers *slower* than 1-2 — so when the last
    job's median task time falls under ``gil_floor_s`` the pool shrinks
    to 2 workers; it widens back to ``workers`` as soon as tasks are
    long enough to release the GIL meaningfully.

This executor is the *single-host* layer: it treats every shard it is
handed as locally resident.  Multi-host topologies stack
``runtime/placement.HostGroupExecutor`` on top, with the dataflow
placement -> balance -> executor: a ``PlacementMap`` bounds where each
shard may run (primary residency + live ring replicas), the optional
``runtime/balance`` layer picks where it should (cost-aware shedding
from hot hosts onto replicas, fed by the per-host realized wall times
this layer reports via ``last_job``), and one ``ShardTaskExecutor``
per host runs its group (per-host warm pool, per-host retry and
speculation) before a cross-host gather merges the per-shard results.
Failure injection for tests is via ``fault_hook`` which may raise on
chosen shards (host-granularity injection lives on the placement
layer).

Shared-scan scheduling (``map_shard_batch``): a batch of queries, each
with its own sampled shard plan, is inverted into one task per shard in
the *union* of the plans; visiting a shard evaluates every query that
sampled it in a single pass.  I/O and task overhead scale with the
union size instead of the sum of per-query plan sizes, and retry /
speculation apply to the composite shard task, so a retried shard
re-evaluates all of its queries (same at-least-once semantics as
``map_shards``).  The schedule itself (invert the plans, visit once,
scatter back per query) is ``run_shared_scan`` — one definition shared
by this executor, the placement layer's per-host scans, and the
executor-less inline fallback in ``core/queries/batch.py``, so the
schedules cannot diverge.

Fault injection has two first-class seams, both consumed by the
``runtime/chaos`` FaultPlan compiler: ``fault_hook(shard_id, attempt)``
(the legacy raise-to-fail hook) and ``task_hook(shard_id, attempt,
job)`` — the per-shard-task hook carrying the executor's job index, so
a scripted plan can target "shard tasks during jobs 3..5" without
keeping its own clock.  ``job_hook(job)`` fires once at job start.

Completions are tagged with a *job epoch*: a job abandoned at its
deadline leaves speculative/stalled futures running on the warm pool,
and when those finish late their completion records carry the old
epoch and are dropped (``stats["stale_completions"]``) instead of
polluting a later job's accounting.
"""
from __future__ import annotations

import heapq
import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, Optional, Sequence

import numpy as np


class ShardTaskError(RuntimeError):
    pass


def invert_plan(plan: Sequence[Sequence[int]]) -> Dict[int, list]:
    """{shard_id: [query indices]} union of per-query shard plans — the
    shared-scan schedule.  One definition serves the executor's
    ``map_shard_batch``, the placement layer's residency split, and the
    executor-less inline fallback in ``core/queries/batch.py`` so the
    schedules cannot diverge."""
    queries_of: Dict[int, list] = {}
    for qi, shard_ids in enumerate(plan):
        for sid in shard_ids:
            queries_of.setdefault(int(sid), []).append(qi)
    return queries_of


def run_shared_scan(
    mapper: Callable[[Any, Sequence[int], Callable[[Any], Any]],
                     Dict[int, Any]],
    corpus,
    plan: Sequence[Sequence[int]],
    fns: Sequence[Callable[[Any], Any]],
    *,
    megakernel: Optional[bool] = None,
) -> "list[Dict[int, Any]]":
    """The full shared-scan schedule over any ``map_shards``-shaped
    mapper: invert the per-query plans, visit each union shard once
    (evaluating every interested query in that visit), and scatter the
    per-shard composites back into one ``{shard_id: result}`` dict per
    query.  ``ShardTaskExecutor.map_shard_batch`` runs it on the local
    pool; ``HostGroupExecutor.map_shard_batch`` runs it through the
    residency split + cross-host gather — same schedule either way.

    When every fn carries the same ``kernels/megascan`` ``MegascanSpec``
    (built via ``MegascanSpec.scan_fns()``), the composite shard task
    becomes ``spec.run_shard`` — the per-shard *fused* scan, one Pallas
    launch per shard for all interested queries — and, unless
    ``megakernel=False``, the composite is tagged with the spec so a
    spec-aware mapper (a megakernel-enabled ``ShardTaskExecutor``) can
    fuse its whole shard group into ONE launch (``spec.run_group``).
    The gather below is the contract either way: per-(query, shard)
    results scattered into one ``{shard_id: result}`` dict per query,
    bit-for-bit identical across routes.  ``megakernel=True`` asserts
    the fns are fusable (raises otherwise); ``None`` auto-detects;
    ``False`` pins the per-shard fused path (the parity reference and
    the fallback when grouping is disabled)."""
    if len(plan) != len(fns):
        raise ValueError(f"plan/fns length mismatch: "
                         f"{len(plan)} != {len(fns)}")
    queries_of = invert_plan(plan)

    spec = None
    if fns:
        cand = getattr(fns[0], "megascan", None)
        if cand is not None and all(
                getattr(f, "megascan", None) is cand for f in fns):
            spec = cand
    if megakernel is True and spec is None:
        raise ValueError("megakernel=True requires scan fns built from "
                         "one MegascanSpec (MegascanSpec.scan_fns())")

    if spec is not None:
        def shared_scan(shard):
            return spec.run_shard(shard.shard_id,
                                  queries_of[shard.shard_id])
        if megakernel is not False:
            shared_scan.megascan = spec
            shared_scan.queries_of = queries_of
    else:
        def shared_scan(shard):
            return {qi: fns[qi](shard)
                    for qi in queries_of[shard.shard_id]}

    by_shard = mapper(corpus, sorted(queries_of), shared_scan)
    out: list = [{} for _ in plan]
    for sid, per_query in by_shard.items():
        for qi, res in per_query.items():
            out[qi][sid] = res
    return out


class ShardTaskExecutor:
    def __init__(
        self,
        workers: int = 4,
        max_retries: int = 2,
        straggler_factor: float = 3.0,
        min_completed_for_speculation: int = 4,
        fault_hook: Optional[Callable[[int, int], None]] = None,
        min_straggler_s: float = 0.05,
        adaptive_workers: bool = False,
        gil_floor_s: float = 1e-3,
        retry_backoff_s: float = 0.0,
        retry_backoff_cap_s: float = 1.0,
        job_deadline_s: Optional[float] = None,
        allow_partial: bool = False,
        task_hook: Optional[Callable[[int, int, int], None]] = None,
        job_hook: Optional[Callable[[int], None]] = None,
        megakernel: bool = True,
    ):
        self.workers = workers
        # Spec-tagged shared scans (kernels/megascan MegascanSpec) run
        # the whole shard group as ONE Pallas launch instead of one
        # composite task per shard; False pins the per-shard fused
        # path (parity reference / interpret-mode fallback).
        self.megakernel = bool(megakernel)
        self.max_retries = max_retries
        self.straggler_factor = straggler_factor
        self.min_completed = min_completed_for_speculation
        self.fault_hook = fault_hook  # (shard_id, attempt) -> None or raise
        # chaos seams: per-shard-task hook with the executor's job index
        # (slow/flaky injection at task granularity) and a job-start
        # hook (lets a FaultPlan injector advance its clock)
        self.task_hook = task_hook    # (shard_id, attempt, job)
        self.job_hook = job_hook      # (job) at job start
        # attempt k of a failed shard waits backoff * 2^(k-1) (capped)
        # before resubmission; 0.0 keeps the legacy immediate retry
        self.retry_backoff_s = float(retry_backoff_s)
        self.retry_backoff_cap_s = float(retry_backoff_cap_s)
        # a job that cannot finish by its deadline stops retrying; with
        # allow_partial it returns what completed (lost shards recorded
        # on last_job), otherwise it raises like exhausted retries
        self.job_deadline_s = job_deadline_s
        self.allow_partial = bool(allow_partial)
        # Floor on the speculation threshold: when the median task time
        # is below the scheduler's own tick (tasks of ~100 us at batch
        # scale), 3x the median is noise-level and speculation would
        # duplicate healthy tasks — a backup task is only worth
        # launching for work at least as long as a scheduling quantum.
        self.min_straggler_s = min_straggler_s
        self.adaptive_workers = adaptive_workers
        self.gil_floor_s = gil_floor_s
        self.stats: Dict[str, int] = {"retries": 0, "speculative": 0,
                                      "jobs": 0, "pool_rebuilds": 0,
                                      "lost_shards": 0,
                                      "stale_completions": 0,
                                      "megascan_jobs": 0}
        # job epoch: bumped at every job start; completion records are
        # tagged with it so futures abandoned by a deadline-expired job
        # are recognizably stale when they finish late.  The completions
        # queue is instance-level (not job-local) and jobs are
        # serialized on _job_lock, so a zombie future's late completion
        # lands in a *live* loop where the epoch guard can count and
        # drop it instead of vanishing into a dead queue.
        self._job_epoch = 0
        self._job_lock = threading.Lock()
        self._completions: "queue.Queue[tuple]" = queue.Queue()
        # per-job service-time telemetry for the last completed job —
        # the window controller reads this to attribute batch cost to
        # the shared scan (wall_s) vs engine overhead; see
        # runtime/controller.WindowController.observe_batch
        self.last_job: Optional[Dict[str, float]] = None
        self._pool: Optional[ThreadPoolExecutor] = None
        self._pool_size = 0
        self._pool_lock = threading.Lock()
        self._active_jobs = 0
        self._median_task_s: Optional[float] = None

    def resize(self, workers: int) -> None:
        """Elastic scaling between jobs (the warm pool is swapped on the
        next job, not mid-flight)."""
        self.workers = max(1, workers)

    # ------------------------------------------------------------------
    # warm pool management
    # ------------------------------------------------------------------
    def target_workers(self) -> int:
        """Worker count the next job will run with: the configured width
        unless adaptive granularity scaling says the tasks are too small
        to parallelize (GIL-bound numpy ops favor 1-2 workers)."""
        w = max(1, int(self.workers))
        if (self.adaptive_workers and self._median_task_s is not None
                and self._median_task_s < self.gil_floor_s):
            w = min(w, 2)
        return w

    def _acquire_pool(self) -> ThreadPoolExecutor:
        """Check out the long-lived worker pool for one job, (re)built
        only when the target width changed *and* no other job is using
        it — a mid-flight swap would shut the pool down under the other
        job's submits.  Concurrent jobs simply share the current width
        until the executor goes idle.  Balance with ``_release_pool``."""
        with self._pool_lock:
            target = self.target_workers()
            if self._pool is None or (self._pool_size != target
                                      and self._active_jobs == 0):
                if self._pool is not None:
                    self._pool.shutdown(wait=False)
                self._pool = ThreadPoolExecutor(
                    max_workers=target, thread_name_prefix="shard-worker")
                self._pool_size = target
                self.stats["pool_rebuilds"] += 1
            self._active_jobs += 1
            return self._pool

    def _release_pool(self) -> None:
        with self._pool_lock:
            self._active_jobs -= 1

    def close(self) -> None:
        """Tear down the warm pool (idempotent).  Call when no job is
        in flight — shutting down under a running ``map_shards`` fails
        that job's remaining submits."""
        with self._pool_lock:
            pool, self._pool, self._pool_size = self._pool, None, 0
        if pool is not None:
            pool.shutdown(wait=True)

    def __enter__(self) -> "ShardTaskExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def map_shards(
        self,
        corpus,
        shard_ids: Sequence[int],
        fn: Callable[[Any], Any],
    ) -> Dict[int, Any]:
        """Run ``fn(shard)`` for every id; returns {shard_id: result}.

        The completion loop is event-driven: every future signals a
        queue via ``add_done_callback`` and the scheduler blocks on that
        queue, so bookkeeping is O(1) per completion.  (The previous
        ``wait(..., FIRST_COMPLETED)`` polling loop re-registered a
        waiter on every still-pending future each iteration — O(tasks)
        per completion, O(tasks^2) per job — which at shared-scan batch
        sizes cost more than the shard work itself.)  Straggler checks
        run on 50 ms ticks and on each completion.

        A ``MegascanSpec``-tagged composite (see ``run_shared_scan``)
        short-circuits the per-shard task fan-out entirely: the whole
        group runs as ONE Pallas launch (``_run_group_scan``) when this
        executor was built with ``megakernel=True``.
        """
        spec = getattr(fn, "megascan", None)
        if spec is not None and self.megakernel:
            with self._job_lock:
                return self._run_group_scan(corpus, shard_ids, fn, spec)
        pool = self._acquire_pool()
        try:
            # jobs are serialized: the epoch guard on the shared
            # completions queue assumes one live job owns the loop
            with self._job_lock:
                return self._run_job(pool, corpus, shard_ids, fn)
        finally:
            self._release_pool()

    def _run_job(
        self,
        pool: ThreadPoolExecutor,
        corpus,
        shard_ids: Sequence[int],
        fn: Callable[[Any], Any],
    ) -> Dict[int, Any]:
        ids = [int(s) for s in shard_ids]
        t_job = time.perf_counter()
        deadline = (t_job + self.job_deadline_s
                    if self.job_deadline_s is not None else None)
        self._job_epoch += 1
        epoch = self._job_epoch
        job = self.stats["jobs"]
        if self.job_hook is not None:
            self.job_hook(job)
        results: Dict[int, Any] = {}
        attempts: Dict[int, int] = {i: 0 for i in ids}
        lock = threading.Lock()

        # live[sid][attempt] = when that attempt actually began executing
        # on a worker (NOT when it was submitted): with queue depth >>
        # workers, submission age measures queue wait, and the straggler
        # check would speculatively duplicate nearly every queued task
        # once the median of the first few completions is small.  Keyed
        # per attempt so a speculative duplicate cannot overwrite the
        # original's start (which would corrupt duration samples), and
        # failed attempts are removed so a queued retry is never
        # mistaken for a running straggler.
        live: Dict[int, Dict[int, float]] = {i: {} for i in ids}

        def run_one(sid: int, attempt: int) -> Any:
            with lock:
                live[sid][attempt] = time.perf_counter()
            if self.fault_hook is not None:
                self.fault_hook(sid, attempt)
            if self.task_hook is not None:
                self.task_hook(sid, attempt, job)
            return fn(corpus.shards[sid])

        completions = self._completions
        in_flight = 0
        durations: list = []
        speculated: set = set()
        # retries waiting out their backoff: heap of (due_time, sid)
        delayed: list = []

        def submit(sid: int) -> None:
            nonlocal in_flight
            with lock:
                attempts[sid] += 1
                attempt = attempts[sid]
            fut = pool.submit(run_one, sid, attempt)
            fut.add_done_callback(
                lambda f, sid=sid, a=attempt: completions.put(
                    (epoch, sid, a, f)))
            in_flight += 1

        def schedule_retry(sid: int) -> None:
            """The r-th retry of a shard waits backoff * 2^(r-1)
            (capped) before resubmission; zero backoff resubmits
            immediately, the legacy behavior."""
            self.stats["retries"] += 1
            if self.retry_backoff_s <= 0.0:
                submit(sid)
                return
            delay = min(self.retry_backoff_cap_s,
                        self.retry_backoff_s * 2.0 ** (attempts[sid] - 1))
            heapq.heappush(delayed, (time.perf_counter() + delay, sid))

        last_check = time.perf_counter()

        def check_stragglers(now: float) -> None:
            nonlocal last_check
            if len(durations) < self.min_completed:
                return
            if now - last_check < 0.05:  # O(ids) scan, throttled
                return
            last_check = now
            median = float(np.median(durations))
            threshold = self.straggler_factor * max(
                median, self.min_straggler_s)
            for sid in ids:
                if sid in results or sid in speculated:
                    continue
                with lock:
                    t_run = min(live[sid].values(), default=None)
                if t_run is not None and now - t_run > threshold:
                    speculated.add(sid)
                    self.stats["speculative"] += 1
                    submit(sid)

        # On permanent failure the error is *recorded*, submissions stop,
        # and the loop still drains every in-flight future before the
        # exception escapes — the old per-job pool got this quiescence
        # from its `with` shutdown; the shared warm pool must not be
        # left running zombie tasks that would queue-jam the next job.
        # A *deadline* expiry is the one exception: draining would let a
        # stalled task hold the job hostage past its own time bound, so
        # the job abandons its in-flight futures on the warm pool and
        # the epoch guard disposes of their late completions.
        fatal: Optional[ShardTaskError] = None
        lost: set = set()
        timed_out = False
        for sid in ids:
            submit(sid)
        while in_flight or delayed:
            now = time.perf_counter()
            if fatal is None and deadline is not None and now >= deadline:
                timed_out = True
                break
            if fatal is None:
                while delayed and delayed[0][0] <= now:
                    _, sid = heapq.heappop(delayed)
                    submit(sid)
                if not in_flight and not delayed:
                    break
            timeout = 0.05
            if delayed and fatal is None:
                timeout = min(timeout, max(1e-4, delayed[0][0] - now))
            if deadline is not None and fatal is None:
                timeout = min(timeout, max(1e-4, deadline - now))
            if not in_flight:
                if fatal is not None:
                    break          # only delayed retries left: drop them
                time.sleep(timeout)
                continue
            try:
                rec_epoch, sid, attempt, fut = completions.get(
                    timeout=timeout)
            except queue.Empty:
                if fatal is None:
                    check_stragglers(time.perf_counter())
                continue
            if rec_epoch != epoch:
                # zombie from an abandoned (deadline-expired) earlier
                # job finishing late — drop, never decrement in_flight
                self.stats["stale_completions"] += 1
                continue
            in_flight -= 1
            now = time.perf_counter()
            try:
                res = fut.result()
                with lock:
                    t_start = live[sid].pop(attempt, now)
                if sid not in results:
                    results[sid] = res
                    durations.append(now - t_start)
                    lost.discard(sid)   # late speculative success
            except Exception:
                with lock:
                    live[sid].pop(attempt, None)
                if sid in results or fatal is not None:
                    pass  # a speculative duplicate failed after the
                          # original already delivered, or the job is
                          # already failing — nothing to redo
                elif attempts[sid] <= self.max_retries:
                    schedule_retry(sid)
                elif self.allow_partial:
                    lost.add(sid)   # degrade instead of failing the job
                else:
                    fatal = ShardTaskError(
                        f"shard {sid} failed after "
                        f"{attempts[sid]} attempts")
            if fatal is None:
                check_stragglers(now)
        if fatal is not None:
            raise fatal
        missing = [s for s in ids if s not in results]
        if missing and not self.allow_partial:
            if timed_out:
                raise ShardTaskError(
                    f"job deadline ({self.job_deadline_s}s) expired; "
                    f"shards incomplete: {missing}")
            raise ShardTaskError(f"shards never completed: {missing}")
        self.stats["lost_shards"] += len(missing)
        median_task = float(np.median(durations)) if durations else 0.0
        if durations:
            # feeds adaptive granularity scaling for the next job
            self._median_task_s = median_task
        self.stats["jobs"] += 1
        self.last_job = {
            "wall_s": time.perf_counter() - t_job,
            "tasks": float(len(ids)),
            "median_task_s": median_task,
            "lost_shards": float(len(missing)),
        }
        return results

    def _run_group_scan(self, corpus, shard_ids: Sequence[int], fn,
                        spec) -> Dict[int, Any]:
        """One-launch megakernel route: the whole shard group is a
        single composite task (``spec.run_group`` — one Pallas launch
        over the packed multi-shard payload) instead of one task per
        shard.  The fault seams keep their per-shard granularity — the
        ``fault_hook``/``task_hook`` pair fires for every shard in the
        group before the launch, so chaos scripts targeting individual
        shards still bite — but failure/retry is at-least-once at
        *group* granularity: any hook raise or launch failure re-runs
        the whole group (with the same bounded-exponential backoff),
        which is exactly the composite-task semantics ``map_shard_batch``
        already documents, at width = whole group."""
        ids = [int(s) for s in shard_ids]
        t_job = time.perf_counter()
        deadline = (t_job + self.job_deadline_s
                    if self.job_deadline_s is not None else None)
        self._job_epoch += 1
        job = self.stats["jobs"]
        if self.job_hook is not None:
            self.job_hook(job)
        queries_of = getattr(fn, "queries_of", None)
        if queries_of is None:
            queries_of = {sid: [] for sid in ids}
        attempt = 0
        lost: list = []
        results: Dict[int, Any] = {}
        while True:
            attempt += 1
            try:
                for sid in ids:
                    if self.fault_hook is not None:
                        self.fault_hook(sid, attempt)
                    if self.task_hook is not None:
                        self.task_hook(sid, attempt, job)
                results = spec.run_group(ids, queries_of)
                break
            except Exception as exc:
                if attempt > self.max_retries:
                    raise ShardTaskError(
                        f"megascan group {ids} failed after "
                        f"{attempt} attempts") from exc
                self.stats["retries"] += 1
                delay = 0.0
                if self.retry_backoff_s > 0.0:
                    delay = min(self.retry_backoff_cap_s,
                                self.retry_backoff_s * 2.0 ** (attempt - 1))
                if deadline is not None and (
                        time.perf_counter() + delay >= deadline):
                    if self.allow_partial:
                        lost = list(ids)
                        break
                    raise ShardTaskError(
                        f"job deadline ({self.job_deadline_s}s) expired; "
                        f"megascan group incomplete: {ids}") from exc
                if delay > 0.0:
                    time.sleep(delay)
        self.stats["lost_shards"] += len(lost)
        self.stats["jobs"] += 1
        self.stats["megascan_jobs"] += 1
        wall = time.perf_counter() - t_job
        # median_task_s is what the window controller amortizes per
        # shard; with one launch for the group the honest attribution
        # is the launch wall spread over its shards
        self.last_job = {
            "wall_s": wall,
            "tasks": float(len(ids)),
            "median_task_s": wall / max(1, len(ids)),
            "lost_shards": float(len(lost)),
        }
        if spec.last_record is not None and not lost:
            self.last_job["megascan"] = dict(spec.last_record)
        return results

    def map_shard_batch(
        self,
        corpus,
        plan: Sequence[Sequence[int]],
        fns: Sequence[Callable[[Any], Any]],
        *,
        megakernel: Optional[bool] = None,
    ) -> "list[Dict[int, Any]]":
        """Shared scan over a batch of queries.

        ``plan[i]`` is the shard ids query ``i`` sampled and ``fns[i]``
        its per-shard task.  Returns one ``{shard_id: result}`` dict per
        query — exactly what ``map_shards(corpus, plan[i], fns[i])``
        would have produced, but each shard in the union of the plans is
        visited once, with all interested queries evaluated in that
        single visit.  Retry and straggler speculation are inherited
        from ``map_shards`` at composite-task granularity.

        ``megakernel`` (None = auto): when the fns come from one
        ``MegascanSpec``, route the whole union as ONE Pallas launch
        (see ``run_shared_scan``); ``False`` pins the per-shard fused
        path — the bit-for-bit parity reference.
        """
        return run_shared_scan(self.map_shards, corpus, plan, fns,
                               megakernel=megakernel)
