"""The query-side serving runtime, bottom-up:

  ``executor``   — single-host fault-tolerant shard tasks (warm pool,
                   retry, straggler speculation, shared scans)
  ``placement``  — shard -> host residency (``PlacementMap``) and the
                   multi-host executor (``HostGroupExecutor``):
                   per-host shared scans, cross-host gather, replica
                   failover
  ``balance``    — replica-aware load balancing (``HostLoadModel`` +
                   ``plan_split``): per-host EWMA cost model over
                   realized host-group wall times, greedy LPT shedding
                   from hot hosts onto live replicas, hysteresis
                   against flapping
  ``window``     — the batching frontend (``BatchWindow``): stream of
                   queries in, deadline/size-closed batches out
  ``controller`` — queueing-theory window autotuner
                   (``WindowController``) + ``Backpressure`` shedding
                   + the degradation-pressure state machine
  ``budget``     — error/latency budgets (``QueryBudget``) and the
                   SLO-driven rate planner (``RatePlanner``): inverts
                   the paper's variance model / fitted error curves to
                   pick the smallest per-query sampling rate meeting
                   each budget

The multi-host dataflow is placement -> balance -> executor: the
``PlacementMap`` bounds where a shard *may* run (primary + live ring
replicas — residency), the balancer picks where it *should* (cost-aware
split, failover as the infinitely-hot-host special case), and the
per-host ``ShardTaskExecutor`` fleet runs the groups, feeding realized
per-host wall times back into the balancer's cost model.  The gather
above is split-agnostic, so every flavor of split produces bit-for-bit
the single-executor results.

``BatchWindow`` takes either executor flavor behind its engine — a
single-host pool and a placement-split host group expose the same
``map_shard_batch`` surface.

Under overload the controller drives *two actuators*, in order:

  1. **degrade** (accuracy): utilization past the saturation band — or
     the pending queue hitting its bound — ratchets the controller's
     ``pressure`` toward 1.0; the window forwards it to an
     accuracy-elastic engine (``QueryBatch`` + ``RatePlanner``), which
     slides every query's sampling rate from its budget-planned value
     toward its budget floor.  Capacity rises because batch service is
     ~linear in shards scanned; answers stay correct because every
     result carries its error bound at whatever rate was served.
  2. **shed** (availability): only once pressure sits at 1.0 — every
     pending query already at its floor — and the queue still
     stretches past twice its bound does ``submit`` raise
     ``Backpressure`` (now with a ``retry_after_s`` hint from the
     controller's plan).

Both directions are hysteretic (asymmetric enter/exit utilization
thresholds, mirroring ``balance``'s asymmetric band), and every
degradation decision lands in a ``BudgetAudit`` on
``last_job["budget"]`` the way balance decisions land on
``last_job["balance"]``.
"""
from repro.runtime.balance import (  # noqa: F401
    BalanceConfig,
    HostLoadModel,
    plan_split,
)
from repro.runtime.budget import (  # noqa: F401
    BudgetAudit,
    PlannerConfig,
    QueryBudget,
    RatePlanner,
)
from repro.runtime.controller import (  # noqa: F401
    Backpressure,
    ControllerConfig,
    WindowController,
    WindowPlan,
)
from repro.runtime.executor import ShardTaskExecutor  # noqa: F401
from repro.runtime.placement import (  # noqa: F401
    HostFailure,
    HostGroupExecutor,
    PlacementMap,
)
from repro.runtime.window import BatchWindow  # noqa: F401
