"""The query-side serving runtime, bottom-up:

  ``executor``   — single-host fault-tolerant shard tasks (warm pool,
                   retry, straggler speculation, shared scans)
  ``placement``  — shard -> host residency (``PlacementMap``) and the
                   multi-host executor (``HostGroupExecutor``):
                   per-host shared scans, cross-host gather, replica
                   failover
  ``window``     — the batching frontend (``BatchWindow``): stream of
                   queries in, deadline/size-closed batches out
  ``controller`` — queueing-theory window autotuner
                   (``WindowController``) + ``Backpressure`` shedding

``BatchWindow`` takes either executor flavor behind its engine — a
single-host pool and a placement-split host group expose the same
``map_shard_batch`` surface.
"""
from repro.runtime.controller import (  # noqa: F401
    Backpressure,
    ControllerConfig,
    WindowController,
    WindowPlan,
)
from repro.runtime.executor import ShardTaskExecutor  # noqa: F401
from repro.runtime.placement import (  # noqa: F401
    HostFailure,
    HostGroupExecutor,
    PlacementMap,
)
from repro.runtime.window import BatchWindow  # noqa: F401
