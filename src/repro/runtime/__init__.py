from repro.runtime.controller import (  # noqa: F401
    Backpressure,
    ControllerConfig,
    WindowController,
    WindowPlan,
)
from repro.runtime.executor import ShardTaskExecutor  # noqa: F401
from repro.runtime.window import BatchWindow  # noqa: F401
