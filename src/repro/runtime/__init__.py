"""The query-side serving runtime, bottom-up:

  ``executor``   — single-host fault-tolerant shard tasks (warm pool,
                   retry, straggler speculation, shared scans)
  ``placement``  — shard -> host residency (``PlacementMap``) and the
                   multi-host executor (``HostGroupExecutor``):
                   per-host shared scans, cross-host gather, replica
                   failover
  ``balance``    — replica-aware load balancing (``HostLoadModel`` +
                   ``plan_split``): per-host EWMA cost model over
                   realized host-group wall times, greedy LPT shedding
                   from hot hosts onto live replicas, hysteresis
                   against flapping
  ``window``     — the batching frontend (``BatchWindow``): stream of
                   queries in, deadline/size-closed batches out
  ``controller`` — queueing-theory window autotuner
                   (``WindowController``) + ``Backpressure`` shedding
                   + the degradation-pressure state machine
  ``budget``     — error/latency budgets (``QueryBudget``) and the
                   SLO-driven rate planner (``RatePlanner``): inverts
                   the paper's variance model / fitted error curves to
                   pick the smallest per-query sampling rate meeting
                   each budget
  ``fleet``      — elastic membership (``FleetManager``): host
                   join/drain/crash as first-class, audited operations
                   over the placement layer
  ``qcache``     — the semantic query cache (``SemanticQueryCache``):
                   per-query plans + full results memoized under the
                   index's own LSH signatures, with TTL / generation /
                   LRU invalidation
  ``generation`` — the single generation authority (``Generation`` +
                   ``GenerationClock``): one typed (placement,
                   content) pair replacing the scattered integer
                   epochs; every placement swap and every content swap
                   in a stack mints through one shared clock
  ``chaos``      — deterministic fault injection (``FaultPlan``): a
                   seeded, scripted scenario DSL compiled onto the
                   executors' injection seams

The multi-host dataflow is placement -> balance -> executor: the
``PlacementMap`` bounds where a shard *may* run (primary + live ring
replicas — residency), the balancer picks where it *should* (cost-aware
split, failover as the infinitely-hot-host special case), and the
per-host ``ShardTaskExecutor`` fleet runs the groups, feeding realized
per-host wall times back into the balancer's cost model.  The gather
above is split-agnostic, so every flavor of split produces bit-for-bit
the single-executor results.

``BatchWindow`` takes either executor flavor behind its engine — a
single-host pool and a placement-split host group expose the same
``map_shard_batch`` surface.

The shared scan has two dispatch shapes.  Per-shard (the default for
arbitrary fns): ``run_shared_scan`` builds one composite task per
shard in the union plan and the executor schedules them across its
pool — retry, speculation, and chaos injection all at shard-task
granularity.  One-launch (the megakernel route): when every fn in the
batch comes from one ``kernels.megascan.MegascanSpec``, the composite
closure carries the spec and a megakernel-enabled executor routes the
WHOLE shard group as a single Pallas launch over the block-aligned
packed payload (``_run_group_scan``) — per-(query, shard) partials
come back in exactly the layout the gather already consumes,
bit-for-bit identical to the per-shard path, so everything above the
executor (placement split, balancing, chaos scripts, cache fencing)
is untouched.  On a ``HostGroupExecutor`` this becomes one launch per
host per job: the residency split happens first, then each host's
``ShardTaskExecutor`` fuses its own group.  Fault seams keep per-shard
granularity (hooks fire for every shard in the group before the
launch) while failure/retry is at-least-once at group width;
``megakernel=False`` on ``map_shard_batch`` pins the per-shard fused
path — the parity reference the serving bench's ``megascan`` record
hard-gates against.

With a cache attached the serving dataflow per query is cache ->
window -> executor: the engine probes the ``SemanticQueryCache``
*before* planning (an exact LSH-signature hit returns the memoized
result with zero scoring, zero rng draws, zero scans; a near-hit
borrows the cached sampling plan — unbiased for any full-support
distribution, Hansen-Hurwitz — and re-runs only the scan + reduce),
the window keeps cache-served queries out of the controller's batch
cost fit (``observe_batch(..., cached=n)``), and every cached entry is
fenced by the engine's composite ``Generation`` — the placement axis
(fleet swaps) AND the content axis (live ingest, ``attach_corpus``) —
so no entry survives either kind of world change.  Degraded,
pressured, and budgeted answers are never cached — a point-in-time
decision must not replay as full fidelity.  Cookbook:

    from repro.launch import build_serving_stack
    stack = build_serving_stack(corpus, index, cache=True,
                                cache_config=QueryCacheConfig(
                                    max_entries=512, ttl_s=30.0,
                                    hamming_radius=8))
    stack.engine.execute(queries, 0.25)       # misses populate
    stack.engine.execute(queries, 0.25)       # exact hits, no scans
    stack.cache.record()                      # hit/near/miss counters

(or hand-wire: ``QueryBatch(corpus, index, executor=...,
cache=SemanticQueryCache(...))``).  The serving bench's ``--zipf`` arm
hard-gates the contract: exact hits bit-for-bit equal to uncached
execution, zero hits across scripted join/drain swaps, and a cached
p50 strictly below the uncached one on the same skewed stream.

Under overload the controller drives *two actuators*, in order:

  1. **degrade** (accuracy): utilization past the saturation band — or
     the pending queue hitting its bound — ratchets the controller's
     ``pressure`` toward 1.0; the window forwards it to an
     accuracy-elastic engine (``QueryBatch`` + ``RatePlanner``), which
     slides every query's sampling rate from its budget-planned value
     toward its budget floor.  Capacity rises because batch service is
     ~linear in shards scanned; answers stay correct because every
     result carries its error bound at whatever rate was served.
  2. **shed** (availability): only once pressure sits at 1.0 — every
     pending query already at its floor — and the queue still
     stretches past twice its bound does ``submit`` raise
     ``Backpressure`` (now with a ``retry_after_s`` hint from the
     controller's plan).

Both directions are hysteretic (asymmetric enter/exit utilization
thresholds, mirroring ``balance``'s asymmetric band), and every
degradation decision lands in a ``BudgetAudit`` on
``last_job["budget"]`` the way balance decisions land on
``last_job["balance"]``.

Fleet lifecycle (``fleet``) rides the same dataflow.  Membership is a
*generation swap*: ``FleetManager`` builds the next ``PlacementMap``
off-line and installs it with ``set_placement`` — every job captures
the placement reference at job start (RCU-style), so in-flight jobs
finish on their old generation while the next job sees the new one,
and serving never pauses.  The three operations share one
residency-transfer path — a drain is a crash you saw coming:

  ``join``   warm first, serve second: every shard the joiner will own
             streams from its current holder (``warm_fn``), and only
             then does the generation swap; the joiner enters the
             ``HostLoadModel`` at the fleet median
  ``drain``  transfer residency to live replicas, then retire — zero
             queries shed, no CI widened (planned=True in the audit)
  ``crash``  retire first (in-flight jobs discover the loss through
             their fault hooks and requeue on replicas), then the same
             transfer with planned=False; shards with no live replica
             orphan and — under ``allow_partial`` — degrade queries to
             partial-sample estimates with widened CIs instead of
             failing (they revive if the slot rejoins)

Live ingest rides the same RCU discipline on a second axis.  The
lifecycle is ingest -> generation -> fence:

  1. **ingest** — ``launch.serve_stack.Ingestor.step(docs)`` builds
     the appended world off to the side: ``data.store``'s
     copy-on-write corpus append (postings deltas merge into any
     built CSR bit-for-bit with a rebuild), then
     ``core.index.refresh_appended`` (frozen-model PV-DBOW inference
     for the new docs — paced with result-neutral cooperative GIL
     yields, ``ingest_yield_s``, so serving threads never stall
     behind the writer — re-centroid/re-sign only the touched
     shards).
  2. **generation** — the new corpus/index refs publish first; only
     then does the stack's shared ``GenerationClock`` mint
     ``bump_content()``.  Readers capture (generation, refs) in that
     order at batch entry, so the one reachable race stamps a *fresh*
     answer with the *old* generation — immediately fenced, never the
     reverse.  An append that spills new shards extends the
     ``PlacementMap`` in place first (old shards keep their hosts;
     that mints ``bump_placement()`` through the same clock).
  3. **fence** — the next probe under the new generation lazily drops
     every entry stamped with the old one (``stats["stale_epoch"]``);
     in-flight batches finish on the refs they captured.  No lock on
     the read path, no serving pause.

Cookbook:

    stack = build_serving_stack(
        corpus, index, cache=True,
        ingest=True, ingest_model=model, ingest_pv_cfg=pv_cfg,
        ingest_source=my_feed)           # or None: drive step() by hand
    stack.ingestor.step(new_docs)        # append + swap, zero pause
    stack.generation                     # Generation(placement, content)
    stack.ingestor.record()              # steps/docs/swaps counters

The deprecated integer views (``stats["placement_epoch"]``, raw-int
qcache epochs) still read correctly — they are mirrors of the clock,
pinned by tests — but new code should mint and compare only through
``runtime.generation``.

Every scenario above is testable without wall-clock races via
``chaos``: a ``FaultPlan`` is a seeded script compiled onto the
executors' hooks, its clock the executor's own job counter.  Cookbook:

    plan = (FaultPlan(seed=7)
            .crash(1, at_job=3)           # host 1 dies at group job 3
            .slow(0, ms_per_shard=5)      # host 0 always degraded
            .flaky(2, error_rate=0.1,
                   jobs=range(4, 8))      # transient faults, jobs 4-7
            .stall(0, s=0.2, jobs=[5]))   # one long pause (deadlines)
    plan.install(host_group)              # or a bare ShardTaskExecutor
    ...
    plan.record()                         # scripted + fired, JSON-ready

Flaky decisions draw from a counter-based stream keyed on
``(seed, host, shard, job, attempt)`` — independent of thread
interleaving, identical across runs and machines; a retried shard
redraws and can deterministically recover.  The executor side holds up
its end with bounded-exponential retry backoff, per-job deadlines
(``job_deadline_s``), graceful partials (``allow_partial``), and a
job-epoch guard that drops zombie completions from abandoned jobs.
The serving bench's chaos arm replays kill -> degrade -> join ->
recover -> drain against all of this and hard-gates zero lost queries,
bit-for-bit gather parity, and post-join makespan recovery
(``benchmarks/serve_bench.py --chaos``).
"""
from repro.runtime.balance import (  # noqa: F401
    BalanceConfig,
    HostLoadModel,
    plan_split,
)
from repro.runtime.budget import (  # noqa: F401
    BudgetAudit,
    PlannerConfig,
    QueryBudget,
    RatePlanner,
)
from repro.runtime.controller import (  # noqa: F401
    Backpressure,
    ControllerConfig,
    WindowController,
    WindowPlan,
)
from repro.runtime.chaos import FaultPlan  # noqa: F401
from repro.runtime.executor import ShardTaskExecutor  # noqa: F401
from repro.runtime.fleet import FleetManager  # noqa: F401
from repro.runtime.generation import (  # noqa: F401
    Generation,
    GenerationClock,
)
from repro.runtime.placement import (  # noqa: F401
    HostFailure,
    HostGroupExecutor,
    PlacementMap,
)
from repro.runtime.qcache import (  # noqa: F401
    QueryCacheConfig,
    SemanticQueryCache,
)
from repro.runtime.window import BatchWindow  # noqa: F401
