"""The query-side serving runtime, bottom-up:

  ``executor``   — single-host fault-tolerant shard tasks (warm pool,
                   retry, straggler speculation, shared scans)
  ``placement``  — shard -> host residency (``PlacementMap``) and the
                   multi-host executor (``HostGroupExecutor``):
                   per-host shared scans, cross-host gather, replica
                   failover
  ``balance``    — replica-aware load balancing (``HostLoadModel`` +
                   ``plan_split``): per-host EWMA cost model over
                   realized host-group wall times, greedy LPT shedding
                   from hot hosts onto live replicas, hysteresis
                   against flapping
  ``window``     — the batching frontend (``BatchWindow``): stream of
                   queries in, deadline/size-closed batches out
  ``controller`` — queueing-theory window autotuner
                   (``WindowController``) + ``Backpressure`` shedding

The multi-host dataflow is placement -> balance -> executor: the
``PlacementMap`` bounds where a shard *may* run (primary + live ring
replicas — residency), the balancer picks where it *should* (cost-aware
split, failover as the infinitely-hot-host special case), and the
per-host ``ShardTaskExecutor`` fleet runs the groups, feeding realized
per-host wall times back into the balancer's cost model.  The gather
above is split-agnostic, so every flavor of split produces bit-for-bit
the single-executor results.

``BatchWindow`` takes either executor flavor behind its engine — a
single-host pool and a placement-split host group expose the same
``map_shard_batch`` surface.
"""
from repro.runtime.balance import (  # noqa: F401
    BalanceConfig,
    HostLoadModel,
    plan_split,
)
from repro.runtime.controller import (  # noqa: F401
    Backpressure,
    ControllerConfig,
    WindowController,
    WindowPlan,
)
from repro.runtime.executor import ShardTaskExecutor  # noqa: F401
from repro.runtime.placement import (  # noqa: F401
    HostFailure,
    HostGroupExecutor,
    PlacementMap,
)
from repro.runtime.window import BatchWindow  # noqa: F401
