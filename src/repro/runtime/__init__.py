from repro.runtime.executor import ShardTaskExecutor  # noqa: F401
from repro.runtime.window import BatchWindow  # noqa: F401
