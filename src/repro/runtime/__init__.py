from repro.runtime.executor import ShardTaskExecutor  # noqa: F401
