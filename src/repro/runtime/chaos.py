"""Deterministic, seeded fault injection: the ``FaultPlan`` DSL.

Fault lambdas used to be scattered one-off closures across tests and
``serve_bench`` — each hand-rolling its own "fail host 1 once" state.
A ``FaultPlan`` is the declarative replacement: a scripted scenario

    plan = (FaultPlan(seed=7)
            .crash(1, at_job=3)               # host 1 dies at job 3
            .slow(0, ms_per_shard=5)          # host 0 is always slow
            .flaky(2, error_rate=0.1,
                   jobs=range(4, 8))          # host 2 flakes jobs 4-7
            .stall(0, s=0.2, jobs=[5]))       # host 0 stalls job 5
    plan.install(executor)

that compiles onto the executor stack's injection seams:

  * ``HostGroupExecutor.job_hook`` — the plan's *clock*.  Faults are
    scheduled in group-job units ("at_job=3" = the executor's 4th
    ``map_shards``/``map_shard_batch``), so a scenario needs no wall
    clock and replays identically run over run.
  * ``HostGroupExecutor.host_fault_hook`` — host-granularity faults:
    ``crash`` raises for every job from ``at_job`` on (the host is
    dead until fleet membership says otherwise), ``stall`` sleeps
    before the host group runs (the delay lands in the host's wall
    telemetry, so the balancer *observes* the stall).
  * ``ShardTaskExecutor.task_hook`` — shard-task-granularity faults,
    installed per host: ``slow`` sleeps per shard visit, ``flaky``
    raises ``ChaosFault`` with the configured probability.

**Determinism**: a flaky decision is drawn from
``np.random.default_rng([seed, host, shard, job, attempt])`` — a
counter-based stream keyed on the fault's coordinates, never on a
shared mutable RNG — so outcomes are independent of worker-thread
interleaving and identical across runs, machines, and retries of the
*other* shards.  Retrying a flaked shard advances ``attempt`` and so
redraws; a retry can deterministically succeed.

Hosts that join after ``install`` (FleetManager.join) are hooked
automatically: the plan wraps ``ensure_host`` so a revived or new slot
gets its per-host task hook before it can serve.

``record()`` summarizes what actually fired (per-kind counters) for
the bench's chaos audit.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Union

import numpy as np

JobSpan = Optional[Union[int, range, list, tuple, set]]


class ChaosFault(RuntimeError):
    """A transient injected task fault (retries may clear it)."""


class ChaosCrash(RuntimeError):
    """An injected host death (persists until membership changes)."""


def _in_span(jobs: JobSpan, job: int) -> bool:
    if jobs is None:
        return True
    if isinstance(jobs, int):
        return job == jobs
    return job in jobs


class FaultPlan:
    """A seeded, scripted fault scenario.  Chainable builder; call
    ``install(executor)`` to compile it onto a ``HostGroupExecutor``
    (or a bare ``ShardTaskExecutor``, whose faults are read as
    host 0)."""

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        self._crashes: List[tuple] = []   # (host, at_job)
        self._slows: List[tuple] = []     # (host, ms_per_shard, jobs)
        self._flaky: List[tuple] = []     # (host, error_rate, jobs)
        self._stalls: List[tuple] = []    # (host, seconds, jobs)
        self._job = -1                    # advanced by the job hook
        self.fired: Dict[str, int] = {"crash": 0, "slow": 0,
                                      "flaky": 0, "stall": 0}

    # ------------------------------------------------------------------
    # DSL
    # ------------------------------------------------------------------
    def crash(self, host: int, at_job: int) -> "FaultPlan":
        """Host dies at group job ``at_job`` and stays dead (every
        later job's group on it raises ``ChaosCrash``) — pair with
        ``FleetManager.crash`` to take it out of rotation."""
        self._crashes.append((int(host), int(at_job)))
        return self

    def slow(self, host: int, ms_per_shard: float,
             jobs: JobSpan = None) -> "FaultPlan":
        """Every shard task on ``host`` sleeps ``ms_per_shard`` during
        ``jobs`` (None = always): a degraded host the balancer can
        observe."""
        self._slows.append((int(host), float(ms_per_shard), jobs))
        return self

    def flaky(self, host: int, error_rate: float,
              jobs: JobSpan = None) -> "FaultPlan":
        """Shard tasks on ``host`` raise ``ChaosFault`` with
        probability ``error_rate``, decided deterministically per
        (seed, host, shard, job, attempt)."""
        self._flaky.append((int(host), float(error_rate), jobs))
        return self

    def stall(self, host: int, s: float,
              jobs: JobSpan = None) -> "FaultPlan":
        """Host pauses ``s`` seconds before serving its group during
        ``jobs`` — long enough stalls trip per-job deadlines."""
        self._stalls.append((int(host), float(s), jobs))
        return self

    # ------------------------------------------------------------------
    # compiled hooks
    # ------------------------------------------------------------------
    def _advance(self, job: int) -> None:
        self._job = int(job)

    def _host_hook(self, host: int, shard_ids) -> None:
        job = self._job
        for h, at in self._crashes:
            if host == h and job >= at:
                self.fired["crash"] += 1
                raise ChaosCrash(
                    f"chaos: host {h} dead since job {at} (job {job})")
        for h, s, jobs in self._stalls:
            if host == h and _in_span(jobs, job):
                self.fired["stall"] += 1
                time.sleep(s)

    def _task_hook_for(self, host: int):
        def hook(sid: int, attempt: int, _local_job: int) -> None:
            job = self._job
            for h, ms, jobs in self._slows:
                if h == host and _in_span(jobs, job):
                    self.fired["slow"] += 1
                    time.sleep(ms / 1000.0)
            for h, rate, jobs in self._flaky:
                if h == host and _in_span(jobs, job):
                    draw = np.random.default_rng(
                        [self.seed, h, int(sid), job, int(attempt)]
                    ).random()
                    if draw < rate:
                        self.fired["flaky"] += 1
                        raise ChaosFault(
                            f"chaos: flaky host {h} shard {sid} "
                            f"job {job} attempt {attempt}")
        return hook

    # ------------------------------------------------------------------
    # installation
    # ------------------------------------------------------------------
    def install(self, executor: Any) -> "FaultPlan":
        """Compile the plan onto an executor's injection seams.  A
        ``HostGroupExecutor`` gets the job clock, the host hook, and a
        per-host task hook (late-joining hosts are hooked through
        ``ensure_host``); a bare ``ShardTaskExecutor`` gets its faults
        read as host 0, with ``crash`` at task granularity and
        ``stall`` on the job hook."""
        if hasattr(executor, "hosts"):            # HostGroupExecutor
            executor.job_hook = self._advance
            executor.host_fault_hook = self._host_hook
            for h, ex in executor.hosts.items():
                ex.task_hook = self._task_hook_for(int(h))
            orig_ensure = executor.ensure_host

            def ensure(host):
                ex = orig_ensure(host)
                ex.task_hook = self._task_hook_for(int(host))
                return ex

            executor.ensure_host = ensure
            return self

        # bare ShardTaskExecutor: host-0 faults, task granularity
        task_hook = self._task_hook_for(0)

        def bare_task_hook(sid: int, attempt: int, job: int) -> None:
            self._job = int(job)        # the executor's own job counter
            for h, at in self._crashes:
                if h == 0 and job >= at:
                    self.fired["crash"] += 1
                    raise ChaosCrash(
                        f"chaos: executor dead since job {at}")
            task_hook(sid, attempt, job)

        def bare_job_hook(job: int) -> None:
            self._job = int(job)
            for h, s, jobs in self._stalls:
                if h == 0 and _in_span(jobs, job):
                    self.fired["stall"] += 1
                    time.sleep(s)

        executor.task_hook = bare_task_hook
        executor.job_hook = bare_job_hook
        return self

    def record(self) -> dict:
        """JSON-ready audit: the scripted faults and what fired."""
        return dict(
            seed=self.seed,
            scripted=dict(
                crashes=[list(c) for c in self._crashes],
                slows=[[h, ms, _span_repr(j)]
                       for h, ms, j in self._slows],
                flaky=[[h, r, _span_repr(j)]
                       for h, r, j in self._flaky],
                stalls=[[h, s, _span_repr(j)]
                        for h, s, j in self._stalls]),
            fired=dict(self.fired))


def _span_repr(jobs: JobSpan):
    if jobs is None:
        return None
    if isinstance(jobs, int):
        return jobs
    return sorted(int(j) for j in jobs)
