"""Queueing-theory batch-window controller (serving-time autotuning).

``BatchWindow``'s static (deadline, max_batch) pair is wrong at both
ends of the load curve: at low traffic a lone query waits out the full
deadline for a batch that never fills, and at high traffic a too-small
window underfills the batched engine's amortization while a too-large
one lets the single dispatcher saturate with no signal to callers.
``WindowController`` closes the loop:

  * **Arrival model** — an EWMA over inter-arrival gaps gives the
    instantaneous arrival rate ``lambda``.  A second, slower EWMA of
    squared gap deviations gives a burstiness hint (diagnostic only).
  * **Service model** — per-batch observations ``(n, service_s)`` feed
    exponentially-weighted first/second moments from which a batch
    cost line ``s(n) = c0 + c1 * n`` is recovered (covariance over
    variance; the same running-moments trick as Welford, but with
    exponential forgetting so the model tracks warmup -> warm shifts).
    ``c0`` is the per-window overhead the batch amortizes (planning,
    dispatch, kernel launch), ``c1`` the marginal per-query cost.
    The cost model is *piecewise*: observations route into a small-n
    fit (``n < pivot_batch``) and a large-n fit, and the planner costs
    each candidate from the fit of the regime its predicted batch size
    falls in (``service_cost``), falling back to the pooled all-sizes
    line until a regime has data.  One pooled line systematically
    overestimates small windows — the shared scan's union coverage
    saturates with batch size, so the true s(n) is concave, and an
    intercept fitted mostly from large batches charges a 1-2 query
    window far more than it costs.  In the *transition* band (arrivals
    ~0.5-1.5x batched capacity) that inflated small-n cost made the
    planner flee to long deadlines the static 2 ms pair beat; the
    small-n fit restores honest pricing there.
  * **Plan** — on every batch completion (and at least every
    ``control_period_s``) the controller sweeps a small candidate grid
    (geometric deadlines x doubling batch sizes, both clamped to
    configured bounds) and picks the pair minimizing the estimated p99
    sojourn of a query under the current ``lambda``.  Each candidate is
    scored under the better of two regimes:

    arrival-fed (windows open on an empty queue and fill from fresh
    arrivals — the light/moderate-load regime):

        fill   = (B - 1) / lambda          time for a window to fill
        closes by size  if fill <= d  ->  n = B,           wait = fill
        closes by deadline otherwise  ->  n = 1 + lambda*d, wait = d

    queue-fed (a standing backlog stuffs every window to B the moment
    it opens — scored only when the arrival-fed regime is unstable,
    because that instability is precisely the condition under which a
    backlog forms; crediting queue-fed batching at light load would
    chase batches the queue can never supply):

        n = B, wait = min(d, fill)

    and in either regime:

        s      = c0 + c1 * n               batch service time
        rho    = lambda * s / n            dispatcher utilization
        queue  = rho / (1 - rho) * s / 2   M/G/1-flavored mean wait
        p99    ~= wait + TAIL_P99 * queue + s

    (``TAIL_P99``: tail factor mapping the mean queue wait to a p99
    estimate; see its definition for why it is lighter than the
    exponential ln(100).)
    ``rho >= 1`` in a regime marks it unstable (infinite sojourn); if
    *every* candidate is unstable in *both* regimes the plan pins
    (min deadline, max batch) — serve immediately, amortize maximally,
    the backlog does the batching — and reports saturation.

The qualitative behavior this buys (pinned by tests/test_controller.py):
under light load the chosen deadline collapses toward ``min_delay_s``
(a lone query's sojourn is ``d + s(1)``, so the optimizer shrinks
``d``); under heavy load the chosen batch grows toward ``max_batch``
(amortizing ``c0`` is the only way to keep ``rho < 1``).

**Backpressure** — the dispatcher saturating is a *caller's* problem
too: ``BatchWindow`` bounds its pending queue and sheds with the typed
``Backpressure`` signal once the bound is hit, so upstream load
balancers see a crisp, immediate reject instead of a silently growing
sojourn.  ``Backpressure`` carries the queue depth and the controller's
current utilization estimate for the caller's retry policy.

All entry points take an explicit ``now`` timestamp (defaulting to
``time.perf_counter()``) so tests drive synthetic clocks.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import List, Optional, Tuple

# Tail factor mapping the mean queue wait to a p99 estimate.  A pure
# exponential tail would give ln(100) ~ 4.6, but batch service here is
# near-deterministic (one shared scan over a similar shard union every
# window), so the M/D/1-flavored tail is far lighter; ln(10) keeps the
# ordering pressure of the tail without making moderate utilization
# look catastrophic (which drove the planner to long idle deadlines).
TAIL_P99 = math.log(10.0)


class Backpressure(RuntimeError):
    """The serving window's pending queue is at its bound: the query was
    shed, not enqueued.  Retry with jitter or divert to another replica.
    ``depth`` is the queue depth at rejection; ``utilization`` the
    controller's dispatcher-utilization estimate (>= 1.0 ~ saturated),
    or None when the window runs without a controller.
    ``retry_after_s`` is the controller's estimate of when capacity
    frees up — the current window deadline plus one full-batch service
    time — so a shed caller can back off for one serving cycle instead
    of hot-retrying into the same full queue (None without a
    controller)."""

    def __init__(self, depth: int, utilization: Optional[float] = None,
                 retry_after_s: Optional[float] = None):
        self.depth = depth
        self.utilization = utilization
        self.retry_after_s = retry_after_s
        util = (f", utilization ~{utilization:.2f}"
                if utilization is not None else "")
        retry = (f", retry after ~{retry_after_s * 1e3:.1f} ms"
                 if retry_after_s is not None else "")
        super().__init__(
            f"batch window pending queue full ({depth} queued{util}{retry})")


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Bounds and gains for ``WindowController``."""
    min_delay_s: float = 1e-4       # never close faster than dispatch cost
    max_delay_s: float = 0.02       # latency ceiling at any load
    min_batch: int = 1
    max_batch: int = 128
    control_period_s: float = 0.05  # re-plan cadence
    arrival_alpha: float = 0.1      # EWMA gain for inter-arrival gaps
    service_alpha: float = 0.2      # EWMA gain for batch-cost moments
    n_delay_candidates: int = 8     # geometric grid resolution
    pivot_batch: int = 8            # small-n / large-n regime boundary
    #                                 (1 collapses to one pooled fit)
    # degradation ladder (the second actuator): utilization above
    # ``degrade_enter_util`` ratchets pressure up by ``degrade_step``
    # per replan, utilization below ``degrade_exit_util`` ratchets it
    # down — the gap between the two thresholds is the hysteresis dead
    # band (mirroring balance.py's asymmetric band) so pressure does
    # not flap when load hovers at the threshold
    degrade_enter_util: float = 0.85
    degrade_exit_util: float = 0.6
    degrade_step: float = 0.25

    def __post_init__(self):
        if not (0 < self.min_delay_s <= self.max_delay_s):
            raise ValueError(
                f"need 0 < min_delay_s <= max_delay_s, got "
                f"{self.min_delay_s} / {self.max_delay_s}")
        if not (1 <= self.min_batch <= self.max_batch):
            raise ValueError(
                f"need 1 <= min_batch <= max_batch, got "
                f"{self.min_batch} / {self.max_batch}")
        for name in ("arrival_alpha", "service_alpha"):
            a = getattr(self, name)
            if not (0 < a <= 1):
                raise ValueError(f"{name} must be in (0, 1], got {a}")
        if self.pivot_batch < 1:
            raise ValueError(
                f"pivot_batch must be >= 1, got {self.pivot_batch}")
        if not (0.0 <= self.degrade_exit_util < self.degrade_enter_util):
            raise ValueError(
                f"need 0 <= degrade_exit_util < degrade_enter_util, got "
                f"{self.degrade_exit_util} / {self.degrade_enter_util}")
        if not (0.0 < self.degrade_step <= 1.0):
            raise ValueError(
                f"degrade_step must be in (0, 1], got {self.degrade_step}")


class _CostFit:
    """Exponentially-forgotten first/second moments of (n, service_s)
    observations for one batch-size regime, recoverable as a cost line
    (the covariance-over-variance fit ``service_model`` documents).
    ``seed`` pre-loads a benign prior (the pooled fit uses one so the
    first plan is sane before any batch completes); unseeded fits
    initialize from their first observation."""

    def __init__(self, alpha: float, seed_per_item_s: float,
                 seed: Optional[Tuple[float, float]] = None):
        self.alpha = float(alpha)
        self.seed_per_item = float(seed_per_item_s)
        self.count = 0
        self.m_n = self.m_s = self.m_nn = self.m_ns = 0.0
        if seed is not None:
            n, s = seed
            self.m_n, self.m_s = float(n), float(s)
            self.m_nn, self.m_ns = float(n * n), float(n * s)

    def observe(self, n: float, s: float) -> None:
        if self.count == 0 and self.m_nn == 0.0:
            self.m_n, self.m_s = n, s
            self.m_nn, self.m_ns = n * n, n * s
        else:
            a = self.alpha
            self.m_n += a * (n - self.m_n)
            self.m_s += a * (s - self.m_s)
            self.m_nn += a * (n * n - self.m_nn)
            self.m_ns += a * (n * s - self.m_ns)
        self.count += 1

    def line(self) -> Tuple[float, float]:
        """``(c0, c1)`` of ``s(n) = c0 + c1 * n`` over this regime's
        observations.  The covariance fit is only trusted once the
        observed batch sizes genuinely spread (var >= 0.25, i.e. more
        than jitter around one size): a fit over near-identical sizes
        amplifies service-time noise into wild marginal costs, and one
        bad transient ``c1`` is enough to misplan a long idle deadline
        straight into the sojourn tail.  Near-constant sizes instead
        split the mean cost with the seeded marginal estimate."""
        var_n = self.m_nn - self.m_n * self.m_n
        cov = self.m_ns - self.m_n * self.m_s
        if var_n >= 0.25 and cov > 0:
            c1 = min(cov / var_n, self.m_s / max(self.m_n, 1.0))
            return max(self.m_s - c1 * self.m_n, 0.0), c1
        c1 = min(self.seed_per_item, self.m_s / max(self.m_n, 1.0))
        return max(self.m_s - c1 * self.m_n, 0.0), c1


@dataclasses.dataclass(frozen=True)
class WindowPlan:
    """One control decision: the (deadline, batch) pair to serve with,
    plus the estimates that chose it (surfaced in stats/benchmarks)."""
    delay_s: float
    max_batch: int
    est_p99_s: float            # estimated p99 sojourn under the plan
    utilization: float          # rho at the chosen candidate
    arrival_rate: float         # lambda the plan was computed for
    saturated: bool             # every candidate had rho >= 1


class WindowController:
    """Picks (deadline, max_batch) minimizing estimated p99 sojourn.

    Not thread-safe by itself; ``BatchWindow`` serializes calls under
    its own condition lock (producers call ``observe_arrival`` /
    ``window_params`` while holding it, the dispatcher calls
    ``observe_batch``)."""

    def __init__(self, config: Optional[ControllerConfig] = None, *,
                 seed_service_s: float = 1e-3,
                 seed_per_item_s: float = 1e-4):
        self.config = config or ControllerConfig()
        self._last_arrival: Optional[float] = None
        self._mean_gap: Optional[float] = None   # EWMA inter-arrival gap
        self._gap_var: float = 0.0               # EWMA squared deviation
        # piecewise service model: every observation feeds the pooled
        # all-sizes fit (seeded with a benign 1-query prior so the first
        # plan is sane before any batch has completed) plus the fit of
        # its size regime; candidates are costed from their regime's fit
        # once it has data (see service_cost)
        a = self.config.service_alpha
        self._fit_all = _CostFit(a, seed_per_item_s,
                                 seed=(1.0, float(seed_service_s)))
        self._fit_small = _CostFit(a, seed_per_item_s)
        self._fit_large = _CostFit(a, seed_per_item_s)
        self._n_batches = 0
        self._scan_s: Optional[float] = None     # executor telemetry EWMA
        self._plan: Optional[WindowPlan] = None
        self._plan_at: float = -math.inf
        # degradation pressure in [0, 1]: the accuracy actuator's
        # position (0 = every query at its planned rate, 1 = every
        # query at its budget floor); ratcheted by plan() under the
        # asymmetric utilization band, escalated to 1.0 by the window
        # when the pending queue hits its bound
        self._pressure: float = 0.0

    # ------------------------------------------------------------------
    # observations
    # ------------------------------------------------------------------
    def observe_arrival(self, now: Optional[float] = None) -> None:
        """One query arrived at ``now``; update the arrival-rate EWMA."""
        now = time.perf_counter() if now is None else now
        if self._last_arrival is not None:
            gap = max(now - self._last_arrival, 1e-9)
            a = self.config.arrival_alpha
            if self._mean_gap is None:
                self._mean_gap = gap
            else:
                dev = gap - self._mean_gap
                self._mean_gap += a * dev
                self._gap_var += a * (dev * dev - self._gap_var)
        self._last_arrival = now

    def observe_batch(self, n: int, service_s: float,
                      scan_s: Optional[float] = None,
                      cached: int = 0) -> None:
        """One window of ``n`` queries took ``service_s`` to execute.
        ``scan_s`` is the executor's per-job service telemetry (the
        shared-scan share of the batch; see
        ``ShardTaskExecutor.last_job``) — tracked so saturation can be
        attributed to scan work vs engine overhead.

        ``cached`` is how many of the ``n`` were served straight from
        the semantic query cache (``runtime/qcache`` exact hits): they
        cost ~no service time, so they are excluded from the cost fit
        — folding them in would deflate the fitted per-query cost and
        make the planner promise capacity the uncached path cannot
        deliver.  An all-cached window is dropped entirely (near-hits
        still scan, so they count as executed)."""
        n = int(n) - int(cached)
        if n < 1 or service_s < 0:
            return
        a = self.config.service_alpha
        self._fit_all.observe(float(n), float(service_s))
        regime = (self._fit_small if n < self.config.pivot_batch
                  else self._fit_large)
        regime.observe(float(n), float(service_s))
        if scan_s is not None:
            self._scan_s = (scan_s if self._scan_s is None else
                            self._scan_s + a * (scan_s - self._scan_s))
        self._n_batches += 1
        # a fresh service observation invalidates the cached plan: one
        # batch against a cold (seeded) cost model can shift the
        # estimate by 10x, and replanning is 72 multiply-adds
        self._plan_at = -math.inf

    # ------------------------------------------------------------------
    # models
    # ------------------------------------------------------------------
    @property
    def arrival_rate(self) -> float:
        """Queries/sec (EWMA); 0.0 until two arrivals have been seen."""
        if self._mean_gap is None or self._mean_gap <= 0:
            return 0.0
        return 1.0 / self._mean_gap

    def service_model(self) -> Tuple[float, float]:
        """``(c0, c1)`` of the *pooled* (all sizes) batch cost line
        ``s(n) = c0 + c1 * n`` — the fallback the planner uses until a
        size regime has its own observations, and the stable summary
        surfaced in stats (see ``_CostFit.line`` for the fit guard)."""
        return self._fit_all.line()

    def service_cost(self, n: float) -> float:
        """Estimated batch service time ``s(n)`` under the piecewise
        cost model: the fit of ``n``'s own size regime (small-n below
        ``pivot_batch``, large-n at or above it) once that regime has
        seen at least two batches, else the pooled line.  Two
        observations, not one — a single batch is indistinguishable
        from noise, and the regime fit replaces the pooled line
        entirely for its half of the candidate grid."""
        fit = (self._fit_small if n < self.config.pivot_batch
               else self._fit_large)
        c0, c1 = fit.line() if fit.count >= 2 else self._fit_all.line()
        return c0 + c1 * n

    @property
    def scan_fraction(self) -> Optional[float]:
        """Share of batch service spent in the executor's shared scan
        (None until executor telemetry has been observed)."""
        if self._scan_s is None or self._fit_all.m_s <= 0:
            return None
        return min(self._scan_s / self._fit_all.m_s, 1.0)

    # ------------------------------------------------------------------
    # planning
    # ------------------------------------------------------------------
    def _regime_p99(self, lam: float, n: float,
                    wait: float) -> Tuple[float, float]:
        s = self.service_cost(n)
        rho = lam * s / max(n, 1.0)
        if rho >= 1.0:
            return math.inf, rho
        queue = rho / (1.0 - rho) * s / 2.0
        return wait + TAIL_P99 * queue + s, rho

    def _estimate_p99(self, lam: float, d: float,
                      batch: int) -> Tuple[float, float]:
        """(estimated p99 sojourn, utilization) for one candidate: the
        better of the arrival-fed and queue-fed regimes (see module
        docstring), costed by the piecewise model at the batch size the
        regime predicts."""
        if lam <= 0:
            # no traffic: a lone query waits the full deadline
            return d + self.service_cost(1.0), 0.0
        fill = (batch - 1) / lam
        if fill <= d:
            n, wait = float(batch), fill
        else:
            n, wait = min(1.0 + lam * d, float(batch)), d
        arrival = self._regime_p99(lam, n, wait)
        if not math.isinf(arrival[0]):
            return arrival
        # arrival-fed service can't keep up, so a backlog forms and
        # feeds full windows; the deadline only delays dispatch
        return self._regime_p99(lam, float(batch), min(d, fill))

    def _candidates(self) -> Tuple[List[float], List[int]]:
        cfg = self.config
        k = max(cfg.n_delay_candidates, 2)
        ratio = cfg.max_delay_s / cfg.min_delay_s
        delays = [cfg.min_delay_s * ratio ** (i / (k - 1)) for i in range(k)]
        batches, b = [], cfg.min_batch
        while b < cfg.max_batch:
            batches.append(b)
            b *= 2
        batches.append(cfg.max_batch)
        return delays, batches

    def plan(self, now: Optional[float] = None) -> WindowPlan:
        """Recompute the plan unconditionally (tests and ``window_params``
        call this; serving code wants ``window_params``)."""
        now = time.perf_counter() if now is None else now
        lam = self.arrival_rate
        delays, batches = self._candidates()
        best: Optional[Tuple[float, float, float, int]] = None
        for d in delays:
            for b in batches:
                p99, rho = self._estimate_p99(lam, d, b)
                key = (p99, d, b)
                if best is None or key < (best[0], best[2], best[3]):
                    best = (p99, rho, d, b)
        p99, rho, d, b = best
        saturated = math.isinf(p99)
        if saturated:
            # No stable candidate: under overload the backlog itself
            # forms the batches (a full queue size-closes the window
            # instantly), so waiting out a long deadline only adds
            # latency — serve immediately with the largest batch and
            # let backpressure shed the excess.
            d, b = self.config.min_delay_s, self.config.max_batch
            _, rho = self._estimate_p99(lam, d, b)
        self._plan = WindowPlan(d, b, p99, rho, lam, saturated)
        self._plan_at = now
        # degradation ladder: ratchet pressure inside the asymmetric
        # utilization band (enter high, exit low — the dead band
        # between them is hysteresis against flapping, as in
        # balance.py).  Saturation counts as over-threshold even when
        # rho at the pinned fallback plan reads < 1.
        cfg = self.config
        if saturated or rho >= cfg.degrade_enter_util:
            self._pressure = min(1.0, self._pressure + cfg.degrade_step)
        elif rho <= cfg.degrade_exit_util:
            self._pressure = max(0.0, self._pressure - cfg.degrade_step)
        return self._plan

    def window_params(self, now: Optional[float] = None
                      ) -> Tuple[float, int]:
        """(max_delay_s, max_batch) to serve the next window with;
        replans at most every ``control_period_s``."""
        now = time.perf_counter() if now is None else now
        if (self._plan is None
                or now - self._plan_at >= self.config.control_period_s):
            self.plan(now)
        return self._plan.delay_s, self._plan.max_batch

    @property
    def current_plan(self) -> Optional[WindowPlan]:
        return self._plan

    @property
    def utilization(self) -> Optional[float]:
        return self._plan.utilization if self._plan is not None else None

    # ------------------------------------------------------------------
    # degradation (the accuracy actuator)
    # ------------------------------------------------------------------
    @property
    def pressure(self) -> float:
        """Current degradation pressure in [0, 1]; the batch engine's
        planner maps it linearly onto each query's rate-vs-floor span
        (``runtime.budget.RatePlanner.plan_batch``)."""
        return self._pressure

    def escalate_pressure(self) -> float:
        """Jump pressure to 1.0 (every query straight to its budget
        floor).  Called by ``BatchWindow`` the moment the pending
        queue hits its bound: the queue filling up is a harder signal
        than any utilization estimate, and the ladder must exhaust the
        accuracy actuator *before* the availability one (shedding)."""
        self._pressure = 1.0
        return self._pressure

    def retry_after_s(self) -> Optional[float]:
        """Estimated time until the dispatcher can absorb new work: the
        current window deadline plus one full-batch service time (one
        serving cycle).  Attached to ``Backpressure`` so shed callers
        back off for a cycle instead of hot-retrying; None before the
        first plan exists."""
        if self._plan is None:
            return None
        return self._plan.delay_s + self.service_cost(
            float(self._plan.max_batch))
