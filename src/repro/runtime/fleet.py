"""Elastic fleet membership: join / drain / crash as first-class,
audited operations over the placement layer.

``PlacementMap`` is frozen — correct for a single topology, but a
production fleet grows, shrinks, and loses hosts while serving.  The
``FleetManager`` makes membership a *generation swap* rather than a
restart: it builds the next ``PlacementMap`` off-line and installs it
with ``HostGroupExecutor.set_placement`` (RCU-style — every job
captures the placement reference at job start, so in-flight jobs
finish on their old generation while the next job sees the new one;
serving never pauses).

The three operations share one residency-transfer path, extending
PR 5's unification ("a dead host is an infinitely-hot host") to
membership: **a drain is a crash you saw coming.**

``join(host)`` — grow the fleet (or revive a down slot).  The joiner
gets an executor slot immediately but *no residency*: first every
shard it will own is warmed — payload streamed from the host that
currently holds it (``warm_fn(shard_id, source_host, dest_host)``, the
injection point for simulated transfer time) — and only then is the
new generation installed, so a query never routes to a cold host.
Shards are stolen one at a time from the currently most-loaded live
host down to an even share, and the joiner enters the
``HostLoadModel`` with no telemetry, which prices it at the fleet
median (neither feared nor favored) until its own walls arrive.

``drain(host)`` — planned departure.  Residency moves to each shard's
first live replica *before* the host leaves rotation
(``_transfer_residency(..., planned=True)``); replicas already hold
the payload, so the handoff is metadata-only.  In-flight jobs finish
on their captured generation (the drained host's executor object stays
alive until ``close()``), so a drain sheds zero queries and never
widens a CI.

``crash(host)`` — the same transfer, ``planned=False``, in the
opposite order: the host leaves rotation *first* (it is gone now —
in-flight jobs discover the loss through their fault hooks and requeue
on replicas), then residency transfers.  A shard whose replicas are
all down keeps its dead primary and *orphans* at split time — with
``allow_partial`` the query layer degrades to a partial-sample
estimate with a widened CI instead of failing (see
``core/queries/batch.py``).  If the slot later rejoins, those shards
come back with it.

Every operation appends an audit event (op, host, ``planned``, shards
moved/warmed/orphaned, resulting placement epoch) to ``events`` —
same pattern as ``BalanceAudit`` / ``BudgetAudit`` — and the serving
bench's chaos arm replays a scripted crash → degrade → join → recover
scenario against these records (``benchmarks/serve_bench.py``).
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.runtime.placement import HostGroupExecutor, PlacementMap


class FleetManager:
    """Join/drain/crash over a ``HostGroupExecutor``'s placement,
    load model, and per-host executor fleet."""

    def __init__(
        self,
        executor: HostGroupExecutor,
        *,
        warm_fn: Optional[Callable[[int, int, int], None]] = None,
    ):
        self.executor = executor
        # warm_fn(shard_id, source_host, dest_host): called once per
        # shard a joiner must fetch, before residency is granted —
        # simulated payload streaming (a sleep models transfer time)
        self.warm_fn = warm_fn
        self.events: List[dict] = []

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    @property
    def placement(self) -> PlacementMap:
        return self.executor.placement

    def live_hosts(self) -> List[int]:
        pm = self.executor.placement
        return [h for h in range(pm.n_hosts)
                if h not in self.executor.down]

    def record(self) -> dict:
        """JSON-ready audit summary of every membership event."""
        ops = [e["op"] for e in self.events]
        return dict(
            events=list(self.events),
            joins=ops.count("join"),
            drains=ops.count("drain"),
            crashes=ops.count("crash"),
            live_hosts=self.live_hosts(),
            # read through the one generation authority (the stats key
            # of the same name is the deprecated mirrored view)
            placement_epoch=int(
                self.executor.clock.current().placement),
            generation=self.executor.clock.current().record(),
        )

    # ------------------------------------------------------------------
    # the one residency-transfer path (drain == planned crash)
    # ------------------------------------------------------------------
    def _transfer_residency(
            self, host: int) -> Tuple[PlacementMap, List[int], List[int]]:
        """Move every shard primaried on ``host`` to its first live
        replica.  Returns (new placement, moved shard ids, orphaned
        shard ids) — an orphan has no live replica and keeps its dead
        primary, so it degrades at split time (and revives if the slot
        rejoins)."""
        ex = self.executor
        pm = ex.placement
        h = int(host)
        down = set(ex.down) | {h}
        primary = pm.primary.copy()
        moved: List[int] = []
        orphaned: List[int] = []
        for sid in np.nonzero(primary == h)[0]:
            for r in pm.replicas[sid]:
                if int(r) not in down:
                    primary[sid] = int(r)
                    moved.append(int(sid))
                    break
            else:
                orphaned.append(int(sid))
        new_pm = PlacementMap._with_ring_replicas(
            primary, pm.n_hosts, pm.n_replicas)
        return new_pm, moved, orphaned

    def _audit(self, op: str, host: int, *, planned: bool,
               moved: int, warmed: int = 0, orphaned: int = 0) -> dict:
        ev = dict(op=op, host=int(host), planned=bool(planned),
                  moved_shards=int(moved), warmed_shards=int(warmed),
                  orphaned_shards=int(orphaned),
                  placement_epoch=int(
                      self.executor.clock.current().placement),
                  live_hosts=len(self.live_hosts()))
        self.events.append(ev)
        return ev

    # ------------------------------------------------------------------
    # operations
    # ------------------------------------------------------------------
    def join(self, host: Optional[int] = None) -> dict:
        """Add a host (default: revive the lowest down slot, else grow
        the fleet by one id).  Warm-up precedes residency: every shard
        the joiner will own streams from the host that currently holds
        it, and only once all transfers complete does the placement
        generation swap — a query is never routed to a cold host."""
        ex = self.executor
        pm = ex.placement
        if host is None:
            h = min(ex.down) if ex.down else pm.n_hosts
        else:
            h = int(host)
        n_hosts = max(pm.n_hosts, h + 1)
        ex.ensure_host(h)                 # slot + revival, no residency
        primary = pm.primary.copy()
        live = [x for x in range(n_hosts) if x not in ex.down or x == h]
        counts = {x: int((primary == x).sum()) for x in live}
        target = len(primary) // max(1, len(live))
        warmed: List[int] = []
        while counts.get(h, 0) < target:
            donor = max((x for x in live if x != h),
                        key=lambda x: (counts[x], x), default=None)
            if donor is None or counts[donor] <= counts.get(h, 0) + 1:
                break                     # already as even as it gets
            donor_shards = np.nonzero(primary == donor)[0]
            sid = int(donor_shards[-1])
            if self.warm_fn is not None:
                self.warm_fn(sid, donor, h)
            primary[sid] = h
            counts[donor] -= 1
            counts[h] = counts.get(h, 0) + 1
            warmed.append(sid)
        new_pm = PlacementMap._with_ring_replicas(
            primary, n_hosts, pm.n_replicas)
        ex.set_placement(new_pm)          # residency granted: warm now
        return self._audit("join", h, planned=True,
                           moved=len(warmed), warmed=len(warmed))

    def drain(self, host: int) -> dict:
        """Planned departure: hand residency to live replicas, *then*
        leave rotation.  In-flight jobs finish on their captured
        generation; zero queries shed, no CI widened."""
        ex = self.executor
        new_pm, moved, orphaned = self._transfer_residency(host)
        ex.set_placement(new_pm)
        ex.retire_host(host)
        if ex.balancer is not None:
            ex.balancer.forget_host(host)
        return self._audit("drain", host, planned=True,
                           moved=len(moved), orphaned=len(orphaned))

    def crash(self, host: int) -> dict:
        """Unplanned loss: the host leaves rotation *first* (in-flight
        jobs discover it through their fault hooks and requeue), then
        the same residency transfer runs with ``planned=False``."""
        ex = self.executor
        ex.retire_host(host)
        new_pm, moved, orphaned = self._transfer_residency(host)
        ex.set_placement(new_pm)
        if ex.balancer is not None:
            ex.balancer.forget_host(host)
        return self._audit("crash", host, planned=False,
                           moved=len(moved), orphaned=len(orphaned))
