"""Semantic query cache keyed on the index's own LSH signatures.

Real query traffic is power-law: a small set of hot and near-duplicate
queries dominates.  The serving stack already embeds every query into
the PV-DBOW space and signs it (``core/lsh.py``) — so the cache key is
free: ``SemanticQueryCache`` memoizes per-query execution state under
the packed SimHash signature of the query's composed scoring vector.

Three outcomes per probe:

  **hit**   — same signature, same query key (kind + words/expr + k),
              same effective sampling rate, same generation, not
              expired.  The engine returns the memoized full result
              (estimate + CI included) with zero scoring, zero rng
              draws, and zero shard scans — the p50 collapse under
              skewed traffic.  The memoized shard-similarity
              distribution and sampled plan ride on the entry for
              callers that want them.
  **near**  — a *different* query whose signature lies within
              ``hamming_radius`` bits of a cached entry of the same
              sampler class ("hh" with-replacement for counts,
              "distinct" for boolean/ranked) at the same rate.  The
              engine reuses the cached shard *plan* — the draws
              together with the probabilities that produced them — and
              re-runs the cheap scan + reduce with the new query's
              per-shard operator.  Unbiasedness survives because the
              Hansen-Hurwitz estimator is unbiased for *any* sampling
              distribution with full support: E[sum tau_s/phi_s] = tau
              regardless of which query's similarities shaped phi.
              The borrowed plan is merely (slightly) higher-variance
              for the new query, never wrong on average.
  **miss**  — the engine plans/samples/executes normally (bit-for-bit
              identical to an uncached engine) and populates the cache
              afterwards.

Invalidation is layered:

  * **generation** — every entry records the engine's
    ``runtime.generation.Generation`` at insert: the *placement* axis
    (``FleetManager`` join/drain/crash all install a new placement
    RCU-style) AND the *content* axis (live ingest swaps / a corpus
    ``attach_corpus``).  A probe under any other generation drops the
    entry lazily (counted in ``stats["stale_epoch"]``) — a cached plan
    from the old fleet can never serve the new one, and a cached
    *estimate* computed over the old corpus can never answer a query
    over the new one.  The cache itself only ever compares epochs for
    equality, so the deprecated raw-int probe (pre-generation callers
    passing ``stats["placement_epoch"]``) keeps working verbatim —
    but it cannot see content changes; that gap was the PR-10 bugfix.
  * **TTL** — wall-clock expiry per entry (``ttl_s``).
  * **LRU** — ``max_entries`` bound, least-recently-used evicted.

What is *never* cached (fidelity fencing, enforced by the engine):
degraded results (``lost_shards > 0``), anything executed under
degradation pressure, and budget-carrying queries whose planned rates
are point-in-time decisions — a budgeted answer must never be replayed
as a full-fidelity one.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.lsh import packed_hamming_np

# sampler compatibility classes: aggregation draws a with-replacement
# multiset (Hansen-Hurwitz needs it), retrieval draws distinct shards
# (Efraimidis-Sampford-style) — a plan is only reusable within its class
_SAMPLER_CLASS = {"count": "hh", "bool": "distinct", "ranked": "distinct"}


def sampler_class(kind: str) -> str:
    """"hh" | "distinct" — which plans are statistically interchangeable."""
    return _SAMPLER_CLASS[kind]


def query_key(q) -> Tuple:
    """Hashable canonical identity of a ``BatchQuery`` — what must match
    *exactly* (beyond the signature) for a memoized result to be the
    answer to this query."""
    if q.kind == "count":
        return ("count", q.phrase)
    if q.kind == "ranked":
        return ("ranked", q.words, int(q.k))
    return ("bool", _expr_key(q.expr))


def _expr_key(e) -> Tuple:
    if e.op == "word":
        return ("w", int(e.word))
    return (e.op, _expr_key(e.left), _expr_key(e.right))


def query_cache_vectors(index, queries) -> np.ndarray:
    """[B, dim] key vectors for a mixed batch: the composed scoring
    vector for count/ranked queries; for Boolean queries the sum of the
    expression's distinct word vectors (the expression *structure*
    rides in the exact-match key — the vector only drives similarity)."""
    vecs = []
    for q in queries:
        if q.kind == "bool":
            words = sorted(set(q.expr.words()))
            vecs.append(index.word_vecs[np.asarray(words, np.int64)]
                        .sum(axis=0))
        else:
            vecs.append(index.query_vector(q.word_ids()))
    return np.stack(vecs)


@dataclasses.dataclass(frozen=True)
class QueryCacheConfig:
    """Knobs for ``SemanticQueryCache``.

    ``hamming_radius`` is in signature bits: 0 restricts plan reuse to
    signature-identical queries; the default trades a little estimator
    variance for plan reuse across near-duplicates (at 128-bit
    signatures, 8 bits ~ cos(pi*8/128) ~ 0.98 cosine similarity)."""
    max_entries: int = 256
    ttl_s: float = 30.0
    hamming_radius: int = 8

    def __post_init__(self):
        if self.max_entries < 1:
            raise ValueError(f"max_entries must be >= 1: {self.max_entries}")
        if self.ttl_s <= 0:
            raise ValueError(f"ttl_s must be > 0: {self.ttl_s}")
        if self.hamming_radius < 0:
            raise ValueError(
                f"hamming_radius must be >= 0: {self.hamming_radius}")


class _Entry:
    __slots__ = ("key", "sig", "sampler", "rate", "probs", "sample",
                 "plan", "result", "epoch", "born")

    def __init__(self, key, sig, sampler, rate, probs, sample, plan,
                 result, epoch, born):
        self.key = key          # exact-probe key (sig bytes, qkey, rate)
        self.sig = sig          # [W] packed uint32 signature
        self.sampler = sampler  # "hh" | "distinct"
        self.rate = rate
        self.probs = probs      # shard-similarity distribution (or None)
        self.sample = sample    # core.sampling.SampleResult (the plan)
        self.plan = plan        # distinct sampled shard ids [k]
        self.result = result    # full memoized result (estimate + CI)
        self.epoch = epoch      # Generation (or deprecated int) at insert
        self.born = born


class SemanticQueryCache:
    """LSH-signature-keyed memo of (plan, distribution, result) per
    query, with TTL + generation invalidation and an LRU bound.

    Not thread-safe by design: the engine probes and populates it from
    within ``QueryBatch.execute``, which the ``BatchWindow`` dispatcher
    already serializes.  ``clock`` is injectable for deterministic TTL
    tests."""

    def __init__(self, config: Optional[QueryCacheConfig] = None, *,
                 clock=time.monotonic):
        self.config = config or QueryCacheConfig()
        self._clock = clock
        self._entries: "OrderedDict[Tuple, _Entry]" = OrderedDict()
        self.stats: Dict[str, int] = dict(
            hits=0, near_hits=0, misses=0, bypassed=0,
            insertions=0, evictions=0, expired=0, stale_epoch=0)

    def __len__(self) -> int:
        return len(self._entries)

    # ------------------------------------------------------------------
    # probe
    # ------------------------------------------------------------------
    def _valid(self, e: _Entry, epoch, now: float) -> bool:
        """Drop-on-probe validation; counts the reason.  ``epoch`` is a
        ``Generation`` (equality compares both axes) or a deprecated
        raw int — the cache only needs ``!=``."""
        if e.epoch != epoch:
            del self._entries[e.key]
            self.stats["stale_epoch"] += 1
            return False
        if now - e.born > self.config.ttl_s:
            del self._entries[e.key]
            self.stats["expired"] += 1
            return False
        return True

    def lookup(self, sig: np.ndarray, qkey: Tuple, sampler: str,
               rate: float, epoch) -> Tuple[str, Optional[_Entry]]:
        """("hit" | "near" | "miss", entry-or-None) for one query.

        ``epoch`` is the probing engine's ``Generation`` (or a
        deprecated raw placement int, still accepted)."""
        now = self._clock()
        key = (sig.tobytes(), qkey, float(rate))
        e = self._entries.get(key)
        if e is not None and self._valid(e, epoch, now):
            self._entries.move_to_end(key)
            self.stats["hits"] += 1
            return "hit", e
        # near probe: nearest valid same-class same-rate entry within
        # the Hamming radius (a vectorized scan — the LRU bound keeps
        # the candidate set small)
        cands = [c for c in list(self._entries.values())
                 if c.sampler == sampler and c.rate == float(rate)
                 and self._valid(c, epoch, now)]
        if cands:
            d = packed_hamming_np(sig, np.stack([c.sig for c in cands]))[0]
            best = int(np.argmin(d))
            if int(d[best]) <= self.config.hamming_radius:
                e = cands[best]
                self._entries.move_to_end(e.key)
                self.stats["near_hits"] += 1
                return "near", e
        self.stats["misses"] += 1
        return "miss", None

    # ------------------------------------------------------------------
    # populate
    # ------------------------------------------------------------------
    def insert(self, sig: np.ndarray, qkey: Tuple, sampler: str,
               rate: float, *, probs: Optional[np.ndarray], sample,
               plan: np.ndarray, result: Any, epoch) -> None:
        key = (sig.tobytes(), qkey, float(rate))
        # the epoch is stored as handed in (Generation or deprecated
        # int) — validation is pure equality, so no coercion is needed
        # and int-era callers keep their exact semantics
        self._entries[key] = _Entry(
            key, np.asarray(sig, np.uint32), sampler, float(rate),
            probs, sample, plan, result, epoch, self._clock())
        self._entries.move_to_end(key)
        self.stats["insertions"] += 1
        while len(self._entries) > self.config.max_entries:
            self._entries.popitem(last=False)
            self.stats["evictions"] += 1

    # ------------------------------------------------------------------
    # maintenance / introspection
    # ------------------------------------------------------------------
    def purge(self, epoch=None) -> int:
        """Eagerly drop expired (and, given ``epoch``, stale) entries;
        returns how many were dropped."""
        now = self._clock()
        dropped = 0
        for e in list(self._entries.values()):
            if e.key not in self._entries:
                continue
            if epoch is not None and e.epoch != epoch:
                del self._entries[e.key]
                self.stats["stale_epoch"] += 1
                dropped += 1
            elif now - e.born > self.config.ttl_s:
                del self._entries[e.key]
                self.stats["expired"] += 1
                dropped += 1
        return dropped

    def clear(self) -> None:
        self._entries.clear()

    def entries(self) -> List[_Entry]:
        """Snapshot of live entries, LRU-oldest first (for tests)."""
        return list(self._entries.values())

    def record(self) -> Dict[str, Any]:
        """JSON-ready counters + configuration snapshot."""
        return dict(
            size=len(self._entries),
            max_entries=int(self.config.max_entries),
            ttl_s=float(self.config.ttl_s),
            hamming_radius=int(self.config.hamming_radius),
            **{k: int(v) for k, v in self.stats.items()})
