"""Error/latency budgets and SLO-driven sampling-rate planning.

The paper's contribution is an accuracy<->speed dial (the sampling
``rate``), but through PR 5 the dial was static config: every query in
a batch ran at the same rate, and the only overload response was to
refuse work (``Backpressure``).  This module turns the dial into the
runtime's *second actuator*:

``QueryBudget`` — what a request is allowed to cost, in either
currency.  An *error* budget ("±5% relative at 95% confidence") asks
for the smallest rate whose estimated error bound fits; a *latency*
budget ("p99 <= 50 ms, best accuracy that fits") asks for the largest
rate whose estimated sojourn fits.  ``floor_rate`` is the degradation
floor: under overload the planner may squeeze the query down to — but
never below — this rate.

``RatePlanner`` — inverts two models to pick per-query rates:

  * For aggregation the paper's own variance model (Eq 2) is
    closed-form invertible: the relative half-width at ``n`` sampled
    shards is ``e(n) ~= t_{n-1,conf} * s_rel / sqrt(n)`` for a
    workload-dependent dispersion scale ``s_rel``.  ``_ErrCurve``
    learns ``s_rel`` online (EWMA over realized ``e * sqrt(n) / t``
    from every served estimate) and ``required_n`` scans the monotone
    curve for the smallest ``n`` meeting the target.  Boolean and
    ranked queries get the same curve *shape* fitted to their own
    realized errors (bootstrap CI width, 1 - top-k stability) — no
    closed form exists, but the 1/sqrt(n) decay is the right family
    and the EWMA keeps it honest.
  * For latency the controller's cost model prices the work:
    ``WindowController.service_cost`` gives batch service time at the
    current plan, and scan work scales ~linearly with rate, so the
    estimated p99 at rate ``r`` is the plan's ``est_p99_s`` scaled by
    ``r / ref_rate`` (``ref_rate`` = EWMA of recently served rates).

``plan_batch`` applies the *degradation ladder* on top: given the
controller's pressure ``d`` in [0, 1], each query's planned rate slides
linearly from its plan (d=0) toward its floor (d=1), so overload
degrades accuracy before it degrades availability.  The decision is
recorded in a ``BudgetAudit`` (mirroring ``balance.BalanceAudit``) that
lands on ``last_job["budget"]`` with planned-vs-realized error so the
serving bench can check the planner's calibration run over run.

Layering: this module sits beside ``controller`` (it *reads* the
controller's models, never drives it) and below ``core.queries.batch``
(the batch engine imports the planner; nothing here imports core).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.utils.stats import t_critical_value


@dataclasses.dataclass(frozen=True)
class QueryBudget:
    """What one query is allowed to cost.

    At least one of ``max_rel_error`` (error budget: smallest rate
    whose estimated relative error bound fits, at ``confidence``) and
    ``max_latency_s`` (latency budget: largest rate whose estimated
    p99 sojourn fits) must be set; with both, the error budget asks
    for a rate and the latency budget caps it.  ``floor_rate`` bounds
    graceful degradation — overload may squeeze the query to the
    floor, never below it."""

    max_rel_error: Optional[float] = None
    confidence: float = 0.95
    max_latency_s: Optional[float] = None
    floor_rate: float = 0.05

    def __post_init__(self):
        if self.max_rel_error is None and self.max_latency_s is None:
            raise ValueError(
                "QueryBudget needs max_rel_error and/or max_latency_s")
        if self.max_rel_error is not None and self.max_rel_error <= 0:
            raise ValueError(
                f"max_rel_error must be > 0, got {self.max_rel_error}")
        if self.max_latency_s is not None and self.max_latency_s <= 0:
            raise ValueError(
                f"max_latency_s must be > 0, got {self.max_latency_s}")
        if not 0.0 < self.confidence < 1.0:
            raise ValueError(
                f"confidence must be in (0, 1), got {self.confidence}")
        if not 0.0 < self.floor_rate <= 1.0:
            raise ValueError(
                f"floor_rate must be in (0, 1], got {self.floor_rate}")


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    """Knobs of ``RatePlanner``.

    ``default_floor_rate`` is the degradation floor for queries that
    carry no budget of their own; ``curve_alpha`` the EWMA gain for the
    per-kind error curves; ``seed_rel_scale`` the dispersion scale
    assumed before any estimate has been observed (1.0 = per-draw
    relative spread about equal to the mean — deliberately pessimistic,
    so cold planning over-samples rather than blowing budgets)."""

    default_floor_rate: float = 0.1
    curve_alpha: float = 0.3
    seed_rel_scale: float = 1.0

    def __post_init__(self):
        if not 0.0 < self.default_floor_rate <= 1.0:
            raise ValueError(f"default_floor_rate must be in (0, 1], got "
                             f"{self.default_floor_rate}")
        if not 0.0 < self.curve_alpha <= 1.0:
            raise ValueError(f"curve_alpha must be in (0, 1], got "
                             f"{self.curve_alpha}")
        if self.seed_rel_scale <= 0:
            raise ValueError(f"seed_rel_scale must be > 0, got "
                             f"{self.seed_rel_scale}")


class _ErrCurve:
    """The invertible error model ``e(n) = t_{n-1,conf} * s_rel /
    sqrt(n)`` for one query kind, with ``s_rel`` learned online.

    Every served estimate yields one observation ``s_rel_obs =
    e * sqrt(n) / t_{n-1}`` (solving the model for the scale), folded
    in with exponential forgetting.  ``required_n`` inverts: ``e(n)``
    is monotone decreasing in ``n`` (t falls, sqrt grows), so a linear
    scan finds the smallest sample size meeting a target."""

    def __init__(self, alpha: float, seed_rel_scale: float):
        self.alpha = float(alpha)
        self.seed = float(seed_rel_scale)
        self.s_rel: Optional[float] = None
        self.count = 0

    def observe(self, n: int, rel_error: float,
                confidence: float = 0.95) -> None:
        """Fold one realized (sample size, relative error) pair in.
        Degenerate observations (n < 2: no variance estimate; infinite
        or zero error: no scale information) are skipped."""
        if n < 2 or not math.isfinite(rel_error) or rel_error <= 0:
            return
        obs = rel_error * math.sqrt(n) / t_critical_value(n - 1, confidence)
        self.s_rel = obs if self.s_rel is None else (
            self.s_rel + self.alpha * (obs - self.s_rel))
        self.count += 1

    def scale(self) -> float:
        return self.s_rel if self.s_rel is not None else self.seed

    def predict(self, n: int, confidence: float = 0.95) -> float:
        """Estimated relative error bound at ``n`` sampled shards."""
        if n < 2:
            return float("inf")
        return t_critical_value(n - 1, confidence) * self.scale() / math.sqrt(n)

    def required_n(self, target_rel_error: float, confidence: float,
                   n_max: int) -> int:
        """Smallest ``n <= n_max`` with ``predict(n) <= target``;
        ``n_max`` (a census) when no sample size fits."""
        for n in range(2, max(n_max, 2) + 1):
            if self.predict(n, confidence) <= target_rel_error:
                return n
        return max(n_max, 2)


@dataclasses.dataclass
class BudgetAudit:
    """What the planner decided for one batch and why — the budget
    analogue of ``balance.BalanceAudit``, attached to
    ``last_job["budget"]`` so serving telemetry can compare the
    planner's predicted error against what the estimators actually
    reported."""

    base_rate: float                     # the caller's nominal rate
    pressure: float                      # controller degradation in [0,1]
    kinds: List[str]                     # per query
    planned_rates: List[float]           # after budgets + degradation
    undegraded_rates: List[float]        # budgets only (pressure = 0)
    floors: List[float]                  # per-query degradation floor
    budgeted: int                        # queries carrying a QueryBudget
    est_rel_error: List[Optional[float]]      # planner's prediction
    realized_rel_error: List[Optional[float]] = dataclasses.field(
        default_factory=list)            # filled after execution
    # filled after execution when the gather came back partial (hosts
    # lost with no live replica): queries whose reduce ran over a
    # smaller surviving sample, and the total shards they lost
    partial_queries: int = 0
    lost_shards: int = 0

    @property
    def degraded(self) -> int:
        """Queries served below their undegraded plan."""
        return sum(1 for p, u in zip(self.planned_rates,
                                     self.undegraded_rates)
                   if p < u - 1e-12)

    @property
    def at_floor(self) -> int:
        """Queries already squeezed to their floor — when this equals
        the batch size, degradation has nothing left to give and
        shedding is the only remaining actuator."""
        return sum(1 for p, f in zip(self.planned_rates, self.floors)
                   if p <= f + 1e-12)

    def record(self) -> dict:
        """JSON-ready summary (finite-or-None floats only)."""
        def clean(xs):
            return [None if x is None or not math.isfinite(x) else float(x)
                    for x in xs]
        return dict(
            base_rate=self.base_rate, pressure=self.pressure,
            budgeted=self.budgeted, degraded=self.degraded,
            at_floor=self.at_floor,
            planned_rates=[float(r) for r in self.planned_rates],
            undegraded_rates=[float(r) for r in self.undegraded_rates],
            floors=[float(f) for f in self.floors],
            est_rel_error=clean(self.est_rel_error),
            realized_rel_error=clean(self.realized_rel_error),
            partial_queries=self.partial_queries,
            lost_shards=self.lost_shards)


class RatePlanner:
    """Per-query sampling-rate planning against error/latency budgets.

    One instance serves one (corpus, controller) pair and learns
    across batches; ``QueryBatch`` calls ``plan_batch`` before
    sampling and ``observe_result`` after reducing.  Thread-safety
    matches the engine's: the window dispatcher serializes batches, so
    no internal locking is needed."""

    KINDS = ("count", "bool", "ranked")

    def __init__(self, n_shards: int, *,
                 config: Optional[PlannerConfig] = None,
                 controller=None):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = int(n_shards)
        self.config = config or PlannerConfig()
        self.controller = controller
        self._curves: Dict[str, _ErrCurve] = {
            k: _ErrCurve(self.config.curve_alpha,
                         self.config.seed_rel_scale)
            for k in self.KINDS}
        # EWMA of rates actually served — the reference point for
        # scaling the controller's p99 estimate to other rates
        self._ref_rate: Optional[float] = None

    # ------------------------------------------------------------------
    # models
    # ------------------------------------------------------------------
    def curve(self, kind: str) -> _ErrCurve:
        return self._curves[kind]

    def est_rel_error(self, kind: str, rate: float,
                      confidence: float = 0.95) -> float:
        """Predicted relative error bound for ``kind`` at ``rate``."""
        n = max(1, int(math.ceil(rate * self.n_shards)))
        return self._curves[kind].predict(n, confidence)

    def _latency_cap(self, max_latency_s: float,
                     base_rate: float) -> float:
        """Largest rate whose estimated p99 sojourn fits the latency
        budget, from the controller's current plan scaled linearly in
        rate (scan work dominates batch service and is proportional to
        shards read).  Without a controller or plan there is no cost
        model — return ``base_rate`` (never degrade on a guess)."""
        plan = (self.controller.current_plan
                if self.controller is not None else None)
        if plan is None or not math.isfinite(plan.est_p99_s):
            return base_rate
        ref = self._ref_rate if self._ref_rate else base_rate
        if plan.est_p99_s <= 0 or ref <= 0:
            return base_rate
        return ref * max_latency_s / plan.est_p99_s

    # ------------------------------------------------------------------
    # planning
    # ------------------------------------------------------------------
    def plan_rate(self, kind: str, budget: Optional[QueryBudget],
                  base_rate: float) -> float:
        """The rate one query should sample at, ignoring pressure.

        No budget -> the caller's nominal rate, untouched (bit-for-bit
        parity with unbudgeted serving, including the precise rate=1.0
        path).  An error budget asks for the smallest sufficient rate,
        a latency budget caps it at the largest affordable one; both
        clamp to [floor_rate, 1.0]."""
        if budget is None:
            return base_rate
        rate = base_rate
        if budget.max_rel_error is not None:
            n_req = self._curves[kind].required_n(
                budget.max_rel_error, budget.confidence, self.n_shards)
            rate = n_req / self.n_shards
        if budget.max_latency_s is not None:
            cap = self._latency_cap(budget.max_latency_s, base_rate)
            if budget.max_rel_error is not None:
                rate = min(rate, cap)
            else:
                rate = cap          # best accuracy that fits
        return min(max(rate, budget.floor_rate), 1.0)

    def plan_batch(self, queries: Sequence[Any], base_rate: float,
                   pressure: float = 0.0
                   ) -> Tuple[List[float], BudgetAudit]:
        """Per-query rates for one batch, with the degradation ladder
        applied: each rate slides linearly from its plan (pressure 0)
        toward its floor (pressure 1).  Unbudgeted queries degrade
        toward ``config.default_floor_rate`` — overload is a property
        of the batch, not of who declared a budget."""
        pressure = min(max(float(pressure), 0.0), 1.0)
        kinds, planned, undegraded, floors, est_err = [], [], [], [], []
        budgeted = 0
        for q in queries:
            budget = getattr(q, "budget", None)
            kind = getattr(q, "kind", "count")
            if budget is not None:
                budgeted += 1
                floor = budget.floor_rate
                conf = budget.confidence
            else:
                floor = self.config.default_floor_rate
                conf = 0.95
            r0 = self.plan_rate(kind, budget, base_rate)
            r = r0
            if pressure > 0.0 and r > floor:
                r = floor + (1.0 - pressure) * (r - floor)
            kinds.append(kind)
            undegraded.append(r0)
            planned.append(r)
            floors.append(min(floor, r0))
            e = self.est_rel_error(kind, r, conf)
            est_err.append(e if math.isfinite(e) else None)
        audit = BudgetAudit(
            base_rate=float(base_rate), pressure=pressure, kinds=kinds,
            planned_rates=planned, undegraded_rates=undegraded,
            floors=floors, budgeted=budgeted, est_rel_error=est_err)
        return planned, audit

    # ------------------------------------------------------------------
    # feedback
    # ------------------------------------------------------------------
    def observe_result(self, kind: str, rate: float, n: int,
                       rel_error: float,
                       confidence: float = 0.95) -> None:
        """Fold one served query's realized (n, relative error) into
        its kind's curve and the reference-rate EWMA."""
        self._curves[kind].observe(n, rel_error, confidence)
        if 0.0 < rate <= 1.0:
            a = self.config.curve_alpha
            self._ref_rate = rate if self._ref_rate is None else (
                self._ref_rate + a * (rate - self._ref_rate))
