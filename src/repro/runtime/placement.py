"""Locality-aware shard placement + multi-host query execution.

On a TPU pod the corpus shards are not a flat local pool: each host of
the data mesh axis holds a *resident* slice of them (the Spark-executor
/ HDFS-block layout the paper's prototype rides).  Two pieces make the
query runtime placement-aware:

``PlacementMap`` — the shard -> host residency table, plus ``R``
replica hosts per shard for failover.  It is derived from the data
mesh topology (``PlacementMap.from_mesh`` reads the residency axes of
a ``launch/mesh.py`` mesh via ``distributed.sharding.data_host_count``)
or built directly (``blocked`` mirrors how a mesh axis shards an array
into contiguous blocks; ``round_robin`` stripes).  ``split`` is the
scheduling primitive: it partitions a set of shard ids into per-host
groups by residency, falling over to the first live replica for hosts
in the ``dead`` set.

``HostGroupExecutor`` — the multi-host analogue of
``ShardTaskExecutor`` (same ``map_shards`` / ``map_shard_batch``
surface, so ``QueryBatch`` and ``BatchWindow`` take either without
change).  A job runs in three phases:

  1. **Residency split**: the shard ids (for a batch: the *union* of
     the per-query plans, inverted once by ``invert_plan``) are split
     by ``PlacementMap.split`` — each host only ever scans shards it
     holds, so no shard payload crosses the interconnect.
  2. **Per-host shared scans**: every host group runs as one
     ``ShardTaskExecutor`` job on that host's own executor — per-host
     warm pools, per-host retry/straggler speculation, and for batches
     the per-host shared scan evaluates every query that sampled a
     resident shard in a single visit.  Host jobs run concurrently on
     a coordinator pool (one thread per active host; on a real pod the
     coordinator thread becomes an RPC to the host).
  3. **Cross-host gather**: per-host results merge into one
     ``{shard_id: result}`` map.  Partials stay at (query, shard)
     granularity — the Hansen-Hurwitz sums, Boolean doc sets, and
     BM25 top-k candidates a reduce consumes are exactly the per-shard
     values the single-executor path would have produced, so the
     merged reduce is bit-for-bit identical to single-host execution
     (pinned by tests/test_placement.py).

**Host failure**: a host job that dies (its ``ShardTaskExecutor``
exhausts retries, or the injected ``host_fault_hook`` raises) marks
the host dead for the rest of the job; its entire shard group is
requeued onto the replica hosts via ``split(..., dead=...)`` and
re-executed there — the same at-least-once semantics as task retry,
lifted to host granularity (a requeued shard re-runs all of its
queries).  A shard whose primary and replicas are all dead raises
``HostFailure``.

**Load balancing** (``balanced=True``): the residency split is
primary-only and therefore bounded by the slowest host — skewed phi
concentrates sampled shards on a few hot hosts.  With a balancer the
dataflow becomes placement -> balance -> executor: ``PlacementMap``
says who *can* run a shard (primary + live ring replicas),
``runtime.balance.plan_split`` says who *should* (greedy LPT over a
per-host EWMA cost model fed by realized host-group wall times, with a
hysteresis band so stable loads don't flap), and the per-host
``ShardTaskExecutor`` fleet actually runs the groups.  Shed shards
land only on replicas that hold them, so every scan stays local, and
the cross-host gather is unchanged — balanced results are bit-for-bit
the single-executor results.  Failover and balancing are one code
path (``_split``): a dead host is an infinitely-hot one.

Telemetry is a per-host aggregate: ``last_job`` carries the job's
critical-path wall time (what the window controller attributes to the
shared scan), total task count, and the per-host breakdown (realized
wall per host, including any injected degradation);
``stats["scans_per_host"]`` counts shard visits per host, which the
serving bench checks against the residency split of the union plan
(primary-only executors — a balanced executor deliberately deviates
from residency counts, and its audit lives in
``last_job["balance"]``).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.runtime.balance import BalanceAudit, HostLoadModel, plan_split
from repro.runtime.executor import (
    ShardTaskExecutor,
    invert_plan,
    run_shared_scan,
)
from repro.runtime.generation import GenerationClock


class HostFailure(RuntimeError):
    """A shard's primary host and every replica are dead — the job
    cannot make progress.  ``host`` is the last host tried, ``shard_ids``
    the orphaned shards."""

    def __init__(self, host: int, shard_ids: Sequence[int]):
        self.host = int(host)
        self.shard_ids = [int(s) for s in shard_ids]
        super().__init__(
            f"host {host} failed and shards {self.shard_ids} have no "
            f"live replica host")


@dataclasses.dataclass(frozen=True)
class PlacementMap:
    """Shard -> host residency with optional replicas.

    ``primary[s]`` is the host shard ``s`` lives on; ``replicas[s]`` are
    up to R additional hosts holding a copy (failover targets, primary
    excluded).  Hosts are dense ids ``0..n_hosts-1`` — on a pod they map
    to the coordinates of the data mesh axis."""

    primary: np.ndarray          # int64 [n_shards]
    replicas: np.ndarray         # int64 [n_shards, R] (R may be 0)
    n_hosts: int

    def __post_init__(self):
        p = np.asarray(self.primary, np.int64)
        r = np.asarray(self.replicas, np.int64)
        if r.ndim != 2 or r.shape[0] != p.shape[0]:
            raise ValueError(f"replicas must be [n_shards, R], got "
                             f"{r.shape} for {p.shape[0]} shards")
        object.__setattr__(self, "primary", p)
        object.__setattr__(self, "replicas", r)
        if self.n_hosts < 1:
            raise ValueError(f"n_hosts must be >= 1, got {self.n_hosts}")
        for name, a in (("primary", p), ("replicas", r)):
            if a.size and (a.min() < 0 or a.max() >= self.n_hosts):
                raise ValueError(f"{name} references hosts outside "
                                 f"0..{self.n_hosts - 1}")
        if r.shape[1] and (r == p[:, None]).any():
            raise ValueError("a replica host duplicates its primary")

    # ------------------------------------------------------------------
    # constructors
    # ------------------------------------------------------------------
    @staticmethod
    def blocked(n_shards: int, n_hosts: int,
                n_replicas: int = 1) -> "PlacementMap":
        """Contiguous-block residency — how a data mesh axis shards an
        array: shard ``s`` lives on host ``s * n_hosts // n_shards``.
        Replica ``j`` of a shard is ``(primary + j) % n_hosts``."""
        ids = np.arange(n_shards, dtype=np.int64)
        primary = ids * n_hosts // max(n_shards, 1)
        return PlacementMap._with_ring_replicas(primary, n_hosts, n_replicas)

    @staticmethod
    def round_robin(n_shards: int, n_hosts: int,
                    n_replicas: int = 1) -> "PlacementMap":
        """Striped residency: shard ``s`` lives on host ``s % n_hosts``
        (spreads hot shard ranges; blocked keeps range scans local)."""
        primary = np.arange(n_shards, dtype=np.int64) % n_hosts
        return PlacementMap._with_ring_replicas(primary, n_hosts, n_replicas)

    @staticmethod
    def from_mesh(mesh, n_shards: int, *,
                  n_replicas: int = 1) -> "PlacementMap":
        """Residency derived from a mesh's data-parallel topology: the
        host count is the product of the residency axes (``pod`` x
        ``data`` — see ``distributed.sharding.data_host_count``), and
        shards lay out in contiguous blocks exactly like an array
        sharded on that axis.  Accepts a concrete ``Mesh`` or an
        ``AbstractMesh`` (placement needs only the shape)."""
        from repro.distributed.sharding import data_host_count
        return PlacementMap.blocked(n_shards, data_host_count(mesh),
                                    n_replicas)

    @staticmethod
    def _with_ring_replicas(primary: np.ndarray, n_hosts: int,
                            n_replicas: int) -> "PlacementMap":
        r = max(0, min(int(n_replicas), n_hosts - 1))
        offsets = np.arange(1, r + 1, dtype=np.int64)
        replicas = (primary[:, None] + offsets[None, :]) % n_hosts
        return PlacementMap(primary, replicas.reshape(len(primary), r),
                            int(n_hosts))

    # ------------------------------------------------------------------
    # lookups
    # ------------------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return int(self.primary.shape[0])

    @property
    def n_replicas(self) -> int:
        return int(self.replicas.shape[1])

    def hosts_of(self, shard_id: int) -> Tuple[int, ...]:
        """(primary, *replicas) for one shard, in failover order."""
        s = int(shard_id)
        return (int(self.primary[s]),
                *(int(h) for h in self.replicas[s]))

    def shards_on(self, host: int) -> np.ndarray:
        """Shard ids whose *primary* residency is ``host``."""
        return np.nonzero(self.primary == int(host))[0].astype(np.int64)

    def extend(self, n_shards: int) -> "PlacementMap":
        """Open-shard residency for live ingest: grow the map to cover
        newly appended shards without moving any existing one.  New
        shard ids take round-robin primaries (spreads ingest load) with
        the same ring-replica count as the rest of the map.  Returns
        ``self`` when nothing grew, so callers can swap unconditionally."""
        old = self.n_shards
        n = int(n_shards)
        if n < old:
            raise ValueError(f"cannot shrink placement from {old} to "
                             f"{n} shards")
        if n == old:
            return self
        new_primary = np.arange(old, n, dtype=np.int64) % self.n_hosts
        primary = np.concatenate([self.primary, new_primary])
        return PlacementMap._with_ring_replicas(primary, self.n_hosts,
                                                self.n_replicas)

    def split(self, shard_ids: Sequence[int],
              dead: frozenset = frozenset(), *,
              load=None,
              hysteresis: Optional[float] = None,
              orphans: Optional[List[int]] = None) -> Dict[int, List[int]]:
        """Partition shard ids into per-host groups by residency.

        Primary-only (``load=None``): each shard goes to its primary
        host, or — when the primary is in ``dead`` — to its first live
        replica (failover order).  Cost-aware (``load`` a
        ``runtime.balance.HostLoadModel``): the residency split is the
        starting point, but shards shed from estimated-hot hosts onto
        their live replicas when the balanced assignment beats the
        residency makespan by more than the ``hysteresis`` band (see
        ``runtime.balance.plan_split`` — a dead host is just an
        infinitely-hot one, so failover is the degenerate case of
        balancing).  Either way every shard lands on a host that holds
        it.  A shard with *no* live host raises ``HostFailure`` — or,
        when ``orphans`` (a mutable list) is supplied, is appended
        there and left out of every group: the degraded-serving path,
        where the query layer answers from the surviving sample with a
        widened CI instead of failing.  Group lists preserve the input
        order (determinism for tests)."""
        if load is not None:
            return plan_split(self, shard_ids, load, dead=dead,
                              hysteresis=hysteresis, orphans=orphans).groups
        groups: Dict[int, List[int]] = {}
        for sid in shard_ids:
            sid = int(sid)
            for h in self.hosts_of(sid):
                if h not in dead:
                    groups.setdefault(h, []).append(sid)
                    break
            else:
                if orphans is not None:
                    orphans.append(sid)
                    continue
                raise HostFailure(int(self.primary[sid]), [sid])
        return groups


class HostGroupExecutor:
    """Locality-split executor: one ``ShardTaskExecutor`` per host,
    per-host shared scans, cross-host gather, replica failover.

    Duck-type compatible with ``ShardTaskExecutor`` where the query
    engine touches it (``map_shards`` / ``map_shard_batch`` /
    ``last_job`` / ``stats`` / ``close``), so it drops into
    ``QueryBatch(executor=...)`` and behind ``BatchWindow`` unchanged.

    ``workers_per_host`` sizes each host's warm pool (keep
    ``hosts * workers_per_host`` at the single-host width for a fair
    same-machine comparison); remaining keyword arguments are forwarded
    to every per-host ``ShardTaskExecutor`` (``fault_hook``,
    ``max_retries``, ``adaptive_workers``, ...).  ``host_fault_hook``
    is the *host*-granularity injection point: called as
    ``(host, shard_ids)`` before the host's scan; raising kills the
    whole host for the current job and triggers replica requeue, while
    a hook that merely sleeps simulates a degraded (hot) host — the
    delay lands in the host's wall-time telemetry, which is how the
    bench and tests exercise the balancer.

    ``balanced=True`` (or an explicit ``balancer=HostLoadModel(...)``)
    turns on replica-aware load balancing: every split goes through
    ``runtime.balance.plan_split`` fed by the per-host realized wall
    times of completed host groups, so estimated-hot hosts shed whole
    shard groups onto their live ring replicas (residency preserved —
    shed scans stay local).  The requeue path uses the same balancer
    split with the dead set grown, unifying failover and balancing;
    ``last_job["balance"]`` records the decision (estimated vs
    realized per-host makespan, shed count) for audit."""

    def __init__(
        self,
        placement: PlacementMap,
        *,
        workers_per_host: int = 2,
        host_fault_hook: Optional[Callable[[int, Sequence[int]], None]] = None,
        balanced: bool = False,
        balancer: Optional["HostLoadModel"] = None,
        allow_partial: bool = False,
        job_hook: Optional[Callable[[int], None]] = None,
        clock: Optional[GenerationClock] = None,
        **executor_kw: Any,
    ):
        self.placement = placement
        self.host_fault_hook = host_fault_hook
        # the one version authority this executor mints placement
        # generations through; build_serving_stack passes the stack's
        # shared clock so cache/index/ingestor fence on the same handle
        self.clock = clock if clock is not None else GenerationClock()
        # group-level degraded serving: a shard whose primary and every
        # replica are dead (or down) is *lost* — recorded on stats /
        # last_job — instead of raising HostFailure.  Deliberately NOT
        # forwarded to the per-host executors: a task that exhausts its
        # retries must still escalate to host failover (the replica may
        # well succeed); only a shard with no live host left degrades.
        self.allow_partial = bool(allow_partial)
        # group-level job-start hook (job index): the chaos layer's
        # clock — per-host executors count their own host-jobs, which
        # is the wrong denomination for a scripted scenario
        self.job_hook = job_hook
        if balanced and balancer is None:
            balancer = HostLoadModel(placement.n_hosts)
        self.balancer = balancer
        self._workers_per_host = workers_per_host
        self._executor_kw = dict(executor_kw)
        self.hosts: Dict[int, ShardTaskExecutor] = {
            h: ShardTaskExecutor(workers=workers_per_host, **executor_kw)
            for h in range(placement.n_hosts)
        }
        # fleet membership: hosts taken out of rotation (crashed, or
        # drained by runtime/fleet.FleetManager).  Unlike the per-job
        # ``dead`` set this persists across jobs; the host's executor
        # object stays alive so an in-flight job that captured an older
        # placement generation can still finish on it (RCU — see
        # set_placement), until close().
        self.down: set = set()
        self.stats: Dict[str, Any] = {
            "jobs": 0, "host_jobs": 0, "host_failures": 0,
            "requeued_shards": 0, "shed_shards": 0,
            "lost_shards": 0,
            # deprecated read-only view of clock.current().placement
            # (pre-generation callers; pinned by tests) — never bumped
            # directly, only mirrored after a clock mint
            "placement_epoch": self.clock.current().placement,
            "scans_per_host": [0] * placement.n_hosts,
        }
        self.last_job: Optional[Dict[str, Any]] = None
        self._coord: Optional[ThreadPoolExecutor] = None
        self._coord_size = 0
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    # fleet membership (driven by runtime/fleet.FleetManager)
    # ------------------------------------------------------------------
    def ensure_host(self, host: int) -> ShardTaskExecutor:
        """Create (or revive) the executor slot for ``host`` and take
        it out of the down set.  Stats arrays grow to cover the id.
        Residency is NOT granted here — that happens when a new
        placement generation is swapped in via ``set_placement`` (a
        joiner must be warm before it serves)."""
        h = int(host)
        with self._lock:
            if h not in self.hosts:
                self.hosts[h] = ShardTaskExecutor(
                    workers=self._workers_per_host, **self._executor_kw)
            while len(self.stats["scans_per_host"]) <= h:
                self.stats["scans_per_host"].append(0)
            self.down.discard(h)
        return self.hosts[h]

    def retire_host(self, host: int) -> None:
        """Take ``host`` out of rotation for every future split (crash
        observed, or drain completed).  The executor object is kept —
        in-flight jobs on an older placement generation may still be
        running host groups on it; ``close()`` tears everything down."""
        self.down.add(int(host))

    def set_placement(self, placement: PlacementMap) -> None:
        """RCU-style generation swap: every job captures the placement
        reference at job start, so in-flight jobs finish on the old
        generation while jobs submitted after this call see the new
        one — membership changes never pause serving.  Executor slots
        and stats arrays are grown to cover any new host ids, and the
        balancer (if any) learns the new fleet width."""
        for h in range(placement.n_hosts):
            if h not in self.hosts:
                self.ensure_host(h)
        with self._lock:
            while len(self.stats["scans_per_host"]) < placement.n_hosts:
                self.stats["scans_per_host"].append(0)
        if self.balancer is not None:
            self.balancer.ensure_hosts(placement.n_hosts)
        self.placement = placement
        # the clock is the mint; stats carries the deprecated view
        self.stats["placement_epoch"] = self.clock.bump_placement().placement

    # ------------------------------------------------------------------
    # coordinator pool (one slot per host; warm across jobs)
    # ------------------------------------------------------------------
    def _coordinator(self, width: Optional[int] = None) -> ThreadPoolExecutor:
        need = max(1, int(width if width is not None
                          else self.placement.n_hosts))
        with self._lock:
            if self._coord is None or self._coord_size < need:
                # a grown fleet needs more concurrent host slots; the
                # old pool drains its in-flight host jobs on its own
                old = self._coord
                self._coord = ThreadPoolExecutor(
                    max_workers=need, thread_name_prefix="host-coord")
                self._coord_size = need
                if old is not None:
                    old.shutdown(wait=False)
            return self._coord

    def close(self) -> None:
        """Tear down the coordinator pool and every host's warm pool
        (idempotent)."""
        with self._lock:
            coord, self._coord = self._coord, None
        if coord is not None:
            coord.shutdown(wait=True)
        for ex in self.hosts.values():
            ex.close()

    def __enter__(self) -> "HostGroupExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------
    def _run_host(self, host: int, corpus, shard_ids: List[int],
                  fn: Callable[[Any], Any]) -> Tuple[Dict[int, Any], float]:
        """One host group: returns (results, realized wall seconds).
        The wall clock covers the injection hook too, so a simulated
        degraded host is *observed* as slow by the balancer."""
        t0 = time.perf_counter()
        if self.host_fault_hook is not None:
            self.host_fault_hook(host, shard_ids)
        res = self.hosts[host].map_shards(corpus, shard_ids, fn)
        return res, time.perf_counter() - t0

    def _split(self, placement: PlacementMap, shard_ids: Sequence[int],
               dead: frozenset, requeue: bool = False,
               orphans: Optional[List[int]] = None,
               ) -> Tuple[Dict[int, List[int]], Optional[BalanceAudit]]:
        """The one split point for both the initial plan and the
        failure requeue: primary residency without a balancer,
        cost-aware shedding with one (a dead host is just an
        infinitely-hot host, so failover rides the same path).  A
        requeue round is read-only on the balancer: the dead host's
        small group must not flip the hysteresis state or inflate the
        planned-shed stat.  ``placement`` is the generation the job
        captured at start, not ``self.placement`` — membership swaps
        must not move a job's shards mid-flight."""
        if self.balancer is None:
            return placement.split(shard_ids, dead, orphans=orphans), None
        audit = plan_split(placement, shard_ids, self.balancer,
                           dead=dead, update_state=not requeue,
                           orphans=orphans)
        if not requeue:
            self.stats["shed_shards"] += audit.shed
        return audit.groups, audit

    def map_shards(
        self,
        corpus,
        shard_ids: Sequence[int],
        fn: Callable[[Any], Any],
    ) -> Dict[int, Any]:
        """Residency-split ``fn(shard)`` over every id; returns the
        cross-host gather ``{shard_id: result}``.

        Hosts run concurrently; a failed host's group requeues onto
        replica hosts (at-least-once at host granularity) until every
        shard has a result or some shard runs out of live hosts — at
        which point the job raises ``HostFailure``, or with
        ``allow_partial`` returns the shards it *did* gather and
        records the rest on ``last_job["lost_shards"]``."""
        ids = [int(s) for s in shard_ids]
        t_job = time.perf_counter()
        # RCU: capture the placement generation for the whole job —
        # a concurrent set_placement (join/drain) must not reshuffle
        # this job's groups; new jobs pick up the new generation
        placement = self.placement
        if self.job_hook is not None:
            self.job_hook(self.stats["jobs"])
        # per-job dead set starts from the persistent membership down
        # set: crashed/drained hosts never receive work again
        dead: set = set(self.down)
        orphans: Optional[List[int]] = [] if self.allow_partial else None
        pending, audit = self._split(placement, ids, frozenset(dead),
                                     orphans=orphans)
        results: Dict[int, Any] = {}
        per_host: Dict[int, Dict[str, float]] = {}
        realized: Dict[int, int] = {}
        failed: Dict[int, List[int]] = {}
        errors: Dict[int, BaseException] = {}

        def collect(h: int, group: List[int], run) -> None:
            try:
                host_res, wall = run()
            except Exception as exc:
                # the host is dead for the rest of this job: its shard
                # group moves wholesale to replica hosts.  The cause is
                # kept so a job that runs out of replicas raises with
                # the real failure chained — a deterministic bug in a
                # query fn must not masquerade as pure infrastructure
                # loss.
                self.stats["host_failures"] += 1
                dead.add(h)
                failed[h] = group
                errors[h] = exc
                return
            results.update(host_res)
            self.stats["host_jobs"] += 1
            self.stats["scans_per_host"][h] += len(host_res)
            realized[h] = realized.get(h, 0) + len(host_res)
            job = dict(self.hosts[h].last_job or {})
            # realized wall includes the injection hook — the cost the
            # balancer must learn is the host's, not just its pool's —
            # and *accumulates* over rounds: a host that ran its own
            # group and then absorbed a requeued one spent both walls
            job["wall_s"] = wall + per_host.get(h, {}).get("wall_s", 0.0)
            per_host[h] = job
            if self.balancer is not None and host_res:
                self.balancer.observe(h, wall, len(host_res))

        while pending:
            items = list(pending.items())
            # all but the first group go through the coordinator; the
            # first runs on the calling thread — the caller would only
            # block on the gather anyway, and skipping its handoff
            # keeps the common small-batch job at one dispatch
            coord = (self._coordinator(placement.n_hosts)
                     if len(items) > 1 else None)
            futures = [
                (h, g, coord.submit(self._run_host, h, corpus, g, fn))
                for h, g in items[1:]
            ]
            h0, g0 = items[0]
            failed = {}
            collect(h0, g0, lambda: self._run_host(h0, corpus, g0, fn))
            for h, g, fut in futures:
                collect(h, g, fut.result)
            if failed:
                requeue = [sid for group in failed.values()
                           for sid in group]
                self.stats["requeued_shards"] += len(requeue)
                try:
                    pending, _ = self._split(placement, requeue,
                                             frozenset(dead),
                                             requeue=True,
                                             orphans=orphans)
                except HostFailure as hf:
                    # no live replica left: chain the underlying host
                    # exception (the orphaned shard's own host if we
                    # have it, else any from this round)
                    cause = errors.get(hf.host)
                    if cause is None and errors:
                        cause = next(iter(errors.values()))
                    raise hf from cause
            else:
                pending = {}
        # shards that never produced a result: orphans (no live host)
        # plus anything a per-host executor configured with its own
        # allow_partial/deadline gave up on
        lost = [s for s in ids if s not in results]
        if lost and not self.allow_partial:
            raise HostFailure(int(placement.primary[lost[0]]), lost)
        self.stats["lost_shards"] += len(lost)
        self.stats["jobs"] += 1
        medians = [j["median_task_s"] for j in per_host.values()
                   if j.get("median_task_s")]
        walls = {h: j.get("wall_s", 0.0) for h, j in per_host.items()}
        self.last_job = {
            # hosts run concurrently, so the job's service time is the
            # coordinator's critical path (incl. the gather) — this is
            # what the window controller attributes to the shared scan
            "wall_s": time.perf_counter() - t_job,
            "tasks": float(len(ids)),
            "median_task_s": float(np.median(medians)) if medians else 0.0,
            "hosts": float(len(per_host)),
            "per_host_wall_s": walls,
            "lost_shards": float(len(lost)),
        }
        if audit is not None:
            # estimated (at split time) vs realized (measured) per-host
            # makespans, for the bench's run-over-run balance audit
            rec = audit.record()
            rec["realized_wall_s"] = [
                walls.get(h, 0.0) for h in range(placement.n_hosts)]
            rec["realized_group_sizes"] = [
                realized.get(h, 0) for h in range(placement.n_hosts)]
            rec["realized_makespan_s"] = max(walls.values(), default=0.0)
            self.last_job["balance"] = rec
        return results

    def map_shard_batch(
        self,
        corpus,
        plan: Sequence[Sequence[int]],
        fns: Sequence[Callable[[Any], Any]],
        *,
        megakernel: "bool | None" = None,
    ) -> List[Dict[int, Any]]:
        """Locality-split shared scan over a batch of queries: the
        union of the per-query plans is inverted once, split by
        residency, scanned per host (each resident shard visited once,
        all interested queries evaluated in that visit), and gathered
        back into one ``{shard_id: result}`` map per query — exactly
        what the single-executor ``map_shard_batch`` produces.

        With ``MegascanSpec`` scan fns (``megakernel`` None/True, see
        ``run_shared_scan``) each *host* becomes one Pallas launch: the
        spec-tagged composite flows through the residency split to the
        per-host ``ShardTaskExecutor``s, whose megakernel route fuses
        their whole group — one task per host instead of one per
        shard-group, with requeue/balance/chaos semantics untouched
        because they all act on the host groups, not on what runs
        inside one."""
        return run_shared_scan(self.map_shards, corpus, plan, fns,
                               megakernel=megakernel)

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    def residency_split(
            self, plan: Sequence[Sequence[int]]) -> Dict[int, int]:
        """{host: number of union-plan shards resident on it} — the
        per-host scan counts one batch *should* produce (the serving
        bench checks observed scans against this)."""
        union = sorted(invert_plan(plan))
        return {h: len(g) for h, g in self.placement.split(union).items()}
