"""Cost-aware, residency-preserving load balancing for shard placement.

The placement layer answers *who can* run a shard (``PlacementMap``:
primary residency + ring replicas); this module answers *who should*.
With skewed phi the sampled shards concentrate on a few hot hosts, and
the job's wall clock is the slowest host's — the classic straggler
bound on partitioned text analytics.  Replicas already hold the data,
so shedding work from a hot host onto a replica keeps every scan local;
the only question is how much to move, which is a cost model plus an
assignment rule:

``HostLoadModel`` — per-host EWMA of realized per-shard scan cost, fed
by the placement executor's per-host wall-time telemetry (each host
group's ``last_job`` wall over its shard count).  Before any telemetry
exists every host is priced identically (``seed_cost_s``), so the
estimated host load degenerates to its residency shard count — the
split starts out count-balanced and sharpens as jobs complete.  A host
that has never run is priced at the fleet median so a cold replica is
neither feared nor favored.

``plan_split`` — the balancer.  It first computes the residency split
(primary hosts, dead primaries falling over to their first live
replica — exactly ``PlacementMap.split``), prices each host group with
the load model, and keeps the residency split unless the balanced
assignment beats its estimated makespan by more than the *hysteresis*
band (stable loads must not flap between near-equal splits: a shard
bouncing hosts invalidates that host's warm caches for no makespan
win).  The band is genuinely hysteretic — the previous decision is
state on the load model, and staying in the balanced split takes only
``stay_fraction`` of the margin that entering it does, so a load
hovering at the threshold keeps whichever split it already runs.
When the gap is real it reassigns with a greedy
longest-processing-time pass: shards ordered by estimated cost, each
placed on the cheapest *eligible* host — eligible meaning the shard's
primary or one of its live replicas, never anywhere else, so every
scan stays on a host that holds the data — followed by a swap pass
that cancels cross-moves (per-shard cost is host-uniform, so
returning misplaced pairs to their base hosts changes nothing about
the makespan and halves the churn).  A dead host is simply
infinitely expensive: it is never eligible, which makes failover a
special case of balancing (one code path for both — see
``HostGroupExecutor.map_shards``).  A shard with no live host raises
``HostFailure`` exactly as the primary-only split does.

The audit trail (``BalanceAudit`` / ``last_job["balance"]``) keeps the
estimated per-host costs, the base and chosen group sizes, and the
estimated makespans of both splits, so the serving bench can compare
estimate vs realized per-host wall time run over run.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class BalanceConfig:
    """Knobs of the load model + balancer.

    ``ewma_alpha`` weighs the newest per-shard cost observation;
    ``hysteresis`` is the relative makespan margin the balanced split
    must win by before the residency split is abandoned (0.25 = the
    balanced estimate must be >25% better); ``seed_cost_s`` prices a
    shard before any telemetry exists (its absolute value is
    irrelevant while all hosts share it — only ratios matter)."""

    ewma_alpha: float = 0.3
    hysteresis: float = 0.25
    seed_cost_s: float = 1e-3
    # fraction of ``hysteresis`` required to *stay* balanced once the
    # split has switched — the asymmetric band is what makes this real
    # hysteresis (the decision depends on the previous decision), so a
    # load hovering exactly at the entry threshold cannot flap the
    # split every job
    stay_fraction: float = 0.5

    def __post_init__(self):
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError(f"ewma_alpha must be in (0, 1], got "
                             f"{self.ewma_alpha}")
        if self.hysteresis < 0.0:
            raise ValueError(f"hysteresis must be >= 0, got "
                             f"{self.hysteresis}")
        if not 0.0 <= self.stay_fraction <= 1.0:
            raise ValueError(f"stay_fraction must be in [0, 1], got "
                             f"{self.stay_fraction}")


class HostLoadModel:
    """Per-host EWMA of realized per-shard scan+task wall time.

    ``observe`` is fed after every per-host group completes (wall time
    of the whole host job — scan work plus any injected degradation —
    over the number of shards it scanned); ``shard_cost`` prices one
    shard on a host for the balancer.  Thread-safe: observations land
    from the placement executor's coordinator threads."""

    def __init__(self, n_hosts: int,
                 config: Optional[BalanceConfig] = None):
        if n_hosts < 1:
            raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
        self.n_hosts = int(n_hosts)
        self.config = config or BalanceConfig()
        self._cost: List[Optional[float]] = [None] * self.n_hosts
        self._lock = threading.Lock()
        # hysteresis state: was the *previous* plan_split balanced?
        # Living on the model (the object that persists across jobs)
        # makes the keep/shed decision path-dependent — the definition
        # of hysteresis — with an easier bar to stay than to switch.
        self.balanced_mode = False

    def observe(self, host: int, wall_s: float, n_shards: int) -> None:
        """Fold one completed host group into the host's cost EWMA."""
        if n_shards <= 0:
            return
        c = float(wall_s) / float(n_shards)
        a = self.config.ewma_alpha
        with self._lock:
            prev = self._cost[int(host)]
            self._cost[int(host)] = c if prev is None else (
                a * c + (1.0 - a) * prev)

    def shard_cost(self, host: int) -> float:
        """Estimated seconds to scan one shard on ``host``.  Hosts
        without telemetry are priced at the fleet median (uniform
        ``seed_cost_s`` when nothing has run yet), so the cold split
        balances residency shard counts."""
        with self._lock:
            c = self._cost[int(host)]
            seen = [x for x in self._cost if x is not None]
        if c is not None:
            return c
        if seen:
            return float(np.median(seen))
        return self.config.seed_cost_s

    def snapshot(self) -> List[Optional[float]]:
        """Raw per-host EWMA values (None = no telemetry yet)."""
        with self._lock:
            return list(self._cost)

    # ------------------------------------------------------------------
    # fleet membership (see runtime/fleet.FleetManager)
    # ------------------------------------------------------------------
    def ensure_hosts(self, n_hosts: int) -> None:
        """Grow the model to at least ``n_hosts`` slots.  A joining
        host arrives with no telemetry (None), so ``shard_cost`` prices
        it at the fleet median — neither feared nor favored until its
        own realized walls arrive."""
        with self._lock:
            n = int(n_hosts)
            if n > self.n_hosts:
                self._cost.extend([None] * (n - self.n_hosts))
                self.n_hosts = n

    def forget_host(self, host: int) -> None:
        """Drop a departed host's telemetry (crash or drain): if the
        host id ever rejoins it re-enters at the fleet median instead
        of a stale EWMA from its previous life."""
        with self._lock:
            h = int(host)
            if 0 <= h < self.n_hosts:
                self._cost[h] = None


@dataclasses.dataclass
class BalanceAudit:
    """What the balancer decided and why — attached to
    ``HostGroupExecutor.last_job["balance"]`` for run-over-run audit
    (the serving bench compares ``est_makespan_s`` against the realized
    per-host walls)."""

    groups: Dict[int, List[int]]        # the chosen split
    base_groups: Dict[int, List[int]]   # the residency (primary) split
    balanced: bool                      # False = hysteresis kept base
    shed: int                           # shards moved off their base host
    est_cost_s: List[Optional[float]]   # per-host per-shard cost (None=dead)
    est_makespan_s: float               # of the chosen split
    est_base_makespan_s: float          # of the residency split
    n_hosts: int

    def record(self) -> dict:
        """JSON-ready per-host summary (host-indexed lists, no int
        keys — survives a json.dump round-trip unchanged)."""
        sizes = [0] * self.n_hosts
        base_sizes = [0] * self.n_hosts
        for h, g in self.groups.items():
            sizes[h] = len(g)
        for h, g in self.base_groups.items():
            base_sizes[h] = len(g)
        return dict(
            balanced=self.balanced, shed=self.shed,
            group_sizes=sizes, base_group_sizes=base_sizes,
            est_cost_s=self.est_cost_s,
            est_makespan_s=self.est_makespan_s,
            est_base_makespan_s=self.est_base_makespan_s)


def _makespan(groups: Dict[int, List[int]],
              cost: Dict[int, float]) -> float:
    return max((len(g) * cost[h] for h, g in groups.items()),
               default=0.0)


def plan_split(
    placement,
    shard_ids: Sequence[int],
    load: HostLoadModel,
    *,
    dead: frozenset = frozenset(),
    hysteresis: Optional[float] = None,
    update_state: bool = True,
    orphans: Optional[List[int]] = None,
) -> BalanceAudit:
    """Cost-aware, residency-preserving split of ``shard_ids``.

    Starts from the residency split (``placement.split`` — primaries,
    dead primaries failing over to live replicas), and reassigns with a
    greedy longest-processing-time pass over the load model's per-shard
    cost estimates only when the balanced split's estimated makespan
    beats the residency split's by more than the hysteresis band.
    Every shard lands on a host that holds it (primary or live
    replica); raises ``HostFailure`` when a shard has none.

    ``update_state=False`` makes the call read-only on the model's
    hysteresis state: a mid-job failure requeue splits only the dead
    host's small group, and letting that degenerate subset flip
    ``balanced_mode`` would make a transient host loss reset the
    band — the flap the state exists to prevent.

    ``orphans`` mirrors ``PlacementMap.split``: when given, shards
    with no live host are appended there and dropped from the plan
    instead of raising ``HostFailure``."""
    if hysteresis is None:
        hysteresis = load.config.hysteresis
    ids = [int(s) for s in shard_ids]
    # the residency split both seeds the comparison and performs the
    # orphan check (HostFailure / orphan collection) so the two split
    # flavors cannot disagree about liveness
    base = placement.split(ids, dead, orphans=orphans)
    if orphans:
        dropped = set(orphans)
        ids = [s for s in ids if s not in dropped]
    cost = {h: load.shard_cost(h)
            for h in range(placement.n_hosts) if h not in dead}
    est_base = _makespan(base, cost)

    # greedy LPT over estimated per-shard cost: expensive shards first
    # (a shard is priced at its cheapest eligible host — that is the
    # work it contributes wherever it lands in a balanced split),
    # each placed on the eligible host with the least accumulated load
    eligible = {
        sid: [h for h in placement.hosts_of(sid) if h not in dead]
        for sid in ids
    }
    order = sorted(
        range(len(ids)),
        key=lambda i: (-min(cost[h] for h in eligible[ids[i]]), i))
    loads = {h: 0.0 for h in cost}
    assign: Dict[int, List[int]] = {}
    for i in order:
        sid = ids[i]
        h = min(eligible[sid], key=lambda h: (loads[h] + cost[h], h))
        assign.setdefault(h, []).append(sid)
        loads[h] += cost[h]
    est_bal = max((v for v in loads.values() if v > 0.0), default=0.0)

    # asymmetric band = true hysteresis: switching *into* the balanced
    # split takes the full margin, staying in it only ``stay_fraction``
    # of it — a load hovering at the entry threshold keeps whatever
    # split it already runs instead of flapping every job
    band = hysteresis * (load.config.stay_fraction
                         if load.balanced_mode else 1.0)
    if est_base <= (1.0 + band) * est_bal:
        # within the band: keep the residency split (no flapping —
        # marginal estimated wins do not justify moving warm shards)
        if update_state:
            load.balanced_mode = False
        return BalanceAudit(
            groups=base, base_groups=base, balanced=False, shed=0,
            est_cost_s=[cost.get(h) for h in range(placement.n_hosts)],
            est_makespan_s=est_base, est_base_makespan_s=est_base,
            n_hosts=placement.n_hosts)
    if update_state:
        load.balanced_mode = True

    # churn minimization: per-shard cost is host-uniform, so exchanging
    # a pair of misplaced shards between two hosts returns both to
    # their base (residency) host while keeping every group size — and
    # hence the estimated makespan — unchanged.  Returning to the base
    # host is always residency-safe: the base split put the shard there
    # with the same dead set.
    base_host = {sid: h for h, g in base.items() for sid in g}
    hosts_used = sorted(assign)
    for ai, h1 in enumerate(hosts_used):
        for h2 in hosts_used[ai + 1:]:
            away1 = [s for s in assign[h1] if base_host[s] == h2]
            away2 = [s for s in assign[h2] if base_host[s] == h1]
            for x, y in zip(away1, away2):
                assign[h1][assign[h1].index(x)] = y
                assign[h2][assign[h2].index(y)] = x

    # restore input order inside each group (determinism: downstream
    # scans and tests see shards in submission order, as split() does)
    pos = {sid: i for i, sid in reversed(list(enumerate(ids)))}
    groups = {h: sorted(g, key=lambda s: pos[s])
              for h, g in assign.items()}
    shed = sum(1 for h, g in groups.items()
               for sid in g if base_host[sid] != h)
    return BalanceAudit(
        groups=groups, base_groups=base, balanced=True, shed=shed,
        est_cost_s=[cost.get(h) for h in range(placement.n_hosts)],
        est_makespan_s=est_bal, est_base_makespan_s=est_base,
        n_hosts=placement.n_hosts)
