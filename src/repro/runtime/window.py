"""Adaptive batch-window serving frontend.

``QueryBatch`` executes fixed, client-chosen batches; a serving process
instead sees a *stream* of single queries.  ``BatchWindow`` sits in
between: callers ``submit`` individual queries and get a future back,
and a dispatcher thread closes the open window when either

  * the window reaches ``max_batch`` queries (high traffic — full
    shared-scan amortization), or
  * ``max_delay_s`` has elapsed since the window's oldest query arrived
    (low traffic — bounded latency; the default 2 ms deadline is small
    next to per-shard scan times but large next to scoring dispatch).

Each closed window executes as one ``QueryBatch.execute`` call —
one batched scoring pass, one shared scan over the union of sampled
shards — on a single dispatcher thread, so the engine's rng draws stay
in a deterministic stream.  ``flush()`` force-closes the open window;
``close()`` drains everything and stops the dispatcher.

The win: low-traffic periods keep latency (a lone query waits at most
the deadline, not for a full batch), high-traffic periods batch up to
``max_batch`` and inherit the batched engine's ~6x throughput (see
BENCH_serve.json's ``windowed`` row).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import Any, Dict, List, Optional, Tuple

import numpy as np


class BatchWindow:
    """Deadline/size-closed batching frontend over a ``QueryBatch``
    engine.  One instance owns one dispatcher thread; it is safe to
    submit from many producer threads."""

    def __init__(
        self,
        engine,
        rate: float,
        *,
        max_batch: int = 32,
        max_delay_s: float = 0.002,
        rng: Optional[np.random.Generator] = None,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_delay_s < 0:
            raise ValueError(f"max_delay_s must be >= 0, got {max_delay_s}")
        self.engine = engine
        self.rate = rate
        self.max_batch = int(max_batch)
        self.max_delay_s = float(max_delay_s)
        self._rng = rng or np.random.default_rng(0)
        self._wake = threading.Condition()
        self._pending: List[Tuple[Any, Future]] = []
        self._first_arrival: Optional[float] = None
        self._flush = False
        self._closed = False
        self.stats: Dict[str, int] = {
            "batches": 0, "served": 0, "cancelled": 0,
            "closed_by_size": 0, "closed_by_deadline": 0,
            "closed_by_flush": 0,
        }
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="batch-window")
        self._thread.start()

    # ------------------------------------------------------------------
    # producer side
    # ------------------------------------------------------------------
    def submit(self, query) -> "Future":
        """Enqueue one query; the future resolves to the same result
        object ``QueryBatch.execute`` would return for it."""
        fut: Future = Future()
        with self._wake:
            if self._closed:
                raise RuntimeError("BatchWindow is closed")
            self._pending.append((query, fut))
            if self._first_arrival is None:
                self._first_arrival = time.perf_counter()
            self._wake.notify_all()
        return fut

    def flush(self) -> None:
        """Force-close the open window without waiting for the deadline
        (returns immediately; wait on the submitted futures)."""
        with self._wake:
            if self._pending:
                self._flush = True
                self._wake.notify_all()

    def close(self, timeout: Optional[float] = None) -> None:
        """Drain all pending queries, then stop the dispatcher."""
        with self._wake:
            self._closed = True
            self._wake.notify_all()
        self._thread.join(timeout)

    def __enter__(self) -> "BatchWindow":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    # dispatcher
    # ------------------------------------------------------------------
    def _loop(self) -> None:
        while True:
            with self._wake:
                while not self._pending and not self._closed:
                    self._flush = False        # nothing left to flush
                    self._wake.wait()
                if not self._pending and self._closed:
                    return
                # a window is open: wait for size, flush, or deadline
                deadline = self._first_arrival + self.max_delay_s
                while (len(self._pending) < self.max_batch
                       and not self._flush and not self._closed):
                    remaining = deadline - time.perf_counter()
                    if remaining <= 0:
                        break
                    self._wake.wait(timeout=remaining)
                batch = self._pending[: self.max_batch]
                del self._pending[: self.max_batch]
                if len(batch) >= self.max_batch:
                    reason = "size"
                elif self._flush or self._closed:
                    reason = "flush"
                else:
                    reason = "deadline"
                # the remainder opens a fresh window "now" — close
                # enough to the true oldest-remaining arrival, and it
                # never *extends* any query's wait past one full window
                self._first_arrival = (time.perf_counter()
                                       if self._pending else None)
                if not self._pending:
                    self._flush = False
            self._run_batch(batch, reason)

    def _run_batch(self, batch: List[Tuple[Any, Future]],
                   reason: str) -> None:
        # Claim every future before executing: a caller may have
        # cancel()ed while it sat PENDING in the window.  Marking the
        # survivors RUNNING means no later cancel can win the race and
        # make set_result raise InvalidStateError (which would kill the
        # dispatcher thread for good).
        claimed = [(q, f) for q, f in batch
                   if f.set_running_or_notify_cancel()]
        dropped = len(batch) - len(claimed)
        if claimed:
            queries = [q for q, _ in claimed]
            try:
                results = self.engine.execute(queries, self.rate,
                                              rng=self._rng)
            except BaseException as exc:  # deliver failures to every waiter
                for _, fut in claimed:
                    fut.set_exception(exc)
            else:
                for (_, fut), res in zip(claimed, results):
                    fut.set_result(res)
        with self._wake:
            self.stats["cancelled"] += dropped
            if not claimed:
                return
            self.stats["batches"] += 1
            self.stats["served"] += len(claimed)
            self.stats[f"closed_by_{reason}"] += 1
