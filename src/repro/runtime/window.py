"""Adaptive batch-window serving frontend.

``QueryBatch`` executes fixed, client-chosen batches; a serving process
instead sees a *stream* of single queries.  ``BatchWindow`` sits in
between: callers ``submit`` individual queries and get a future back,
and a dispatcher thread closes the open window when either

  * the window reaches ``max_batch`` queries (high traffic — full
    shared-scan amortization), or
  * ``max_delay_s`` has elapsed since the window's oldest query arrived
    (low traffic — bounded latency; the default 2 ms deadline is small
    next to per-shard scan times but large next to scoring dispatch).

Each closed window executes as one ``QueryBatch.execute`` call —
one batched scoring pass, one shared scan over the union of sampled
shards — on a single dispatcher thread, so the engine's rng draws stay
in a deterministic stream.  On a multi-host engine (a
``runtime/placement.HostGroupExecutor`` behind ``QueryBatch``) that
shared scan splits by shard residency and runs per host; the window
neither knows nor cares — the executor's ``last_job`` telemetry it
forwards to the controller is already the per-host *aggregate* (the
cross-host critical-path wall time).  ``flush()`` force-closes the
open window; ``close()`` drains everything and stops the dispatcher.

The win: low-traffic periods keep latency (a lone query waits at most
the deadline, not for a full batch), high-traffic periods batch up to
``max_batch`` and inherit the batched engine's ~6x throughput (see
BENCH_serve.json's ``windowed`` row).

Two optional control loops close the remaining gaps:

  * ``controller=WindowController(...)`` replaces the static pair with
    the queueing-theory autotuner in ``runtime/controller.py``: every
    window opens with the (deadline, size) the controller currently
    estimates minimizes p99 sojourn, fed by the window's own arrival /
    batch-cost observations (``max_delay_s`` / ``max_batch`` then only
    apply when the controller is absent).
  * ``max_pending=N`` bounds the pending queue: once N queries sit
    unserved, ``submit`` sheds with the typed ``Backpressure`` signal
    instead of letting sojourn grow without bound behind a saturated
    dispatcher.

When the engine can trade accuracy for capacity (it advertises
``accepts_pressure``, i.e. a ``QueryBatch`` with a
``runtime.budget.RatePlanner``), the queue bound becomes a *two-stage*
ladder instead of a cliff: the first bound-hit escalates the
controller's degradation pressure to 1.0 (every pending query drops to
its budget floor rate — see ``runtime/budget.py``) and the query is
*accepted*; only once the queue stretches to twice the bound with the
engine already fully degraded does ``submit`` shed.  Overload degrades
accuracy before availability, and every shed carries the controller's
``retry_after_s`` hint so callers back off one serving cycle.  The
dispatcher forwards the controller's current pressure to each
``engine.execute`` call, and the engine's per-batch budget audit
(planned vs realized rates and errors) lands on ``last_budget``.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.runtime.controller import Backpressure, WindowController


class BatchWindow:
    """Deadline/size-closed batching frontend over a ``QueryBatch``
    engine.  One instance owns one dispatcher thread; it is safe to
    submit from many producer threads."""

    def __init__(
        self,
        engine,
        rate: float,
        *,
        max_batch: int = 32,
        max_delay_s: float = 0.002,
        rng: Optional[np.random.Generator] = None,
        controller: Optional[WindowController] = None,
        max_pending: Optional[int] = None,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_delay_s < 0:
            raise ValueError(f"max_delay_s must be >= 0, got {max_delay_s}")
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.engine = engine
        self.rate = rate
        self.max_batch = int(max_batch)
        self.max_delay_s = float(max_delay_s)
        self.controller = controller
        self.max_pending = max_pending
        self._rng = rng or np.random.default_rng(0)
        self._wake = threading.Condition()
        self._pending: List[Tuple[Any, Future]] = []
        self._first_arrival: Optional[float] = None
        self._flush = False
        self._closed = False
        self.stats: Dict[str, int] = {
            "batches": 0, "served": 0, "cancelled": 0, "shed": 0,
            "escalated": 0, "degraded": 0, "batch_retries": 0,
            "closed_by_size": 0, "closed_by_deadline": 0,
            "closed_by_flush": 0,
        }
        # the engine's budget audit for the most recent batch (planned
        # vs realized per-query rates/errors), when the engine keeps
        # one (QueryBatch with a RatePlanner) — None otherwise
        self.last_budget: Optional[Dict[str, Any]] = None
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="batch-window")
        self._thread.start()

    # ------------------------------------------------------------------
    # producer side
    # ------------------------------------------------------------------
    def submit(self, query) -> "Future":
        """Enqueue one query; the future resolves to the same result
        object ``QueryBatch.execute`` would return for it.

        Raises ``Backpressure`` (the query is *not* enqueued) when
        ``max_pending`` queries already wait — the dispatcher is
        saturated and callers must shed or retry elsewhere."""
        fut: Future = Future()
        with self._wake:
            # timestamp under the lock: the controller's EWMA needs
            # monotone arrival times, and two producers reading the
            # clock before racing for the lock can deliver them
            # out of order
            now = time.perf_counter()
            if self._closed:
                raise RuntimeError("BatchWindow is closed")
            if (self.max_pending is not None
                    and len(self._pending) >= self.max_pending):
                # degrade before shedding: an accuracy-elastic engine
                # absorbs the overload by dropping every pending query
                # to its budget floor (pressure -> 1.0), and the queue
                # may stretch to twice the bound while the degraded
                # capacity catches up.  Shed only beyond that hard cap
                # — by then every query is already at its floor and
                # accuracy has nothing left to give.
                can_degrade = (
                    self.controller is not None
                    and getattr(self.engine, "accepts_pressure", False))
                if can_degrade and len(self._pending) < 2 * self.max_pending:
                    self.controller.escalate_pressure()
                    self.stats["escalated"] += 1
                else:
                    self.stats["shed"] += 1
                    util = retry = None
                    if self.controller is not None:
                        util = self.controller.utilization
                        retry = self.controller.retry_after_s()
                    raise Backpressure(len(self._pending), util, retry)
            if self.controller is not None:
                self.controller.observe_arrival(now)
            self._pending.append((query, fut))
            if self._first_arrival is None:
                self._first_arrival = now
            self._wake.notify_all()
        return fut

    def flush(self) -> None:
        """Force-close the open window without waiting for the deadline
        (returns immediately; wait on the submitted futures)."""
        with self._wake:
            if self._pending:
                self._flush = True
                self._wake.notify_all()

    def close(self, timeout: Optional[float] = None) -> None:
        """Drain all pending queries, then stop the dispatcher."""
        with self._wake:
            self._closed = True
            self._wake.notify_all()
        self._thread.join(timeout)

    def __enter__(self) -> "BatchWindow":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    # dispatcher
    # ------------------------------------------------------------------
    def _loop(self) -> None:
        while True:
            with self._wake:
                while not self._pending and not self._closed:
                    self._flush = False        # nothing left to flush
                    self._wake.wait()
                if not self._pending and self._closed:
                    return
                # a window is open: its (deadline, size) pair is fixed
                # at open time — static, or the controller's current
                # p99-sojourn-minimizing plan
                if self.controller is not None:
                    delay_s, max_batch = self.controller.window_params()
                else:
                    delay_s, max_batch = self.max_delay_s, self.max_batch
                deadline = self._first_arrival + delay_s
                while (len(self._pending) < max_batch
                       and not self._flush and not self._closed):
                    remaining = deadline - time.perf_counter()
                    if remaining <= 0:
                        break
                    self._wake.wait(timeout=remaining)
                batch = self._pending[: max_batch]
                del self._pending[: max_batch]
                if len(batch) >= max_batch:
                    reason = "size"
                elif self._flush or self._closed:
                    reason = "flush"
                else:
                    reason = "deadline"
                # the remainder opens a fresh window "now" — close
                # enough to the true oldest-remaining arrival, and it
                # never *extends* any query's wait past one full window
                self._first_arrival = (time.perf_counter()
                                       if self._pending else None)
                if not self._pending:
                    self._flush = False
            self._run_batch(batch, reason)

    def _execute_once_retried(self, queries: List[Any],
                              kwargs: Dict[str, Any]) -> List[Any]:
        """One batch through the engine, with a single synchronous
        in-place retry on *infrastructure* failure (``HostFailure`` /
        ``ShardTaskError``): a host that died mid-batch is marked dead
        by the first attempt's requeue path (or taken out of rotation
        by ``FleetManager.crash``), so the immediate re-run lands on
        the survivors.  In place because the claimed futures are
        already RUNNING — ``set_running_or_notify_cancel`` returns
        False for a re-enqueued future, so queueing them again would
        silently drop them.  Exactly one retry: a second consecutive
        infra failure means the fleet genuinely cannot serve the batch
        and the waiters get the exception."""
        from repro.runtime.executor import ShardTaskError
        from repro.runtime.placement import HostFailure

        try:
            return self.engine.execute(queries, self.rate,
                                       rng=self._rng, **kwargs)
        except (HostFailure, ShardTaskError):
            self.stats["batch_retries"] += 1
            return self.engine.execute(queries, self.rate,
                                       rng=self._rng, **kwargs)

    def _run_batch(self, batch: List[Tuple[Any, Future]],
                   reason: str) -> None:
        # Claim every future before executing: a caller may have
        # cancel()ed while it sat PENDING in the window.  Marking the
        # survivors RUNNING means no later cancel can win the race and
        # make set_result raise InvalidStateError (which would kill the
        # dispatcher thread for good).
        claimed = [(q, f) for q, f in batch
                   if f.set_running_or_notify_cancel()]
        dropped = len(batch) - len(claimed)
        service_s = None
        pressure = 0.0
        if claimed:
            queries = [q for q, _ in claimed]
            # an accuracy-elastic engine takes the controller's current
            # degradation pressure with the batch; plain engines keep
            # the legacy signature (the kwarg would be a TypeError)
            kwargs = {}
            if getattr(self.engine, "accepts_pressure", False):
                pressure = (self.controller.pressure
                            if self.controller is not None else 0.0)
                kwargs["pressure"] = pressure
            t0 = time.perf_counter()
            try:
                results = self._execute_once_retried(queries, kwargs)
            except BaseException as exc:  # deliver failures to every waiter
                for _, fut in claimed:
                    fut.set_exception(exc)
            else:
                service_s = time.perf_counter() - t0
                for (_, fut), res in zip(claimed, results):
                    fut.set_result(res)
        with self._wake:
            self.stats["cancelled"] += dropped
            if not claimed:
                return
            self.stats["batches"] += 1
            self.stats["served"] += len(claimed)
            if pressure > 0.0:
                self.stats["degraded"] += len(claimed)
            self.last_budget = getattr(self.engine, "last_budget", None)
            self.stats[f"closed_by_{reason}"] += 1
            if self.controller is not None and service_s is not None:
                # the executor's per-job telemetry attributes the batch
                # cost: scan_s is the shared-scan share of service_s
                # (for a host group, the cross-host critical path)
                executor = getattr(self.engine, "executor", None)
                job = getattr(executor, "last_job", None)
                scan_s = job["wall_s"] if job else None
                # semantic-cache exact hits never touched the executor;
                # keep them out of the fitted batch cost model
                report = getattr(self.engine, "last_report", None)
                cache_meta = getattr(report, "cache", None)
                cached_n = cache_meta.get("hits", 0) if cache_meta else 0
                self.controller.observe_batch(len(claimed), service_s,
                                              scan_s, cached=cached_n)
