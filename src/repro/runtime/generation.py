"""The one typed version authority for the serving stack.

Through PR 9 three ad-hoc signals accreted that all mean "which
generation of the world am I reading":

  * ``HostGroupExecutor.stats["placement_epoch"]`` — a bare int bumped
    inside ``set_placement`` on every fleet membership change;
  * the semantic query cache's raw-int epoch probe — every cached entry
    recorded that int and ``lookup`` fenced on inequality;
  * the megascan payload cache on ``ApproxIndex`` — keyed on the shard
    id tuple, dropped wholesale by ``attach_corpus``.

Live ingest is the forcing function to unify them: an append changes
*content* without changing *placement*, and a fleet swap changes
placement without changing content — a cache entry is valid only under
both.  This module owns the mint.  Nothing else in the tree increments
a generation int; every layer reads and fences on the same handle.

``Generation`` is a frozen value with two independent axes:

  * ``placement`` — which placement map queries route under.  Bumped by
    ``HostGroupExecutor.set_placement`` (fleet join / drain / crash,
    balancer splits, open-shard residency extension).
  * ``content`` — which corpus + index artifacts queries read.  Bumped
    by the ingest swap and by ``ApproxIndex.attach_corpus``.

Equality compares both axes, so fencing code written against the old
int epochs (``entry.epoch != epoch`` → drop) keeps working verbatim
once handed ``Generation`` values.  ``GenerationClock`` is the
thread-safe mint: readers call ``current()``; the two writers call
``bump_placement()`` / ``bump_content()``.  ``build_serving_stack``
creates one clock and binds every layer (executor, index, cache
epochs, ingestor) to it; standalone constructions get a private clock
so the API works un-wired too.

Deprecated read-only views (kept for pre-generation callers, pinned by
tests): ``stats["placement_epoch"]`` mirrors ``current().placement``
after every bump, and the cache still accepts raw ints as epochs.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict


@dataclasses.dataclass(frozen=True)
class Generation:
    """An immutable (placement, content) version pair.

    Hashable and JSON-clean (via :meth:`record`), so it can key caches
    and ride inside bench/audit records.  Ordering is deliberately not
    defined: the two axes advance independently, so "newer" is only
    meaningful per axis.
    """

    placement: int = 0
    content: int = 0

    def record(self) -> Dict[str, int]:
        """JSON-clean dict form for audits and bench records."""
        return dict(placement=int(self.placement), content=int(self.content))


class GenerationClock:
    """Thread-safe single mint for :class:`Generation` values.

    One instance per serving stack (shared by executor, index, cache
    and ingestor); components built standalone default to a private
    clock so nothing needs wiring to merely work.
    """

    def __init__(self, start: Generation | None = None) -> None:
        self._gen = start if start is not None else Generation()
        self._lock = threading.Lock()

    def current(self) -> Generation:
        """The generation new work should capture (RCU read side)."""
        with self._lock:
            return self._gen

    def bump_placement(self) -> Generation:
        """Mint the next placement generation; returns the new value."""
        with self._lock:
            self._gen = Generation(self._gen.placement + 1, self._gen.content)
            return self._gen

    def bump_content(self) -> Generation:
        """Mint the next content generation; returns the new value."""
        with self._lock:
            self._gen = Generation(self._gen.placement, self._gen.content + 1)
            return self._gen
