"""Blockwise int8 quantization for optimizer state and gradients.

Dynamic blockwise quantization (Dettmers et al., 8-bit optimizers):
flatten, split into blocks of 256, store int8 codes + one fp32 absmax
scale per block.  Linear (not dynamic-tree) codes keep the kernel
trivially vectorizable; measured quality loss on Adam moments is
negligible at block 256.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


class Q8State(NamedTuple):
    codes: jax.Array    # int8  [n_blocks, BLOCK]
    scales: jax.Array   # float32 [n_blocks]
    size: int           # original element count (static)


jax.tree_util.register_pytree_node(
    Q8State,
    lambda s: ((s.codes, s.scales), s.size),
    lambda size, kids: Q8State(kids[0], kids[1], size),
)


def q8_quantize(x: jax.Array) -> Q8State:
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    absmax = jnp.max(jnp.abs(blocks), axis=1)
    scales = jnp.maximum(absmax, 1e-12) / 127.0
    codes = jnp.clip(jnp.round(blocks / scales[:, None]), -127, 127
                     ).astype(jnp.int8)
    return Q8State(codes, scales, n)


def q8_dequantize(s: Q8State, shape: Tuple[int, ...]) -> jax.Array:
    flat = (s.codes.astype(jnp.float32) * s.scales[:, None]).reshape(-1)
    return flat[: s.size].reshape(shape)
