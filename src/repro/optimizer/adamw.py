"""AdamW with dtype-policy moments and optional 8-bit state.

Moments can live in fp32 (default), bf16 (halves optimizer HBM — what
maverick-400b needs on 512 chips), or blockwise-quantized int8
("q8", quarter HBM).  The update math always runs in fp32; only storage
is compressed.  Moment tensors inherit the parameter's logical sharding
so FSDP shards optimizer state too (ZeRO-style).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optimizer.quantized import Q8State, q8_dequantize, q8_quantize
from repro.utils.trees import tree_global_norm


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # "float32" | "bfloat16" | "q8"
    state_dtype: str = "float32"


class OptState(NamedTuple):
    step: jax.Array
    m: Any     # pytree matching params (arrays or Q8State leaves)
    v: Any


def _store(x: jax.Array, state_dtype: str):
    if state_dtype == "q8":
        return q8_quantize(x)
    if state_dtype == "bfloat16":
        return x.astype(jnp.bfloat16)
    return x.astype(jnp.float32)


def _load(x, ref_shape) -> jax.Array:
    if isinstance(x, Q8State):
        return q8_dequantize(x, ref_shape)
    return x.astype(jnp.float32)


def adamw_init(params, cfg: AdamWConfig) -> OptState:
    zeros = jax.tree_util.tree_map(
        lambda p: _store(jnp.zeros(p.shape, jnp.float32), cfg.state_dtype),
        params)
    zeros2 = jax.tree_util.tree_map(
        lambda p: _store(jnp.zeros(p.shape, jnp.float32), cfg.state_dtype),
        params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros, v=zeros2)


def adamw_update(
    params,
    grads,
    opt_state: OptState,
    cfg: AdamWConfig,
    lr_scale: jax.Array | float = 1.0,
):
    """Returns (new_params, new_opt_state, metrics dict)."""
    gnorm = tree_global_norm(grads)
    clip_coef = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    step = opt_state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def is_q8(x):
        return isinstance(x, Q8State)

    def upd(p, g, m_s, v_s):
        g = g.astype(jnp.float32) * clip_coef
        m = _load(m_s, p.shape) * cfg.b1 + (1 - cfg.b1) * g
        v = _load(v_s, p.shape) * cfg.b2 + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:   # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, _store(m, cfg.state_dtype), _store(v, cfg.state_dtype)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state.m, is_leaf=is_q8)
    flat_v = jax.tree_util.tree_leaves(opt_state.v, is_leaf=is_q8)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, OptState(step, new_m, new_v), metrics
