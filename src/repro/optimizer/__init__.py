"""Optimizers: AdamW with dtype-policy moments, 8-bit blockwise state,
schedules, and global-norm clipping."""
from repro.optimizer.adamw import (  # noqa: F401
    AdamWConfig,
    OptState,
    adamw_init,
    adamw_update,
)
from repro.optimizer.schedules import cosine_warmup_schedule  # noqa: F401
from repro.optimizer.quantized import (  # noqa: F401
    Q8State,
    q8_quantize,
    q8_dequantize,
)
