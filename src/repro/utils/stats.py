"""Statistics helpers: Student-t critical values without SciPy.

The paper's error bounds (Eq 2) use ``t_{n-1, 1-alpha/2}``.  SciPy is not
part of the runtime, so we implement the inverse CDF of the
t-distribution with the classic Hill (1970) expansion around the normal
quantile.  Accuracy is ~1e-6 for df >= 3 and better than 1e-3 for df in
{1, 2}, which we special-case exactly (Cauchy / closed form).

Checked against tabulated values in tests/test_stats.py.
"""
from __future__ import annotations

import math


def _norm_ppf(p: float) -> float:
    """Inverse standard-normal CDF (Acklam's rational approximation).

    Max abs error ~1.15e-9 over (0, 1).
    """
    if not 0.0 < p < 1.0:
        raise ValueError(f"p must be in (0,1), got {p}")
    a = (-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00)
    b = (-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00)
    d = (7.784695709041462e-03, 3.224671290700398e-01,
         2.445134137142996e+00, 3.754408661907416e+00)
    plow, phigh = 0.02425, 1 - 0.02425
    if p < plow:
        q = math.sqrt(-2 * math.log(p))
        return (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / \
               ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
    if p > phigh:
        q = math.sqrt(-2 * math.log(1 - p))
        return -(((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / \
                ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
    q = p - 0.5
    r = q * q
    return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * q / \
           (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1)


def t_critical_value(df: int, confidence: float = 0.95) -> float:
    """Two-sided critical value ``t_{df, 1-alpha/2}`` for the given
    confidence level (paper Eq 2 uses 95%)."""
    if df <= 0:
        raise ValueError(f"df must be positive, got {df}")
    p = 1.0 - (1.0 - confidence) / 2.0  # upper-tail quantile
    if df == 1:  # Cauchy: exact
        return math.tan(math.pi * (p - 0.5))
    if df == 2:  # exact closed form
        alpha2 = 2.0 * (1.0 - p)
        return math.sqrt(2.0 / (alpha2 * (2.0 - alpha2)) - 2.0)
    # Hill's asymptotic expansion: normal quantile + Cornish-Fisher terms.
    x = _norm_ppf(p)
    g1 = (x ** 3 + x) / 4.0
    g2 = (5 * x ** 5 + 16 * x ** 3 + 3 * x) / 96.0
    g3 = (3 * x ** 7 + 19 * x ** 5 + 17 * x ** 3 - 15 * x) / 384.0
    g4 = (79 * x ** 9 + 776 * x ** 7 + 1482 * x ** 5 - 1920 * x ** 3 - 945 * x) / 92160.0
    return x + g1 / df + g2 / df ** 2 + g3 / df ** 3 + g4 / df ** 4
