"""Pytree math helpers used by the optimizer, checkpointing and tests."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_param_count(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(x.size for x in leaves))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree (uses each leaf's dtype itemsize)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(x.size * x.dtype.itemsize for x in leaves))


def tree_global_norm(tree) -> jax.Array:
    """L2 norm across every leaf of the pytree (fp32 accumulation)."""
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((), jnp.float32)
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    return jnp.sqrt(sq)


def tree_zeros_like(tree, dtype=None):
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree
    )


def tree_cast(tree, dtype):
    """Cast all floating leaves to ``dtype``; leave integer leaves alone."""
    def cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(cast, tree)
