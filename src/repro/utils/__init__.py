"""Shared utilities: PRNG helpers, tree math, timing, dtype policies."""
from repro.utils.trees import (  # noqa: F401
    tree_bytes,
    tree_global_norm,
    tree_param_count,
    tree_zeros_like,
)
from repro.utils.stats import t_critical_value  # noqa: F401
