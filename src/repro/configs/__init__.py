"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``.

One module per assigned architecture (exact configs from the assignment
table) plus ``emapprox`` (the paper's own PV-DBOW workload).  Each module
exposes ``CONFIG`` (full-size) and ``smoke_config()`` (reduced, CPU-runnable).
"""
from __future__ import annotations

import importlib
from typing import List

_ARCHS = [
    "smollm_360m",
    "qwen2_5_14b",
    "starcoder2_3b",
    "internlm2_20b",
    "mamba2_780m",
    "whisper_small",
    "hymba_1_5b",
    "llama4_scout_17b_a16e",
    "llama4_maverick_400b_a17b",
    "llama_3_2_vision_11b",
]

ALIASES = {a.replace("_", "-"): a for a in _ARCHS}
ALIASES.update({
    "smollm-360m": "smollm_360m",
    "qwen2.5-14b": "qwen2_5_14b",
    "starcoder2-3b": "starcoder2_3b",
    "internlm2-20b": "internlm2_20b",
    "mamba2-780m": "mamba2_780m",
    "whisper-small": "whisper_small",
    "hymba-1.5b": "hymba_1_5b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
})


def list_archs() -> List[str]:
    return list(_ARCHS)


def get_config(arch: str, smoke: bool = False):
    mod_name = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.smoke_config() if smoke else mod.CONFIG
