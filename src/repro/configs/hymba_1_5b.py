"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attn+mamba heads
[arXiv:2411.13676].  Sliding-window attention (1024) in every layer +
parallel SSM heads is what bounds the decode state for long_500k."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    sliding_window=1024,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16, ssm_state=8,
        ssm_head_dim=16, ssm_chunk=32, sliding_window=32, max_seq_len=128)
