"""whisper-small [audio]: 12L d_model=768 12H (kv=12) d_ff=3072
vocab=51865 — enc-dec, conv frontend (stub) [arXiv:2212.04356].
The 12L spec maps to whisper-small's 12 encoder + 12 decoder layers;
the modality frontend is a stub per the assignment (input_specs
provides precomputed frame embeddings [B, 1500, d_model])."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=51865, head_dim=64,
    encoder_layers=12, encoder_seq=1500, max_seq_len=32768 + 8,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, encoder_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=256, head_dim=16,
        encoder_seq=32, max_seq_len=128)
