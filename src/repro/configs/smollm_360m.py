"""smollm-360m [dense]: 32L d_model=960 15H (GQA kv=5) d_ff=2560
vocab=49152 — llama-arch small [hf:HuggingFaceTB/SmolLM-135M]."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
    d_ff=2560, vocab_size=49152, head_dim=64,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16, max_seq_len=128)
