"""The paper's own workload config: PV-DBOW index training + the three
query families over a synthetic corpus (DESIGN.md Sec. 9)."""
import dataclasses

from repro.core.lsh import LSHConfig
from repro.core.pv_dbow import PVDBOWConfig
from repro.data.corpus import SyntheticCorpusConfig


@dataclasses.dataclass(frozen=True)
class EmApproxConfig:
    corpus: SyntheticCorpusConfig = dataclasses.field(
        default_factory=lambda: SyntheticCorpusConfig(
            n_docs=3200, vocab_size=4096, n_topics=16))
    pv: PVDBOWConfig = dataclasses.field(
        default_factory=lambda: PVDBOWConfig(
            dim=64, steps=2000, batch_pairs=4096, lr=0.01, temperature=8.0))
    lsh: LSHConfig = dataclasses.field(
        default_factory=lambda: LSHConfig(bits=256))
    shard_tokens: int = 4096
    kmeans_allocate: bool = True


CONFIG = EmApproxConfig()


def smoke_config() -> EmApproxConfig:
    return EmApproxConfig(
        corpus=SyntheticCorpusConfig(n_docs=400, vocab_size=1024, n_topics=8),
        pv=PVDBOWConfig(dim=16, steps=100, batch_pairs=1024, lr=0.01,
                        temperature=8.0),
        lsh=LSHConfig(bits=64),
        shard_tokens=4096,
    )
