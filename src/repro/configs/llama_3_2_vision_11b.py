"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=128256 — cross-attn image layers every 5th layer
[hf:meta-llama/Llama-3.2-11B-Vision].  Vision frontend is a stub:
input_specs provides precomputed patch embeddings [B, 1601, d_model]."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=128256, head_dim=128,
    cross_attn_every=5, vision_tokens=1601,
    remat="full",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16, cross_attn_every=2,
        vision_tokens=16, max_seq_len=128)
