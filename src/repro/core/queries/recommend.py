"""Recommendation queries: approximate user-centric collaborative
filtering (paper Sec. IV-C, VII-D).

Setup (matches the paper): a "document" is the concatenation of all
reviews by one user, so PV-DBOW doc vectors are *user* vectors encoding
preference.  For a target user u:

  1. sample shards of users with probability proportional to
     exp(u . s)   (Eq 10 with the user vector as the query),
  2. neighbors = users in the sampled shards,
  3. predicted rating r(u, i) = sum_v sim(u,v) r(v,i) / sum_v sim(u,v)
     over neighbors v who rated i, with sim(u,v) = exp(u . v)
     (the paper's softmax-weighted average),
  4. rank unpurchased items by predicted rating for the top-k list.
"""
from __future__ import annotations

import time
from typing import Dict, NamedTuple, Optional, Sequence

import numpy as np

from repro.core.index import ApproxIndex
from repro.core.sampling import (
    SampleResult,
    pps_sample,
    similarity_probabilities,
    srcs_sample,
    unique_shards,
)
from repro.data.corpus import ReviewData
from repro.data.store import ShardedCorpus


class RecommendResult(NamedTuple):
    predictions: Dict[int, float]   # item_id -> predicted rating
    top_k: np.ndarray               # item ids, best first
    sample: SampleResult
    shards_read: int
    n_shards: int
    elapsed_s: float

    @property
    def data_fraction(self) -> float:
        return self.shards_read / self.n_shards


def recommend_query(
    corpus: ShardedCorpus,          # shards of user-documents
    index: Optional[ApproxIndex],
    reviews: ReviewData,
    target_user: int,
    rate: float,
    k: int = 10,
    *,
    method: str = "emapprox",
    rng: Optional[np.random.Generator] = None,
    target_vector: Optional[np.ndarray] = None,
    exclude_items: Optional[Sequence[int]] = None,
    candidate_items: Optional[Sequence[int]] = None,
) -> RecommendResult:
    """Predict ratings for ``target_user`` from a sampled neighborhood.

    ``target_vector`` overrides the index's stored user vector (used when
    the target user was held out / is new — paper Sec. V inference)."""
    rng = rng or np.random.default_rng(0)
    t0 = time.perf_counter()

    if target_vector is None:
        if index is None or index.doc_vecs is None:
            raise ValueError("need a target_vector or an index with doc vectors")
        target_vector = index.doc_vecs[target_user]

    if rate >= 1.0:
        distinct = np.arange(corpus.n_shards)
        sample = SampleResult(distinct.astype(np.int64),
                              np.full(corpus.n_shards, 1.0 / corpus.n_shards), 1.0)
    elif method == "emapprox":
        sims = index.vector_shard_similarities(target_vector)
        sample = pps_sample(similarity_probabilities(sims), rate, rng)
        distinct = unique_shards(sample)
    elif method == "srcs":
        sample = srcs_sample(corpus.n_shards, rate, rng)
        distinct = unique_shards(sample)
    else:
        raise ValueError(f"unknown method {method!r}")

    # neighbor set = users co-located in sampled shards (minus target)
    neighbor_ids = np.concatenate(
        [corpus.shards[int(s)].doc_ids for s in distinct]
    ) if len(distinct) else np.zeros(0, np.int64)
    neighbor_ids = neighbor_ids[neighbor_ids != target_user]

    # similarity weights sim(u, v) = exp(u . v) over neighbor user vectors
    if index is not None and index.doc_vecs is not None:
        nvecs = index.doc_vecs[neighbor_ids].astype(np.float64)
        u = np.asarray(target_vector, np.float64)
        u = u / max(np.linalg.norm(u), 1e-9)
        sims = np.exp(nvecs @ u)
    else:
        sims = np.ones(len(neighbor_ids), np.float64)
    sim_of = dict(zip(neighbor_ids.tolist(), sims.tolist()))

    # gather neighbor ratings per item (single pass over interactions)
    neighbor_mask = np.isin(reviews.user_of, neighbor_ids)
    u_of = reviews.user_of[neighbor_mask]
    i_of = reviews.item_of[neighbor_mask]
    r_of = reviews.ratings[neighbor_mask]

    num: Dict[int, float] = {}
    den: Dict[int, float] = {}
    for v, i, r in zip(u_of.tolist(), i_of.tolist(), r_of.tolist()):
        w = sim_of[v]
        num[i] = num.get(i, 0.0) + w * r
        den[i] = den.get(i, 0.0) + w
    predictions = {i: num[i] / den[i] for i in num if den[i] > 0}

    exclude = (set(int(x) for x in exclude_items)
               if exclude_items is not None else set())
    if candidate_items is not None:
        cand = [i for i in candidate_items if i in predictions and i not in exclude]
    else:
        cand = [i for i in predictions if i not in exclude]
    cand.sort(key=lambda i: -predictions[i])
    top_k = np.asarray(cand[:k], np.int64)
    return RecommendResult(predictions, top_k, sample, len(distinct),
                           corpus.n_shards, time.perf_counter() - t0)


def mse(predictions: Dict[int, float], truth_items: np.ndarray,
        truth_ratings: np.ndarray) -> float:
    """MSE over held-out (item, rating) pairs that received a prediction;
    items with no neighbor rating fall back to the global midpoint 3.0
    (so missing coverage is penalized, not silently dropped)."""
    errs = []
    for i, r in zip(truth_items.tolist(), truth_ratings.tolist()):
        pred = predictions.get(int(i), 3.0)
        errs.append((pred - r) ** 2)
    return float(np.mean(errs)) if errs else float("nan")


def precision_at_k(top_k: np.ndarray, purchased: np.ndarray, k: int = 10) -> float:
    if len(top_k) == 0:
        return 0.0
    return float(np.isin(top_k[:k], purchased).mean())
