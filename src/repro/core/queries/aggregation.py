"""Aggregation queries: phrase-occurrence estimation with error bounds
(paper Sec. III, evaluated in Sec. VII-B).

Pipeline (paper Fig. 2 a1-a5):
  1. q = sum of query word vectors; phi_s = softmax over exp(q . s)
     (or uniform for SRCS).
  2. pps-sample ceil(rate * n_shards) shards with replacement.
  3. Count the phrase exactly inside each distinct sampled shard
     (the "Spark job" — here the shard executor, which can run local
     threads or shard_map over devices).
  4. Hansen-Hurwitz estimate + t-based error bound (Eq 1, 2).
"""
from __future__ import annotations

import time
from typing import NamedTuple, Optional, Sequence

import numpy as np

from repro.core.index import ApproxIndex
from repro.core.sampling import (
    Estimate,
    SampleResult,
    ht_estimate,
    pps_sample,
    srcs_sample,
    unique_shards,
)
from repro.data.store import ShardedCorpus, count_phrase_in_shard


class PhraseCountResult(NamedTuple):
    estimate: Estimate
    sample: SampleResult
    shards_read: int
    n_shards: int
    elapsed_s: float
    # planned-but-unreachable shards (every replica dead): the reduce
    # ran over the surviving sample with a widened CI (batch engine
    # with allow_partial executors; always 0 on the healthy path)
    lost_shards: int = 0

    @property
    def data_fraction(self) -> float:
        return self.shards_read / self.n_shards

    @property
    def achieved_rate(self) -> float:
        """The rate actually served (after budget planning and any
        degradation): the fraction of shards physically read."""
        return self.data_fraction


def phrase_count_query(
    corpus: ShardedCorpus,
    index: Optional[ApproxIndex],
    phrase: Sequence[int],
    rate: float,
    *,
    method: str = "emapprox",       # "emapprox" | "srcs"
    rng: Optional[np.random.Generator] = None,
    confidence: float = 0.95,
    executor=None,
) -> PhraseCountResult:
    rng = rng or np.random.default_rng(0)
    t0 = time.perf_counter()
    if rate >= 1.0:
        # precise execution: scan everything, zero error bound
        total = precise_phrase_count(corpus, phrase, executor=executor)
        sample = SampleResult(
            np.arange(corpus.n_shards, dtype=np.int64),
            np.full(corpus.n_shards, 1.0 / corpus.n_shards), 1.0)
        return PhraseCountResult(
            estimate=Estimate(float(total), 0.0, confidence,
                              corpus.n_shards),
            sample=sample, shards_read=corpus.n_shards,
            n_shards=corpus.n_shards,
            elapsed_s=time.perf_counter() - t0)
    if method == "emapprox":
        if index is None:
            raise ValueError("emapprox method requires an index")
        probs = index.shard_probabilities(phrase)
        sample = pps_sample(probs, rate, rng)
    elif method == "srcs":
        sample = srcs_sample(corpus.n_shards, rate, rng)
    else:
        raise ValueError(f"unknown method {method!r}")

    distinct = unique_shards(sample)
    if executor is not None:
        counts_by_shard = executor.map_shards(
            corpus, distinct, lambda shard: count_phrase_in_shard(shard, phrase)
        )
    else:
        counts_by_shard = {
            int(sid): count_phrase_in_shard(corpus.shards[int(sid)], phrase)
            for sid in distinct
        }
    local = np.asarray([counts_by_shard[int(s)] for s in sample.shard_ids], np.float64)
    est = ht_estimate(local, sample, confidence)
    return PhraseCountResult(
        estimate=est,
        sample=sample,
        shards_read=len(distinct),
        n_shards=corpus.n_shards,
        elapsed_s=time.perf_counter() - t0,
    )


def precise_phrase_count(corpus: ShardedCorpus, phrase: Sequence[int],
                         executor=None) -> int:
    """The exact baseline ('pure Spark program')."""
    if executor is not None:
        all_ids = np.arange(corpus.n_shards)
        counts = executor.map_shards(
            corpus, all_ids, lambda shard: count_phrase_in_shard(shard, phrase)
        )
        return int(sum(counts.values()))
    return corpus.count_phrase(phrase)
