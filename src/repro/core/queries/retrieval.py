"""Distributed information retrieval queries (paper Sec. IV-B, VII-C).

Boolean retrieval: query is an AND/OR tree over words.  Shard
similarity follows the paper's generative-probability algebra:
    p(wi AND wj | s) = p(wi|s) * p(wj|s)
    p(wi OR  wj | s) = p(wi|s) + p(wj|s)
with each p(w|s) proportional to exp(w . s) (Eq 10).  Shards are then
pps-sampled and only their documents are evaluated against the query.

Ranked retrieval: query is a bag of words; shards are sampled via the
standard query-vector similarity (Eq 11); documents in the sample are
scored with BM25 (the paper's choice) using *offline* global df stats
from the index.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, NamedTuple, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.index import ApproxIndex
from repro.core.sampling import (
    Estimate,
    SampleResult,
    pps_sample_distinct,
    similarity_probabilities,
    unique_shards,
)
from repro.data.store import DocShard, ShardedCorpus


# ----------------------------------------------------------------------
# Boolean expression AST
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BoolExpr:
    op: str                                  # "word" | "and" | "or"
    word: Optional[int] = None
    left: Optional["BoolExpr"] = None
    right: Optional["BoolExpr"] = None

    @staticmethod
    def w(word: int) -> "BoolExpr":
        return BoolExpr("word", word=word)

    def __and__(self, other: "BoolExpr") -> "BoolExpr":
        return BoolExpr("and", left=self, right=other)

    def __or__(self, other: "BoolExpr") -> "BoolExpr":
        return BoolExpr("or", left=self, right=other)

    def words(self) -> List[int]:
        if self.op == "word":
            return [self.word]
        return self.left.words() + self.right.words()


def parse_boolean(tokens: Sequence[Union[int, str]]) -> BoolExpr:
    """Tiny recursive-descent parser: ints are words, 'and'/'or'/'('/')'
    are operators.  AND binds tighter than OR (paper Sec. IV-B)."""
    pos = 0

    def peek():
        return tokens[pos] if pos < len(tokens) else None

    def eat():
        nonlocal pos
        t = tokens[pos]
        pos += 1
        return t

    def atom() -> BoolExpr:
        t = eat()
        if t == "(":
            e = expr()
            if eat() != ")":
                raise ValueError("unbalanced parens")
            return e
        if isinstance(t, (int, np.integer)):
            return BoolExpr.w(int(t))
        raise ValueError(f"unexpected token {t!r}")

    def conj() -> BoolExpr:
        e = atom()
        while peek() == "and":
            eat()
            e = e & atom()
        return e

    def expr() -> BoolExpr:
        e = conj()
        while peek() == "or":
            eat()
            e = e | conj()
        return e

    out = expr()
    if pos != len(tokens):
        raise ValueError("trailing tokens")
    return out


def _expr_shard_similarity(expr: BoolExpr, index: ApproxIndex) -> np.ndarray:
    """p(q_b | s) for every shard via the paper's AND->product OR->sum
    algebra over per-word exp-similarities."""
    if expr.op == "word":
        return index.word_shard_similarity(expr.word)
    l = _expr_shard_similarity(expr.left, index)
    r = _expr_shard_similarity(expr.right, index)
    return l * r if expr.op == "and" else l + r


def _expr_eval_docs(expr: BoolExpr, shard: DocShard) -> np.ndarray:
    """Boolean [n_docs] mask of documents in ``shard`` satisfying expr.

    Word leaves walk the shard's CSR postings — O(docs containing the
    word) — instead of rescanning the flat token array per word; see
    ``_expr_eval_docs_scan`` for the parity reference."""
    if expr.op == "word":
        from repro.data.store import shard_postings
        mask = np.zeros(shard.n_docs, bool)
        mask[shard_postings(shard).lookup(expr.word)[0]] = True
        return mask
    l = _expr_eval_docs(expr.left, shard)
    r = _expr_eval_docs(expr.right, shard)
    return (l & r) if expr.op == "and" else (l | r)


def _expr_eval_docs_scan(expr: BoolExpr, shard: DocShard) -> np.ndarray:
    """Flat-scan reference for ``_expr_eval_docs`` (O(shard tokens) per
    word leaf) — kept for parity tests and one-shot evaluation."""
    if expr.op == "word":
        from repro.data.store import segment_sum_by_offsets
        hit = (shard.tokens == np.int32(expr.word)).astype(np.int64)
        return segment_sum_by_offsets(hit, shard.offsets) > 0
    l = _expr_eval_docs_scan(expr.left, shard)
    r = _expr_eval_docs_scan(expr.right, shard)
    return (l & r) if expr.op == "and" else (l | r)


class RetrievalResult(NamedTuple):
    doc_ids: np.ndarray
    sample: SampleResult
    shards_read: int
    n_shards: int
    elapsed_s: float
    # result-size estimate with bootstrap CI (batch engine with CIs
    # enabled; None from the single-query path / with CIs off)
    estimate: Optional["Estimate"] = None
    # planned-but-unreachable shards (every replica dead) — the union
    # ran over survivors only; always 0 on the healthy path
    lost_shards: int = 0

    @property
    def data_fraction(self) -> float:
        return self.shards_read / self.n_shards

    @property
    def achieved_rate(self) -> float:
        """The rate actually served (after budget planning and any
        degradation): the fraction of shards physically read."""
        return self.data_fraction


def boolean_query(
    corpus: ShardedCorpus,
    index: Optional[ApproxIndex],
    expr: BoolExpr,
    rate: float,
    *,
    method: str = "emapprox",
    rng: Optional[np.random.Generator] = None,
    executor=None,
) -> RetrievalResult:
    rng = rng or np.random.default_rng(0)
    t0 = time.perf_counter()
    if rate >= 1.0:
        distinct = np.arange(corpus.n_shards)
        sample = SampleResult(distinct.astype(np.int64),
                              np.full(corpus.n_shards, 1.0 / corpus.n_shards), 1.0)
    elif method == "emapprox":
        sims = _expr_shard_similarity(expr, index)
        sample = pps_sample_distinct(
            similarity_probabilities(sims), rate, rng)
        distinct = unique_shards(sample)
    elif method == "srcs":
        # NOTE: retrieval SRCS is uniform *without* replacement (the
        # paper's with-replacement SRCS only matters for the HH
        # aggregation estimator) so both methods read the same number
        # of distinct shards at a given rate — the comparison stays a
        # comparison of *which* shards, not how many
        uniform = np.full(corpus.n_shards, 1.0 / corpus.n_shards)
        sample = pps_sample_distinct(uniform, rate, rng)
        distinct = unique_shards(sample)
    else:
        raise ValueError(f"unknown method {method!r}")

    def work(shard: DocShard) -> np.ndarray:
        return shard.doc_ids[_expr_eval_docs(expr, shard)]

    if executor is not None:
        by_shard = executor.map_shards(corpus, distinct, work)
        hits = [by_shard[int(s)] for s in distinct]
    else:
        hits = [work(corpus.shards[int(s)]) for s in distinct]
    doc_ids = np.concatenate(hits) if hits else np.zeros(0, np.int64)
    return RetrievalResult(np.unique(doc_ids), sample, len(distinct),
                           corpus.n_shards, time.perf_counter() - t0)


# ----------------------------------------------------------------------
# Ranked retrieval (BM25)
# ----------------------------------------------------------------------
def bm25_scores_for_shard(
    shard: DocShard,
    query_words: Sequence[int],
    doc_freq: np.ndarray,
    n_docs: int,
    avg_doc_len: float,
    k1: float = 1.2,
    b: float = 0.75,
) -> np.ndarray:
    """BM25 (Robertson) over every document in the shard; [n_docs].

    Walks the shard's CSR postings of the query words, touching only
    documents that actually contain them (documents with tf=0
    contribute 0 to the sum, exactly as in the dense formula); see
    ``bm25_scores_for_shard_scan`` for the flat-scan parity reference.
    """
    from repro.data.store import shard_postings
    lens = np.diff(shard.offsets).astype(np.float64)
    scores = np.zeros(shard.n_docs, np.float64)
    norm = k1 * (1.0 - b + b * lens / max(avg_doc_len, 1e-9))
    post = shard_postings(shard)
    for w in query_words:
        docs, tf = post.lookup(w)
        if docs.size == 0:
            continue
        tf = tf.astype(np.float64)
        df = float(doc_freq[w])
        idf = np.log(1.0 + (n_docs - df + 0.5) / (df + 0.5))
        scores[docs] += idf * tf * (k1 + 1.0) / np.maximum(
            tf + norm[docs], 1e-9)
    return scores


def bm25_scores_for_shard_scan(
    shard: DocShard,
    query_words: Sequence[int],
    doc_freq: np.ndarray,
    n_docs: int,
    avg_doc_len: float,
    k1: float = 1.2,
    b: float = 0.75,
) -> np.ndarray:
    """Flat-scan reference for ``bm25_scores_for_shard``: one pass over
    the whole token array per query word."""
    lens = np.diff(shard.offsets).astype(np.float64)
    scores = np.zeros(shard.n_docs, np.float64)
    from repro.data.store import segment_sum_by_offsets
    norm = k1 * (1.0 - b + b * lens / max(avg_doc_len, 1e-9))
    for w in query_words:
        hit = (shard.tokens == np.int32(w)).astype(np.int64)
        tf = segment_sum_by_offsets(hit, shard.offsets).astype(np.float64)
        df = float(doc_freq[w])
        idf = np.log(1.0 + (n_docs - df + 0.5) / (df + 0.5))
        scores += idf * tf * (k1 + 1.0) / np.maximum(tf + norm, 1e-9)
    return scores


class RankedResult(NamedTuple):
    doc_ids: np.ndarray      # top-k, best first
    scores: np.ndarray
    sample: SampleResult
    shards_read: int
    n_shards: int
    elapsed_s: float
    # top-k stability score with bootstrap CI: 1.0 = every resample of
    # the sampled shards reproduces this top-k (batch engine with CIs
    # enabled; None from the single-query path / with CIs off)
    estimate: Optional["Estimate"] = None
    # planned-but-unreachable shards (every replica dead) — the top-k
    # merged survivors only; always 0 on the healthy path
    lost_shards: int = 0

    @property
    def data_fraction(self) -> float:
        return self.shards_read / self.n_shards

    @property
    def achieved_rate(self) -> float:
        """The rate actually served (after budget planning and any
        degradation): the fraction of shards physically read."""
        return self.data_fraction


def ranked_query(
    corpus: ShardedCorpus,
    index: Optional[ApproxIndex],
    query_words: Sequence[int],
    rate: float,
    k: int = 10,
    *,
    method: str = "emapprox",
    rng: Optional[np.random.Generator] = None,
    doc_freq: Optional[np.ndarray] = None,
    executor=None,
) -> RankedResult:
    """Top-k BM25 over a similarity-selected sample of shards."""
    rng = rng or np.random.default_rng(0)
    t0 = time.perf_counter()
    if doc_freq is None:
        if index is None:
            raise ValueError("need doc_freq or an index")
        doc_freq = index.doc_freq
    n_docs = index.n_docs if index is not None else corpus.n_docs
    avg_len = index.avg_doc_len if index is not None else corpus.n_tokens / max(n_docs, 1)

    if rate >= 1.0:
        distinct = np.arange(corpus.n_shards)
        sample = SampleResult(distinct.astype(np.int64),
                              np.full(corpus.n_shards, 1.0 / corpus.n_shards), 1.0)
    elif method == "emapprox":
        probs = index.shard_probabilities(query_words)
        sample = pps_sample_distinct(probs, rate, rng)
        distinct = unique_shards(sample)
    elif method == "srcs":
        # same note as boolean_query: uniform without replacement so
        # the srcs/emapprox comparison holds read budget fixed
        uniform = np.full(corpus.n_shards, 1.0 / corpus.n_shards)
        sample = pps_sample_distinct(uniform, rate, rng)
        distinct = unique_shards(sample)
    else:
        raise ValueError(f"unknown method {method!r}")

    def work(shard: DocShard) -> Tuple[np.ndarray, np.ndarray]:
        s = bm25_scores_for_shard(shard, query_words, doc_freq, n_docs, avg_len)
        return shard.doc_ids, s

    if executor is not None:
        by_shard = executor.map_shards(corpus, distinct, work)
        parts = [by_shard[int(s)] for s in distinct]
    else:
        parts = [work(corpus.shards[int(s)]) for s in distinct]
    if parts:
        ids = np.concatenate([p[0] for p in parts])
        sc = np.concatenate([p[1] for p in parts])
    else:
        ids, sc = np.zeros(0, np.int64), np.zeros(0, np.float64)
    order = np.argsort(-sc, kind="stable")[:k]
    return RankedResult(ids[order], sc[order], sample, len(distinct),
                        corpus.n_shards, time.perf_counter() - t0)


# ----------------------------------------------------------------------
# metrics
# ----------------------------------------------------------------------
def recall(approx_ids: np.ndarray, precise_ids: np.ndarray) -> float:
    if precise_ids.size == 0:
        return 1.0
    return float(np.isin(precise_ids, approx_ids).mean())


def precision_at_k(approx_ids: np.ndarray, precise_ids: np.ndarray, k: int) -> float:
    """Fraction of approx top-k that appear in the precise top-k (paper
    Sec. VII-A definition of P@k)."""
    a = approx_ids[:k]
    p = precise_ids[:k]
    if len(a) == 0:
        return 0.0
    return float(np.isin(a, p).mean())
