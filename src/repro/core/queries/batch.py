"""Batched query execution engine (the serving hot path).

Single-query execution (``phrase_count_query`` / ``boolean_query`` /
``ranked_query``) pays three per-query costs that a multi-user serving
workload should amortize:

  1. **Scoring** — every query scores its vector against all shard
     signatures alone (a GEMV per query).  ``QueryBatch`` plans the
     whole batch with one call to ``ApproxIndex.shard_similarities_batch``
     (one GEMM / one fused Pallas kernel launch; kernel-backed
     doc-granular indices take the fused in-kernel segment reduction,
     so the [B, n_docs] intermediate never reaches HBM), and Boolean
     queries batch-score the union of their distinct words once before
     applying the AND->product / OR->sum algebra per expression.
  2. **Shard I/O and task overhead** — every query pps-samples and then
     visits its shards independently, so a shard sampled by k queries
     is dispatched k times.  The batch engine unions the per-query
     plans and runs one *shared scan* per distinct shard
     (``ShardTaskExecutor.map_shard_batch``), evaluating all interested
     queries in that single visit — task count scales with the union,
     not the sum.  On a multi-host topology the same union splits by
     shard residency instead of pooling locally: pass a
     ``runtime.placement.HostGroupExecutor`` as ``executor`` and each
     host shared-scans only its resident slice of the union, with the
     cross-host gather feeding the per-query reduces unchanged (the
     executed plan is kept on ``last_report.plan`` so callers can audit
     the residency split, and a balanced host group's split decision —
     estimated vs realized per-host makespan, shed count — lands on
     ``last_report.balance``; the pre-report ``last_plan`` /
     ``last_audit`` names survive as deprecated read-only properties).
  3. **Scan work** — per-shard operators walk the lazily-built CSR
     postings (``data/store.shard_postings``), so the second query to
     touch a shard pays O(matching tokens), not O(shard tokens).

Statistical behavior is unchanged: each query still draws its own pps
sample from its own probability row (paper Eq 11), and the estimators
consume exactly the per-shard values the single-query path would have
produced — batching is purely an execution-layer rewrite, which is what
the parity tests in tests/test_batch_engine.py pin down.

Three serving-side extensions ride on the same machinery:

  * **Semantic query caching** — construct with a
    ``runtime.qcache.SemanticQueryCache`` and queries resolve against
    the index's own LSH signatures before planning: exact-signature
    hits return memoized results with zero scoring/draws/scans,
    near-hits within a Hamming radius reuse the cached sampling plan
    (unbiased for any sampling distribution — Hansen-Hurwitz) while
    re-running the scan + reduce, and misses stay bit-for-bit the
    uncached path.  Generation fencing (``runtime.generation``: a
    placement axis bumped by fleet swaps, a content axis bumped by
    live ingest / ``attach_corpus``) keeps cached plans and estimates
    from crossing either kind of world change; degraded and budgeted
    answers are never cached.  ``execute`` captures its corpus/index
    refs RCU-style at entry, so a concurrent ingest swap never splits
    a batch across generations and never pauses serving.

  * **Per-query error/latency budgets** — construct with a
    ``runtime.budget.RatePlanner`` and queries may carry a
    ``QueryBudget``; ``execute``'s ``rate`` argument becomes the
    *nominal* rate, and the planner picks each query's actual rate
    (smallest meeting an error budget, largest fitting a latency
    budget, degraded toward its floor under the controller's overload
    ``pressure``).  The per-query plans were always heterogeneous-safe:
    the shared scan unions whatever shard sets the samples produce.
    Queries without budgets keep the nominal rate bit-for-bit,
    including the precise rate>=1.0 fast path.
  * **Confidence intervals on every result** — count estimates always
    carry the closed-form Hansen-Hurwitz bound (Eq 2); with ``ci=True``
    Boolean results gain a bootstrap-over-sampled-shards CI on the
    result size and ranked results a bootstrap top-k stability score
    (``core.sampling.bootstrap_estimate`` /
    ``bootstrap_topk_stability``), so every answer ships as
    (estimate, ci_low, ci_high, achieved_rate).  The bootstrap uses
    its own deterministic generator — the sampling ``rng`` stream is
    never touched, so batched-vs-single draw-order parity holds.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.index import ApproxIndex
from repro.core.queries.aggregation import PhraseCountResult
from repro.core.queries.retrieval import (
    BoolExpr,
    RankedResult,
    RetrievalResult,
    _expr_eval_docs,
    bm25_scores_for_shard,
)
from repro.core.sampling import (
    Estimate,
    SampleResult,
    bootstrap_estimate,
    bootstrap_topk_stability,
    ht_estimate,
    pps_sample,
    pps_sample_distinct,
    similarity_probabilities,
    unique_shards,
)
from repro.data.store import (
    ShardedCorpus,
    count_phrase_in_shard,
    shard_postings,
)
from repro.runtime.generation import Generation
from repro.runtime.qcache import query_cache_vectors, query_key, sampler_class


@dataclasses.dataclass(frozen=True)
class ExecutionReport:
    """Typed, JSON-clean record of one ``QueryBatch.execute`` call.

    Replaces the old mutable ``last_plan`` / ``last_audit`` /
    ``last_budget`` / ``last_degraded`` attribute grab-bag with one
    report per batch on ``QueryBatch.last_report`` (the old names
    survive as deprecated read-only properties reading through it).

    ``plan`` is the *executed* plan — one array of scanned shard ids
    per query.  A semantic-cache exact hit executed nothing, so its
    slot is an empty array; ``cache`` carries the batch's cache outcome
    counts (hits / near_hits / misses / bypassed) when the engine has a
    ``SemanticQueryCache`` attached, None otherwise.
    """
    n_queries: int
    rate: float                          # nominal rate passed to execute
    elapsed_s: float
    rates: Tuple[float, ...]             # per-query effective rates
    plan: Tuple[np.ndarray, ...]         # executed shard ids per query
    balance: Optional[Dict[str, Any]] = None
    budget: Optional[Dict[str, Any]] = None
    degraded: Optional[Dict[str, Any]] = None
    cache: Optional[Dict[str, int]] = None

    def record(self) -> Dict[str, Any]:
        """JSON-serializable view (numpy arrays become int lists)."""
        return dict(
            n_queries=int(self.n_queries),
            rate=float(self.rate),
            elapsed_s=float(self.elapsed_s),
            rates=[float(r) for r in self.rates],
            plan=[[int(s) for s in p] for p in self.plan],
            balance=self.balance,
            budget=self.budget,
            degraded=self.degraded,
            cache=self.cache)


@dataclasses.dataclass(frozen=True)
class BatchQuery:
    """One query in a mixed batch: an aggregation phrase count, a
    Boolean retrieval, or a ranked (BM25 top-k) retrieval.

    ``budget`` (a ``runtime.budget.QueryBudget``) declares what the
    query may cost — an error budget, a latency budget, and a
    degradation floor.  It only takes effect when the executing
    ``QueryBatch`` carries a ``RatePlanner``; otherwise it is inert
    metadata and the query runs at the batch's nominal rate."""
    kind: str                                    # "count" | "bool" | "ranked"
    phrase: Optional[Tuple[int, ...]] = None     # kind == "count"
    expr: Optional[BoolExpr] = None              # kind == "bool"
    words: Optional[Tuple[int, ...]] = None      # kind == "ranked"
    k: int = 10                                  # kind == "ranked"
    budget: Optional[Any] = None                 # runtime.budget.QueryBudget

    @staticmethod
    def count(phrase: Sequence[int], budget=None) -> "BatchQuery":
        return BatchQuery("count", phrase=tuple(int(w) for w in phrase),
                          budget=budget)

    @staticmethod
    def boolean(expr: BoolExpr, budget=None) -> "BatchQuery":
        return BatchQuery("bool", expr=expr, budget=budget)

    @staticmethod
    def ranked(words: Sequence[int], k: int = 10,
               budget=None) -> "BatchQuery":
        return BatchQuery("ranked", words=tuple(int(w) for w in words),
                          k=k, budget=budget)

    def word_ids(self) -> List[int]:
        """The word ids whose vectors compose this query's scoring
        vector (Boolean queries score per-word instead)."""
        if self.kind == "count":
            return list(self.phrase)
        if self.kind == "ranked":
            return list(self.words)
        raise ValueError(f"no composed vector for kind {self.kind!r}")


class QueryBatch:
    """Plans, samples, and executes a mixed batch of queries end-to-end.

    One instance wraps a (corpus, index, executor) triple and is reused
    across batches; ``execute`` is the entry point.  Construction is
    cheap — all state lives in the arguments.  For serving a *stream*
    of queries, front this with ``runtime.window.BatchWindow``, which
    forms the batches adaptively (deadline- or size-closed) and runs
    them through ``execute`` on a warm executor pool.
    """

    def __init__(
        self,
        corpus: ShardedCorpus,
        index: Optional[ApproxIndex],
        *,
        executor=None,
        method: str = "emapprox",
        confidence: float = 0.95,
        planner=None,
        ci: bool = False,
        cache=None,
    ):
        if method not in ("emapprox", "srcs"):
            raise ValueError(f"unknown method {method!r}")
        if method == "emapprox" and index is None:
            raise ValueError("emapprox method requires an index")
        if cache is not None and index is None:
            raise ValueError("semantic query cache requires an index "
                             "(its keys are the index's LSH signatures)")
        # the engine's world is ONE tuple so RCU readers capture
        # (corpus, index) with a single atomic attribute load — a
        # concurrent ingest swap can never hand a batch a torn pair
        self._world = (corpus, index)
        self.executor = executor
        self.method = method
        self.confidence = confidence
        # ``planner`` (a runtime.budget.RatePlanner) turns the nominal
        # execute() rate into per-query rates honoring each query's
        # QueryBudget, and makes the engine accuracy-elastic under the
        # controller's degradation pressure (accepts_pressure below)
        self.planner = planner
        # ``ci=True`` adds bootstrap confidence intervals to Boolean /
        # ranked results (count bounds are closed-form and always on);
        # off by default because the bootstrap, while cheap, is not
        # free on the microsecond-scale serving hot path
        self.ci = bool(ci)
        # ``cache`` (a runtime.qcache.SemanticQueryCache) memoizes
        # per-query plans and results under the index's LSH signatures:
        # exact hits skip scoring, sampling, and the scan entirely;
        # near hits reuse the sampled shard plan and re-run the cheap
        # reduce.  Misses stay bit-for-bit the uncached path.
        self.cache = cache
        # the typed record of the most recent execute() call
        self.last_report: Optional[ExecutionReport] = None

    # ------------------------------------------------------------------
    # the world: (corpus, index) behind one atomic reference
    # ------------------------------------------------------------------
    @property
    def corpus(self) -> ShardedCorpus:
        return self._world[0]

    @corpus.setter
    def corpus(self, corpus) -> None:
        self._world = (corpus, self._world[1])

    @property
    def index(self) -> Optional[ApproxIndex]:
        return self._world[1]

    @index.setter
    def index(self, index) -> None:
        self._world = (self._world[0], index)

    def swap_world(self, corpus, index) -> None:
        """Publish a new (corpus, index) pair in one store — the RCU
        write side of live ingest.  Individual ``corpus``/``index``
        assignment still works but publishes in two stores; a swap
        that changes both MUST go through here (or a racing reader
        could capture a torn pair)."""
        self._world = (corpus, index)

    @property
    def accepts_pressure(self) -> bool:
        """Whether ``execute`` understands the ``pressure`` kwarg —
        i.e. the engine can trade accuracy for capacity.  BatchWindow
        checks this before forwarding the controller's degradation
        pressure (and before preferring degradation over shedding)."""
        return self.planner is not None

    # ------------------------------------------------------------------
    # deprecated read-only views of last_report (pre-report callers)
    # ------------------------------------------------------------------
    @property
    def last_plan(self) -> Optional[List[np.ndarray]]:
        """Deprecated: read ``last_report.plan`` — the executed shard
        plan (one array of scanned shard ids per query)."""
        r = self.last_report
        return list(r.plan) if r is not None else None

    @property
    def last_audit(self) -> Optional[Dict[str, Any]]:
        """Deprecated: read ``last_report.balance`` — the balanced
        host group's split audit, None otherwise."""
        r = self.last_report
        return r.balance if r is not None else None

    @property
    def last_budget(self) -> Optional[Dict[str, Any]]:
        """Deprecated: read ``last_report.budget`` — the planner's
        budget audit record, None without a planner."""
        r = self.last_report
        return r.budget if r is not None else None

    @property
    def last_degraded(self) -> Optional[Dict[str, Any]]:
        """Deprecated: read ``last_report.degraded`` — the partial
        gather record (lost shards, per-query breakdown), None on the
        healthy path."""
        r = self.last_report
        return r.degraded if r is not None else None

    # ------------------------------------------------------------------
    # planning: one batched scoring pass -> per-query probability rows
    # ------------------------------------------------------------------
    def _probability_rows(
            self, queries: Sequence[BatchQuery], corpus: ShardedCorpus,
            index: Optional[ApproxIndex]) -> List[np.ndarray]:
        # corpus/index come in as the refs execute() captured at entry
        # (RCU: a concurrent ingest swap must not split one batch
        # across two content generations)
        n_shards = corpus.n_shards
        if self.method == "srcs":
            uniform = np.full(n_shards, 1.0 / n_shards, np.float64)
            return [uniform] * len(queries)
        # one batched scoring pass for all vector-composed queries ...
        vec_pos = [i for i, q in enumerate(queries) if q.kind != "bool"]
        rows: List[Optional[np.ndarray]] = [None] * len(queries)
        if vec_pos:
            sims = index.shard_similarities_batch(
                [queries[i].word_ids() for i in vec_pos])
            for row, i in zip(sims, vec_pos):
                rows[i] = similarity_probabilities(row)
        # ... and one for the union of Boolean query words
        bool_pos = [i for i, q in enumerate(queries) if q.kind == "bool"]
        if bool_pos:
            words = sorted({w for i in bool_pos
                            for w in queries[i].expr.words()})
            word_rows = dict(zip(
                words, index.word_shard_similarities_batch(words)))

            def algebra(e: BoolExpr) -> np.ndarray:
                if e.op == "word":
                    return word_rows[e.word]
                l, r = algebra(e.left), algebra(e.right)
                return l * r if e.op == "and" else l + r

            for i in bool_pos:
                rows[i] = similarity_probabilities(algebra(queries[i].expr))
        return rows

    # ------------------------------------------------------------------
    # per-query shard tasks
    # ------------------------------------------------------------------
    @staticmethod
    def _shard_fn(q: BatchQuery, doc_freq: np.ndarray, n_docs: int,
                  avg_len: float) -> Callable[[Any], Any]:
        if q.kind == "count":
            if len(q.phrase) == 1:
                w = q.phrase[0]
                return lambda shard: shard_postings(shard).word_count(w)
            return lambda shard: count_phrase_in_shard(shard, q.phrase)
        if q.kind == "bool":
            return lambda shard: shard.doc_ids[_expr_eval_docs(q.expr, shard)]
        if q.kind == "ranked":
            return lambda shard: (shard.doc_ids, bm25_scores_for_shard(
                shard, q.words, doc_freq, n_docs, avg_len))
        raise ValueError(f"unknown query kind {q.kind!r}")

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------
    def execute(
        self,
        queries: Sequence[BatchQuery],
        rate: float,
        rng: Optional[np.random.Generator] = None,
        *,
        pressure: float = 0.0,
    ) -> List[Any]:
        """Run the batch; returns one result per query, in order:
        ``PhraseCountResult`` / ``RetrievalResult`` / ``RankedResult``
        (the same types the single-query entry points return).

        ``elapsed_s`` on every result is the wall time of the *whole*
        batch — under shared scans per-query attribution is not well
        defined; divide by ``len(queries)`` for amortized latency.

        Sampling draws happen in query order from ``rng``, so a batch
        reproduces the exact sample sequence of a single-query loop
        over the same queries with the same generator.

        With a planner, ``rate`` is the nominal rate and each query
        samples at its own planned rate (its budget inverted through
        the planner's error/latency models, degraded toward its floor
        by ``pressure`` in [0, 1] — the controller's overload signal,
        forwarded by ``BatchWindow``).  Queries at a planned rate
        >= 1.0 take the precise path individually, so an unbudgeted
        batch at nominal rate 1.0 stays bit-for-bit the precise
        fast path.

        With a semantic cache attached, queries resolve against it
        before planning: exact-signature hits return their memoized
        result (no scoring, no draws, no scan — and no rng
        consumption, so the remaining misses draw exactly what they
        would draw in a batch of their own), near-hits borrow the
        cached sampling plan and re-run only the scan + reduce, and
        misses execute bit-for-bit the uncached path.  Budgeted
        queries and pressure-degraded batches bypass the cache in both
        directions: a planned-rate or partial answer is a
        point-in-time decision, never replayable as full fidelity.
        """
        rng = rng or np.random.default_rng(0)
        t0 = time.perf_counter()
        # RCU entry: read the generation BEFORE capturing the corpus /
        # index refs.  The ingest swap publishes new refs first and
        # bumps the content generation second, so this order can at
        # worst stamp a new-content result with the old generation (an
        # entry the very next probe drops) — never the reverse, which
        # would let an old-content answer serve under the new
        # generation.  The whole batch then runs against the captured
        # refs: a concurrent swap never splits one batch across two
        # content generations.
        epoch = self._generation() if self.cache is not None else 0
        corpus, index = self._world
        n_shards = corpus.n_shards
        n = len(queries)

        if self.planner is not None:
            rates, audit = self.planner.plan_batch(queries, rate, pressure)
        else:
            rates, audit = [float(rate)] * n, None

        # ---- semantic cache probe (before planning) ----
        hits: Dict[int, Any] = {}
        near: Dict[int, Any] = {}
        cache_meta: Optional[Dict[str, int]] = None
        sigs = qkeys = None
        if self.cache is not None and n:
            sigs = index.query_signatures(
                query_cache_vectors(index, queries))
            qkeys = [query_key(q) for q in queries]
            bypassed = 0
            for i, q in enumerate(queries):
                if pressure > 0.0 or q.budget is not None:
                    bypassed += 1
                    self.cache.stats["bypassed"] += 1
                    continue
                outcome, entry = self.cache.lookup(
                    sigs[i], qkeys[i], sampler_class(q.kind),
                    rates[i], epoch)
                if outcome == "hit":
                    hits[i] = entry
                elif outcome == "near":
                    near[i] = entry
            cache_meta = dict(
                hits=len(hits), near_hits=len(near),
                misses=n - len(hits) - len(near) - bypassed,
                bypassed=bypassed)

        all_ids = np.arange(n_shards, dtype=np.int64)
        uniform = np.full(n_shards, 1.0 / n_shards, np.float64)
        census = SampleResult(all_ids, uniform, 1.0)
        samples: List[Optional[SampleResult]] = [None] * n
        plan: List[Optional[np.ndarray]] = [None] * n
        for i, e in list(hits.items()) + list(near.items()):
            samples[i], plan[i] = e.sample, e.plan
        need = [i for i in range(n) if samples[i] is None]
        rows_by_pos: Dict[int, np.ndarray] = {}
        if need and all(rates[i] >= 1.0 for i in need):
            for i in need:
                samples[i], plan[i] = census, all_ids
        elif need:
            rows = self._probability_rows(
                [queries[i] for i in need], corpus, index)
            # aggregation keeps the with-replacement multiset (the
            # Hansen-Hurwitz estimator needs it); retrieval unions docs
            # over the sample, so it draws distinct shards — same
            # samplers, in the same query order, as the single-query
            # entry points (pinned by the parity tests).  Per-query
            # precise rates draw nothing, exactly as the single-query
            # precise path draws nothing; cache-resolved queries draw
            # nothing either, so the misses' draw sequence matches a
            # batch of only the misses.
            for i, row in zip(need, rows):
                r, q = rates[i], queries[i]
                if r >= 1.0:
                    samples[i], plan[i] = census, all_ids
                    continue
                rows_by_pos[i] = row
                samples[i] = (pps_sample(row, r, rng) if q.kind == "count"
                              else pps_sample_distinct(row, r, rng))
                plan[i] = unique_shards(samples[i])

        if index is not None:
            doc_freq = index.doc_freq
            n_docs, avg_len = index.n_docs, index.avg_doc_len
        else:
            doc_freq = np.ones(corpus.vocab_size, np.int64)
            n_docs = corpus.n_docs
            avg_len = corpus.n_tokens / max(n_docs, 1)
        fns = [self._shard_fn(q, doc_freq, n_docs, avg_len) for q in queries]

        # exact hits scan nothing: their slot in the executed plan is
        # empty, and an all-hit batch skips executor dispatch entirely
        empty = np.zeros(0, np.int64)
        scan_plan = [empty if i in hits else plan[i] for i in range(n)]
        if n and len(hits) == n:
            per_query: List[Dict[int, Any]] = [{} for _ in range(n)]
            job, balance = None, None
        elif self.executor is not None:
            per_query = self.executor.map_shard_batch(
                corpus, scan_plan, fns)
            job = getattr(self.executor, "last_job", None)
            balance = (dict(job["balance"])
                       if isinstance(job, dict) and "balance" in job
                       else None)
        else:
            per_query = self._inline_shared_scan(scan_plan, fns, corpus)
            job, balance = None, None

        # partial gather (allow_partial executors only): shards whose
        # hosts all died never produced results — each affected query
        # reduces over its surviving sample with a widened CI instead
        # of the whole batch aborting
        lost_total = (int(job.get("lost_shards", 0))
                      if isinstance(job, dict) else 0)
        lost_per_query = [0] * n
        degraded = None
        if lost_total:
            lost_per_query = [
                sum(1 for s in scan_plan[i] if int(s) not in per_query[i])
                for i in range(n)]
            degraded = dict(
                lost_shards=lost_total,
                degraded_queries=sum(1 for k in lost_per_query if k),
                lost_per_query=lost_per_query)

        elapsed = time.perf_counter() - t0
        results = [
            hits[i].result._replace(elapsed_s=elapsed) if i in hits
            else self._reduce(queries[i], samples[i], plan[i], per_query[i],
                              elapsed, rates[i] >= 1.0, n_shards,
                              lost=lost_per_query[i])
            for i in range(n)]

        # populate: misses and near-hits insert their own full-fidelity
        # entries; degraded answers (lost draws) never enter the cache
        if self.cache is not None and n:
            for i, q in enumerate(queries):
                if (i in hits or pressure > 0.0 or q.budget is not None
                        or lost_per_query[i]):
                    continue
                self.cache.insert(
                    sigs[i], qkeys[i], sampler_class(q.kind), rates[i],
                    probs=rows_by_pos.get(i), sample=samples[i],
                    plan=plan[i], result=results[i], epoch=epoch)

        budget = self._feedback(queries, rates, results, audit, job,
                                degraded)
        self.last_report = ExecutionReport(
            n_queries=n, rate=float(rate), elapsed_s=elapsed,
            rates=tuple(float(r) for r in rates), plan=tuple(scan_plan),
            balance=balance, budget=budget, degraded=degraded,
            cache=cache_meta)
        return results

    def _generation(self) -> Generation:
        """The engine's composite ``Generation`` — the fencing value
        cache entries are stamped with and probed against.

        The *placement* axis comes from the executor's
        ``GenerationClock`` (every RCU placement swap — fleet
        join/drain/crash, ingest shard growth — bumps it), falling
        back to the deprecated ``stats["placement_epoch"]`` view for
        clock-less executors; executors without placement (single
        host, inline) are placement 0.  The *content* axis comes from
        the index's clock (live ingest swaps and ``attach_corpus``
        bump it) — this is what lets the cache see corpus changes that
        leave placement untouched."""
        clock = getattr(self.executor, "clock", None)
        placement = (clock.current().placement if clock is not None
                     else self._cache_epoch())
        content = (self.index.clock.current().content
                   if self.index is not None else 0)
        return Generation(placement=placement, content=content)

    def _cache_epoch(self) -> int:
        """Deprecated: the raw placement int read off executor stats.
        Kept as the fallback placement source for executors predating
        ``GenerationClock`` — it cannot see content changes, which is
        why ``_generation`` exists."""
        stats = getattr(self.executor, "stats", None)
        if isinstance(stats, dict):
            return int(stats.get("placement_epoch", 0))
        return 0

    def _feedback(self, queries: Sequence[BatchQuery],
                  rates: Sequence[float], results: Sequence[Any],
                  audit, job, degraded) -> Optional[Dict[str, Any]]:
        """Close the planning loop: fold every realized (sample size,
        relative error) back into the planner's per-kind error curves,
        complete the batch's ``BudgetAudit`` with realized errors, and
        attach its record to the executor's ``last_job["budget"]`` (the
        budget analogue of the balance audit).  Returns the budget
        record for the batch's ``ExecutionReport``."""
        if self.planner is None or audit is None:
            return None
        realized: List[Optional[float]] = []
        for q, r, res in zip(queries, rates, results):
            est = getattr(res, "estimate", None)
            if est is None:
                realized.append(None)
                continue
            # ranked stability is a score in [0, 1]; its error is the
            # instability (1 - value), already relative
            rel = (1.0 - est.value if q.kind == "ranked"
                   else est.relative_error)
            realized.append(rel)
            conf = (q.budget.confidence if q.budget is not None
                    else self.confidence)
            self.planner.observe_result(q.kind, r, est.n, rel, conf)
        audit.realized_rel_error = realized
        if degraded is not None:
            audit.partial_queries = degraded["degraded_queries"]
            audit.lost_shards = degraded["lost_shards"]
        budget = audit.record()
        if isinstance(job, dict):
            job["budget"] = budget
        return budget

    def _inline_shared_scan(
        self,
        plan: Sequence[np.ndarray],
        fns: Sequence[Callable[[Any], Any]],
        corpus: ShardedCorpus,
    ) -> List[Dict[int, Any]]:
        """Executor-less fallback: the same union-and-visit-once
        schedule (``run_shared_scan``), run sequentially in-process
        over the corpus ref ``execute`` captured at entry."""
        from repro.runtime.executor import run_shared_scan

        def inline_mapper(corpus, shard_ids, fn):
            return {sid: fn(corpus.shards[sid]) for sid in shard_ids}

        return run_shared_scan(inline_mapper, corpus, plan, fns)

    def _reduce(self, q: BatchQuery, sample: SampleResult,
                distinct: np.ndarray, by_shard: Dict[int, Any],
                elapsed: float, precise: bool, n_shards: int,
                lost: int = 0) -> Any:
        conf = (q.budget.confidence if q.budget is not None
                else self.confidence)
        if lost:
            # degraded reduce: drop the unreachable shards from the
            # sample and the visit set and run the normal estimators
            # over the survivors.  Host loss is independent of shard
            # values, so Hansen-Hurwitz over the surviving draws stays
            # unbiased — the CI simply widens with the smaller sample
            # (fewer draws, fewer distinct shards of t-df).  A census
            # that lost shards is no longer precise: it degrades to
            # the same surviving-sample estimator.
            keep = np.asarray([int(s) in by_shard
                               for s in sample.shard_ids], bool)
            sample = SampleResult(sample.shard_ids[keep],
                                  sample.probabilities, sample.rate)
            distinct = np.asarray([s for s in distinct
                                   if int(s) in by_shard], np.int64)
            precise = False
        if q.kind == "count":
            if precise:
                total = float(sum(by_shard.values()))
                est = Estimate(total, 0.0, conf, n_shards)
            elif len(sample.shard_ids) == 0:
                # every draw lost: no information, infinite bound
                est = Estimate(0.0, float("inf"), conf, 0)
            else:
                local = np.asarray([by_shard[int(s)]
                                    for s in sample.shard_ids], np.float64)
                est = ht_estimate(local, sample, conf)
            return PhraseCountResult(est, sample, len(distinct), n_shards,
                                     elapsed, lost)
        if q.kind == "bool":
            hits = [by_shard[int(s)] for s in distinct]
            doc_ids = (np.concatenate(hits) if hits
                       else np.zeros(0, np.int64))
            est = None
            if self.ci:
                if precise:
                    est = Estimate(float(len(np.unique(doc_ids))), 0.0,
                                   conf, n_shards)
                else:
                    # result-size CI by resampling the per-shard hit
                    # counts; a fresh deterministic generator so the
                    # sampling rng stream stays parity-exact
                    local = np.asarray([len(by_shard[int(s)])
                                        for s in sample.shard_ids],
                                       np.float64)
                    est = bootstrap_estimate(
                        local, sample, conf,
                        rng=np.random.default_rng(len(distinct)))
            return RetrievalResult(np.unique(doc_ids), sample, len(distinct),
                                   n_shards, elapsed, est, lost)
        parts = [by_shard[int(s)] for s in distinct]
        if parts:
            ids = np.concatenate([p[0] for p in parts])
            sc = np.concatenate([p[1] for p in parts])
        else:
            ids, sc = np.zeros(0, np.int64), np.zeros(0, np.float64)
        order = np.argsort(-sc, kind="stable")[:q.k]
        est = None
        if self.ci:
            if precise:
                est = Estimate(1.0, 0.0, conf, n_shards)
            else:
                est = bootstrap_topk_stability(
                    parts, q.k, conf,
                    rng=np.random.default_rng(len(distinct)))
        return RankedResult(ids[order], sc[order], sample, len(distinct),
                            n_shards, elapsed, est, lost)
