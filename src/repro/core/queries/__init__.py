"""The paper's three query families over one index (Table I)."""
from repro.core.queries.aggregation import phrase_count_query, PhraseCountResult  # noqa: F401
from repro.core.queries.retrieval import (  # noqa: F401
    BoolExpr, boolean_query, ranked_query, parse_boolean,
)
from repro.core.queries.recommend import recommend_query, RecommendResult  # noqa: F401
