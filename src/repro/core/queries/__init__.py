"""The paper's three query families over one index (Table I), plus the
batched execution engine that serves mixed batches of them end-to-end
(one-pass scoring, per-shard postings, shared-scan scheduling)."""
from repro.core.queries.aggregation import phrase_count_query, PhraseCountResult  # noqa: F401
from repro.core.queries.retrieval import (  # noqa: F401
    BoolExpr, boolean_query, ranked_query, parse_boolean,
    precision_at_k, recall,
)
from repro.core.queries.recommend import recommend_query, RecommendResult  # noqa: F401
from repro.core.queries.batch import (  # noqa: F401
    BatchQuery, ExecutionReport, QueryBatch,
)
