"""Document allocation via spherical k-means (paper Sec. IV-D).

Clusters documents by cosine of their PV-DBOW vectors so semantically
similar documents land in the same shard.  By the AM-GM argument in the
paper, co-locating documents with similar p(w|d) pushes the shard-level
p(w|s) (a geometric mean) toward its maximum, skewing phi_s(w) — which
is what retrieval-style queries need.

The assignment step (docs x centroids normalized dot + argmax) is the
compute hot spot and has a Pallas kernel (kernels/kmeans); this module
falls back to pure jnp.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class KMeansConfig:
    n_clusters: int
    iters: int = 25
    seed: int = 3
    balanced: bool = True   # cap cluster sizes so shards stay rectangular-ish
    use_kernel: bool = False


def _unit(x: jnp.ndarray) -> jnp.ndarray:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-8)


def spherical_kmeans(
    doc_vecs: np.ndarray,
    cfg: KMeansConfig,
) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (assignment[n_docs] int64, centroids[k, dim] float32)."""
    x = _unit(jnp.asarray(doc_vecs, jnp.float32))
    n, dim = x.shape
    k = cfg.n_clusters
    key = jax.random.PRNGKey(cfg.seed)
    init_ids = jax.random.choice(key, n, shape=(k,), replace=False)
    centroids = x[init_ids]

    if cfg.use_kernel:
        from repro.kernels.kmeans import ops as kmeans_ops
        def assign_fn(xx, cc):
            return kmeans_ops.assign(xx, cc)
    else:
        @jax.jit
        def assign_fn(xx, cc):
            scores = xx @ cc.T            # cosine since both unit
            return jnp.argmax(scores, axis=1).astype(jnp.int32)

    @jax.jit
    def update_fn(xx, assign):
        sums = jax.ops.segment_sum(xx, assign, num_segments=k)
        counts = jax.ops.segment_sum(jnp.ones((xx.shape[0],), jnp.float32), assign, num_segments=k)
        new_c = sums / jnp.maximum(counts[:, None], 1.0)
        # dead centroids keep their position (norm ~ 0 -> re-unit protects)
        return _unit(jnp.where(counts[:, None] > 0, new_c, 0.0) +
                     jnp.where(counts[:, None] > 0, 0.0, 1e-4))

    assign = assign_fn(x, centroids)
    for _ in range(cfg.iters):
        centroids = update_fn(x, assign)
        new_assign = assign_fn(x, centroids)
        if bool(jnp.all(new_assign == assign)):
            assign = new_assign
            break
        assign = new_assign

    assign = np.asarray(assign, np.int64)
    if cfg.balanced:
        assign = _rebalance(np.asarray(x), np.asarray(centroids), assign, k)
    return assign, np.asarray(centroids, np.float32)


def _rebalance(x: np.ndarray, centroids: np.ndarray, assign: np.ndarray, k: int) -> np.ndarray:
    """Greedy capacity rebalancing: clusters above ceil(n/k)*slack spill
    their worst-fitting members to the nearest under-capacity cluster.
    Keeps shard sizes within ~25% of uniform so no shard becomes a
    straggler (runtime concern the paper's HDFS blocks got for free)."""
    n = x.shape[0]
    cap = int(np.ceil(n / k * 1.25))
    scores = x @ centroids.T
    order = np.argsort(-scores.max(axis=1))  # strongest members keep seats
    counts = np.zeros(k, np.int64)
    out = np.empty(n, np.int64)
    pref = np.argsort(-scores, axis=1)
    for i in order:
        for c in pref[i]:
            if counts[c] < cap:
                out[i] = c
                counts[c] += 1
                break
        else:  # all full (can't happen with slack>1, but be safe)
            c = int(np.argmin(counts))
            out[i] = c
            counts[c] += 1
    return out


def allocate_corpus(corpus, index_doc_vecs: np.ndarray, n_shards: Optional[int] = None,
                    cfg: Optional[KMeansConfig] = None):
    """Convenience: cluster + reallocate, returning the new corpus.

    Paper Sec. VII-A sets n_clusters = number of HDFS blocks; we default
    to the current shard count."""
    n_shards = n_shards or corpus.n_shards
    cfg = cfg or KMeansConfig(n_clusters=n_shards)
    if cfg.n_clusters != n_shards:
        cfg = dataclasses.replace(cfg, n_clusters=n_shards)
    assign, _ = spherical_kmeans(index_doc_vecs, cfg)
    return corpus.reallocate(assign, n_shards)
