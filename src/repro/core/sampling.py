"""Cluster sampling + estimators (paper Sec. II-B, III-A).

The estimator is Hansen-Hurwitz / pps-with-replacement (paper Eq 1):

    tau_hat = (1/n) sum_{s in S} tau_s / phi_s

with the variance estimate and t-based confidence interval of Eq 2.
``phi_s`` comes either from similarity (EmApprox: Eq 11 softmax over
exp(q . s)) or is uniform (SRCS baseline).  The math is identical for
both — only the probability vector changes, which is exactly the paper's
framing.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.utils.stats import t_critical_value


class SampleResult(NamedTuple):
    shard_ids: np.ndarray        # int64 [n] sampled shard ids (with replacement)
    probabilities: np.ndarray    # float64 [n_shards] the phi vector used
    rate: float                  # nominal sampling rate


def similarity_probabilities(
    similarities: np.ndarray,
    floor: float = 1e-6,
) -> np.ndarray:
    """Paper Eq 11: phi_s = sim_s / sum(sim).  A small floor keeps every
    shard selectable so the HT estimator stays unbiased (phi_s > 0)."""
    s = np.asarray(similarities, np.float64)
    s = np.maximum(s, 0.0) + floor
    return s / s.sum()


def pps_sample(
    probabilities: np.ndarray,
    rate: float,
    rng: np.random.Generator,
) -> SampleResult:
    """Probability-proportional-to-size sampling with replacement.

    ``rate`` maps to a sample size n = ceil(rate * n_shards), matching
    the paper's 'block sampling rate'."""
    p = np.asarray(probabilities, np.float64)
    p = p / p.sum()
    n_shards = p.shape[0]
    n = max(1, int(np.ceil(rate * n_shards)))
    ids = rng.choice(n_shards, size=n, replace=True, p=p)
    return SampleResult(ids.astype(np.int64), p, rate)


def pps_sample_distinct(
    probabilities: np.ndarray,
    rate: float,
    rng: np.random.Generator,
) -> SampleResult:
    """Probability-proportional-to-size sampling *without* replacement
    (Efraimidis-Spirakis exponential keys: take the n smallest
    ``-log(u)/phi``).

    Retrieval queries (Boolean / ranked top-k) union documents over the
    sampled shards — they never form a Hansen-Hurwitz estimate — so a
    with-replacement multiset only wastes read budget on duplicate
    draws: at rate 0.6 on a skewed phi a with-replacement sample can
    physically touch under a third of the shards.  Drawing ``n =
    ceil(rate * n_shards)`` *distinct* shards makes the realized data
    fraction match the nominal rate while still concentrating reads on
    similar shards.  Aggregation queries keep ``pps_sample`` (Eq 1
    needs the with-replacement multiset)."""
    p = np.asarray(probabilities, np.float64)
    p = p / p.sum()
    n_shards = p.shape[0]
    n = min(n_shards, max(1, int(np.ceil(rate * n_shards))))
    u = rng.random(n_shards)
    with np.errstate(divide="ignore"):
        keys = -np.log(u) / np.maximum(p, 1e-300)
    ids = np.sort(np.argpartition(keys, n - 1)[:n])
    return SampleResult(ids.astype(np.int64), p, rate)


def srcs_sample(
    n_shards: int,
    rate: float,
    rng: np.random.Generator,
) -> SampleResult:
    """Simple random cluster sampling (the paper's baseline)."""
    p = np.full(n_shards, 1.0 / n_shards, np.float64)
    n = max(1, int(np.ceil(rate * n_shards)))
    ids = rng.choice(n_shards, size=n, replace=True, p=p)
    return SampleResult(ids.astype(np.int64), p, rate)


class Estimate(NamedTuple):
    value: float          # tau_hat
    error_bound: float    # epsilon at the requested confidence
    confidence: float
    n: int                # sample size

    @property
    def relative_error(self) -> float:
        """``error_bound / |value|``, degenerate-safe.

        Serving plans rates from realized relative errors, so the
        degenerate corners an online planner actually hits must come
        back as orderable floats, never raise or go NaN: a single
        sampled shard carries an infinite bound (df=0 — no variance
        estimate exists); a zero-valued estimate has no scale, so any
        positive bound is unbounded error while a zero-width bound
        around zero (an exact zero, e.g. a census that found nothing)
        is exactly 0.0."""
        if math.isnan(self.error_bound) or math.isinf(self.error_bound):
            return float("inf")
        if self.value == 0.0 or not math.isfinite(self.value):
            return 0.0 if self.error_bound == 0.0 else float("inf")
        return abs(self.error_bound) / abs(self.value)

    @property
    def interval(self) -> Tuple[float, float]:
        """``(value - eps, value + eps)``, always well-ordered: an
        infinite bound yields ``(-inf, inf)`` (covers everything)
        instead of the NaN endpoints naive arithmetic produces when
        the value itself is non-finite."""
        if not math.isfinite(self.error_bound):
            return (float("-inf"), float("inf"))
        return (self.value - self.error_bound, self.value + self.error_bound)

    def covers(self, truth: float) -> bool:
        """Does the interval contain ``truth``?  (The smoke gate's
        ground-truth coverage check for count queries.)"""
        lo, hi = self.interval
        return lo <= truth <= hi


def ht_estimate(
    local_values: np.ndarray,
    sample: SampleResult,
    confidence: float = 0.95,
) -> Estimate:
    """Paper Eq 1 & 2 over per-shard local results ``tau_s``.

    ``local_values[i]`` is the exact local quantity computed on sampled
    shard ``sample.shard_ids[i]`` (duplicates allowed — with-replacement
    draws each count once, per Hansen-Hurwitz)."""
    tau = np.asarray(local_values, np.float64)
    phi = sample.probabilities[sample.shard_ids]
    n = tau.shape[0]
    scaled = tau / phi                      # tau_s / phi_s
    tau_hat = scaled.mean() / 1.0
    # Eq 1 has (1/n) sum, i.e. the mean of scaled values.  The interval
    # is degenerate-safe for the tiny samples degraded serving actually
    # draws: with-replacement draws that all land on ONE shard carry no
    # variance information (the naive formula returns a zero-width CI
    # around that shard's scaled value — confidently wrong), so the
    # bound goes infinite; and the t quantile uses the *distinct* draw
    # count as its effective replication — duplicate draws of a hot
    # shard are not independent evidence, and the naive n-1 df lets a
    # near-collapsed sample report a far tighter interval than its
    # information content supports.
    n_distinct = len(np.unique(sample.shard_ids)) if n else 0
    if n > 1 and n_distinct > 1:
        var_hat = np.sum((scaled - tau_hat) ** 2) / (n * (n - 1))
        eps = t_critical_value(n_distinct - 1, confidence) * np.sqrt(var_hat)
    else:
        eps = float("inf")
    return Estimate(float(tau_hat), float(eps), confidence, n)


def mean_estimate(
    local_sums: np.ndarray,
    local_counts: np.ndarray,
    sample: SampleResult,
    confidence: float = 0.95,
) -> Estimate:
    """Ratio estimator for averages (the paper's second provided reduce
    function): estimate sum and count jointly, report sum/count with a
    linearized (Taylor) variance."""
    sums = np.asarray(local_sums, np.float64)
    counts = np.asarray(local_counts, np.float64)
    phi = sample.probabilities[sample.shard_ids]
    n = sums.shape[0]
    s_hat = (sums / phi).mean()
    c_hat = (counts / phi).mean()
    if c_hat == 0:
        return Estimate(0.0, float("inf"), confidence, n)
    r = s_hat / c_hat
    # same degenerate-sample guard as ht_estimate: one distinct shard
    # carries no variance information, and duplicate draws are not
    # independent evidence for the t quantile
    n_distinct = len(np.unique(sample.shard_ids)) if n else 0
    if n > 1 and n_distinct > 1:
        resid = (sums - r * counts) / phi
        var = np.sum((resid - resid.mean()) ** 2) / (n * (n - 1)) / (c_hat ** 2)
        eps = t_critical_value(n_distinct - 1, confidence) * np.sqrt(max(var, 0.0))
    else:
        eps = float("inf")
    return Estimate(float(r), float(eps), confidence, n)


def unique_shards(sample: SampleResult) -> np.ndarray:
    """Distinct shards to physically read (I/O dedup; estimator still
    uses the with-replacement multiset)."""
    return np.unique(sample.shard_ids)


def bootstrap_estimate(
    local_values: np.ndarray,
    sample: SampleResult,
    confidence: float = 0.95,
    n_boot: int = 64,
    rng: Optional[np.random.Generator] = None,
) -> Estimate:
    """Percentile-bootstrap CI over *sampled shard partials*.

    Where no closed-form variance exists (Boolean result sizes, union
    cardinalities) we resample the per-shard scaled partials
    ``tau_s/phi_s`` with replacement — never the documents, so the cost
    is O(n_boot * n_sampled_shards), trivial next to the scan itself.
    The point estimate is the same Hansen-Hurwitz mean as
    ``ht_estimate``; only the interval differs."""
    tau = np.asarray(local_values, np.float64)
    phi = sample.probabilities[sample.shard_ids]
    n = tau.shape[0]
    scaled = tau / np.maximum(phi, 1e-300)
    point = float(scaled.mean()) if n else 0.0
    if n < 2:
        return Estimate(point, float("inf"), confidence, n)
    if rng is None:
        rng = np.random.default_rng(0)
    idx = rng.integers(0, n, size=(n_boot, n))
    reps = scaled[idx].mean(axis=1)
    alpha = (1.0 - confidence) / 2.0
    lo, hi = np.quantile(reps, [alpha, 1.0 - alpha])
    eps = max(point - float(lo), float(hi) - point, 0.0)
    return Estimate(point, float(eps), confidence, n)


def bootstrap_topk_stability(
    parts: Sequence[Tuple[np.ndarray, np.ndarray]],
    k: int,
    confidence: float = 0.95,
    n_boot: int = 48,
    rng: Optional[np.random.Generator] = None,
) -> Estimate:
    """Stability score for a sampled top-k: mean overlap fraction between
    the full-sample top-k and top-k lists recomputed on bootstrap
    resamples of the sampled shards.

    ``parts`` holds one ``(doc_ids, scores)`` pair per sampled shard.
    A value of 1.0 means the ranking is insensitive to which of the
    sampled shards contributed (every resample reproduces the same
    top-k); low values flag rankings that a slightly different sample
    would have changed.  Reported as an ``Estimate`` so ranked results
    carry the same ``(value, ci)`` shape as counts."""
    n = len(parts)
    if n == 0 or k <= 0:
        return Estimate(0.0, float("inf"), confidence, n)

    def _topk(pairs) -> np.ndarray:
        ids = np.concatenate([p[0] for p in pairs])
        sc = np.concatenate([p[1] for p in pairs])
        order = np.argsort(-sc, kind="stable")
        uniq, first = np.unique(ids[order], return_index=True)
        return uniq[np.argsort(first, kind="stable")[:k]]

    ref = _topk(parts)
    if ref.size == 0:
        return Estimate(0.0, float("inf"), confidence, n)
    if n < 2:
        return Estimate(1.0, float("inf"), confidence, n)
    if rng is None:
        rng = np.random.default_rng(0)
    ref_set = set(ref.tolist())
    overlaps = np.empty(n_boot, np.float64)
    for b in range(n_boot):
        pick = rng.integers(0, n, size=n)
        top = _topk([parts[i] for i in pick])
        hit = sum(1 for d in top.tolist() if d in ref_set)
        overlaps[b] = hit / float(ref.size)
    value = float(overlaps.mean())
    alpha = (1.0 - confidence) / 2.0
    lo, hi = np.quantile(overlaps, [alpha, 1.0 - alpha])
    eps = max(value - float(lo), float(hi) - value, 0.0)
    return Estimate(value, float(eps), confidence, n)
