"""Cluster sampling + estimators (paper Sec. II-B, III-A).

The estimator is Hansen-Hurwitz / pps-with-replacement (paper Eq 1):

    tau_hat = (1/n) sum_{s in S} tau_s / phi_s

with the variance estimate and t-based confidence interval of Eq 2.
``phi_s`` comes either from similarity (EmApprox: Eq 11 softmax over
exp(q . s)) or is uniform (SRCS baseline).  The math is identical for
both — only the probability vector changes, which is exactly the paper's
framing.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.utils.stats import t_critical_value


class SampleResult(NamedTuple):
    shard_ids: np.ndarray        # int64 [n] sampled shard ids (with replacement)
    probabilities: np.ndarray    # float64 [n_shards] the phi vector used
    rate: float                  # nominal sampling rate


def similarity_probabilities(
    similarities: np.ndarray,
    floor: float = 1e-6,
) -> np.ndarray:
    """Paper Eq 11: phi_s = sim_s / sum(sim).  A small floor keeps every
    shard selectable so the HT estimator stays unbiased (phi_s > 0)."""
    s = np.asarray(similarities, np.float64)
    s = np.maximum(s, 0.0) + floor
    return s / s.sum()


def pps_sample(
    probabilities: np.ndarray,
    rate: float,
    rng: np.random.Generator,
) -> SampleResult:
    """Probability-proportional-to-size sampling with replacement.

    ``rate`` maps to a sample size n = ceil(rate * n_shards), matching
    the paper's 'block sampling rate'."""
    p = np.asarray(probabilities, np.float64)
    p = p / p.sum()
    n_shards = p.shape[0]
    n = max(1, int(np.ceil(rate * n_shards)))
    ids = rng.choice(n_shards, size=n, replace=True, p=p)
    return SampleResult(ids.astype(np.int64), p, rate)


def pps_sample_distinct(
    probabilities: np.ndarray,
    rate: float,
    rng: np.random.Generator,
) -> SampleResult:
    """Probability-proportional-to-size sampling *without* replacement
    (Efraimidis-Spirakis exponential keys: take the n smallest
    ``-log(u)/phi``).

    Retrieval queries (Boolean / ranked top-k) union documents over the
    sampled shards — they never form a Hansen-Hurwitz estimate — so a
    with-replacement multiset only wastes read budget on duplicate
    draws: at rate 0.6 on a skewed phi a with-replacement sample can
    physically touch under a third of the shards.  Drawing ``n =
    ceil(rate * n_shards)`` *distinct* shards makes the realized data
    fraction match the nominal rate while still concentrating reads on
    similar shards.  Aggregation queries keep ``pps_sample`` (Eq 1
    needs the with-replacement multiset)."""
    p = np.asarray(probabilities, np.float64)
    p = p / p.sum()
    n_shards = p.shape[0]
    n = min(n_shards, max(1, int(np.ceil(rate * n_shards))))
    u = rng.random(n_shards)
    with np.errstate(divide="ignore"):
        keys = -np.log(u) / np.maximum(p, 1e-300)
    ids = np.sort(np.argpartition(keys, n - 1)[:n])
    return SampleResult(ids.astype(np.int64), p, rate)


def srcs_sample(
    n_shards: int,
    rate: float,
    rng: np.random.Generator,
) -> SampleResult:
    """Simple random cluster sampling (the paper's baseline)."""
    p = np.full(n_shards, 1.0 / n_shards, np.float64)
    n = max(1, int(np.ceil(rate * n_shards)))
    ids = rng.choice(n_shards, size=n, replace=True, p=p)
    return SampleResult(ids.astype(np.int64), p, rate)


class Estimate(NamedTuple):
    value: float          # tau_hat
    error_bound: float    # epsilon at the requested confidence
    confidence: float
    n: int                # sample size

    @property
    def relative_error(self) -> float:
        return self.error_bound / abs(self.value) if self.value else float("inf")

    @property
    def interval(self):
        return (self.value - self.error_bound, self.value + self.error_bound)


def ht_estimate(
    local_values: np.ndarray,
    sample: SampleResult,
    confidence: float = 0.95,
) -> Estimate:
    """Paper Eq 1 & 2 over per-shard local results ``tau_s``.

    ``local_values[i]`` is the exact local quantity computed on sampled
    shard ``sample.shard_ids[i]`` (duplicates allowed — with-replacement
    draws each count once, per Hansen-Hurwitz)."""
    tau = np.asarray(local_values, np.float64)
    phi = sample.probabilities[sample.shard_ids]
    n = tau.shape[0]
    scaled = tau / phi                      # tau_s / phi_s
    tau_hat = scaled.mean() / 1.0
    # Eq 1 has (1/n) sum, i.e. the mean of scaled values
    if n > 1:
        var_hat = np.sum((scaled - tau_hat) ** 2) / (n * (n - 1))
        eps = t_critical_value(n - 1, confidence) * np.sqrt(var_hat)
    else:
        eps = float("inf")
    return Estimate(float(tau_hat), float(eps), confidence, n)


def mean_estimate(
    local_sums: np.ndarray,
    local_counts: np.ndarray,
    sample: SampleResult,
    confidence: float = 0.95,
) -> Estimate:
    """Ratio estimator for averages (the paper's second provided reduce
    function): estimate sum and count jointly, report sum/count with a
    linearized (Taylor) variance."""
    sums = np.asarray(local_sums, np.float64)
    counts = np.asarray(local_counts, np.float64)
    phi = sample.probabilities[sample.shard_ids]
    n = sums.shape[0]
    s_hat = (sums / phi).mean()
    c_hat = (counts / phi).mean()
    if c_hat == 0:
        return Estimate(0.0, float("inf"), confidence, n)
    r = s_hat / c_hat
    if n > 1:
        resid = (sums - r * counts) / phi
        var = np.sum((resid - resid.mean()) ** 2) / (n * (n - 1)) / (c_hat ** 2)
        eps = t_critical_value(n - 1, confidence) * np.sqrt(max(var, 0.0))
    else:
        eps = float("inf")
    return Estimate(float(r), float(eps), confidence, n)


def unique_shards(sample: SampleResult) -> np.ndarray:
    """Distinct shards to physically read (I/O dedup; estimator still
    uses the with-replacement multiset)."""
    return np.unique(sample.shard_ids)
