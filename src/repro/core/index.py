"""The approximation index (paper Fig. 1 / Fig. 2 steps p1-p2).

Contents:
  * word vectors           [V, dim]        (PV-DBOW)
  * shard vectors          [n_shards, dim] (mean of member doc vectors)
  * optional doc vectors   [n_docs, dim]   (needed for recsys + allocation)
  * LSH packed signatures for words and shards + the shared hyperplanes
  * document-frequency table for BM25 scoring (ranked retrieval)

Query-time API (paper Fig. 2 step a1): compose a query vector from word
vectors, score it against shard signatures (XOR+popcount Hamming ->
exp-cosine), normalize into sampling probabilities.

Batched scoring (the serving hot path): ``shard_similarities_batch``
and ``_exp_sim_batch`` score a [B, dim] block of query vectors against
every target signature in one pass — the asym path becomes a single
[M, bits] @ [bits, B] GEMM instead of B GEMVs, the sym path packs all
B query signatures once and rides the Hamming kernel's multi-query
``tn`` tiles, and the asym+kernel path runs the fused Pallas kernel in
``kernels/asym`` (projection + sign-matmul + exp-cosine in VMEM).
Single-query scoring stays on the latency-tuned numpy path; batched
scoring trades a little latency for throughput and is what
``core/queries/batch.QueryBatch`` uses.

Fused reductions: for doc-granular scoring the planner only consumes
per-shard sums (M = n_docs >> n_shards), so ``shard_similarities_batch
(..., fused=True)`` routes kernel-backed indices through the fused
segment-sum kernels — doc signatures are fed shard-sorted and each tile
reduces into a narrow band of shard slots in VMEM, so the [B, n_docs]
intermediate never reaches HBM.  ``topk_doc_similarities_batch`` is the
ranked analogue (fused in-kernel top-k).  The unfused ``_exp_sim_batch``
+ ``_sum_docs_to_shards_batch`` route is kept as the parity reference
(and as the non-kernel hot path, vectorized via one shard-sorted
``np.add.reduceat``).

The index is deliberately tiny relative to the corpus (paper Table II:
125 MB for 62 GB) — LSH compresses each 100-dim fp32 vector 64x.  Here
the exact compression is dim*4*8/bits bits per item.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lsh as lsh_mod
from repro.core import pv_dbow as pv
from repro.core.sampling import similarity_probabilities
from repro.data.store import ShardedCorpus, atomic_savez
from repro.runtime.generation import GenerationClock


@dataclasses.dataclass
class ApproxIndex:
    word_vecs: np.ndarray          # [V, dim] float32 (unit rows)
    shard_vecs: np.ndarray         # [n_shards, dim] float32
    doc_vecs: Optional[np.ndarray]  # [n_docs, dim] or None
    planes: np.ndarray             # [bits, dim] LSH hyperplanes
    word_sig: np.ndarray           # [V, bits//32] uint32
    shard_sig: np.ndarray          # [n_shards, bits//32] uint32
    doc_sig: Optional[np.ndarray]  # [n_docs, bits//32] uint32 or None
    bits: int
    doc_freq: np.ndarray           # [V] int64 document frequency (BM25)
    n_docs: int
    avg_doc_len: float
    use_lsh: bool = True           # False = score with real-valued vectors
    use_kernel: bool = False       # route Hamming through the Pallas kernel
    # "sym": paper-faithful two-sided Hamming (exp(beta cos(pi m/L)));
    # "asym": beyond-paper asymmetric scoring — stored side quantized,
    # query side real — same index bytes, ~half the quantization noise.
    lsh_mode: str = "asym"
    # "shard": paper Eq 10 (one vector per shard);  "doc": beyond-paper
    # doc-granular scoring (see shard_similarities).
    granularity: str = "shard"
    _doc_shard_ids: Optional[np.ndarray] = None  # doc_id -> shard_id
    # Scoring temperature: similarities are exp(beta * cos).  Must match
    # the temperature the PV-DBOW model was trained with so that
    # exp(beta cos) ~ exp(PMI - log k) ~ p(q|d) (paper Eq 5); see
    # PVDBOWConfig.temperature.
    temperature: float = 1.0
    # The joint word/doc mean subtracted by build_index(center=True) —
    # persisted so live ingest can put incrementally inferred doc
    # vectors through the identical centering transform (None for
    # uncentered indexes and pre-PR-10 saves).
    center_mean: Optional[np.ndarray] = None

    # ------------------------------------------------------------------
    # content generation
    # ------------------------------------------------------------------
    @property
    def clock(self) -> GenerationClock:
        """The generation authority this index bumps its *content* axis
        through.  Lazily a private clock so a standalone index works
        un-wired; ``build_serving_stack`` rebinds it to the stack's
        shared clock via ``use_clock`` so the cache/executor fence on
        the same handle.  Kept off the dataclass fields: it is identity
        state, not index content — ``dataclasses.replace`` (the ingest
        refresh) and save/load must not carry it."""
        c = getattr(self, "_gen_clock", None)
        if c is None:
            c = GenerationClock()
            object.__setattr__(self, "_gen_clock", c)
        return c

    def use_clock(self, clock: GenerationClock) -> "ApproxIndex":
        """Bind this index to a shared ``GenerationClock``; returns self."""
        object.__setattr__(self, "_gen_clock", clock)
        return self

    # ------------------------------------------------------------------
    # query-time scoring
    # ------------------------------------------------------------------
    def query_vector(self, word_ids: Sequence[int]) -> np.ndarray:
        """q = sum of word vectors (paper Sec. III)."""
        q = self.word_vecs[np.asarray(list(word_ids), np.int64)].sum(axis=0)
        return q

    def _signs_cache(self, target_sig: np.ndarray, role: str) -> np.ndarray:
        """Unpacked ±1 sign matrix for asym scoring, cached per target
        set.  Pure numpy keeps single-query latency at ~100 us; routing
        tiny index lookups through jax device dispatch costs ~3-50 ms
        per query (measured), swamping the similarity math itself.

        ``role`` ("shard" | "doc" | "word") is the cache key: keying on
        ``id(target_sig)`` — the old scheme — is unsound because ids are
        reused after garbage collection, so a stale entry could be
        served for a different signature array."""
        cache = getattr(self, "_signs", None)
        if cache is None:
            cache = {}
            object.__setattr__(self, "_signs", cache)
        if role not in cache:
            bits = np.unpackbits(
                target_sig.view(np.uint8), bitorder="little",
            ).reshape(target_sig.shape[0], -1)[:, : self.bits]
            cache[role] = (2.0 * bits - 1.0).astype(np.float32)
        return cache[role]

    def _exp_sim(self, vec: np.ndarray, target_sig: np.ndarray,
                 target_vecs: np.ndarray, role: str) -> np.ndarray:
        """exp(beta * cos) similarity of one vector against a signed set."""
        if self.use_lsh and self.lsh_mode == "asym":
            if self.use_kernel:
                cos = lsh_mod.asymmetric_cosine(
                    jnp.asarray(vec, jnp.float32), jnp.asarray(target_sig),
                    jnp.asarray(self.planes), self.bits)
                cos = np.asarray(cos, np.float64)
            else:
                q = np.asarray(vec, np.float64)
                q = q / max(np.linalg.norm(q), 1e-9)
                proj = (self.planes.astype(np.float64) @ q).astype(np.float32)
                signs = self._signs_cache(target_sig, role)
                scale = 1.0 / (self.bits * np.sqrt(2.0 / np.pi))
                cos = np.clip(signs @ proj * scale, -1.0, 1.0).astype(np.float64)
            return np.exp(self.temperature * cos)
        if self.use_lsh:
            q = np.asarray(vec, np.float32)
            qsig = lsh_mod.pack_bits(
                lsh_mod.signature_bits(jnp.asarray(q[None, :]), jnp.asarray(self.planes))
            )
            if self.use_kernel:
                from repro.kernels.hamming import ops as hamming_ops
                sims = hamming_ops.hamming_similarity(
                    qsig, jnp.asarray(target_sig), self.bits,
                    temperature=self.temperature)
            else:
                sims = lsh_mod.hamming_similarity(
                    qsig, jnp.asarray(target_sig), self.bits,
                    temperature=self.temperature)
            return np.asarray(sims[0], np.float64)
        # real-valued path: exp(beta cos) with unit-normalized query
        q = np.asarray(vec, np.float64)
        qn = q / max(np.linalg.norm(q), 1e-9)
        return np.exp(self.temperature * (target_vecs.astype(np.float64) @ qn))

    def _exp_sim_batch(self, vecs: np.ndarray, target_sig: np.ndarray,
                       target_vecs: np.ndarray, role: str) -> np.ndarray:
        """exp(beta * cos) of a [B, dim] query block against a signed
        set; returns [B, M] float64.

        Matches ``_exp_sim`` row-for-row (same projection dtype walk)
        but runs every query in one pass: the asym path is a single
        [M, bits] @ [bits, B] GEMM, the sym path packs B signatures at
        once and scores through the multi-query Hamming tiles, and the
        asym+kernel path uses the fused Pallas kernel in kernels/asym.
        """
        vecs = np.atleast_2d(np.asarray(vecs))
        if self.use_lsh and self.lsh_mode == "asym":
            if self.use_kernel:
                from repro.kernels.asym import ops as asym_ops
                sims = asym_ops.asym_exp_similarity(
                    jnp.asarray(vecs, jnp.float32), jnp.asarray(target_sig),
                    jnp.asarray(self.planes), self.bits,
                    temperature=self.temperature)
                return np.asarray(sims, np.float64)
            q = np.asarray(vecs, np.float64)
            q = q / np.maximum(
                np.linalg.norm(q, axis=-1, keepdims=True), 1e-9)
            proj = (self.planes.astype(np.float64) @ q.T).astype(np.float32)
            signs = self._signs_cache(target_sig, role)      # [M, bits]
            scale = 1.0 / (self.bits * np.sqrt(2.0 / np.pi))
            cos = np.clip(signs @ proj * scale, -1.0, 1.0)   # [M, B]
            return np.exp(self.temperature * cos.astype(np.float64)).T
        if self.use_lsh:
            qsig = lsh_mod.pack_bits(lsh_mod.signature_bits(
                jnp.asarray(vecs, jnp.float32), jnp.asarray(self.planes)))
            if self.use_kernel:
                from repro.kernels.hamming import ops as hamming_ops
                sims = hamming_ops.hamming_similarity(
                    qsig, jnp.asarray(target_sig), self.bits,
                    temperature=self.temperature)
            else:
                sims = lsh_mod.hamming_similarity(
                    qsig, jnp.asarray(target_sig), self.bits,
                    temperature=self.temperature)
            return np.asarray(sims, np.float64)
        q = np.asarray(vecs, np.float64)
        q = q / np.maximum(np.linalg.norm(q, axis=-1, keepdims=True), 1e-9)
        return np.exp(self.temperature * (q @ target_vecs.astype(np.float64).T))

    def shard_similarities(self, query_word_ids: Sequence[int]) -> np.ndarray:
        """Similarity of the query to every shard.

        ``granularity='shard'`` is the paper's Eq 10: exp(q . s_bar) with
        s_bar the mean doc vector — a geometric mean of per-doc
        probabilities.  ``granularity='doc'`` (beyond-paper) sums
        exp(beta cos(q, d)) over member documents — the arithmetic mean,
        which is exactly proportional to the expected count
        sum_d |d| p(q|d) the pps sampler wants; it reuses the doc
        signatures already stored for recommendation, so index bytes are
        unchanged."""
        if self.granularity == "doc" and (self.doc_sig is not None or
                                          self.doc_vecs is not None):
            doc_sims = self._exp_sim(self.query_vector(query_word_ids),
                                     self.doc_sig, self.doc_vecs, "doc")
            return self._sum_docs_to_shards(doc_sims)
        return self._exp_sim(self.query_vector(query_word_ids),
                             self.shard_sig, self.shard_vecs, "shard")

    def query_vectors(self, queries: Sequence[Sequence[int]]) -> np.ndarray:
        """[B, dim] stack of query vectors (sum of word vectors each)."""
        return np.stack([self.query_vector(q) for q in queries])

    def query_signatures(self, vecs: np.ndarray) -> np.ndarray:
        """[B, bits//32] packed LSH signatures for query vectors under
        the index's own hyperplanes, on the pure-numpy path (no device
        dispatch) — the key material for the semantic query cache
        (``runtime/qcache``).  Bit-identical to the jax signing the
        index itself was built with."""
        return lsh_mod.sign_vectors_np(vecs, self.planes)

    def shard_similarities_batch(
            self, queries: Sequence[Sequence[int]], *,
            fused: bool = True) -> np.ndarray:
        """[B, n_shards] similarity of every query to every shard in one
        scoring pass — the batch analogue of ``shard_similarities`` (see
        ``_exp_sim_batch`` for how each LSH mode batches).

        ``fused=True`` (default) routes kernel-backed doc-granular
        scoring through the fused segment-sum kernels, which reduce the
        [B, n_docs] similarity tile directly into [B, n_shards] in VMEM
        — the doc-wide intermediate never reaches HBM.  ``fused=False``
        keeps the unfused ``_exp_sim_batch`` + numpy reduce route (the
        parity reference the fused tests pin against)."""
        return self._shard_sims_from_vectors(self.query_vectors(queries),
                                             fused=fused)

    def _shard_sims_from_vectors(self, vecs: np.ndarray, *,
                                 fused: bool = True) -> np.ndarray:
        doc_granular = self.granularity == "doc" and (
            self.doc_sig is not None or self.doc_vecs is not None)
        if not doc_granular:
            return self._exp_sim_batch(vecs, self.shard_sig,
                                       self.shard_vecs, "shard")
        if (fused and self.use_lsh and self.use_kernel
                and self.doc_sig is not None
                and self._doc_shard_ids is not None):
            return self._fused_doc_shard_sims_batch(vecs)
        doc_sims = self._exp_sim_batch(vecs, self.doc_sig,
                                       self.doc_vecs, "doc")
        return self._sum_docs_to_shards_batch(doc_sims)

    def _fused_device_arrays(self) -> dict:
        """Device-resident operands for the fused kernels, uploaded once
        and cached: re-running ``jnp.asarray`` on the [n_docs, W] doc
        signature database per batch would push the whole index
        host->device every ~2 ms serving window — traffic that dwarfs
        the [B, n_docs] intermediate the fusion saves."""
        dev = getattr(self, "_fused_dev", None)
        if dev is None:
            dev = dict(planes=jnp.asarray(self.planes, jnp.float32))
            if self.doc_sig is not None:
                dev["doc_sig"] = jnp.asarray(self.doc_sig)
            if self._doc_shard_ids is not None:
                _, _, _, seg_sorted, sig_sorted = self._shard_sorted_docs()
                dev["seg"] = jnp.asarray(seg_sorted)
                dev["sig"] = jnp.asarray(sig_sorted)
            object.__setattr__(self, "_fused_dev", dev)
        return dev

    def _fused_doc_shard_sims_batch(self, vecs: np.ndarray) -> np.ndarray:
        """[B, n_shards] via the fused in-kernel segment reduction: doc
        signatures are fed shard-sorted so each kernel tile reduces into
        a narrow band of shard slots (kernels/asym, kernels/hamming)."""
        vecs = np.atleast_2d(np.asarray(vecs))
        dev = self._fused_device_arrays()
        n_shards = self.shard_vecs.shape[0]
        if self.lsh_mode == "asym":
            from repro.kernels.asym import ops as asym_ops
            out = asym_ops.asym_exp_segment_sum(
                jnp.asarray(vecs, jnp.float32), dev["sig"], dev["planes"],
                self.bits, dev["seg"], n_shards,
                temperature=self.temperature)
        else:
            from repro.kernels.hamming import ops as hamming_ops
            qsig = lsh_mod.pack_bits(lsh_mod.signature_bits(
                jnp.asarray(vecs, jnp.float32), dev["planes"]))
            out = hamming_ops.hamming_segment_similarity(
                qsig, dev["sig"], self.bits, dev["seg"], n_shards,
                temperature=self.temperature)
        return np.asarray(out, np.float64)

    def topk_doc_similarities_batch(
            self, queries: Sequence[Sequence[int]], k: int = 10, *,
            fused: bool = True) -> "tuple[np.ndarray, np.ndarray]":
        """Ranked retrieval over *documents*: ([B, k] doc indices,
        [B, k] exp-similarities), rows sorted descending.

        With ``fused=True`` on a kernel-backed asym index the top-k
        reduction runs inside the Pallas kernel (per-tile candidates
        only leave VMEM); otherwise the [B, n_docs] matrix is scored
        unfused and reduced with an argsort — the parity reference."""
        if self.doc_sig is None and self.doc_vecs is None:
            raise ValueError("index was built without document vectors")
        vecs = np.atleast_2d(self.query_vectors(queries))
        if (fused and self.use_lsh and self.use_kernel
                and self.lsh_mode == "asym" and self.doc_sig is not None):
            from repro.kernels.asym import ops as asym_ops
            dev = self._fused_device_arrays()
            idx, vals = asym_ops.asym_exp_topk(
                jnp.asarray(vecs, jnp.float32), dev["doc_sig"],
                dev["planes"], self.bits, k,
                temperature=self.temperature)
            return (np.asarray(idx, np.int64),
                    np.asarray(vals, np.float64))
        sims = self._exp_sim_batch(vecs, self.doc_sig, self.doc_vecs, "doc")
        k = min(int(k), sims.shape[1])
        idx = np.argsort(-sims, axis=1, kind="stable")[:, :k]
        return idx.astype(np.int64), np.take_along_axis(sims, idx, axis=1)

    def word_shard_similarities_batch(
            self, word_ids: Sequence[int]) -> np.ndarray:
        """[n_words, n_shards] per-word p(w|s) rows in one pass — lets a
        batch of Boolean queries score all their distinct words with a
        single GEMM before applying the AND->product / OR->sum algebra."""
        ids = np.asarray(list(word_ids), np.int64)
        return self._exp_sim_batch(self.word_vecs[ids], self.shard_sig,
                                   self.shard_vecs, "shard")

    def _sum_docs_to_shards(self, doc_values: np.ndarray) -> np.ndarray:
        if self._doc_shard_ids is None:
            raise ValueError("doc-granular scoring requires attach_corpus()")
        out = np.zeros(self.shard_vecs.shape[0], np.float64)
        np.add.at(out, self._doc_shard_ids, doc_values)
        return out

    def _shard_sorted_docs(self):
        """Cached shard-sort structures for doc→shard reductions:
        (order, starts, counts, seg_sorted, sig_sorted) where ``order``
        permutes docs into shard-contiguous position, ``starts``/
        ``counts`` delimit each shard's segment in that order,
        ``seg_sorted`` is the int32 shard slot per sorted doc, and
        ``sig_sorted`` the doc signatures in sorted order (None when
        the index carries no doc signatures)."""
        if self._doc_shard_ids is None:
            raise ValueError("doc-granular scoring requires attach_corpus()")
        cache = getattr(self, "_shard_sort", None)
        if cache is None:
            ids = np.asarray(self._doc_shard_ids, np.int64)
            n_shards = self.shard_vecs.shape[0]
            order = np.argsort(ids, kind="stable")
            counts = np.bincount(ids, minlength=n_shards)
            starts = np.zeros(n_shards, np.int64)
            np.cumsum(counts[:-1], out=starts[1:])
            seg_sorted = ids[order].astype(np.int32)
            sig_sorted = (self.doc_sig[order]
                          if self.doc_sig is not None else None)
            cache = (order, starts, counts, seg_sorted, sig_sorted)
            object.__setattr__(self, "_shard_sort", cache)
        return cache

    def _sum_docs_to_shards_batch(self, doc_values: np.ndarray) -> np.ndarray:
        """[B, n_docs] -> [B, n_shards] row-wise scatter-add, vectorized
        as one ``np.add.reduceat`` over shard-sorted doc order — the
        non-kernel doc-granular hot path.  (The previous per-row
        ``np.bincount`` loop re-walked the doc→shard map B times;
        ``np.add.at`` with a 2-D fancy index is unbuffered and ~100x
        slower still.)  Empty shards need the same care as
        ``data/store.segment_sum_by_offsets``: reduceat mis-handles
        empty segments, so their slots are masked to zero."""
        order, starts, counts, _, _ = self._shard_sorted_docs()
        doc_values = np.atleast_2d(doc_values)
        n_docs = doc_values.shape[1]
        out = np.zeros((doc_values.shape[0], counts.shape[0]), np.float64)
        nonempty = counts > 0
        if n_docs == 0 or doc_values.shape[0] == 0 or not nonempty.any():
            return out
        # reduceat only at non-empty segment starts: those are strictly
        # increasing and in-bounds, so every slice is a real segment.
        # (Clamping empty starts into range instead would fold the last
        # docs of the preceding shard into the wrong slice whenever a
        # trailing shard is empty.)
        vals = np.ascontiguousarray(doc_values[:, order])
        out[:, nonempty] = np.add.reduceat(vals, starts[nonempty], axis=1)
        return out

    def megascan_payload(self, shard_ids, *, tm: int = 256):
        """Block-aligned packed signature payload for the one-launch
        megascan (kernels/megascan): the named shards' shard-sorted doc
        signatures, each padded independently to TM-row blocks and
        concatenated, with row -> shard-slot and row -> doc-id maps.
        Cached per ``(shard_ids, tm, content generation)`` — the serving
        path re-scans the same host groups every window, and the payload
        (like the fused device arrays) must not be re-uploaded per
        batch; the content axis in the key means an ``attach_corpus``
        content bump retires every stale payload without the cache dict
        having to be cleared by hand."""
        if self.doc_sig is None:
            raise ValueError("megascan requires doc signatures")
        from repro.kernels.megascan import ops as mega_ops
        ids = tuple(int(s) for s in shard_ids)
        key = (ids, int(tm), self.clock.current().content)
        cache = getattr(self, "_megascan_pay", None)
        if cache is None:
            cache = {}
            object.__setattr__(self, "_megascan_pay", cache)
        payload = cache.get(key)
        if payload is None:
            order, starts, counts, _, sig_sorted = self._shard_sorted_docs()
            segments = [
                (sig_sorted[starts[s]:starts[s] + counts[s]],
                 order[starts[s]:starts[s] + counts[s]])
                for s in ids
            ]
            payload = mega_ops.build_payload(segments, tm=tm,
                                             shard_ids=ids)
            cache[key] = payload
        return payload

    def attach_corpus(self, corpus) -> "ApproxIndex":
        """Record the doc->shard map (needed for doc-granular scoring).
        Drops the shard-sort and device-array caches — both are derived
        from the map — and bumps the *content* generation: anything
        keyed on what this index answers from (semantic-cache entries,
        megascan payloads) is stale the moment a new corpus attaches.
        (Pre-PR-10 only the derived caches were dropped; a semantic
        cache fenced on placement alone would keep serving estimates
        computed over the old corpus.)"""
        self._doc_shard_ids = corpus.doc_shard_map()
        for cached in ("_shard_sort", "_fused_dev", "_megascan_pay"):
            if hasattr(self, cached):
                object.__delattr__(self, cached)
        self.clock.bump_content()
        return self

    def shard_probabilities(self, query_word_ids: Sequence[int]) -> np.ndarray:
        """phi_s(q) (paper Eq 11)."""
        return similarity_probabilities(self.shard_similarities(query_word_ids))

    def word_shard_similarity(self, word_id: int) -> np.ndarray:
        """p(w|s) up to constant for a single word (Boolean retrieval)."""
        return self._exp_sim(self.word_vecs[word_id], self.shard_sig,
                             self.shard_vecs, "shard")

    def vector_shard_similarities(self, vec: np.ndarray) -> np.ndarray:
        """exp-similarity of an arbitrary vector (e.g. a user vector) to
        every shard — used by recommendation."""
        return self._exp_sim(vec, self.shard_sig, self.shard_vecs, "shard")

    def vector_shard_similarities_batch(self, vecs: np.ndarray) -> np.ndarray:
        """[B, dim] arbitrary vectors -> [B, n_shards] exp-similarity."""
        return self._exp_sim_batch(vecs, self.shard_sig, self.shard_vecs,
                                   "shard")

    def vector_doc_similarities(self, vec: np.ndarray) -> np.ndarray:
        if self.doc_sig is None and self.doc_vecs is None:
            raise ValueError("index was built without document vectors")
        return self._exp_sim(vec, self.doc_sig, self.doc_vecs, "doc")

    # ------------------------------------------------------------------
    # persistence (atomic, manifest-checked)
    # ------------------------------------------------------------------
    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        payload = dict(
            word_vecs=self.word_vecs, shard_vecs=self.shard_vecs,
            planes=self.planes, word_sig=self.word_sig, shard_sig=self.shard_sig,
            doc_freq=self.doc_freq,
            meta=np.asarray(json.dumps(dict(
                bits=self.bits, n_docs=self.n_docs, avg_doc_len=self.avg_doc_len,
                use_lsh=self.use_lsh, has_docs=self.doc_vecs is not None,
                temperature=self.temperature, lsh_mode=self.lsh_mode,
                use_kernel=self.use_kernel, granularity=self.granularity,
                has_doc_shard_ids=self._doc_shard_ids is not None,
                has_center_mean=self.center_mean is not None,
            ))),
        )
        if self.doc_vecs is not None:
            payload["doc_vecs"] = self.doc_vecs
            payload["doc_sig"] = self.doc_sig
        if self._doc_shard_ids is not None:
            payload["doc_shard_ids"] = np.asarray(self._doc_shard_ids, np.int64)
        if self.center_mean is not None:
            payload["center_mean"] = np.asarray(self.center_mean, np.float32)
        atomic_savez(path, **payload)

    @staticmethod
    def load(path: str) -> "ApproxIndex":
        z = np.load(path, allow_pickle=False)
        meta = json.loads(str(z["meta"]))
        return ApproxIndex(
            word_vecs=z["word_vecs"], shard_vecs=z["shard_vecs"],
            doc_vecs=z["doc_vecs"] if meta["has_docs"] else None,
            planes=z["planes"], word_sig=z["word_sig"], shard_sig=z["shard_sig"],
            doc_sig=z["doc_sig"] if meta["has_docs"] else None,
            bits=meta["bits"], doc_freq=z["doc_freq"], n_docs=meta["n_docs"],
            avg_doc_len=meta["avg_doc_len"], use_lsh=meta["use_lsh"],
            temperature=meta.get("temperature", 1.0),
            lsh_mode=meta.get("lsh_mode", "sym"),
            # round-trip fidelity: a persisted doc-granular / kernel-routed
            # index used to silently revert to shard-granular numpy scoring
            use_kernel=meta.get("use_kernel", False),
            granularity=meta.get("granularity", "shard"),
            _doc_shard_ids=(z["doc_shard_ids"]
                            if meta.get("has_doc_shard_ids") else None),
            # pre-PR-10 saves lack the centering mean; such an index
            # still loads and serves — it just cannot host live ingest
            # with bit-consistent centering
            center_mean=(z["center_mean"]
                         if meta.get("has_center_mean") else None),
        )

    def nbytes(self) -> int:
        total = self.word_sig.nbytes + self.shard_sig.nbytes + self.planes.nbytes
        if self.doc_sig is not None:
            total += self.doc_sig.nbytes
        return total


def _doc_frequency(corpus: ShardedCorpus) -> np.ndarray:
    df = np.zeros(corpus.vocab_size, np.int64)
    for shard in corpus.shards:
        for doc in shard.iter_documents():
            df[np.unique(doc.tokens)] += 1
    return df


def _center_and_unit(x: np.ndarray, mean: np.ndarray) -> np.ndarray:
    y = x - mean
    n = np.linalg.norm(y, axis=-1, keepdims=True)
    return (y / np.maximum(n, 1e-8)).astype(np.float32)


def build_index(
    corpus: ShardedCorpus,
    model: pv.PVDBOWModel,
    lsh_cfg: Optional[lsh_mod.LSHConfig] = None,
    *,
    keep_doc_vectors: bool = True,
    use_lsh: bool = True,
    center: bool = True,
    temperature: float = 8.0,   # must match PVDBOWConfig.temperature
    lsh_mode: str = "asym",
    granularity: str = "shard",
) -> ApproxIndex:
    """Paper Fig. 2 step p2: compose shard vectors, hash everything.

    ``center`` applies the all-but-the-top style post-process: subtract
    the joint word/doc mean direction before re-normalizing.  SGNS with
    negative sampling leaves a large common offset (all docs repelled
    from the frequent-word direction); on unit vectors that offset
    pins every cosine near a constant and flattens phi_s.  Centering
    recovers the relative structure the sampler needs.  Set False for
    the strictly-paper-faithful ablation."""
    lsh_cfg = lsh_cfg or lsh_mod.LSHConfig()
    word_vecs = np.asarray(model.word_vecs, np.float32)
    doc_vecs = np.asarray(model.doc_vecs, np.float32)
    mean = None
    if center:
        mean = 0.5 * (word_vecs.mean(axis=0) + doc_vecs.mean(axis=0))
        word_vecs = _center_and_unit(word_vecs, mean)
        doc_vecs = _center_and_unit(doc_vecs, mean)
    shard_vecs = np.asarray(
        pv.shard_vectors(jnp.asarray(doc_vecs), corpus), np.float32)

    planes = np.asarray(lsh_mod.hyperplanes(lsh_cfg, word_vecs.shape[1]))
    jplanes = jnp.asarray(planes)

    def sign(x: np.ndarray) -> np.ndarray:
        return np.asarray(lsh_mod.pack_bits(
            lsh_mod.signature_bits(jnp.asarray(x), jplanes)))

    df = _doc_frequency(corpus)
    total_tokens = corpus.n_tokens
    return ApproxIndex(
        word_vecs=word_vecs,
        shard_vecs=shard_vecs,
        doc_vecs=doc_vecs if keep_doc_vectors else None,
        planes=planes,
        word_sig=sign(word_vecs),
        shard_sig=sign(shard_vecs),
        doc_sig=sign(doc_vecs) if keep_doc_vectors else None,
        bits=lsh_cfg.bits,
        doc_freq=df,
        n_docs=corpus.n_docs,
        avg_doc_len=total_tokens / max(corpus.n_docs, 1),
        use_lsh=use_lsh,
        temperature=temperature,
        lsh_mode=lsh_mode,
        granularity=granularity,
        _doc_shard_ids=corpus.doc_shard_map() if granularity == "doc" else None,
        center_mean=mean,
    )


def refresh_appended(
    index: ApproxIndex,
    corpus: ShardedCorpus,
    model: pv.PVDBOWModel,
    cfg: pv.PVDBOWConfig,
    appended_docs: Sequence[np.ndarray],
    affected_shards: Sequence[int],
    *,
    infer_steps: int = 50,
    infer_pause_s: float = 0.0,
) -> ApproxIndex:
    """Incremental index refresh for the live-ingest append path.

    ``corpus`` is the grown corpus (``ShardedCorpus.append_documents``),
    ``appended_docs`` the token arrays appended — in order, so their
    dense global ids start at ``index.n_docs`` — and
    ``affected_shards`` the shard ids whose membership changed.  New
    doc vectors come from *frozen-model* PV-DBOW inference (the word
    matrix fixed, ``pv_dbow.infer_doc_vectors``), pass through the
    identical centering transform the build applied
    (``index.center_mean``), and are signed on the numpy path —
    bit-identical to the jax signing of the build.  Only the affected
    shard centroids/signatures are recomputed (the same mean + sign
    ops as the build, so untouched rows are byte-identical and touched
    rows match a from-scratch rebuild); doc-frequency and length stats
    take exact integer deltas.  ``infer_pause_s`` is the writer's
    cooperative GIL yield between inference steps (result-neutral; see
    ``pv_dbow.infer_doc_vector``) so concurrent serving threads are
    never stalled for more than one dispatch.

    Returns a NEW ``ApproxIndex`` sharing the old one's generation
    clock — derived caches (sign matrices, fused device arrays,
    megascan payloads) start empty on the new object, and the *caller*
    bumps the content generation after swapping the new index in
    (swap-then-bump: a reader that races sees new refs under the old
    generation, which at worst inserts an immediately-stale cache
    entry, never serves one)."""
    if index.doc_vecs is None or index.doc_sig is None:
        raise ValueError("live refresh requires an index built with "
                         "keep_doc_vectors=True")
    k = len(appended_docs)
    if k == 0:
        return index
    if index.n_docs + k != corpus.n_docs:
        raise ValueError(
            f"appended docs do not line up: index has {index.n_docs}, "
            f"corpus has {corpus.n_docs}, appended {k}")
    vecs = pv.infer_doc_vectors(model, appended_docs, cfg,
                                steps=infer_steps, pause_s=infer_pause_s)
    if index.center_mean is not None:
        vecs = _center_and_unit(vecs, index.center_mean)
    else:
        vecs = np.asarray(vecs, np.float32)
    doc_vecs = np.concatenate([index.doc_vecs, vecs])
    doc_sig = np.concatenate(
        [index.doc_sig, lsh_mod.sign_vectors_np(vecs, index.planes)])

    old_shards = index.shard_vecs.shape[0]
    dim = index.shard_vecs.shape[1]
    shard_vecs = np.zeros((corpus.n_shards, dim), np.float32)
    shard_vecs[:old_shards] = index.shard_vecs
    touched = sorted({int(s) for s in affected_shards}
                     | set(range(old_shards, corpus.n_shards)))
    for sid in touched:
        # same op as the build path (pv.shard_vectors: numpy mean over
        # member doc vectors), so a touched row matches a full rebuild
        shard_vecs[sid] = doc_vecs[corpus.shards[sid].doc_ids].mean(axis=0)
    shard_sig = np.zeros((corpus.n_shards, index.shard_sig.shape[1]),
                         index.shard_sig.dtype)
    shard_sig[:old_shards] = index.shard_sig
    if touched:
        shard_sig[touched] = lsh_mod.sign_vectors_np(
            shard_vecs[np.asarray(touched)], index.planes)

    doc_freq = index.doc_freq.copy()
    for tokens in appended_docs:
        doc_freq[np.unique(np.asarray(tokens, np.int64))] += 1

    attach = (index.granularity == "doc"
              or index._doc_shard_ids is not None)
    new = dataclasses.replace(
        index,
        doc_vecs=doc_vecs, doc_sig=doc_sig,
        shard_vecs=shard_vecs, shard_sig=shard_sig,
        doc_freq=doc_freq, n_docs=corpus.n_docs,
        avg_doc_len=corpus.n_tokens / max(corpus.n_docs, 1),
        _doc_shard_ids=corpus.doc_shard_map() if attach else None,
    )
    # generation continuity: the new index answers under the same
    # authority; the ingest swap mints the content bump
    return new.use_clock(index.clock)
