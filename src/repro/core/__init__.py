"""EmApprox core: the paper's contribution as a composable JAX library.

Layers (DESIGN.md Sec. 1):
  pv_dbow     - PV-DBOW embedding model + negative-sampling training (C1, C2)
  lsh         - random-hyperplane signatures, packed Hamming similarity (C4)
  sampling    - pps / SRCS cluster sampling + Horvitz-Thompson estimators (C3)
  index       - the approximation index: vectors + LSH + corpus stats (C1)
  allocation  - spherical k-means document allocation (C6)
  queries/    - aggregation, Boolean/ranked retrieval, recommendation (C5)
"""
from repro.core.pv_dbow import PVDBOWConfig, PVDBOWModel, train_pv_dbow  # noqa: F401
from repro.core.lsh import LSHConfig, LSHIndex, pack_bits, hamming_similarity  # noqa: F401
from repro.core.sampling import (  # noqa: F401
    SampleResult,
    pps_sample,
    pps_sample_distinct,
    srcs_sample,
    ht_estimate,
)
from repro.core.index import ApproxIndex, build_index  # noqa: F401
