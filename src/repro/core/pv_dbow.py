"""PV-DBOW (Paragraph Vector, distributed bag of words) in JAX.

The paper (Sec. II-C) uses PV-DBOW with window = document length, i.e.
every (document, word) occurrence is a training pair.  Negative sampling
with k noise words factorizes the shifted PMI matrix (Levy-Goldberg,
Eq 4), which is what makes ``exp(q . d) proportional to p(q|d)`` (Eq 5)
— the theoretical basis of the whole index.

TPU adaptation (DESIGN.md Sec. 2): Gensim's hogwild SGD becomes
synchronous data-parallel Adam-free SGNS with large batches.  The fused
gather->dot->sigmoid->scatter-add step has a Pallas kernel
(kernels/negsamp); this module provides the pure-jnp reference path and
the training loop.

Paper modification for LSH (Sec. III-B): vectors are re-normalized to
unit length at each update step so dot product == cosine.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.store import ShardedCorpus


@dataclasses.dataclass(frozen=True)
class PVDBOWConfig:
    dim: int = 64                 # lambda_1 in the paper (default 100 there)
    negatives: int = 5            # k in Eq 4
    lr: float = 0.05
    steps: int = 1500
    batch_pairs: int = 8192
    noise_power: float = 0.75     # unigram^0.75 noise distribution
    unit_norm: bool = True        # paper's modification for LSH-cosine
    subsample_t: float = 1e-3     # word2vec frequent-word subsampling threshold
    # Temperature inside the SGNS sigmoid: sigma(beta * cos).  With the
    # paper's per-step unit-norm projection, dots are capped at [-1, 1];
    # sigma(-1) = 0.27 never decays, so negative-sample repulsion never
    # equilibrates and the tables collapse (all words at one point, all
    # docs at the antipode — measured).  With beta, equilibrium sits at
    # cos = (PMI - log k) / beta, i.e. the Levy-Goldberg factorization
    # survives, just compressed by 1/beta; scoring exponentiates with
    # the same beta (exp(beta cos)) so Eq 5's proportionality to p(w|d)
    # is restored exactly.
    temperature: float = 8.0
    seed: int = 0
    use_kernel: bool = False      # route the update through kernels/negsamp


class PVDBOWModel(NamedTuple):
    word_vecs: jax.Array   # [V, dim]
    doc_vecs: jax.Array    # [n_docs, dim]

    @property
    def dim(self) -> int:
        return self.word_vecs.shape[1]


class CorpusPairs(NamedTuple):
    """Flat (doc, word) training pairs + the negative-sampling noise law.

    ``noise_cdf`` is the cumulative unigram^power distribution; negatives
    are drawn by inverse-CDF (searchsorted) which costs O(B k log V)
    instead of the O(B k V) a naive categorical would (that Gumbel path
    materializes a [B, k, V] tensor — measured pathological on CPU and
    wasteful on TPU)."""
    doc_of_token: np.ndarray   # int32 [total_tokens]
    word_of_token: np.ndarray  # int32 [total_tokens]
    noise_cdf: np.ndarray      # float32 [V] cumulative noise distribution


def corpus_pairs(
    corpus: ShardedCorpus,
    noise_power: float = 0.75,
    subsample_t: float = 1e-3,
    seed: int = 0,
) -> CorpusPairs:
    """Extract (doc, word) pairs with word2vec frequent-word subsampling.

    Subsampling (Mikolov et al.: keep prob = sqrt(t/f) for frequency f)
    removes most stopword-like mass.  Without it the shared high-
    frequency words dominate the gradient and drag every document vector
    in the same direction — the classic global-offset collapse that
    flattens exp(cos) similarities."""
    docs, words = [], []
    for shard in corpus.shards:
        lens = np.diff(shard.offsets)
        docs.append(np.repeat(shard.doc_ids.astype(np.int32), lens))
        words.append(shard.tokens)
    word_of_token = np.concatenate(words)
    doc_of_token = np.concatenate(docs)

    counts = np.bincount(word_of_token, minlength=corpus.vocab_size).astype(np.float64)
    if subsample_t > 0:
        freq = counts / counts.sum()
        with np.errstate(divide="ignore", invalid="ignore"):
            keep_p = np.sqrt(subsample_t / np.maximum(freq, 1e-12))
        keep_p = np.clip(keep_p, 0.0, 1.0)
        rng = np.random.default_rng(seed)
        keep = rng.random(word_of_token.shape[0]) < keep_p[word_of_token]
        if keep.sum() > 1024:  # don't subsample tiny corpora into nothing
            word_of_token = word_of_token[keep]
            doc_of_token = doc_of_token[keep]

    p = counts ** noise_power
    p /= p.sum()
    return CorpusPairs(doc_of_token, word_of_token,
                       np.cumsum(p).astype(np.float32))


def sample_negatives(key: jax.Array, noise_cdf: jax.Array,
                     shape) -> jax.Array:
    """Inverse-CDF negative sampling: int32 ids with the unigram^power law."""
    u = jax.random.uniform(key, shape)
    ids = jnp.searchsorted(noise_cdf, u)
    return jnp.clip(ids, 0, noise_cdf.shape[0] - 1).astype(jnp.int32)


def init_model(key: jax.Array, vocab_size: int, n_docs: int, dim: int) -> PVDBOWModel:
    kw, kd = jax.random.split(key)
    scale = 1.0 / np.sqrt(dim)
    w = jax.random.normal(kw, (vocab_size, dim), jnp.float32) * scale
    d = jax.random.normal(kd, (n_docs, dim), jnp.float32) * scale
    return PVDBOWModel(_unit_rows(w), _unit_rows(d))


def _unit_rows(x: jax.Array) -> jax.Array:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-8)


def sgns_loss(
    model: PVDBOWModel,
    doc_ids: jax.Array,     # int32 [B]
    word_ids: jax.Array,    # int32 [B]
    neg_ids: jax.Array,     # int32 [B, k]
    temperature: float = 1.0,
) -> jax.Array:
    """Skip-gram-with-negative-sampling loss, document as context.

    L = -log sigma(w.d) - sum_neg log sigma(-w'.d)   (Eq 3/4 approximation)

    SUM reduction over the batch (word2vec/hogwild semantics): each
    sampled pair contributes an O(1) gradient to its embedding rows
    regardless of batch size.  Mean reduction would shrink per-row
    updates by 1/B and stall learning at large batch.
    """
    d = model.doc_vecs[doc_ids]              # [B, dim]
    w = model.word_vecs[word_ids]            # [B, dim]
    wn = model.word_vecs[neg_ids]            # [B, k, dim]
    pos = jnp.einsum("bd,bd->b", w, d) * temperature
    neg = jnp.einsum("bkd,bd->bk", wn, d) * temperature
    # -log sigma(x) = softplus(-x); numerically stable
    loss = jax.nn.softplus(-pos).sum() + jax.nn.softplus(neg).sum()
    return loss


@functools.partial(jax.jit, static_argnames=("negatives", "lr", "unit_norm", "temperature"))
def sgns_step(
    model: PVDBOWModel,
    key: jax.Array,
    doc_ids: jax.Array,
    word_ids: jax.Array,
    noise_cdf: jax.Array,
    *,
    negatives: int,
    lr: float,
    unit_norm: bool,
    temperature: float = 1.0,
) -> Tuple[PVDBOWModel, jax.Array]:
    neg_ids = sample_negatives(key, noise_cdf, (doc_ids.shape[0], negatives))
    loss, grads = jax.value_and_grad(sgns_loss)(
        model, doc_ids, word_ids, neg_ids, temperature)
    new_w = model.word_vecs - lr * grads.word_vecs
    new_d = model.doc_vecs - lr * grads.doc_vecs
    if unit_norm:
        # Paper Sec III-B: renormalize each update so dot == cosine.
        new_w = _unit_rows(new_w)
        new_d = _unit_rows(new_d)
    # report the per-pair mean for monitoring
    return PVDBOWModel(new_w, new_d), loss / doc_ids.shape[0]


def train_pv_dbow(
    corpus: ShardedCorpus,
    cfg: PVDBOWConfig,
    *,
    callback=None,
) -> PVDBOWModel:
    """Offline index-model training (paper Fig. 2 step p1)."""
    pairs = corpus_pairs(corpus, cfg.noise_power, cfg.subsample_t, cfg.seed)
    n_pairs = pairs.doc_of_token.shape[0]
    key = jax.random.PRNGKey(cfg.seed)
    model = init_model(key, corpus.vocab_size, corpus.n_docs, cfg.dim)
    noise_cdf = jnp.asarray(pairs.noise_cdf)
    rng = np.random.default_rng(cfg.seed)

    if cfg.use_kernel:
        from repro.kernels.negsamp import ops as negsamp_ops

    for step in range(cfg.steps):
        idx = rng.integers(0, n_pairs, size=cfg.batch_pairs)
        doc_ids = jnp.asarray(pairs.doc_of_token[idx])
        word_ids = jnp.asarray(pairs.word_of_token[idx])
        key, sub = jax.random.split(key)
        if cfg.use_kernel:
            model, loss = negsamp_ops.negsamp_step(
                model, sub, doc_ids, word_ids, noise_cdf,
                negatives=cfg.negatives, lr=cfg.lr, unit_norm=cfg.unit_norm,
                temperature=cfg.temperature,
            )
        else:
            model, loss = sgns_step(
                model, sub, doc_ids, word_ids, noise_cdf,
                negatives=cfg.negatives, lr=cfg.lr, unit_norm=cfg.unit_norm,
                temperature=cfg.temperature,
            )
        if callback is not None and (step % 100 == 0 or step == cfg.steps - 1):
            callback(step, float(loss))
    return model


@functools.partial(jax.jit, static_argnames=("steps",))
def _split_chain(key: jax.Array, steps: int) -> jax.Array:
    """The iterated ``key, sub = jax.random.split(key)`` chain as ONE
    dispatch: [steps, 2] uint32 subkeys, bit-identical to the eager
    loop (threefry is integer math — no float reassociation risk under
    fusion).  Inference runs one Python-level jit dispatch per step;
    without this the eager per-step split roughly doubles the GIL-held
    work, which is exactly what the live-ingest writer must not do to
    concurrently serving readers."""
    def body(k, _):
        k, sub = jax.random.split(k)
        return k, sub
    _, subs = jax.lax.scan(body, key, None, length=steps)
    return subs


@functools.partial(jax.jit,
                   static_argnames=("negatives", "lr", "temperature"))
def _infer_step(
    word_vecs: jax.Array,
    tokens: jax.Array,
    vec: jax.Array,
    key: jax.Array,
    *,
    negatives: int,
    lr: float,
    temperature: float,
) -> jax.Array:
    """One frozen-model inference step (word matrix fixed, one doc
    vector trained).  Module-level so the compiled program is shared
    across calls and documents — the ingest path infers whole batches
    of appended docs, and re-tracing per document would swamp the math
    (one compile per distinct token count remains)."""
    def loss_fn(v):
        w = word_vecs[tokens]
        pos = w @ v[0] * temperature
        kneg = jax.random.randint(
            key, (tokens.shape[0], negatives), 0, word_vecs.shape[0])
        wn = word_vecs[kneg]
        neg = jnp.einsum("bkd,d->bk", wn, v[0]) * temperature
        return jax.nn.softplus(-pos).mean() + jax.nn.softplus(neg).sum(-1).mean()
    g = jax.grad(loss_fn)(vec)
    return _unit_rows(vec - lr * g)


def infer_doc_vector(
    model: PVDBOWModel,
    tokens: np.ndarray,
    cfg: PVDBOWConfig,
    steps: int = 50,
    *,
    pause_s: float = 0.0,
) -> jax.Array:
    """Infer a vector for an unseen document with word vectors frozen
    (paper Sec. V, model-drift mitigation).  Deterministic in
    (cfg.seed, tokens): the rng chain restarts from the config seed for
    every document, so re-inferring the same tokens always reproduces
    the same vector.

    ``pause_s`` sleeps between inference steps.  It never changes the
    result — the rng chain and the math are untouched — it only yields
    the GIL so a concurrent serving thread is stalled for at most one
    dispatch, not a whole document.  The live-ingest writer paces
    itself with it; foreground callers leave it at 0."""
    key = jax.random.PRNGKey(cfg.seed + 1)
    vec = _unit_rows(jax.random.normal(key, (1, cfg.dim), jnp.float32) / np.sqrt(cfg.dim))
    tokens = jnp.asarray(tokens, jnp.int32)
    word_vecs = jnp.asarray(model.word_vecs)
    subs = _split_chain(key, steps)
    for i in range(steps):
        vec = _infer_step(word_vecs, tokens, vec, subs[i],
                          negatives=cfg.negatives, lr=cfg.lr,
                          temperature=cfg.temperature)
        if pause_s > 0.0:
            time.sleep(pause_s)
    return vec[0]


def infer_doc_vectors(
    model: PVDBOWModel,
    docs: Sequence[np.ndarray],
    cfg: PVDBOWConfig,
    steps: int = 50,
    *,
    pause_s: float = 0.0,
) -> np.ndarray:
    """Frozen-model inference for a batch of documents: [len(docs), dim]
    float32, row ``i`` bit-for-bit equal to
    ``infer_doc_vector(model, docs[i], cfg, steps)`` (pinned by tests).

    Documents are ragged and the negative draws are shaped by each
    doc's token count, so padding to a rectangle would change the rng
    stream and break that equality — instead the batch path shares the
    jitted ``_infer_step`` across docs (one compile per distinct
    length).  This is the live-ingest workhorse: appended docs get
    vectors without touching the trained word matrix; ``pause_s`` is
    the writer's cooperative GIL yield (see ``infer_doc_vector``)."""
    if not len(docs):
        return np.zeros((0, cfg.dim), np.float32)
    return np.stack([
        np.asarray(infer_doc_vector(model, d, cfg, steps, pause_s=pause_s),
                   np.float32)
        for d in docs
    ])


def query_vector(model_or_words: jax.Array, word_ids: Sequence[int]) -> jax.Array:
    """Paper Sec. III: q = elementwise sum of the query's word vectors."""
    w = model_or_words if isinstance(model_or_words, (jax.Array, np.ndarray)) \
        else model_or_words.word_vecs
    return jnp.asarray(w)[jnp.asarray(list(word_ids), jnp.int32)].sum(axis=0)


def shard_vectors(doc_vecs: jax.Array, corpus: ShardedCorpus) -> jax.Array:
    """Paper Sec. III-A: subcollection vector = arithmetic mean of member
    document vectors."""
    out = []
    dv = np.asarray(doc_vecs)
    for shard in corpus.shards:
        if shard.n_docs:
            out.append(dv[shard.doc_ids].mean(axis=0))
        else:
            out.append(np.zeros(dv.shape[1], dv.dtype))
    return jnp.asarray(np.stack(out))
