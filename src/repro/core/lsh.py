"""Locality-sensitive hashing for the approximation index (paper C4).

Random-hyperplane signatures (Charikar's SimHash): bit i of sig(x) is
``1[r_i . x >= 0]`` for Gaussian hyperplanes r_i.  Then

    Pr[bit_i(x) != bit_i(y)] = angle(x, y) / pi

so with Hamming distance m over L bits,  cos(pi * m / L) ~= cosine(x, y)
and the paper approximates ``exp(w . d)`` by ``exp(cos(pi m / L))``
(Sec. III-B; vectors are unit length after the training modification).

Bits are packed into uint32 lanes; Hamming distance is XOR + popcount —
the exact trick the paper credits for index efficiency.  The packed
kernel lives in kernels/hamming; this module holds the reference
implementation and the index container.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LSHConfig:
    bits: int = 256        # lambda_2; paper uses 100, we use wider + see asym
    seed: int = 7

    @property
    def words(self) -> int:
        if self.bits % 32:
            raise ValueError(f"bits must be a multiple of 32, got {self.bits}")
        return self.bits // 32


def hyperplanes(cfg: LSHConfig, dim: int) -> jax.Array:
    """[bits, dim] Gaussian hyperplanes (fixed seed => reusable index)."""
    key = jax.random.PRNGKey(cfg.seed)
    return jax.random.normal(key, (cfg.bits, dim), jnp.float32)


def signature_bits(x: jax.Array, planes: jax.Array) -> jax.Array:
    """[N, bits] uint8 of raw sign bits for row vectors ``x`` [N, dim]."""
    proj = x @ planes.T
    return (proj >= 0).astype(jnp.uint8)


def pack_bits(bits: jax.Array) -> jax.Array:
    """[N, bits] uint8 -> [N, bits//32] uint32, bit j of word k is
    signature bit 32*k + j (little-endian within the lane)."""
    n, b = bits.shape
    assert b % 32 == 0, b
    lanes = bits.reshape(n, b // 32, 32).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return (lanes * weights).sum(axis=-1, dtype=jnp.uint32)


def unpack_bits(packed: jax.Array, bits: int) -> jax.Array:
    n, w = packed.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    out = (packed[:, :, None] >> shifts) & jnp.uint32(1)
    return out.reshape(n, w * 32)[:, :bits].astype(jnp.uint8)


def popcount32(x: jax.Array) -> jax.Array:
    """Branch-free popcount over uint32 (classic SWAR bit tricks)."""
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def hamming_distance(a_packed: jax.Array, b_packed: jax.Array) -> jax.Array:
    """[N, W] x [M, W] -> [N, M] int32 Hamming distance (XOR+popcount)."""
    x = a_packed[:, None, :] ^ b_packed[None, :, :]
    return popcount32(x).sum(axis=-1)


def hamming_similarity(
    a_packed: jax.Array, b_packed: jax.Array, bits: int,
    temperature: float = 1.0,
) -> jax.Array:
    """Paper Sec. III-B: approximate exp(beta * x . y) for unit vectors
    by exp(beta * cos(pi * m / L));  returns [N, M] float32.  beta is the
    PV-DBOW training temperature (see PVDBOWConfig.temperature)."""
    m = hamming_distance(a_packed, b_packed).astype(jnp.float32)
    return jnp.exp(temperature * jnp.cos(jnp.pi * m / bits))


def asymmetric_cosine(
    query_vec: jax.Array,     # [dim] real-valued, any norm
    db_packed: jax.Array,     # [M, W] uint32 signatures
    planes: jax.Array,        # [bits, dim]
    bits: int,
) -> jax.Array:
    """Asymmetric LSH scoring (beyond-paper; index unchanged, noise ~1/2).

    E[(2 b_i(s) - 1) * r_i] = sqrt(2/pi) * s for unit s and Gaussian
    hyperplanes r_i, so

        cos(q, s) ~= sum_i (2 b_i(s) - 1) * (r_i . q_hat) / (L sqrt(2/pi))

    quantizes only the *stored* side; the query keeps its real
    projections.  Returns [M] estimated cosines (clipped to [-1, 1])."""
    q = query_vec / jnp.maximum(jnp.linalg.norm(query_vec), 1e-9)
    proj = planes @ q                                 # [bits]
    db_bits = unpack_bits(db_packed, bits).astype(jnp.float32)  # [M, bits]
    signs = 2.0 * db_bits - 1.0
    scale = 1.0 / (bits * jnp.sqrt(2.0 / jnp.pi))
    return jnp.clip(signs @ proj * scale, -1.0, 1.0)


# ---------------------------------------------------------------------------
# pure-numpy signing / distance for the serving hot path
#
# The semantic query cache (runtime/qcache) signs every incoming query
# vector per batch to form its key.  Operands are tiny ([B, dim] with B
# in the tens), where jax dispatch overhead dominates the actual math —
# so the cache keys on a numpy replica of the jax signing convention:
# bit j of word k is signature bit 32*k + j, identical to
# ``pack_bits(signature_bits(x, planes))`` (numpy's little-endian
# ``packbits`` + a uint32 view reproduces the in-lane layout on the
# little-endian machines everything here runs on).
# ---------------------------------------------------------------------------

_POPCOUNT8 = np.array([bin(i).count("1") for i in range(256)], np.uint8)


def sign_vectors_np(vecs: np.ndarray, planes) -> np.ndarray:
    """[B, dim] float -> [B, bits//32] uint32 packed signatures, pure
    numpy, bit-identical to ``pack_bits(signature_bits(vecs, planes))``."""
    vecs = np.atleast_2d(np.asarray(vecs, np.float32))
    planes_np = np.asarray(planes, np.float32)
    bits = (np.asarray(vecs @ planes_np.T) >= 0)
    packed = np.packbits(bits, axis=1, bitorder="little")
    return np.ascontiguousarray(packed).view(np.uint32)


def packed_hamming_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """[N, W] x [M, W] packed uint32 -> [N, M] int32 Hamming distances
    (XOR + uint8-LUT popcount), pure numpy."""
    a2 = np.atleast_2d(np.asarray(a, np.uint32))
    b2 = np.atleast_2d(np.asarray(b, np.uint32))
    x = np.bitwise_xor(a2[:, None, :], b2[None, :, :])
    per_byte = _POPCOUNT8[np.ascontiguousarray(x).view(np.uint8)]
    return per_byte.reshape(a2.shape[0], b2.shape[0], -1).sum(
        axis=-1, dtype=np.int32)


class LSHIndex(NamedTuple):
    """Packed signatures + the hyperplanes that produced them."""
    packed: jax.Array      # [N, bits//32] uint32
    planes: jax.Array      # [bits, dim] float32
    bits: int

    @staticmethod
    def build(x: jax.Array, cfg: LSHConfig) -> "LSHIndex":
        planes = hyperplanes(cfg, x.shape[-1])
        return LSHIndex(pack_bits(signature_bits(x, planes)), planes, cfg.bits)

    def sign(self, x: jax.Array) -> jax.Array:
        """Signature for new vectors under the same hyperplanes."""
        if x.ndim == 1:
            x = x[None, :]
        return pack_bits(signature_bits(x, self.planes))

    def similarities(self, query_vec: jax.Array, use_kernel: bool = False,
                     temperature: float = 1.0) -> jax.Array:
        """exp-cosine similarity of ``query_vec`` to every indexed item."""
        q = self.sign(query_vec)
        if use_kernel:
            from repro.kernels.hamming import ops as hamming_ops
            return hamming_ops.hamming_similarity(q, self.packed, self.bits,
                                                  temperature=temperature)[0]
        return hamming_similarity(q, self.packed, self.bits, temperature)[0]
