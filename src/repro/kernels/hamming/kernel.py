"""Pallas TPU kernel: packed-bit Hamming distance / exp-cosine similarity.

This is the paper's query-time hot spot (Sec. III-B): similarity between
LSH signatures computed as XOR + popcount.  On TPU the packed uint32
lanes live in VMEM and the XOR/popcount run on the VPU; one grid step
processes a (TN x TM) tile of the (queries x items) distance matrix with
the W packed words unrolled into the tile.

Layout choices (HARDWARE ADAPTATION note):
  * signatures are [_, W] uint32 with W = bits/32 (typically 4); the
    item axis is tiled to TM=512 lanes — a multiple of the 128-lane VPU
    registers and small enough that TN*TM*W stays << VMEM.
  * popcount is jax.lax.population_count (native TPU op), summed over W
    in registers — no intermediate [TN, TM, W] tensor is materialized in
    HBM, which is the whole point of fusing here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _distance_kernel(q_ref, db_ref, out_ref):
    """One (TN, TM) tile: out[i, j] = sum_w popcount(q[i, w] ^ db[j, w])."""
    q = q_ref[...]            # [TN, W] uint32
    db = db_ref[...]          # [TM, W] uint32
    w = q.shape[1]
    acc = jnp.zeros((q.shape[0], db.shape[0]), jnp.int32)
    for k in range(w):        # W is tiny (bits/32); unrolled in-register
        x = q[:, k][:, None] ^ db[:, k][None, :]          # [TN, TM] uint32
        acc = acc + jax.lax.population_count(x).astype(jnp.int32)
    out_ref[...] = acc


def _sim_tile(q_ref, db_ref, bits: float, temperature: float) -> jax.Array:
    """[TN, TM] exp(beta*cos(pi*m/L)) tile — the shared fusion core."""
    q = q_ref[...]
    db = db_ref[...]
    w = q.shape[1]
    acc = jnp.zeros((q.shape[0], db.shape[0]), jnp.int32)
    for k in range(w):
        x = q[:, k][:, None] ^ db[:, k][None, :]
        acc = acc + jax.lax.population_count(x).astype(jnp.int32)
    m = acc.astype(jnp.float32)
    return jnp.exp(temperature * jnp.cos(jnp.pi * m / bits))


def _similarity_kernel(q_ref, db_ref, out_ref, *, bits: float,
                       temperature: float):
    """Fused variant also applying the paper's exp(beta*cos(pi*m/L)) map."""
    out_ref[...] = _sim_tile(q_ref, db_ref, bits, temperature)


def _segsum_similarity_kernel(q_ref, db_ref, seg_ref, out_ref, *,
                              bits: float, temperature: float):
    """Fused similarity + segment reduction (the doc-granular serving
    path): each (TN, TM) similarity tile is reduced into the resident
    [TN, S] output by a one-hot matmul against the doc→shard-slot map.
    The output block's index map ignores the M grid axis, so it stays
    in VMEM and accumulates over all ceil(M/TM) steps — the [N, M]
    similarity matrix never reaches HBM.  Padding docs carry an
    out-of-range slot and contribute to nothing.

    VMEM budget per step (TN=8, TM=512, W=8, S<=1024): tiles + one-hot
    [TM, S] f32 ~2 MiB << the ~16 MB/core VMEM."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tile = _sim_tile(q_ref, db_ref, bits, temperature)      # [TN, TM]
    seg = seg_ref[0, ...]                                   # [TM] int32
    slots = jax.lax.broadcasted_iota(
        jnp.int32, (seg.shape[0], out_ref.shape[1]), 1)     # [TM, S]
    onehot = (seg[:, None] == slots).astype(jnp.float32)
    out_ref[...] += jnp.dot(tile, onehot,
                            preferred_element_type=jnp.float32)


def _tiled_call(kernel_fn, q, db, out_dtype, tn: int, tm: int, interpret: bool):
    n, w = q.shape
    m, w2 = db.shape
    assert w == w2, (w, w2)
    grid = (pl.cdiv(n, tn), pl.cdiv(m, tm))
    return pl.pallas_call(
        kernel_fn,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, w), lambda i, j: (i, 0)),
            pl.BlockSpec((tm, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tn, tm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), out_dtype),
        interpret=interpret,
    )(q, db)


@functools.partial(jax.jit, static_argnames=("tn", "tm", "interpret"))
def hamming_distance_kernel(
    q_packed: jax.Array,
    db_packed: jax.Array,
    *,
    tn: int = 8,
    tm: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """[N, W] x [M, W] uint32 -> [N, M] int32."""
    return _tiled_call(_distance_kernel, q_packed, db_packed, jnp.int32,
                       tn, tm, interpret)


@functools.partial(jax.jit, static_argnames=("bits", "tn", "tm", "interpret",
                                             "temperature"))
def hamming_similarity_kernel(
    q_packed: jax.Array,
    db_packed: jax.Array,
    bits: int,
    *,
    tn: int = 8,
    tm: int = 512,
    interpret: bool = False,
    temperature: float = 1.0,
) -> jax.Array:
    """[N, W] x [M, W] uint32 -> [N, M] float32 exp(beta*cos(pi*m/bits))."""
    kernel = functools.partial(_similarity_kernel, bits=float(bits),
                               temperature=float(temperature))
    return _tiled_call(kernel, q_packed, db_packed, jnp.float32,
                       tn, tm, interpret)


@functools.partial(jax.jit, static_argnames=("bits", "n_segments", "tn", "tm",
                                             "interpret", "temperature"))
def hamming_segment_similarity_kernel(
    q_packed: jax.Array,     # [N, W] uint32
    db_packed: jax.Array,    # [M, W] uint32, rows segment-sorted
    seg_ids: jax.Array,      # [1, M] int32 doc -> segment slot
    bits: int,
    n_segments: int,         # S (lane-padded by the ops wrapper)
    *,
    tn: int = 8,
    tm: int = 512,
    interpret: bool = False,
    temperature: float = 1.0,
) -> jax.Array:
    """[N, W] x [M, W] -> [N, S] segment sums of exp(beta*cos(pi*m/L)).

    The M axis is the innermost grid dimension; the output block index
    map ignores it, so each [TN, S] block accumulates in VMEM across
    the whole M sweep (classic K-reduction matmul layout)."""
    n, w = q_packed.shape
    m, w2 = db_packed.shape
    assert w == w2, (w, w2)
    kernel = functools.partial(_segsum_similarity_kernel, bits=float(bits),
                               temperature=float(temperature))
    grid = (pl.cdiv(n, tn), pl.cdiv(m, tm))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, w), lambda i, j: (i, 0)),
            pl.BlockSpec((tm, w), lambda i, j: (j, 0)),
            pl.BlockSpec((1, tm), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tn, n_segments), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n_segments), jnp.float32),
        interpret=interpret,
    )(q_packed, db_packed, seg_ids)
