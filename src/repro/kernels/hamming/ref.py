"""Pure-jnp oracle for the hamming kernel (used by allclose tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hamming_distance_ref(q_packed: jax.Array, db_packed: jax.Array) -> jax.Array:
    """[N, W] x [M, W] uint32 -> [N, M] int32 via broadcast XOR+popcount."""
    x = q_packed[:, None, :] ^ db_packed[None, :, :]
    return jax.lax.population_count(x).astype(jnp.int32).sum(axis=-1)


def hamming_similarity_ref(q_packed: jax.Array, db_packed: jax.Array,
                           bits: int) -> jax.Array:
    m = hamming_distance_ref(q_packed, db_packed).astype(jnp.float32)
    return jnp.exp(jnp.cos(jnp.pi * m / bits))


def hamming_segment_similarity_ref(q_packed: jax.Array, db_packed: jax.Array,
                                   bits: int, seg_ids: jax.Array,
                                   n_segments: int,
                                   temperature: float = 1.0) -> jax.Array:
    """[N, n_segments] via the unfused [N, M] matrix + jnp segment_sum."""
    sims = hamming_similarity_ref(q_packed, db_packed, bits) ** temperature
    return jax.ops.segment_sum(sims.T, jnp.asarray(seg_ids),
                               num_segments=n_segments).T
