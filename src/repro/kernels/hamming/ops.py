"""Public jit'd wrappers for the hamming kernel.

On CPU (this container) the Pallas body runs in interpret mode; on TPU
the same BlockSpecs compile to Mosaic.  Inputs are padded to tile
multiples here so the kernel never sees ragged blocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import on_tpu, pad_rows
from repro.kernels.hamming import kernel as _k


def hamming_distance(q_packed: jax.Array, db_packed: jax.Array,
                     *, tn: int = 8, tm: int = 512) -> jax.Array:
    n, m = q_packed.shape[0], db_packed.shape[0]
    tn = min(tn, max(1, n))
    tm = min(tm, max(1, m))
    q = pad_rows(jnp.asarray(q_packed, jnp.uint32), tn)
    db = pad_rows(jnp.asarray(db_packed, jnp.uint32), tm)
    out = _k.hamming_distance_kernel(q, db, tn=tn, tm=tm,
                                     interpret=not on_tpu())
    return out[:n, :m]


def hamming_similarity(q_packed: jax.Array, db_packed: jax.Array, bits: int,
                       *, tn: int = 8, tm: int = 512,
                       temperature: float = 1.0) -> jax.Array:
    n, m = q_packed.shape[0], db_packed.shape[0]
    tn = min(tn, max(1, n))
    tm = min(tm, max(1, m))
    q = pad_rows(jnp.asarray(q_packed, jnp.uint32), tn)
    db = pad_rows(jnp.asarray(db_packed, jnp.uint32), tm)
    out = _k.hamming_similarity_kernel(q, db, bits, tn=tn, tm=tm,
                                       interpret=not on_tpu(),
                                       temperature=temperature)
    return out[:n, :m]
