"""Public jit'd wrappers for the hamming kernel.

On CPU (this container) the Pallas body runs in interpret mode; on TPU
the same BlockSpecs compile to Mosaic.  Inputs are padded to tile
multiples here so the kernel never sees ragged blocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import on_tpu, pad_rows
from repro.kernels.hamming import kernel as _k


def hamming_distance(q_packed: jax.Array, db_packed: jax.Array,
                     *, tn: int = 8, tm: int = 512) -> jax.Array:
    n, m = q_packed.shape[0], db_packed.shape[0]
    tn = min(tn, max(1, n))
    tm = min(tm, max(1, m))
    q = pad_rows(jnp.asarray(q_packed, jnp.uint32), tn)
    db = pad_rows(jnp.asarray(db_packed, jnp.uint32), tm)
    out = _k.hamming_distance_kernel(q, db, tn=tn, tm=tm,
                                     interpret=not on_tpu())
    return out[:n, :m]


def hamming_similarity(q_packed: jax.Array, db_packed: jax.Array, bits: int,
                       *, tn: int = 8, tm: int = 512,
                       temperature: float = 1.0) -> jax.Array:
    n, m = q_packed.shape[0], db_packed.shape[0]
    tn = min(tn, max(1, n))
    tm = min(tm, max(1, m))
    q = pad_rows(jnp.asarray(q_packed, jnp.uint32), tn)
    db = pad_rows(jnp.asarray(db_packed, jnp.uint32), tm)
    out = _k.hamming_similarity_kernel(q, db, bits, tn=tn, tm=tm,
                                       interpret=not on_tpu(),
                                       temperature=temperature)
    return out[:n, :m]


def hamming_segment_similarity(q_packed: jax.Array, db_packed: jax.Array,
                               bits: int, seg_ids: jax.Array,
                               n_segments: int,
                               *, tn: int = 8, tm: int = 512,
                               temperature: float = 1.0) -> jax.Array:
    """Fused scoring + reduction: [N, W] x [M, W] -> [N, n_segments]
    sums of exp(beta*cos(pi*m/L)) grouped by ``seg_ids`` (the doc ->
    segment slot map, int, [M]).  The [N, M] similarity matrix stays
    in VMEM tile-by-tile and never reaches HBM.  Rows of ``db_packed``
    should be segment-sorted so each TM tile reduces into a narrow
    band of slots (correctness holds for any order); padding docs get
    an out-of-range slot and contribute to nothing."""
    n, m = q_packed.shape[0], db_packed.shape[0]
    tn = min(tn, max(1, n))
    tm = min(tm, max(1, m))
    q = pad_rows(jnp.asarray(q_packed, jnp.uint32), tn)
    db = pad_rows(jnp.asarray(db_packed, jnp.uint32), tm)
    s_pad = max(128, -(-int(n_segments) // 128) * 128)
    seg = jnp.asarray(seg_ids, jnp.int32).reshape(1, -1)
    seg = jnp.pad(seg, ((0, 0), (0, db.shape[0] - m)),
                  constant_values=s_pad)
    out = _k.hamming_segment_similarity_kernel(
        q, db, seg, bits, s_pad, tn=tn, tm=tm,
        interpret=not on_tpu(), temperature=temperature)
    return out[:n, :n_segments]
