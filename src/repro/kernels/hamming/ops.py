"""Public jit'd wrappers for the hamming kernel.

On CPU (this container) the Pallas body runs in interpret mode; on TPU
the same BlockSpecs compile to Mosaic.  Inputs are padded to tile
multiples here so the kernel never sees ragged blocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.hamming import kernel as _k


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_rows(x: jax.Array, multiple: int) -> jax.Array:
    n = x.shape[0]
    pad = (-n) % multiple
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x


def hamming_distance(q_packed: jax.Array, db_packed: jax.Array,
                     *, tn: int = 8, tm: int = 512) -> jax.Array:
    n, m = q_packed.shape[0], db_packed.shape[0]
    tn = min(tn, max(1, n))
    tm = min(tm, max(1, m))
    q = _pad_rows(jnp.asarray(q_packed, jnp.uint32), tn)
    db = _pad_rows(jnp.asarray(db_packed, jnp.uint32), tm)
    out = _k.hamming_distance_kernel(q, db, tn=tn, tm=tm,
                                     interpret=not _on_tpu())
    return out[:n, :m]


def hamming_similarity(q_packed: jax.Array, db_packed: jax.Array, bits: int,
                       *, tn: int = 8, tm: int = 512,
                       temperature: float = 1.0) -> jax.Array:
    n, m = q_packed.shape[0], db_packed.shape[0]
    tn = min(tn, max(1, n))
    tm = min(tm, max(1, m))
    q = _pad_rows(jnp.asarray(q_packed, jnp.uint32), tn)
    db = _pad_rows(jnp.asarray(db_packed, jnp.uint32), tm)
    out = _k.hamming_similarity_kernel(q, db, bits, tn=tn, tm=tm,
                                       interpret=not _on_tpu(),
                                       temperature=temperature)
    return out[:n, :m]
