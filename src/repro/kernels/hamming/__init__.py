from repro.kernels.hamming.ops import hamming_distance, hamming_similarity  # noqa: F401
