from repro.kernels.hamming.ops import (  # noqa: F401
    hamming_distance,
    hamming_segment_similarity,
    hamming_similarity,
)
