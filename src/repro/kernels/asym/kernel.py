"""Pallas TPU kernel: fused batched asymmetric LSH exp-similarity.

Asymmetric scoring (core/lsh.py ``asymmetric_cosine``) quantizes only
the *stored* side of the similarity: for unit query q and Gaussian
hyperplanes r_i,

    cos(q, s) ~= sum_i (2 b_i(s) - 1) (r_i . q) / (L sqrt(2/pi))

For a batch of B queries this is a [B, bits] x [bits, M] GEMM — the
single-query path's B GEMVs collapsed into one MXU pass.  One grid step
handles a (TB x TM) tile of the (queries x items) output:

  * queries arrive as a [TB, dim] fp32 tile (rows pre-normalized by the
    ops wrapper) and the full [bits, dim] plane matrix sits in VMEM —
    the projection runs on the MXU per tile (bits, dim are both small,
    so recomputing beats an extra HBM round-trip for a [B, bits]
    intermediate);
  * stored signatures arrive packed [TM, W] uint32 and are unpacked to
    ±1 signs in-register (shift/mask on the VPU), never touching HBM
    at [TM, bits] width;
  * the sign-matmul + clip + exp(beta * cos) all fuse into the same
    tile before the single [TB, TM] store.

HARDWARE ADAPTATION note: TM defaults to 256 lanes (multiple of the
128-lane VPU registers); TB to 8 sublanes.  W = bits/32 is unrolled.

Fused reductions (the serving hot path): for doc-granular scoring the
[B, M] similarity matrix is only an intermediate — the planner consumes
per-*shard* sums and ranked retrieval consumes a top-k.  Two fused
variants keep that intermediate in VMEM:

  * ``asym_segment_sum_kernel`` reduces each post-exp (TB, TM) tile
    into a [TB, S] segment-sum block via a one-hot matmul against the
    doc→shard-slot map (an MXU pass), accumulating across the M grid
    axis in the output block that stays resident in VMEM.  Docs are
    expected shard-sorted, so each TM tile's one-hot columns hit a
    narrow band of shard slots; only [B, S] ever reaches HBM.
  * ``asym_topk_kernel`` reduces each tile to its per-tile top-k
    (values + global doc indices); the caller does the final top-k
    over the [B, ceil(M/TM)*K] candidates — only those reach HBM.

VMEM budget per grid step (defaults TB=8, TM=256, bits=256, dim<=128,
S<=1024): q 4 KiB + planes 128 KiB + packed db 8 KiB + unpacked signs
256 KiB + one-hot 1 MiB + out 32 KiB — well under the ~16 MB/core VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _unpack_signs(db: jax.Array, bits: int) -> jax.Array:
    """[TM, W] uint32 -> [TM, bits] float32 in {-1, +1}.

    Bit j of lane word k is signature bit 32*k + j (the pack_bits
    layout).  The shift table is built with broadcasted_iota — 1D iota
    does not lower on TPU."""
    tm, w = db.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
    b = (db[:, :, None] >> shifts) & jnp.uint32(1)          # [TM, W, 32]
    b = b.reshape(tm, w * 32)[:, :bits].astype(jnp.float32)
    return 2.0 * b - 1.0


def _exp_sim_tile(q_ref, planes_ref, db_ref, bits: int,
                  temperature: float) -> jax.Array:
    """[TB, TM] exp(beta * cos_asym) tile — the shared fusion core."""
    q = q_ref[...]                 # [TB, dim] float32, unit rows
    planes = planes_ref[...]       # [bits, dim] float32
    db = db_ref[...]               # [TM, W] uint32
    proj = jnp.dot(q, planes.T, preferred_element_type=jnp.float32)
    signs = _unpack_signs(db, bits)                         # [TM, bits]
    scale = 1.0 / (bits * math.sqrt(2.0 / math.pi))
    cos = jnp.dot(proj, signs.T, preferred_element_type=jnp.float32) * scale
    cos = jnp.clip(cos, -1.0, 1.0)
    return jnp.exp(temperature * cos)


def _asym_sim_kernel(q_ref, planes_ref, db_ref, out_ref, *, bits: int,
                     temperature: float):
    """One (TB, TM) tile of exp(beta * cos_asym(q, db))."""
    out_ref[...] = _exp_sim_tile(q_ref, planes_ref, db_ref, bits, temperature)


def _asym_segsum_kernel(q_ref, planes_ref, db_ref, seg_ref, out_ref, *,
                        bits: int, temperature: float):
    """One (TB, TM) tile reduced into the resident [TB, S] output.

    ``seg_ref`` holds the shard slot of each doc column (out-of-range
    slots for padding docs).  The segment sum is a one-hot matmul: with
    docs shard-sorted the [TM, S] one-hot matrix is a narrow diagonal
    band, but correctness does not depend on the ordering.  The output
    block's index map ignores the M grid axis, so it stays in VMEM and
    accumulates across all ceil(M/TM) steps — the [B, M] intermediate
    never reaches HBM."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tile = _exp_sim_tile(q_ref, planes_ref, db_ref, bits, temperature)
    seg = seg_ref[0, ...]                                   # [TM] int32
    slots = jax.lax.broadcasted_iota(
        jnp.int32, (seg.shape[0], out_ref.shape[1]), 1)     # [TM, S]
    onehot = (seg[:, None] == slots).astype(jnp.float32)
    out_ref[...] += jnp.dot(tile, onehot,
                            preferred_element_type=jnp.float32)


def _asym_topk_kernel(q_ref, planes_ref, db_ref, vals_ref, idx_ref, *,
                      bits: int, temperature: float, k: int, tm: int,
                      m_total: int):
    """Per-tile top-k: each (TB, TM) tile emits its K best values and
    their *global* doc indices; padding columns are masked to -inf so
    they can never enter the candidate set.  The caller runs the final
    top-k over the [B, ceil(M/TM)*K] candidates."""
    j = pl.program_id(1)
    tile = _exp_sim_tile(q_ref, planes_ref, db_ref, bits, temperature)
    col = jax.lax.broadcasted_iota(jnp.int32, tile.shape, 1) + j * tm
    tile = jnp.where(col < m_total, tile, -jnp.inf)
    vals, local_idx = jax.lax.top_k(tile, k)
    vals_ref[...] = vals
    idx_ref[...] = local_idx.astype(jnp.int32) + j * tm


@functools.partial(jax.jit, static_argnames=("bits", "tb", "tm", "interpret",
                                             "temperature"))
def asym_similarity_kernel(
    q: jax.Array,            # [B, dim] float32, rows unit-normalized
    planes: jax.Array,       # [bits, dim] float32
    db_packed: jax.Array,    # [M, W] uint32
    bits: int,
    *,
    tb: int = 8,
    tm: int = 256,
    interpret: bool = False,
    temperature: float = 1.0,
) -> jax.Array:
    """[B, dim] x [M, W] -> [B, M] float32 exp(beta * asym-cos)."""
    b, dim = q.shape
    m, w = db_packed.shape
    assert w * 32 >= bits, (w, bits)
    kernel = functools.partial(_asym_sim_kernel, bits=int(bits),
                               temperature=float(temperature))
    grid = (pl.cdiv(b, tb), pl.cdiv(m, tm))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, dim), lambda i, j: (i, 0)),
            pl.BlockSpec((planes.shape[0], dim), lambda i, j: (0, 0)),
            pl.BlockSpec((tm, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tb, tm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.float32),
        interpret=interpret,
    )(q, planes, db_packed)


@functools.partial(jax.jit, static_argnames=("bits", "n_segments", "tb", "tm",
                                             "interpret", "temperature"))
def asym_segment_sum_kernel(
    q: jax.Array,            # [B, dim] float32, rows unit-normalized
    planes: jax.Array,       # [bits, dim] float32
    db_packed: jax.Array,    # [M, W] uint32, rows segment-sorted
    seg_ids: jax.Array,      # [1, M] int32 doc -> segment slot
    bits: int,
    n_segments: int,         # S (lane-padded by the ops wrapper)
    *,
    tb: int = 8,
    tm: int = 256,
    interpret: bool = False,
    temperature: float = 1.0,
) -> jax.Array:
    """[B, dim] x [M, W] -> [B, S] segment sums of exp(beta*asym-cos).

    The M axis is the innermost grid dimension and the output block's
    index map ignores it, so each [TB, S] block accumulates in VMEM
    across the whole M sweep (the classic K-reduction matmul layout)."""
    b, dim = q.shape
    m, w = db_packed.shape
    assert w * 32 >= bits, (w, bits)
    kernel = functools.partial(_asym_segsum_kernel, bits=int(bits),
                               temperature=float(temperature))
    grid = (pl.cdiv(b, tb), pl.cdiv(m, tm))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, dim), lambda i, j: (i, 0)),
            pl.BlockSpec((planes.shape[0], dim), lambda i, j: (0, 0)),
            pl.BlockSpec((tm, w), lambda i, j: (j, 0)),
            pl.BlockSpec((1, tm), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tb, n_segments), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_segments), jnp.float32),
        interpret=interpret,
    )(q, planes, db_packed, seg_ids)


@functools.partial(jax.jit, static_argnames=("bits", "k", "m_total", "tb",
                                             "tm", "interpret", "temperature"))
def asym_topk_kernel(
    q: jax.Array,            # [B, dim] float32, rows unit-normalized
    planes: jax.Array,       # [bits, dim] float32
    db_packed: jax.Array,    # [M, W] uint32
    bits: int,
    k: int,
    m_total: int,            # unpadded M (padding cols masked to -inf)
    *,
    tb: int = 8,
    tm: int = 256,
    interpret: bool = False,
    temperature: float = 1.0,
) -> "tuple[jax.Array, jax.Array]":
    """Two-stage fused top-k: returns ([B, J*K] values, [B, J*K] int32
    global doc indices) with J = ceil(M/TM) — per-tile candidates only;
    the ops wrapper runs the cheap final top-k over them.

    HARDWARE ADAPTATION note: K is the output block's lane width and
    must be a multiple of the 128-lane registers for Mosaic to lower
    the [TB, K] stores onto hardware tiles — on TPU the ops wrapper
    (``asym_exp_topk``) lane-pads the caller's k before it reaches
    here; interpret mode (this container) has no alignment constraint
    and skips the padding to avoid the extra per-tile work."""
    b, dim = q.shape
    m, w = db_packed.shape
    assert w * 32 >= bits, (w, bits)
    assert k <= tm, (k, tm)
    kernel = functools.partial(_asym_topk_kernel, bits=int(bits),
                               temperature=float(temperature), k=int(k),
                               tm=int(tm), m_total=int(m_total))
    jm = pl.cdiv(m, tm)
    grid = (pl.cdiv(b, tb), jm)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, dim), lambda i, j: (i, 0)),
            pl.BlockSpec((planes.shape[0], dim), lambda i, j: (0, 0)),
            pl.BlockSpec((tm, w), lambda i, j: (j, 0)),
        ],
        out_specs=(
            pl.BlockSpec((tb, k), lambda i, j: (i, j)),
            pl.BlockSpec((tb, k), lambda i, j: (i, j)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, jm * k), jnp.float32),
            jax.ShapeDtypeStruct((b, jm * k), jnp.int32),
        ),
        interpret=interpret,
    )(q, planes, db_packed)
