"""Pallas TPU kernel: fused batched asymmetric LSH exp-similarity.

Asymmetric scoring (core/lsh.py ``asymmetric_cosine``) quantizes only
the *stored* side of the similarity: for unit query q and Gaussian
hyperplanes r_i,

    cos(q, s) ~= sum_i (2 b_i(s) - 1) (r_i . q) / (L sqrt(2/pi))

For a batch of B queries this is a [B, bits] x [bits, M] GEMM — the
single-query path's B GEMVs collapsed into one MXU pass.  One grid step
handles a (TB x TM) tile of the (queries x items) output:

  * queries arrive as a [TB, dim] fp32 tile (rows pre-normalized by the
    ops wrapper) and the full [bits, dim] plane matrix sits in VMEM —
    the projection runs on the MXU per tile (bits, dim are both small,
    so recomputing beats an extra HBM round-trip for a [B, bits]
    intermediate);
  * stored signatures arrive packed [TM, W] uint32 and are unpacked to
    ±1 signs in-register (shift/mask on the VPU), never touching HBM
    at [TM, bits] width;
  * the sign-matmul + clip + exp(beta * cos) all fuse into the same
    tile before the single [TB, TM] store.

HARDWARE ADAPTATION note: TM defaults to 256 lanes (multiple of the
128-lane VPU registers); TB to 8 sublanes.  W = bits/32 is unrolled.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _unpack_signs(db: jax.Array, bits: int) -> jax.Array:
    """[TM, W] uint32 -> [TM, bits] float32 in {-1, +1}.

    Bit j of lane word k is signature bit 32*k + j (the pack_bits
    layout).  The shift table is built with broadcasted_iota — 1D iota
    does not lower on TPU."""
    tm, w = db.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
    b = (db[:, :, None] >> shifts) & jnp.uint32(1)          # [TM, W, 32]
    b = b.reshape(tm, w * 32)[:, :bits].astype(jnp.float32)
    return 2.0 * b - 1.0


def _asym_sim_kernel(q_ref, planes_ref, db_ref, out_ref, *, bits: int,
                     temperature: float):
    """One (TB, TM) tile of exp(beta * cos_asym(q, db))."""
    q = q_ref[...]                 # [TB, dim] float32, unit rows
    planes = planes_ref[...]       # [bits, dim] float32
    db = db_ref[...]               # [TM, W] uint32
    proj = jnp.dot(q, planes.T, preferred_element_type=jnp.float32)
    signs = _unpack_signs(db, bits)                         # [TM, bits]
    scale = 1.0 / (bits * math.sqrt(2.0 / math.pi))
    cos = jnp.dot(proj, signs.T, preferred_element_type=jnp.float32) * scale
    cos = jnp.clip(cos, -1.0, 1.0)
    out_ref[...] = jnp.exp(temperature * cos)


@functools.partial(jax.jit, static_argnames=("bits", "tb", "tm", "interpret",
                                             "temperature"))
def asym_similarity_kernel(
    q: jax.Array,            # [B, dim] float32, rows unit-normalized
    planes: jax.Array,       # [bits, dim] float32
    db_packed: jax.Array,    # [M, W] uint32
    bits: int,
    *,
    tb: int = 8,
    tm: int = 256,
    interpret: bool = False,
    temperature: float = 1.0,
) -> jax.Array:
    """[B, dim] x [M, W] -> [B, M] float32 exp(beta * asym-cos)."""
    b, dim = q.shape
    m, w = db_packed.shape
    assert w * 32 >= bits, (w, bits)
    kernel = functools.partial(_asym_sim_kernel, bits=int(bits),
                               temperature=float(temperature))
    grid = (pl.cdiv(b, tb), pl.cdiv(m, tm))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, dim), lambda i, j: (i, 0)),
            pl.BlockSpec((planes.shape[0], dim), lambda i, j: (0, 0)),
            pl.BlockSpec((tm, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tb, tm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.float32),
        interpret=interpret,
    )(q, planes, db_packed)
