"""Public jit'd wrappers for the fused batched asym kernels.

On CPU (this container) the Pallas bodies run in interpret mode; on TPU
the same BlockSpecs compile to Mosaic.  Query rows are normalized and
both row axes padded to tile multiples here so the kernels never see
ragged blocks.  The fused-reduction wrappers additionally pad the
segment axis to lane multiples and give padding docs an out-of-range
segment slot so they cannot contribute to any sum.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.asym import kernel as _k
from repro.kernels.common import on_tpu, pad_rows


def _prep_queries(query_vecs: jax.Array, tb: int):
    """Unit-normalize + row-pad the query block; returns (q, B, tb)."""
    q = jnp.asarray(query_vecs, jnp.float32)
    if q.ndim == 1:
        q = q[None, :]
    b = q.shape[0]
    tb = min(tb, max(1, b))
    q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-9)
    return pad_rows(q, tb), b, tb


def asym_exp_similarity(query_vecs: jax.Array, db_packed: jax.Array,
                        planes: jax.Array, bits: int,
                        *, tb: int = 8, tm: int = 256,
                        temperature: float = 1.0) -> jax.Array:
    """[B, dim] queries x [M, W] packed signatures -> [B, M] float32
    exp(temperature * asym-cos).  Queries may have any norm; rows are
    unit-normalized before projection (padding rows stay zero — their
    projections are zero, and the padded outputs are sliced away)."""
    q, b, tb = _prep_queries(query_vecs, tb)
    m = db_packed.shape[0]
    tm = min(tm, max(1, m))
    db = pad_rows(jnp.asarray(db_packed, jnp.uint32), tm)
    out = _k.asym_similarity_kernel(
        q, jnp.asarray(planes, jnp.float32), db, bits,
        tb=tb, tm=tm, interpret=not on_tpu(), temperature=temperature)
    return out[:b, :m]


def asym_exp_segment_sum(query_vecs: jax.Array, db_packed: jax.Array,
                         planes: jax.Array, bits: int, seg_ids: jax.Array,
                         n_segments: int,
                         *, tb: int = 8, tm: int = 256,
                         temperature: float = 1.0) -> jax.Array:
    """Fused scoring + reduction: [B, dim] x [M, W] -> [B, n_segments]
    sums of exp(temperature * asym-cos) grouped by ``seg_ids`` (the
    doc -> segment slot map, int, [M]).  The [B, M] similarity matrix
    stays in VMEM tile-by-tile and never reaches HBM.

    Rows of ``db_packed`` should be segment-sorted so each TM tile
    reduces into a narrow band of slots (correctness holds for any
    order).  The segment axis is padded to a lane multiple in-kernel
    and sliced back here; padding docs get the out-of-range slot
    ``s_pad``, so they contribute to nothing."""
    q, b, tb = _prep_queries(query_vecs, tb)
    m = db_packed.shape[0]
    tm = min(tm, max(1, m))
    db = pad_rows(jnp.asarray(db_packed, jnp.uint32), tm)
    s_pad = max(128, -(-int(n_segments) // 128) * 128)
    seg = jnp.asarray(seg_ids, jnp.int32).reshape(1, -1)
    seg = jnp.pad(seg, ((0, 0), (0, db.shape[0] - m)),
                  constant_values=s_pad)
    out = _k.asym_segment_sum_kernel(
        q, jnp.asarray(planes, jnp.float32), db, seg, bits, s_pad,
        tb=tb, tm=tm, interpret=not on_tpu(), temperature=temperature)
    return out[:b, :n_segments]


def asym_exp_topk(query_vecs: jax.Array, db_packed: jax.Array,
                  planes: jax.Array, bits: int, k: int,
                  *, tb: int = 8, tm: int = 256,
                  temperature: float = 1.0,
                  pad_lanes: "bool | None" = None,
                  ) -> "tuple[jax.Array, jax.Array]":
    """Fused scoring + ranked reduction: returns ([B, k] int32 doc
    indices, [B, k] float32 values), each row sorted by descending
    exp(temperature * asym-cos).  Stage 1 (in-kernel) keeps only the
    per-tile top-k; stage 2 reduces the [B, ceil(M/TM)*kp] candidate
    set — the full [B, M] matrix never reaches HBM.

    K is the kernel's output-block lane width, so on TPU it is padded
    here to a multiple of the 128-lane registers (``kp``) and the
    final top-k slices back to the caller's k — Mosaic then always
    sees aligned [TB, kp] stores (hardware tile shapes reject ragged
    K).  The padding only widens the per-tile candidate sets, a
    superset of the unpadded candidates, so results are unchanged —
    but it is real extra work, so interpret mode (which tolerates
    ragged K) skips it; ``pad_lanes`` overrides the default for
    parity tests of the padded shape off-TPU."""
    q, b, tb = _prep_queries(query_vecs, tb)
    m = db_packed.shape[0]
    k = min(int(k), m)
    if pad_lanes is None:
        pad_lanes = on_tpu()
    kp = -(-k // 128) * 128 if pad_lanes else k
    tm = min(tm, max(1, m))
    tm = max(tm, kp)         # a tile must be able to hold kp candidates
    db = pad_rows(jnp.asarray(db_packed, jnp.uint32), tm)
    vals, idx = _k.asym_topk_kernel(
        q, jnp.asarray(planes, jnp.float32), db, bits, kp, m,
        tb=tb, tm=tm, interpret=not on_tpu(), temperature=temperature)
    vals, idx = vals[:b], idx[:b]
    top_vals, pos = jax.lax.top_k(vals, k)
    top_idx = jnp.take_along_axis(idx, pos, axis=1)
    return top_idx, top_vals
