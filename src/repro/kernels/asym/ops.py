"""Public jit'd wrapper for the fused batched asym kernel.

On CPU (this container) the Pallas body runs in interpret mode; on TPU
the same BlockSpecs compile to Mosaic.  Query rows are normalized and
both row axes padded to tile multiples here so the kernel never sees
ragged blocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.asym import kernel as _k
from repro.kernels.common import on_tpu, pad_rows


def asym_exp_similarity(query_vecs: jax.Array, db_packed: jax.Array,
                        planes: jax.Array, bits: int,
                        *, tb: int = 8, tm: int = 256,
                        temperature: float = 1.0) -> jax.Array:
    """[B, dim] queries x [M, W] packed signatures -> [B, M] float32
    exp(temperature * asym-cos).  Queries may have any norm; rows are
    unit-normalized before projection (padding rows stay zero — their
    projections are zero, and the padded outputs are sliced away)."""
    q = jnp.asarray(query_vecs, jnp.float32)
    if q.ndim == 1:
        q = q[None, :]
    b, m = q.shape[0], db_packed.shape[0]
    tb = min(tb, max(1, b))
    tm = min(tm, max(1, m))
    q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-9)
    q = pad_rows(q, tb)
    db = pad_rows(jnp.asarray(db_packed, jnp.uint32), tm)
    out = _k.asym_similarity_kernel(
        q, jnp.asarray(planes, jnp.float32), db, bits,
        tb=tb, tm=tm, interpret=not on_tpu(), temperature=temperature)
    return out[:b, :m]
