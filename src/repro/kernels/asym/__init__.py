"""Fused batched asymmetric LSH scoring kernel.

The batched query engine's hot path: score a block of B query vectors
against M packed signatures in one shot.  The kernel fuses the three
stages that the numpy path runs separately —

    proj  = Q_hat @ planes.T          (query-side projection, MXU)
    cos   ~ proj @ signs.T * scale    (sign-matmul against unpacked
                                       stored bits, MXU)
    out   = exp(beta * clip(cos))     (exp-cosine map, VPU)

— so the [M, bits] sign matrix is unpacked tile-by-tile in VMEM and
never materialized in HBM.  See kernels/hamming for the symmetric
(two-sided Hamming) sibling.
"""
from repro.kernels.asym.ops import asym_exp_similarity  # noqa: F401
