"""Fused batched asymmetric LSH scoring kernel.

The batched query engine's hot path: score a block of B query vectors
against M packed signatures in one shot.  The kernel fuses the three
stages that the numpy path runs separately —

    proj  = Q_hat @ planes.T          (query-side projection, MXU)
    cos   ~ proj @ signs.T * scale    (sign-matmul against unpacked
                                       stored bits, MXU)
    out   = exp(beta * clip(cos))     (exp-cosine map, VPU)

— so the [M, bits] sign matrix is unpacked tile-by-tile in VMEM and
never materialized in HBM.  See kernels/hamming for the symmetric
(two-sided Hamming) sibling.

Fused reductions go one step further: ``asym_exp_segment_sum`` folds
the doc→shard segment sum into the same tile pass (the [B, M] matrix
never leaves VMEM either) and ``asym_exp_topk`` keeps only per-tile
top-k candidates for ranked retrieval.
"""
from repro.kernels.asym.ops import (  # noqa: F401
    asym_exp_segment_sum,
    asym_exp_similarity,
    asym_exp_topk,
)
