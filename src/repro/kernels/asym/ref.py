"""Pure-jnp oracle for the fused batched asym kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lsh as lsh_mod


def asym_exp_similarity_ref(
    query_vecs: jax.Array,   # [B, dim] real-valued, any norm
    db_packed: jax.Array,    # [M, W] uint32
    planes: jax.Array,       # [bits, dim]
    bits: int,
    temperature: float = 1.0,
) -> jax.Array:
    """[B, M] exp(beta * asym-cos) via the unbatched reference path."""
    q = query_vecs / jnp.maximum(
        jnp.linalg.norm(query_vecs, axis=-1, keepdims=True), 1e-9)
    proj = q @ planes.T                                       # [B, bits]
    signs = 2.0 * lsh_mod.unpack_bits(db_packed, bits).astype(jnp.float32) - 1.0
    scale = 1.0 / (bits * jnp.sqrt(2.0 / jnp.pi))
    cos = jnp.clip(proj @ signs.T * scale, -1.0, 1.0)
    return jnp.exp(temperature * cos)
