"""Pure-jnp oracle for the fused batched asym kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lsh as lsh_mod


def asym_exp_similarity_ref(
    query_vecs: jax.Array,   # [B, dim] real-valued, any norm
    db_packed: jax.Array,    # [M, W] uint32
    planes: jax.Array,       # [bits, dim]
    bits: int,
    temperature: float = 1.0,
) -> jax.Array:
    """[B, M] exp(beta * asym-cos) via the unbatched reference path."""
    q = query_vecs / jnp.maximum(
        jnp.linalg.norm(query_vecs, axis=-1, keepdims=True), 1e-9)
    proj = q @ planes.T                                       # [B, bits]
    signs = 2.0 * lsh_mod.unpack_bits(db_packed, bits).astype(jnp.float32) - 1.0
    scale = 1.0 / (bits * jnp.sqrt(2.0 / jnp.pi))
    cos = jnp.clip(proj @ signs.T * scale, -1.0, 1.0)
    return jnp.exp(temperature * cos)


def asym_exp_segment_sum_ref(
    query_vecs: jax.Array,   # [B, dim] real-valued, any norm
    db_packed: jax.Array,    # [M, W] uint32
    planes: jax.Array,       # [bits, dim]
    bits: int,
    seg_ids: jax.Array,      # [M] int doc -> segment slot
    n_segments: int,
    temperature: float = 1.0,
) -> jax.Array:
    """[B, n_segments] via the unfused [B, M] matrix + jnp segment_sum."""
    sims = asym_exp_similarity_ref(query_vecs, db_packed, planes, bits,
                                   temperature)
    return jax.ops.segment_sum(sims.T, jnp.asarray(seg_ids),
                               num_segments=n_segments).T


def asym_exp_topk_ref(
    query_vecs: jax.Array,
    db_packed: jax.Array,
    planes: jax.Array,
    bits: int,
    k: int,
    temperature: float = 1.0,
) -> "tuple[jax.Array, jax.Array]":
    """([B, k] indices, [B, k] values) via the unfused matrix + top_k."""
    sims = asym_exp_similarity_ref(query_vecs, db_packed, planes, bits,
                                   temperature)
    vals, idx = jax.lax.top_k(sims, min(int(k), sims.shape[1]))
    return idx, vals
