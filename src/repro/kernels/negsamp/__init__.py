from repro.kernels.negsamp.ops import negsamp_grads, negsamp_step  # noqa: F401
