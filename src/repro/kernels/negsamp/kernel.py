"""Pallas TPU kernel: fused PV-DBOW negative-sampling gradient step.

The offline index cost the paper reports (Table II T-Time, hours of
Gensim) is dominated by the SGNS inner loop: for each (doc, word,
negatives) example,

    pos = sigma(w . d) - 1          grad scale for the positive pair
    neg_k = sigma(w_k . d)          grad scales for the k negatives
    g_d  = pos * w + sum_k neg_k * w_k
    g_w  = pos * d
    g_wk = neg_k * d

A naive jnp implementation materializes [B, K, dim] intermediates in HBM
three times (scores, sigmoid, products).  This kernel fuses the whole
example in VMEM: one grid step loads a TB-row tile of the gathered
embeddings, computes scores/sigmoids in registers, and writes the three
gradient tiles — one HBM round-trip instead of ~four.

The gather/scatter stays outside (XLA's sorted scatter-add is already
optimal on TPU and duplicate-index semantics belong to the caller).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _negsamp_kernel(d_ref, w_ref, wn_ref, loss_ref, gd_ref, gw_ref, gwn_ref,
                    *, temperature: float):
    d = d_ref[...]                 # [TB, dim]
    w = w_ref[...]                 # [TB, dim]
    wn = wn_ref[...]               # [TB, K, dim]
    t = temperature

    pos = jnp.sum(w * d, axis=-1) * t                   # [TB]
    neg = jnp.einsum("bkd,bd->bk", wn, d,
                     preferred_element_type=jnp.float32) * t  # [TB, K]

    # loss pieces: softplus(-pos) + sum softplus(neg)
    loss_ref[...] = jax.nn.softplus(-pos) + jax.nn.softplus(neg).sum(axis=-1)

    gpos = (jax.nn.sigmoid(pos) - 1.0) * t              # dL/d(w.d)  [TB]
    gneg = jax.nn.sigmoid(neg) * t                      # dL/d(wn.d) [TB, K]

    gd_ref[...] = gpos[:, None] * w + jnp.einsum(
        "bk,bkd->bd", gneg, wn, preferred_element_type=jnp.float32)
    gw_ref[...] = gpos[:, None] * d
    gwn_ref[...] = gneg[:, :, None] * d[:, None, :]


@functools.partial(jax.jit, static_argnames=("tb", "interpret", "temperature"))
def negsamp_grads_kernel(
    d: jax.Array,    # [B, dim] gathered doc vectors
    w: jax.Array,    # [B, dim] gathered positive word vectors
    wn: jax.Array,   # [B, K, dim] gathered negative word vectors
    *,
    tb: int = 256,
    interpret: bool = False,
    temperature: float = 1.0,
):
    """Returns (loss [B], grad_d [B,dim], grad_w [B,dim], grad_wn [B,K,dim])."""
    b, dim = d.shape
    k = wn.shape[1]
    grid = (pl.cdiv(b, tb),)
    return pl.pallas_call(
        functools.partial(_negsamp_kernel, temperature=temperature),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, dim), lambda i: (i, 0)),
            pl.BlockSpec((tb, dim), lambda i: (i, 0)),
            pl.BlockSpec((tb, k, dim), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tb,), lambda i: (i,)),
            pl.BlockSpec((tb, dim), lambda i: (i, 0)),
            pl.BlockSpec((tb, dim), lambda i: (i, 0)),
            pl.BlockSpec((tb, k, dim), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b, dim), jnp.float32),
            jax.ShapeDtypeStruct((b, dim), jnp.float32),
            jax.ShapeDtypeStruct((b, k, dim), jnp.float32),
        ],
        interpret=interpret,
    )(d, w, wn)
