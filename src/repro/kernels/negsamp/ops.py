"""Public wrappers: fused SGNS gradients + a full PV-DBOW SGD step.

``negsamp_step`` has the same signature/semantics as
``repro.core.pv_dbow.sgns_step`` so the trainer can swap paths with the
``use_kernel`` config flag; scatter-adds with duplicate-index addition
semantics are done with ``.at[].add`` (XLA scatter-add) outside the
kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.negsamp import kernel as _k


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_batch(x: jax.Array, multiple: int) -> jax.Array:
    pad = (-x.shape[0]) % multiple
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x


def negsamp_grads(d: jax.Array, w: jax.Array, wn: jax.Array, *, tb: int = 256,
                  temperature: float = 1.0):
    b = d.shape[0]
    tb = min(tb, max(1, b))
    dp, wp, wnp = _pad_batch(d, tb), _pad_batch(w, tb), _pad_batch(wn, tb)
    loss, gd, gw, gwn = _k.negsamp_grads_kernel(dp, wp, wnp, tb=tb,
                                                interpret=not _on_tpu(),
                                                temperature=temperature)
    return loss[:b], gd[:b], gw[:b], gwn[:b]


@functools.partial(jax.jit, static_argnames=("negatives", "lr", "unit_norm", "temperature"))
def negsamp_step(
    model,                       # PVDBOWModel(word_vecs, doc_vecs)
    key: jax.Array,
    doc_ids: jax.Array,          # int32 [B]
    word_ids: jax.Array,         # int32 [B]
    noise_cdf: jax.Array,        # [V] cumulative noise distribution
    *,
    negatives: int,
    lr: float,
    unit_norm: bool,
    temperature: float = 1.0,
):
    from repro.core.pv_dbow import PVDBOWModel, _unit_rows, sample_negatives

    b = doc_ids.shape[0]
    neg_ids = sample_negatives(key, noise_cdf, (b, negatives))

    d = model.doc_vecs[doc_ids]
    w = model.word_vecs[word_ids]
    wn = model.word_vecs[neg_ids]
    loss, gd, gw, gwn = negsamp_grads(d, w, wn, temperature=temperature)

    # sum-reduction semantics (matches sgns_loss): per-row O(1) updates
    new_d = model.doc_vecs.at[doc_ids].add(-lr * gd)
    new_w = model.word_vecs.at[word_ids].add(-lr * gw)
    new_w = new_w.at[neg_ids.reshape(-1)].add(
        -lr * gwn.reshape(-1, gwn.shape[-1]))
    if unit_norm:
        new_w = _unit_rows(new_w)
        new_d = _unit_rows(new_d)
    return PVDBOWModel(new_w, new_d), loss.mean()
