"""Pure-jnp oracle for the negsamp kernel: same math, autodiff-free."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def negsamp_grads_ref(d: jax.Array, w: jax.Array, wn: jax.Array,
                      temperature: float = 1.0):
    """Returns (loss [B], grad_d, grad_w, grad_wn) — identical contract
    to kernels.negsamp.kernel.negsamp_grads_kernel."""
    t = temperature
    pos = jnp.sum(w * d, axis=-1) * t
    neg = jnp.einsum("bkd,bd->bk", wn, d) * t
    loss = jax.nn.softplus(-pos) + jax.nn.softplus(neg).sum(axis=-1)
    gpos = (jax.nn.sigmoid(pos) - 1.0) * t
    gneg = jax.nn.sigmoid(neg) * t
    grad_d = gpos[:, None] * w + jnp.einsum("bk,bkd->bd", gneg, wn)
    grad_w = gpos[:, None] * d
    grad_wn = gneg[:, :, None] * d[:, None, :]
    return loss, grad_d, grad_w, grad_wn
