"""One-launch scan-over-shards megakernel (see ``kernel`` docstring).

``ops`` exposes the payload builder, the jit'd wrappers, and the
executor-facing ``MegascanSpec``; ``ref`` the slow oracles.
"""
from repro.kernels.megascan.ops import (  # noqa: F401
    MegascanPayload,
    MegascanSpec,
    build_payload,
    megascan_segment_sums,
    megascan_topk,
)
