"""Pure-jnp oracles for the megascan, built on the per-mode refs.

These score the *packed payload* the slow-but-obvious way: full
[B, n_rows] similarity matrix, padding rows masked by their
out-of-range slot, then a dense segment reduction / per-slot top-k.
Used by tests to pin the one-launch kernels independently of the
per-shard fused path they must also match bit-for-bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.asym.ref import asym_exp_similarity_ref
from repro.kernels.hamming.ref import hamming_similarity_ref
from repro.kernels.megascan.ops import MegascanPayload


def _payload_sims(payload: MegascanPayload, queries, planes, bits: int,
                  *, mode: str, temperature: float) -> jax.Array:
    if mode == "asym":
        return asym_exp_similarity_ref(
            jnp.asarray(queries, jnp.float32), payload.sig,
            jnp.asarray(planes, jnp.float32), bits, temperature)
    if mode == "hamming":
        # the hamming oracle folds temperature in afterwards:
        # exp(cos)**t == exp(t*cos)
        return hamming_similarity_ref(
            jnp.asarray(queries, jnp.uint32), payload.sig,
            bits) ** temperature
    raise ValueError(f"unknown megascan mode {mode!r}")


def megascan_segment_sums_ref(payload: MegascanPayload, queries, planes,
                              bits: int, *, mode: str = "asym",
                              temperature: float = 1.0) -> np.ndarray:
    """[B, n_slots] float64 per-(query, slot) sums over real rows."""
    sims = np.asarray(_payload_sims(payload, queries, planes, bits,
                                    mode=mode, temperature=temperature),
                      np.float64)
    slots = np.asarray(payload.slots).ravel()
    out = np.zeros((sims.shape[0], payload.n_slots), np.float64)
    for s in range(payload.n_slots):
        out[:, s] = sims[:, slots == s].sum(axis=1)
    return out


def megascan_topk_ref(payload: MegascanPayload, queries, planes,
                      bits: int, k: int, *, temperature: float = 1.0,
                      ) -> "tuple[np.ndarray, np.ndarray]":
    """([B, n_slots, k] int64 doc ids, [B, n_slots, k] float64 values),
    padded with -1 / -inf like ``megascan_topk``."""
    sims = np.asarray(_payload_sims(payload, queries, planes, bits,
                                    mode="asym", temperature=temperature),
                      np.float64)
    slots = np.asarray(payload.slots).ravel()
    b = sims.shape[0]
    ids = np.full((b, payload.n_slots, k), -1, np.int64)
    vals = np.full((b, payload.n_slots, k), -np.inf, np.float64)
    for s in range(payload.n_slots):
        rows = np.nonzero(slots == s)[0]
        if rows.size == 0:
            continue
        v = sims[:, rows]
        kk = min(k, rows.size)
        order = np.argsort(-v, axis=1, kind="stable")[:, :kk]
        ids[:, s, :kk] = payload.doc_idx[rows[order]]
        vals[:, s, :kk] = np.take_along_axis(v, order, axis=1)
    return ids, vals
