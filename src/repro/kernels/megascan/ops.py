"""Payload builder + jit'd wrappers + executor spec for the megascan.

The megascan's input contract is the **block-aligned packed payload**
(``build_payload``): every shard's shard-sorted signature rows are
padded *independently* to TM-row block boundaries and concatenated, so
each TM block belongs to exactly one shard slot.  That alignment is
what buys bit-for-bit parity with a per-shard launch sequence: a slot's
output column only ever accumulates its own blocks, in its own block
order, through the same one-hot MXU dot — blocks of other shards (and
padding rows, which carry an out-of-range slot) contribute exact float
zeros, and ``x + 0.0`` is bitwise ``x`` for the strictly-positive
``exp`` sums the scan produces.  The per-shard reference path
(``MegascanSpec.run_shard``) therefore runs the *same* fused segment-sum
kernels (PR 2) on a single-shard payload with the same TM padding — one
launch per shard, bit-identical partials — which is also the
interpret-mode fallback when a deployment wants to disable grouping.

``MegascanSpec`` is the executor-facing handle: ``scan_fns()`` returns
per-query scan fns whose composite ``run_shared_scan`` closure carries
the spec, so ``ShardTaskExecutor.map_shards`` can route a whole shard
group as ONE launch (``run_group``) while emitting per-(query, shard)
results in exactly the layout the cross-host gather already consumes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterable, Optional, Sequence, Tuple

import jax
import numpy as np
import jax.numpy as jnp

from repro.data.store import plan_blocked_layout
from repro.kernels.asym import kernel as _ka
from repro.kernels.asym.ops import _prep_queries
from repro.kernels.common import on_tpu, pad_rows
from repro.kernels.hamming import kernel as _kh
from repro.kernels.megascan import kernel as _km


def _lane_pad(n: int) -> int:
    return max(128, -(-int(n) // 128) * 128)


@dataclasses.dataclass(frozen=True)
class MegascanPayload:
    """Block-aligned packed multi-shard signature payload.

    ``sig`` rows are grouped per shard slot, each slot padded to a TM
    multiple (padding rows are zero signatures with slot ``slot_pad``,
    an out-of-range slot that reduces into nothing); ``slots`` maps
    every row to its shard slot; ``doc_idx`` maps every row back to the
    global doc id (-1 for padding); ``block_slot[j]`` names the single
    slot TM-block ``j`` belongs to."""

    sig: jax.Array          # [n_rows, W] uint32, device-resident
    slots: jax.Array        # [1, n_rows] int32, device-resident
    doc_idx: np.ndarray     # [n_rows] int64, -1 on padding rows
    counts: np.ndarray      # [n_slots] int64 real rows per slot
    block_slot: np.ndarray  # [n_blocks] int32 block -> slot
    shard_ids: Tuple[int, ...]
    tm: int
    n_slots: int
    n_blocks: int
    n_rows: int

    @property
    def slot_pad(self) -> int:
        """Lane-padded slot-axis width (also the padding rows' slot)."""
        return _lane_pad(self.n_slots)

    @property
    def nbytes_streamed(self) -> int:
        """HBM bytes the scan streams through VMEM per launch."""
        return int(self.sig.size * 4 + self.slots.size * 4)


def build_payload(segments: Sequence[Tuple[np.ndarray, np.ndarray]],
                  *, tm: int = 256,
                  shard_ids: Optional[Sequence[int]] = None,
                  ) -> MegascanPayload:
    """Pack per-shard ``(signatures [c_i, W] uint32, doc_ids [c_i])``
    segments into one block-aligned payload.  Empty shards get zero
    blocks (their slot simply never appears in ``block_slot``)."""
    if not segments:
        raise ValueError("megascan payload needs at least one shard")
    if tm <= 0 or tm & (tm - 1) != 0:
        raise ValueError(f"tm must be a positive power of two, got {tm}")
    w = int(segments[0][0].shape[1])
    counts = np.array([len(s[0]) for s in segments], np.int64)
    row_starts, blocks, n_rows = plan_blocked_layout(counts, tm)
    n_slots = len(segments)
    slot_pad = _lane_pad(n_slots)
    sig = np.zeros((n_rows, w), np.uint32)
    slots = np.full(n_rows, slot_pad, np.int32)
    doc_idx = np.full(n_rows, -1, np.int64)
    for i, (seg_sig, seg_docs) in enumerate(segments):
        c = int(counts[i])
        if c == 0:
            continue
        r = int(row_starts[i])
        sig[r:r + c] = np.asarray(seg_sig, np.uint32)
        slots[r:r + c] = i
        doc_idx[r:r + c] = np.asarray(seg_docs, np.int64)
    block_slot = np.repeat(np.arange(n_slots, dtype=np.int32),
                           blocks).astype(np.int32)
    if shard_ids is None:
        shard_ids = range(n_slots)
    return MegascanPayload(
        sig=jnp.asarray(sig), slots=jnp.asarray(slots.reshape(1, -1)),
        doc_idx=doc_idx, counts=counts, block_slot=block_slot,
        shard_ids=tuple(int(s) for s in shard_ids),
        tm=int(tm), n_slots=n_slots, n_blocks=int(block_slot.shape[0]),
        n_rows=int(n_rows))


def megascan_segment_sums(payload: MegascanPayload, queries: jax.Array,
                          planes: Optional[jax.Array], bits: int,
                          *, mode: str = "asym", tb: int = 8,
                          temperature: float = 1.0,
                          double_buffer: "bool | None" = None,
                          interpret: "bool | None" = None) -> np.ndarray:
    """One-launch per-(query, shard-slot) partial sums over the packed
    payload: [B, n_slots] float64.  ``mode="asym"`` takes [B, dim] real
    query vectors (any norm) + hyperplanes; ``mode="hamming"`` takes
    [B, W] packed query signatures (``planes`` ignored).

    ``double_buffer`` picks the data-movement schedule (None = the
    explicit DMA schedule on TPU, Mosaic's BlockSpec grid pipeline in
    interpret mode); both are bit-identical."""
    if interpret is None:
        interpret = not on_tpu()
    if double_buffer is None:
        double_buffer = on_tpu()
    s_pad = payload.slot_pad
    if mode == "asym":
        q, b, tb = _prep_queries(queries, tb)
        if payload.n_rows == 0:
            return np.zeros((b, payload.n_slots), np.float64)
        pl_ = jnp.asarray(planes, jnp.float32)
        if double_buffer:
            out = _km.asym_megascan_segsum_db_kernel(
                q, pl_, payload.sig, payload.slots, bits, s_pad,
                tb=tb, tm=payload.tm, interpret=interpret,
                temperature=temperature)
        else:
            out = _ka.asym_segment_sum_kernel(
                q, pl_, payload.sig, payload.slots, bits, s_pad,
                tb=tb, tm=payload.tm, interpret=interpret,
                temperature=temperature)
    elif mode == "hamming":
        qp = jnp.asarray(queries, jnp.uint32)
        b = qp.shape[0]
        tb = min(tb, max(1, b))
        if payload.n_rows == 0:
            return np.zeros((b, payload.n_slots), np.float64)
        qp = pad_rows(qp, tb)
        if double_buffer:
            out = _km.hamming_megascan_segsum_db_kernel(
                qp, payload.sig, payload.slots, bits, s_pad,
                tn=tb, tm=payload.tm, interpret=interpret,
                temperature=temperature)
        else:
            out = _kh.hamming_segment_similarity_kernel(
                qp, payload.sig, payload.slots, bits, s_pad,
                tn=tb, tm=payload.tm, interpret=interpret,
                temperature=temperature)
    else:
        raise ValueError(f"unknown megascan mode {mode!r}")
    return np.asarray(out[:b, :payload.n_slots], np.float64)


def megascan_topk(payload: MegascanPayload, queries: jax.Array,
                  planes: jax.Array, bits: int, k: int,
                  *, tb: int = 8, temperature: float = 1.0,
                  pad_lanes: "bool | None" = None,
                  double_buffer: "bool | None" = None,
                  interpret: "bool | None" = None,
                  ) -> "tuple[np.ndarray, np.ndarray]":
    """Ranked megascan (asym mode): per-(query, shard-slot) top-k doc
    ids + values in one launch.  Returns ([B, n_slots, k] int64 doc
    ids, [B, n_slots, k] float64 values); a slot with fewer than k docs
    pads with id -1 / value -inf.  The kernel emits only per-tile
    bitonic candidates (K lane-padded on TPU, PR 4's rule); the final
    per-slot reduction over <= blocks*K candidates happens here."""
    if interpret is None:
        interpret = not on_tpu()
    if double_buffer is None:
        double_buffer = on_tpu()
    if pad_lanes is None:
        pad_lanes = on_tpu()
    q, b, tb = _prep_queries(queries, tb)
    k = int(k)
    ids = np.full((b, payload.n_slots, k), -1, np.int64)
    vals = np.full((b, payload.n_slots, k), -np.inf, np.float64)
    if payload.n_rows == 0 or k == 0:
        return ids, vals
    kp = _lane_pad(k) if pad_lanes else k
    if kp > payload.tm:
        raise ValueError(
            f"k={k} (lane-padded {kp}) exceeds payload tile tm={payload.tm}")
    kernel = (_km.asym_megascan_topk_db_kernel if double_buffer
              else _km.asym_megascan_topk_kernel)
    cvals, cpos = kernel(
        q, jnp.asarray(planes, jnp.float32), payload.sig, payload.slots,
        bits, kp, payload.n_slots, tb=tb, tm=payload.tm,
        interpret=interpret, temperature=temperature)
    cvals = np.asarray(cvals[:b])          # [B, n_blocks*kp] float32
    cpos = np.asarray(cpos[:b])            # [B, n_blocks*kp] int32
    lane = np.arange(kp)
    for s in range(payload.n_slots):
        blocks_s = np.nonzero(payload.block_slot == s)[0]
        if blocks_s.size == 0:
            continue
        cols = (blocks_s[:, None] * kp + lane[None, :]).ravel()
        v = cvals[:, cols]
        p = cpos[:, cols]
        kk = min(k, v.shape[1])
        # stable argsort on -v == lax.top_k order (ties -> lowest
        # candidate index first), matching asym_exp_topk's final stage
        order = np.argsort(-v, axis=1, kind="stable")[:, :kk]
        tv = np.take_along_axis(v, order, axis=1)
        tp = np.take_along_axis(p, order, axis=1)
        real = np.isfinite(tv)
        ids[:, s, :kk] = np.where(real, payload.doc_idx[tp], -1)
        vals[:, s, :kk] = np.where(real, tv.astype(np.float64), -np.inf)
    return ids, vals


# ----------------------------------------------------------------------
# executor-facing spec
# ----------------------------------------------------------------------
class MegascanSpec:
    """A batch of query scans the executor may run as ONE launch per
    shard group.  ``scan_fns()`` yields the per-query fns
    ``run_shared_scan`` expects; the composite closure it builds carries
    this spec, and a megakernel-enabled ``ShardTaskExecutor`` routes the
    whole group through ``run_group`` (one Pallas launch) instead of one
    task per shard.  ``run_shard`` is the per-shard fused parity
    reference — the same PR-2 segment-sum kernels on a single-shard
    payload with identical TM padding, hence bit-for-bit equal partials
    (see the module docstring for why the packing guarantees it).

    Results per (query, shard): a python float (sum-mode) or a
    ``{"doc_ids": int64[k_s], "values": float64[k_s]}`` dict
    (ranked mode, ``k_s = min(k, shard doc count)``)."""

    def __init__(self, index, query_vecs, *, ranked_k: Optional[int] = None,
                 mode: Optional[str] = None, tb: int = 8, tm: int = 256,
                 temperature: Optional[float] = None,
                 double_buffer: "bool | None" = None,
                 pad_lanes: "bool | None" = None):
        if index.doc_sig is None:
            raise ValueError("megascan needs doc signatures "
                             "(build_index(keep_doc_vectors=True))")
        self.index = index
        vecs = np.atleast_2d(np.asarray(query_vecs, np.float32))
        self.n_queries = vecs.shape[0]
        self.mode = mode or ("asym" if index.lsh_mode == "asym"
                             else "hamming")
        if ranked_k is not None and self.mode != "asym":
            raise ValueError("ranked megascan requires asym mode")
        self.ranked_k = ranked_k
        self.tb = int(tb)
        self.tm = int(tm)
        self.temperature = float(index.temperature if temperature is None
                                 else temperature)
        self.double_buffer = double_buffer
        self.pad_lanes = pad_lanes
        dev = index._fused_device_arrays()
        self.planes = dev["planes"]
        if self.mode == "asym":
            self.queries = jnp.asarray(vecs, jnp.float32)
        else:
            from repro.core import lsh as lsh_mod
            self.queries = lsh_mod.pack_bits(lsh_mod.signature_bits(
                jnp.asarray(vecs, jnp.float32), self.planes))
        self.stats: Dict[str, int] = {"group_launches": 0,
                                      "shard_launches": 0}
        self.last_record: Optional[dict] = None

    # -- payloads ------------------------------------------------------
    def _payload(self, shard_ids: Tuple[int, ...]) -> MegascanPayload:
        return self.index.megascan_payload(shard_ids, tm=self.tm)

    # -- compute -------------------------------------------------------
    def _scan(self, payload: MegascanPayload):
        """Run the scan over one payload; returns the dense per-slot
        arrays (sum-mode [B, S] or ranked ([B, S, k], [B, S, k]))."""
        if self.ranked_k is None:
            return megascan_segment_sums(
                payload, self.queries, self.planes, self.index.bits,
                mode=self.mode, tb=self.tb, temperature=self.temperature,
                double_buffer=self.double_buffer)
        return megascan_topk(
            payload, self.queries, self.planes, self.index.bits,
            self.ranked_k, tb=self.tb, temperature=self.temperature,
            pad_lanes=self.pad_lanes, double_buffer=self.double_buffer)

    def _extract(self, payload: MegascanPayload, dense, slot: int,
                 qi: int):
        if self.ranked_k is None:
            return float(dense[qi, slot])
        ids, vals = dense
        k_s = int(min(self.ranked_k, payload.counts[slot]))
        return {"doc_ids": np.asarray(ids[qi, slot, :k_s]),
                "values": np.asarray(vals[qi, slot, :k_s])}

    def _flops(self, payload: MegascanPayload) -> int:
        b = self.n_queries
        bits = int(self.index.bits)
        rows = int(payload.n_rows)
        if self.mode == "asym":
            dim = int(self.planes.shape[1])
            proj = 2 * b * bits * dim
            score = 2 * b * rows * bits
        else:
            proj = 0
            score = 3 * b * rows * (bits // 32)
        reduce_ = 2 * b * rows * payload.slot_pad
        return proj + score + reduce_

    def run_group(self, shard_ids: Sequence[int],
                  queries_of: Dict[int, Iterable[int]]) -> dict:
        """ONE launch for the whole shard group; returns
        ``{shard_id: {query_index: result}}`` — exactly the layout the
        shared-scan gather consumes."""
        ids = tuple(int(s) for s in shard_ids)
        payload = self._payload(ids)
        t0 = time.perf_counter()
        dense = self._scan(payload)
        wall = time.perf_counter() - t0
        self.stats["group_launches"] += 1
        self.last_record = {
            "kind": "megascan", "mode": self.mode,
            "ranked": self.ranked_k is not None, "launches": 1,
            "shards": len(ids), "blocks": payload.n_blocks,
            "rows": payload.n_rows, "queries": self.n_queries,
            "tm": payload.tm, "prefetch_depth": 2,
            "double_buffer": bool(self.double_buffer
                                  if self.double_buffer is not None
                                  else on_tpu()),
            "bytes_streamed": payload.nbytes_streamed,
            "flops": self._flops(payload), "wall_s": wall,
        }
        out: Dict[int, dict] = {}
        for slot, sid in enumerate(ids):
            out[sid] = {qi: self._extract(payload, dense, slot, qi)
                        for qi in queries_of.get(sid, ())}
        return out

    def run_shard(self, shard_id: int, query_ids: Iterable[int]) -> dict:
        """Per-shard fused parity reference / fallback: one launch for
        THIS shard only, same kernels + padding as the group path."""
        payload = self._payload((int(shard_id),))
        dense = self._scan(payload)
        self.stats["shard_launches"] += 1
        return {qi: self._extract(payload, dense, 0, qi)
                for qi in query_ids}

    # -- shared-scan integration --------------------------------------
    def scan_fns(self):
        """Per-query scan fns for ``run_shared_scan``; each carries this
        spec so spec-aware layers can fuse the whole batch."""
        fns = []
        for qi in range(self.n_queries):
            def fn(shard, _qi=qi):
                return self.run_shard(shard.shard_id, (_qi,))[_qi]
            fn.megascan = self
            fn.query_index = qi
            fns.append(fn)
        return fns
