"""Pallas TPU megakernel: one-launch scan over a host's shard payloads.

The fused kernels (kernels/asym, kernels/hamming) reduce one contiguous
signature database per launch; at per-host shard counts in the hundreds
the per-shard launch cadence is dispatch-bound — kernel launch latency
and HBM<->VMEM round-trips dominate the very scan EmApprox is supposed
to make cheap.  This module restructures the per-host shared scan as a
*single* Pallas program over a packed multi-shard payload (see
``megascan.ops.build_payload``): every shard's signature rows are padded
to TM-block boundaries and concatenated, so each TM block belongs to
exactly one shard *slot* and the whole host group streams through VMEM
in one launch — the compile-once-scan-many idiom of levanter's
``Stacked`` scan-over-layers, applied to shard payloads instead of
transformer blocks.

Two data-movement schedules produce bit-identical results:

  * the *streamed* schedule (``megascan.ops`` routes it through the
    existing ``asym``/``hamming`` segment-sum kernels with shard-slot
    ids as the segment map) — Mosaic's BlockSpec grid pipeline already
    double-buffers the HBM->VMEM block copies;
  * the *double-buffered DMA* schedule here (``*_segsum_db_kernel``):
    the packed payload stays in HBM (``memory_space=ANY``) and the
    kernel itself prefetches block i+1 into the alternate VMEM scratch
    slot with ``pltpu.make_async_copy`` while the MXU scores block i —
    the explicit form of the same overlap, and the schedule that keeps
    working when the block sequence is the whole program (grid collapses
    to query tiles, so there is no M grid axis for Mosaic to pipeline).

Both accumulate per-(query, slot) partials block-by-block in a resident
[TB, S] VMEM output — identical op shapes and identical accumulation
order, hence bit-for-bit equality between the schedules *and* with a
per-shard launch sequence over the same blocks (a slot's column only
ever sums its own blocks, in the same order, with the same one-hot dot;
other blocks contribute exact float zeros).

Ranked epilogue (``_topk_block``): instead of ``jax.lax.top_k`` (which
Mosaic may lower slowly), each tile runs a *lane-padded bitonic sort*
(``bitonic_sort_desc``) — descending by value, ties broken by ascending
index, exactly ``jax.lax.top_k``'s order — and emits only its K best
(value, payload-position) candidates, so ranked queries never
materialize full per-doc scores.  Compare-exchange partners are reached
with reshape/flip (lane XOR by a power-of-two stride), which lowers to
hardware tile shuffles; K is lane-padded to 128 multiples by the ops
wrapper on TPU (PR 4's rule), and TM must be a power of two.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.asym.kernel import _unpack_signs


def _asym_tile(q, planes, db, bits: int, temperature: float) -> jax.Array:
    """[TB, TM] exp(beta * cos_asym) from *values* — op-for-op the same
    math as ``asym.kernel._exp_sim_tile`` (which reads refs), so the two
    paths are bit-identical on identical inputs."""
    proj = jnp.dot(q, planes.T, preferred_element_type=jnp.float32)
    signs = _unpack_signs(db, bits)
    scale = 1.0 / (bits * math.sqrt(2.0 / math.pi))
    cos = jnp.dot(proj, signs.T, preferred_element_type=jnp.float32) * scale
    cos = jnp.clip(cos, -1.0, 1.0)
    return jnp.exp(temperature * cos)


def _hamming_tile(q, db, bits: float, temperature: float) -> jax.Array:
    """[TN, TM] exp(beta*cos(pi*m/L)) from values — mirrors
    ``hamming.kernel._sim_tile``."""
    w = q.shape[1]
    acc = jnp.zeros((q.shape[0], db.shape[0]), jnp.int32)
    for k in range(w):
        x = q[:, k][:, None] ^ db[:, k][None, :]
        acc = acc + jax.lax.population_count(x).astype(jnp.int32)
    m = acc.astype(jnp.float32)
    return jnp.exp(temperature * jnp.cos(jnp.pi * m / bits))


def _segsum_block(tile: jax.Array, seg: jax.Array, out_ref) -> None:
    """Accumulate one [TB, TM] tile into the resident [TB, S] output by
    a one-hot dot against the row -> slot map (padding rows carry an
    out-of-range slot, so their one-hot column is zero and they add
    exact float zeros)."""
    slots = jax.lax.broadcasted_iota(
        jnp.int32, (seg.shape[0], out_ref.shape[1]), 1)
    onehot = (seg[:, None] == slots).astype(jnp.float32)
    out_ref[...] += jnp.dot(tile, onehot,
                            preferred_element_type=jnp.float32)


# ----------------------------------------------------------------------
# double-buffered DMA schedule: grid = query tiles only; the kernel owns
# the block loop and prefetches block j+1 while scoring block j
# ----------------------------------------------------------------------
def _asym_segsum_db_body(q_ref, planes_ref, slot_ref, sig_ref, out_ref,
                         buf, sems, *, bits: int, temperature: float,
                         n_blocks: int, tm: int):
    q = q_ref[...]
    planes = planes_ref[...]

    def dma(slot, j):
        return pltpu.make_async_copy(sig_ref.at[pl.ds(j * tm, tm)],
                                     buf.at[slot], sems.at[slot])

    dma(0, 0).start()
    out_ref[...] = jnp.zeros_like(out_ref)

    def step(j, carry):
        cur = jax.lax.rem(j, 2)

        @pl.when(j + 1 < n_blocks)
        def _prefetch():
            dma(jax.lax.rem(j + 1, 2), j + 1).start()

        dma(cur, j).wait()
        tile = _asym_tile(q, planes, buf[cur], bits, temperature)
        seg = slot_ref[0, pl.ds(j * tm, tm)]
        _segsum_block(tile, seg, out_ref)
        return carry

    jax.lax.fori_loop(0, n_blocks, step, 0)


def _hamming_segsum_db_body(q_ref, slot_ref, sig_ref, out_ref, buf, sems,
                            *, bits: float, temperature: float,
                            n_blocks: int, tm: int):
    q = q_ref[...]

    def dma(slot, j):
        return pltpu.make_async_copy(sig_ref.at[pl.ds(j * tm, tm)],
                                     buf.at[slot], sems.at[slot])

    dma(0, 0).start()
    out_ref[...] = jnp.zeros_like(out_ref)

    def step(j, carry):
        cur = jax.lax.rem(j, 2)

        @pl.when(j + 1 < n_blocks)
        def _prefetch():
            dma(jax.lax.rem(j + 1, 2), j + 1).start()

        dma(cur, j).wait()
        tile = _hamming_tile(q, buf[cur], bits, temperature)
        seg = slot_ref[0, pl.ds(j * tm, tm)]
        _segsum_block(tile, seg, out_ref)
        return carry

    jax.lax.fori_loop(0, n_blocks, step, 0)


@functools.partial(jax.jit, static_argnames=(
    "bits", "n_slots", "tb", "tm", "interpret", "temperature"))
def asym_megascan_segsum_db_kernel(
    q: jax.Array,            # [B, dim] float32, rows unit-normalized
    planes: jax.Array,       # [bits, dim] float32
    sig: jax.Array,          # [n_blocks*TM, W] uint32, block-aligned
    slot_ids: jax.Array,     # [1, n_blocks*TM] int32 row -> shard slot
    bits: int,
    n_slots: int,            # S (lane-padded by the ops wrapper)
    *,
    tb: int = 8,
    tm: int = 256,
    interpret: bool = False,
    temperature: float = 1.0,
) -> jax.Array:
    """[B, S] per-(query, shard-slot) partial sums, one launch for the
    whole packed payload; signature blocks are DMA'd HBM->VMEM through a
    2-slot scratch ring (prefetch block j+1 while scoring block j)."""
    b, dim = q.shape
    mp, w = sig.shape
    assert mp % tm == 0, (mp, tm)
    n_blocks = mp // tm
    body = functools.partial(_asym_segsum_db_body, bits=int(bits),
                             temperature=float(temperature),
                             n_blocks=int(n_blocks), tm=int(tm))
    return pl.pallas_call(
        body,
        grid=(pl.cdiv(b, tb),),
        in_specs=[
            pl.BlockSpec((tb, dim), lambda i: (i, 0)),
            pl.BlockSpec((planes.shape[0], dim), lambda i: (0, 0)),
            pl.BlockSpec((1, mp), lambda i: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((tb, n_slots), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_slots), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((2, tm, w), jnp.uint32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(q, planes, slot_ids, sig)


@functools.partial(jax.jit, static_argnames=(
    "bits", "n_slots", "tn", "tm", "interpret", "temperature"))
def hamming_megascan_segsum_db_kernel(
    q_packed: jax.Array,     # [N, W] uint32
    sig: jax.Array,          # [n_blocks*TM, W] uint32, block-aligned
    slot_ids: jax.Array,     # [1, n_blocks*TM] int32
    bits: int,
    n_slots: int,
    *,
    tn: int = 8,
    tm: int = 256,
    interpret: bool = False,
    temperature: float = 1.0,
) -> jax.Array:
    n, w = q_packed.shape
    mp, w2 = sig.shape
    assert w == w2 and mp % tm == 0, (w, w2, mp, tm)
    n_blocks = mp // tm
    body = functools.partial(_hamming_segsum_db_body, bits=float(bits),
                             temperature=float(temperature),
                             n_blocks=int(n_blocks), tm=int(tm))
    return pl.pallas_call(
        body,
        grid=(pl.cdiv(n, tn),),
        in_specs=[
            pl.BlockSpec((tn, w), lambda i: (i, 0)),
            pl.BlockSpec((1, mp), lambda i: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((tn, n_slots), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n_slots), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((2, tm, w), jnp.uint32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(q_packed, slot_ids, sig)


# ----------------------------------------------------------------------
# lane-padded bitonic per-tile top-k (the ranked-mode epilogue)
# ----------------------------------------------------------------------
def _lane_xor_partner(x: jax.Array, stride: int) -> jax.Array:
    """Value at lane ``l ^ stride`` for every lane — a reshape + flip of
    adjacent ``stride``-wide groups (no gather), Mosaic-friendly for
    power-of-two strides."""
    tb, tm = x.shape
    xr = x.reshape(tb, tm // (2 * stride), 2, stride)
    return xr[:, :, ::-1, :].reshape(tb, tm)


def bitonic_sort_desc(vals: jax.Array,
                      idx: jax.Array) -> "tuple[jax.Array, jax.Array]":
    """Full bitonic sort of each row of ``vals`` (lane count a power of
    two), descending by value with ties broken by ascending ``idx`` —
    exactly ``jax.lax.top_k``'s order — co-sorting ``idx``.  Runs as
    log2(TM)*(log2(TM)+1)/2 vectorized compare-exchange stages; every
    partner exchange is a reshape/flip, never a gather."""
    tb, tm = vals.shape
    assert tm & (tm - 1) == 0, f"lane count {tm} must be a power of two"
    lane = jax.lax.broadcasted_iota(jnp.int32, (tb, tm), 1)
    size = 2
    while size <= tm:
        stride = size // 2
        while stride >= 1:
            pv = _lane_xor_partner(vals, stride)
            pi = _lane_xor_partner(idx, stride)
            desc = (lane & size) == 0          # block sorts descending
            is_lower = (lane & stride) == 0    # lane is lower of the pair
            take_big = is_lower == desc
            cur_big = (vals > pv) | ((vals == pv) & (idx < pi))
            keep = take_big == cur_big
            vals = jnp.where(keep, vals, pv)
            idx = jnp.where(keep, idx, pi)
            stride //= 2
        size *= 2
    return vals, idx


def _topk_block(tile: jax.Array, seg: jax.Array, j, *, k: int, tm: int,
                n_valid_slots: int) -> "tuple[jax.Array, jax.Array]":
    """One tile's K best (value, global payload position) candidates:
    padding rows (slot >= the real slot count) are masked to -inf so
    they can never enter a candidate set, then the bitonic sort ranks
    the tile and the first K lanes are emitted."""
    masked = jnp.where(seg[None, :] < n_valid_slots, tile, -jnp.inf)
    pos = jax.lax.broadcasted_iota(jnp.int32, tile.shape, 1) + j * tm
    svals, spos = bitonic_sort_desc(masked, pos)
    return svals[:, :k], spos[:, :k]


def _asym_topk_stream_body(q_ref, planes_ref, db_ref, slot_ref, vals_ref,
                           idx_ref, *, bits: int, temperature: float,
                           k: int, tm: int, n_valid_slots: int):
    j = pl.program_id(1)
    tile = _asym_tile(q_ref[...], planes_ref[...], db_ref[...], bits,
                      temperature)
    vals, pos = _topk_block(tile, slot_ref[0, ...], j, k=k, tm=tm,
                            n_valid_slots=n_valid_slots)
    vals_ref[...] = vals
    idx_ref[...] = pos


def _asym_topk_db_body(q_ref, planes_ref, slot_ref, sig_ref, vals_ref,
                       idx_ref, buf, sems, *, bits: int,
                       temperature: float, k: int, tm: int,
                       n_blocks: int, n_valid_slots: int):
    q = q_ref[...]
    planes = planes_ref[...]

    def dma(slot, j):
        return pltpu.make_async_copy(sig_ref.at[pl.ds(j * tm, tm)],
                                     buf.at[slot], sems.at[slot])

    dma(0, 0).start()

    def step(j, carry):
        cur = jax.lax.rem(j, 2)

        @pl.when(j + 1 < n_blocks)
        def _prefetch():
            dma(jax.lax.rem(j + 1, 2), j + 1).start()

        dma(cur, j).wait()
        tile = _asym_tile(q, planes, buf[cur], bits, temperature)
        seg = slot_ref[0, pl.ds(j * tm, tm)]
        vals, pos = _topk_block(tile, seg, j, k=k, tm=tm,
                                n_valid_slots=n_valid_slots)
        vals_ref[:, pl.ds(j * k, k)] = vals
        idx_ref[:, pl.ds(j * k, k)] = pos
        return carry

    jax.lax.fori_loop(0, n_blocks, step, 0)


@functools.partial(jax.jit, static_argnames=(
    "bits", "k", "n_valid_slots", "tb", "tm", "interpret", "temperature"))
def asym_megascan_topk_kernel(
    q: jax.Array,            # [B, dim] float32, rows unit-normalized
    planes: jax.Array,       # [bits, dim] float32
    sig: jax.Array,          # [n_blocks*TM, W] uint32, block-aligned
    slot_ids: jax.Array,     # [1, n_blocks*TM] int32
    bits: int,
    k: int,
    n_valid_slots: int,      # real (unpadded) slot count, for masking
    *,
    tb: int = 8,
    tm: int = 256,
    interpret: bool = False,
    temperature: float = 1.0,
) -> "tuple[jax.Array, jax.Array]":
    """Streamed-schedule ranked megascan: ([B, n_blocks*K] values,
    [B, n_blocks*K] int32 payload positions) — per-tile bitonic top-k
    candidates only; the ops wrapper groups candidates by shard slot
    and runs the cheap final per-slot top-k."""
    b, dim = q.shape
    mp, w = sig.shape
    assert mp % tm == 0 and k <= tm, (mp, tm, k)
    n_blocks = mp // tm
    body = functools.partial(_asym_topk_stream_body, bits=int(bits),
                             temperature=float(temperature), k=int(k),
                             tm=int(tm), n_valid_slots=int(n_valid_slots))
    return pl.pallas_call(
        body,
        grid=(pl.cdiv(b, tb), n_blocks),
        in_specs=[
            pl.BlockSpec((tb, dim), lambda i, j: (i, 0)),
            pl.BlockSpec((planes.shape[0], dim), lambda i, j: (0, 0)),
            pl.BlockSpec((tm, w), lambda i, j: (j, 0)),
            pl.BlockSpec((1, tm), lambda i, j: (0, j)),
        ],
        out_specs=(
            pl.BlockSpec((tb, k), lambda i, j: (i, j)),
            pl.BlockSpec((tb, k), lambda i, j: (i, j)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, n_blocks * k), jnp.float32),
            jax.ShapeDtypeStruct((b, n_blocks * k), jnp.int32),
        ),
        interpret=interpret,
    )(q, planes, sig, slot_ids)


@functools.partial(jax.jit, static_argnames=(
    "bits", "k", "n_valid_slots", "tb", "tm", "interpret", "temperature"))
def asym_megascan_topk_db_kernel(
    q: jax.Array,
    planes: jax.Array,
    sig: jax.Array,
    slot_ids: jax.Array,
    bits: int,
    k: int,
    n_valid_slots: int,
    *,
    tb: int = 8,
    tm: int = 256,
    interpret: bool = False,
    temperature: float = 1.0,
) -> "tuple[jax.Array, jax.Array]":
    """Double-buffered DMA schedule of ``asym_megascan_topk_kernel`` —
    same per-block candidates, signature blocks prefetched through the
    2-slot VMEM scratch ring while the current block is scored."""
    b, dim = q.shape
    mp, w = sig.shape
    assert mp % tm == 0 and k <= tm, (mp, tm, k)
    n_blocks = mp // tm
    body = functools.partial(_asym_topk_db_body, bits=int(bits),
                             temperature=float(temperature), k=int(k),
                             tm=int(tm), n_blocks=int(n_blocks),
                             n_valid_slots=int(n_valid_slots))
    return pl.pallas_call(
        body,
        grid=(pl.cdiv(b, tb),),
        in_specs=[
            pl.BlockSpec((tb, dim), lambda i: (i, 0)),
            pl.BlockSpec((planes.shape[0], dim), lambda i: (0, 0)),
            pl.BlockSpec((1, mp), lambda i: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=(
            pl.BlockSpec((tb, n_blocks * k), lambda i: (i, 0)),
            pl.BlockSpec((tb, n_blocks * k), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, n_blocks * k), jnp.float32),
            jax.ShapeDtypeStruct((b, n_blocks * k), jnp.int32),
        ),
        scratch_shapes=[
            pltpu.VMEM((2, tm, w), jnp.uint32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(q, planes, slot_ids, sig)
