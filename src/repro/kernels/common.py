"""Shared helpers for the kernel ops wrappers.

Every kernel's public wrapper needs the same two things: backend
detection (Pallas bodies run in interpret mode off-TPU) and row
padding to tile multiples so kernels never see ragged blocks.  One
copy here keeps the wrappers in sync.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pad_rows(x: jax.Array, multiple: int) -> jax.Array:
    """Zero-pad axis 0 of ``x`` up to a multiple of ``multiple``."""
    n = x.shape[0]
    pad = (-n) % multiple
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x
