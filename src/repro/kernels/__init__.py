"""Pallas TPU kernels for the compute hot-spots the paper optimizes
(DESIGN.md Sec. 8):

  hamming/  - packed XOR+popcount LSH similarity (paper Sec. III-B,
              the "extremely cheap" query-time similarity)
  asym/     - fused batched asymmetric scoring (projection +
              sign-matmul + exp-cosine) for the batched query engine
              (core/queries/batch.py): one kernel launch scores a
              [B, dim] query block against all packed signatures
  negsamp/  - fused PV-DBOW negative-sampling training step (the
              offline T-Time cost in paper Table II)
  kmeans/   - spherical k-means assignment (paper Sec. IV-D allocation)

Each kernel ships kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd public wrapper with an interpret fallback on CPU) and
ref.py (pure-jnp oracle used by the allclose tests).
"""
