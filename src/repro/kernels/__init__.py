"""Pallas TPU kernels for the compute hot-spots the paper optimizes
(DESIGN.md Sec. 8):

  hamming/  - packed XOR+popcount LSH similarity (paper Sec. III-B,
              the "extremely cheap" query-time similarity)
  asym/     - fused batched asymmetric scoring (projection +
              sign-matmul + exp-cosine) for the batched query engine
              (core/queries/batch.py): one kernel launch scores a
              [B, dim] query block against all packed signatures
  negsamp/  - fused PV-DBOW negative-sampling training step (the
              offline T-Time cost in paper Table II)
  kmeans/   - spherical k-means assignment (paper Sec. IV-D allocation)
  megascan/ - the one-launch scan-over-shards megakernel: a host's
              shard signatures packed into a block-aligned payload
              (every shard padded independently to TM-row blocks) and
              streamed through VMEM in a single launch — on TPU via
              explicit double-buffered DMA (prefetch shard block j+1
              while the MXU scores block j) — emitting per-(query,
              shard) partials bit-for-bit identical to a per-shard
              launch sequence of the asym/hamming segment-sum kernels.
              Ranked mode replaces ``jax.lax.top_k`` with an in-tile
              bitonic sort network (lane-padded K) as the epilogue.
              ``MegascanSpec`` is the executor-facing handle: a
              megakernel-enabled ``ShardTaskExecutor`` routes a whole
              shard group as ONE launch (runtime/executor
              ``_run_group_scan``) instead of one task per shard

Each kernel ships kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd public wrapper with an interpret fallback on CPU) and
ref.py (pure-jnp oracle used by the allclose tests).
"""
