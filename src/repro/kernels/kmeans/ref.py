"""Pure-jnp oracle for the kmeans assignment kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def assign_ref(x: jax.Array, c: jax.Array):
    """Returns (assignment int32 [N], best_score float32 [N])."""
    scores = x @ c.T
    return (jnp.argmax(scores, axis=-1).astype(jnp.int32),
            jnp.max(scores, axis=-1).astype(jnp.float32))
