from repro.kernels.kmeans.ops import assign  # noqa: F401
