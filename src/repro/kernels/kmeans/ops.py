"""Public wrapper for the spherical k-means assignment kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.kmeans import kernel as _k


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def assign(x: jax.Array, c: jax.Array, *, tn: int = 512) -> jax.Array:
    """Assignment only (int32 [N]); pads N to the tile multiple."""
    n = x.shape[0]
    tn = min(tn, max(1, n))
    pad = (-n) % tn
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    out, _ = _k.assign_kernel(x, c, tn=tn, interpret=not _on_tpu())
    return out[:n]


def assign_with_scores(x: jax.Array, c: jax.Array, *, tn: int = 512):
    n = x.shape[0]
    tn = min(tn, max(1, n))
    pad = (-n) % tn
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    out, sc = _k.assign_kernel(x, c, tn=tn, interpret=not _on_tpu())
    return out[:n], sc[:n]
