"""Pallas TPU kernel: spherical k-means assignment step.

The allocation policy (paper Sec. IV-D) clusters document vectors by
cosine.  The assignment step is a dense [N, dim] x [dim, K] matmul
followed by a row argmax — MXU work, fused here so the [TN, K] score
tile never leaves VMEM.

Tiling: rows of x are tiled TN at a time; the centroid matrix is kept
whole in VMEM (K <= ~4096 at dim 128 is ~2 MB fp32 — well under the
~16 MB VMEM budget).  The K axis is tiled only in the ops.py wrapper if
a caller exceeds that.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(x_ref, c_ref, out_ref, score_ref):
    x = x_ref[...]            # [TN, dim]
    c = c_ref[...]            # [K, dim]
    scores = jnp.dot(x, c.T, preferred_element_type=jnp.float32)  # MXU
    out_ref[...] = jnp.argmax(scores, axis=-1).astype(jnp.int32)
    score_ref[...] = jnp.max(scores, axis=-1)


@functools.partial(jax.jit, static_argnames=("tn", "interpret"))
def assign_kernel(
    x: jax.Array,     # [N, dim] unit rows
    c: jax.Array,     # [K, dim] unit rows
    *,
    tn: int = 512,
    interpret: bool = False,
):
    """Returns (assignment int32 [N], best_score float32 [N])."""
    n, dim = x.shape
    k = c.shape[0]
    grid = (pl.cdiv(n, tn),)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, dim), lambda i: (i, 0)),
            pl.BlockSpec((k, dim), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tn,), lambda i: (i,)),
            pl.BlockSpec((tn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(x, c)
