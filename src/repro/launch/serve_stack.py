"""One-call construction of the serving stack (``build_serving_stack``).

Every serving consumer — ``examples/serve_queries.py``,
``benchmarks/serve_bench.py``, the tests — used to hand-assemble the
same tower three different ways: placement + executor, budget planner,
window controller, batching window, semantic cache, fleet manager,
each with its own kwarg spelling.  ``ServeConfig`` names every knob
once and ``build_serving_stack`` wires the layers in the one correct
order:

    corpus + index
        -> executor        (single-host pool, or PlacementMap +
                            HostGroupExecutor when ``hosts >= 2``,
                            balanced / replicated / partial-tolerant)
        -> cache           (SemanticQueryCache, optional)
        -> planner         (RatePlanner against the controller's cost
                            model, optional)
        -> engine          (QueryBatch carrying all of the above)
        -> controller      (WindowController, optional)
        -> window          (BatchWindow frontend, optional)
        -> fleet           (FleetManager over the host group, optional)

The returned ``ServingStack`` exposes each layer by name, closes
bottom-up, and works as a context manager.  The facade is additive:
``QueryBatch(...)`` and friends keep their constructors — this is the
single *convenient* construction path, not the only one.

    from repro.launch.serve_stack import ServeConfig, build_serving_stack

    with build_serving_stack(corpus, index, hosts=2, cache=True,
                             planner=True) as stack:
        fut = stack.window.submit(query)          # streaming front
        results = stack.engine.execute(qs, 0.25)  # or batch-at-a-time
        print(stack.cache.record())
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import numpy as np

from repro.core.queries.batch import QueryBatch
from repro.runtime.budget import PlannerConfig, RatePlanner
from repro.runtime.controller import ControllerConfig, WindowController
from repro.runtime.executor import ShardTaskExecutor
from repro.runtime.fleet import FleetManager
from repro.runtime.placement import HostGroupExecutor, PlacementMap
from repro.runtime.qcache import QueryCacheConfig, SemanticQueryCache
from repro.runtime.window import BatchWindow


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Every serving-stack knob, named once.

    Groups (all optional beyond the defaults):

    * engine — ``rate`` (nominal sampling rate the window serves at),
      ``method``, ``confidence``, ``ci``.
    * topology — ``hosts`` (>= 2 builds a blocked ``PlacementMap`` +
      ``HostGroupExecutor``; otherwise a single ``ShardTaskExecutor``),
      ``replicas``, ``balanced``, ``workers`` (total across hosts),
      ``allow_partial``, ``fault_hook`` (per-shard-task),
      ``host_fault_hook`` (per-host, host groups only),
      ``adaptive_workers``, ``max_retries``.
    * budget — ``planner`` attaches a ``RatePlanner``
      (``planner_config``) so queries may carry ``QueryBudget``s and
      the engine degrades under pressure.
    * cache — ``cache`` attaches a ``SemanticQueryCache``
      (``cache_config``) keyed on the index's LSH signatures.
    * window — ``window`` builds the ``BatchWindow`` frontend
      (``max_batch``, ``max_delay_s``, ``max_pending``); ``adaptive``
      adds the ``WindowController`` (``controller_config``).
    * fleet — ``fleet`` wraps a host group in a ``FleetManager``
      (``warm_fn``) for join/drain/crash.
    """
    # engine
    rate: float = 0.25
    method: str = "emapprox"
    confidence: float = 0.95
    ci: bool = False
    # topology
    hosts: int = 0
    replicas: int = 1
    balanced: bool = False
    workers: int = 2
    allow_partial: bool = False
    fault_hook: Optional[Callable[[int, int], None]] = None
    host_fault_hook: Optional[Callable[[int, Any], None]] = None
    adaptive_workers: bool = False
    max_retries: int = 2
    # budget
    planner: bool = False
    planner_config: Optional[PlannerConfig] = None
    # cache
    cache: bool = False
    cache_config: Optional[QueryCacheConfig] = None
    # window
    window: bool = False
    adaptive: bool = True
    max_batch: int = 32
    max_delay_s: float = 0.002
    max_pending: Optional[int] = None
    controller_config: Optional[ControllerConfig] = None
    seed: int = 0
    # fleet
    fleet: bool = False
    warm_fn: Optional[Callable[[int, int, int], None]] = None

    def __post_init__(self):
        if self.hosts < 0:
            raise ValueError(f"hosts must be >= 0, got {self.hosts}")
        if self.workers < 1:
            raise ValueError(f"workers must be >= 1, got {self.workers}")
        if self.hosts < 2:
            for flag in ("balanced", "fleet"):
                if getattr(self, flag):
                    raise ValueError(
                        f"{flag}=True needs a host group (hosts >= 2), "
                        f"got hosts={self.hosts}")
            if self.host_fault_hook is not None:
                raise ValueError("host_fault_hook needs a host group "
                                 "(hosts >= 2)")
        if self.hosts >= 2 and self.replicas < 0:
            raise ValueError(f"replicas must be >= 0, got {self.replicas}")


@dataclasses.dataclass
class ServingStack:
    """The wired layers, by name.  ``window``/``controller``/
    ``planner``/``cache``/``fleet`` are None when not configured;
    ``executor`` and ``engine`` always exist."""
    config: ServeConfig
    corpus: Any
    index: Any
    executor: Any
    engine: QueryBatch
    controller: Optional[WindowController] = None
    planner: Optional[RatePlanner] = None
    cache: Optional[SemanticQueryCache] = None
    window: Optional[BatchWindow] = None
    fleet: Optional[FleetManager] = None

    def close(self) -> None:
        """Idempotent bottom-up shutdown: drain the window, then stop
        the executor pool(s)."""
        if self.window is not None:
            self.window.close()
        self.executor.close()

    def __enter__(self) -> "ServingStack":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def build_serving_stack(corpus, index, config: Optional[ServeConfig] = None,
                        **overrides) -> ServingStack:
    """Wire the full serving stack from one config.

    ``config`` may be a ready ``ServeConfig``; keyword overrides are
    applied on top (``build_serving_stack(c, i, hosts=2, cache=True)``
    is the short form).  See ``ServeConfig`` for the knobs."""
    cfg = config or ServeConfig()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    if cfg.hosts >= 2:
        placement = PlacementMap.blocked(corpus.n_shards, cfg.hosts,
                                         n_replicas=cfg.replicas)
        executor = HostGroupExecutor(
            placement,
            workers_per_host=max(1, cfg.workers // cfg.hosts),
            balanced=cfg.balanced,
            allow_partial=cfg.allow_partial,
            host_fault_hook=cfg.host_fault_hook,
            fault_hook=cfg.fault_hook,
            adaptive_workers=cfg.adaptive_workers,
            max_retries=cfg.max_retries)
    else:
        executor = ShardTaskExecutor(
            workers=cfg.workers,
            fault_hook=cfg.fault_hook,
            adaptive_workers=cfg.adaptive_workers,
            allow_partial=cfg.allow_partial,
            max_retries=cfg.max_retries)

    controller = None
    if cfg.window and cfg.adaptive:
        controller = WindowController(cfg.controller_config
                                      or ControllerConfig())

    planner = None
    if cfg.planner:
        planner = RatePlanner(corpus.n_shards, controller=controller,
                              config=cfg.planner_config)

    cache = None
    if cfg.cache:
        cache = SemanticQueryCache(cfg.cache_config)

    engine = QueryBatch(corpus, index, executor=executor,
                        method=cfg.method, confidence=cfg.confidence,
                        planner=planner, ci=cfg.ci, cache=cache)

    window = None
    if cfg.window:
        window = BatchWindow(engine, cfg.rate,
                             max_batch=cfg.max_batch,
                             max_delay_s=cfg.max_delay_s,
                             controller=controller,
                             max_pending=cfg.max_pending,
                             rng=np.random.default_rng(cfg.seed))

    fleet = None
    if cfg.fleet:
        fleet = FleetManager(executor, warm_fn=cfg.warm_fn)

    return ServingStack(config=cfg, corpus=corpus, index=index,
                        executor=executor, engine=engine,
                        controller=controller, planner=planner,
                        cache=cache, window=window, fleet=fleet)
