"""One-call construction of the serving stack (``build_serving_stack``).

Every serving consumer — ``examples/serve_queries.py``,
``benchmarks/serve_bench.py``, the tests — used to hand-assemble the
same tower three different ways: placement + executor, budget planner,
window controller, batching window, semantic cache, fleet manager,
each with its own kwarg spelling.  ``ServeConfig`` names every knob
once and ``build_serving_stack`` wires the layers in the one correct
order:

    corpus + index
        -> clock           (GenerationClock — the stack's single
                            generation authority, shared by the
                            executor's placement axis and the index's
                            content axis)
        -> executor        (single-host pool, or PlacementMap +
                            HostGroupExecutor when ``hosts >= 2``,
                            balanced / replicated / partial-tolerant)
        -> cache           (SemanticQueryCache, optional)
        -> planner         (RatePlanner against the controller's cost
                            model, optional)
        -> engine          (QueryBatch carrying all of the above)
        -> controller      (WindowController, optional)
        -> window          (BatchWindow frontend, optional)
        -> fleet           (FleetManager over the host group, optional)
        -> ingestor        (Ingestor — live append path, optional)

The returned ``ServingStack`` exposes each layer by name, closes
bottom-up, and works as a context manager.  The facade is additive:
``QueryBatch(...)`` and friends keep their constructors — this is the
single *convenient* construction path, not the only one.

    from repro.launch.serve_stack import ServeConfig, build_serving_stack

    with build_serving_stack(corpus, index, hosts=2, cache=True,
                             planner=True) as stack:
        fut = stack.window.submit(query)          # streaming front
        results = stack.engine.execute(qs, 0.25)  # or batch-at-a-time
        print(stack.cache.record())

Live ingest (``ingest=True`` + the trained model) appends documents
to a *serving* stack with zero pause: ``stack.ingestor.step(docs)``
builds the appended corpus/index off to the side (postings delta
merge + frozen-model PV-DBOW inference + incremental centroid
refresh), publishes the new refs RCU-style, then bumps the content
generation so cached answers over the old corpus fence themselves.
In-flight batches keep the refs they captured at entry — no reader
ever blocks on the writer.  Give ``ingest_source`` a callable and the
stack polls it from a background thread; ``close()`` stops the writer
first, then drains the window, then the pools.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, List, Optional

import numpy as np

from repro.core.index import refresh_appended
from repro.core.queries.batch import QueryBatch
from repro.runtime.budget import PlannerConfig, RatePlanner
from repro.runtime.controller import ControllerConfig, WindowController
from repro.runtime.executor import ShardTaskExecutor
from repro.runtime.fleet import FleetManager
from repro.runtime.generation import Generation, GenerationClock
from repro.runtime.placement import HostGroupExecutor, PlacementMap
from repro.runtime.qcache import QueryCacheConfig, SemanticQueryCache
from repro.runtime.window import BatchWindow


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Every serving-stack knob, named once.

    Groups (all optional beyond the defaults):

    * engine — ``rate`` (nominal sampling rate the window serves at),
      ``method``, ``confidence``, ``ci``.
    * topology — ``hosts`` (>= 2 builds a blocked ``PlacementMap`` +
      ``HostGroupExecutor``; otherwise a single ``ShardTaskExecutor``),
      ``replicas``, ``balanced``, ``workers`` (total across hosts),
      ``allow_partial``, ``fault_hook`` (per-shard-task),
      ``host_fault_hook`` (per-host, host groups only),
      ``adaptive_workers``, ``max_retries``.
    * budget — ``planner`` attaches a ``RatePlanner``
      (``planner_config``) so queries may carry ``QueryBudget``s and
      the engine degrades under pressure.
    * cache — ``cache`` attaches a ``SemanticQueryCache``
      (``cache_config``) keyed on the index's LSH signatures.
    * window — ``window`` builds the ``BatchWindow`` frontend
      (``max_batch``, ``max_delay_s``, ``max_pending``); ``adaptive``
      adds the ``WindowController`` (``controller_config``).
    * fleet — ``fleet`` wraps a host group in a ``FleetManager``
      (``warm_fn``) for join/drain/crash.
    * ingest — ``ingest`` attaches an ``Ingestor`` (requires the
      trained ``ingest_model`` + its ``ingest_pv_cfg`` for
      frozen-model inference over appended docs).  ``ingest_source``
      (a ``source(max_docs) -> list-of-token-arrays`` callable, or
      None for manual ``step()`` driving) is polled ``refresh_docs``
      docs at a time every ``refresh_interval_s`` seconds from a
      background thread; ``ingest_infer_steps`` are the per-doc
      inference steps, ``ingest_shard_tokens`` the shard-spill budget
      for appended docs (None grows the open shard unboundedly, so
      placement never changes).  ``ingest_yield_s`` paces the writer:
      a cooperative GIL yield between inference steps (result-neutral)
      that bounds how long any concurrent serving batch can stall
      behind the append path — raise it to favor serving latency,
      zero it to favor ingest throughput.
    """
    # engine
    rate: float = 0.25
    method: str = "emapprox"
    confidence: float = 0.95
    ci: bool = False
    # topology
    hosts: int = 0
    replicas: int = 1
    balanced: bool = False
    workers: int = 2
    allow_partial: bool = False
    fault_hook: Optional[Callable[[int, int], None]] = None
    host_fault_hook: Optional[Callable[[int, Any], None]] = None
    adaptive_workers: bool = False
    max_retries: int = 2
    # budget
    planner: bool = False
    planner_config: Optional[PlannerConfig] = None
    # cache
    cache: bool = False
    cache_config: Optional[QueryCacheConfig] = None
    # window
    window: bool = False
    adaptive: bool = True
    max_batch: int = 32
    max_delay_s: float = 0.002
    max_pending: Optional[int] = None
    controller_config: Optional[ControllerConfig] = None
    seed: int = 0
    # fleet
    fleet: bool = False
    warm_fn: Optional[Callable[[int, int, int], None]] = None
    # ingest
    ingest: bool = False
    ingest_model: Any = None
    ingest_pv_cfg: Any = None
    ingest_source: Optional[Callable[[int], Any]] = None
    refresh_docs: int = 64
    refresh_interval_s: float = 0.25
    ingest_infer_steps: int = 50
    ingest_shard_tokens: Optional[int] = None
    ingest_yield_s: float = 0.002

    def __post_init__(self):
        if self.hosts < 0:
            raise ValueError(f"hosts must be >= 0, got {self.hosts}")
        if self.workers < 1:
            raise ValueError(f"workers must be >= 1, got {self.workers}")
        if self.hosts < 2:
            for flag in ("balanced", "fleet"):
                if getattr(self, flag):
                    raise ValueError(
                        f"{flag}=True needs a host group (hosts >= 2), "
                        f"got hosts={self.hosts}")
            if self.host_fault_hook is not None:
                raise ValueError("host_fault_hook needs a host group "
                                 "(hosts >= 2)")
        if self.hosts >= 2 and self.replicas < 0:
            raise ValueError(f"replicas must be >= 0, got {self.replicas}")
        if self.ingest:
            if self.ingest_model is None or self.ingest_pv_cfg is None:
                raise ValueError(
                    "ingest=True requires ingest_model and ingest_pv_cfg "
                    "(the index refresh runs frozen-model PV-DBOW "
                    "inference over appended docs)")
            if self.refresh_docs < 1:
                raise ValueError(
                    f"refresh_docs must be >= 1, got {self.refresh_docs}")
            if self.refresh_interval_s <= 0:
                raise ValueError(f"refresh_interval_s must be > 0, "
                                 f"got {self.refresh_interval_s}")
            if self.ingest_infer_steps < 1:
                raise ValueError(f"ingest_infer_steps must be >= 1, "
                                 f"got {self.ingest_infer_steps}")
            if (self.ingest_shard_tokens is not None
                    and self.ingest_shard_tokens < 1):
                raise ValueError(f"ingest_shard_tokens must be >= 1 or "
                                 f"None, got {self.ingest_shard_tokens}")
            if self.ingest_yield_s < 0:
                raise ValueError(f"ingest_yield_s must be >= 0, "
                                 f"got {self.ingest_yield_s}")
        else:
            for name in ("ingest_model", "ingest_pv_cfg", "ingest_source"):
                if getattr(self, name) is not None:
                    raise ValueError(
                        f"{name} is set but ingest=False — pass "
                        f"ingest=True to attach the live append path")


class Ingestor:
    """The live append path: documents in, a new generation out, with
    zero serving pause.

    ``step(docs)`` runs the whole ingest pipeline synchronously under
    the writer lock (there is exactly one writer; readers never take
    it):

      1. **append** — ``corpus.append_documents`` builds the grown
         corpus copy-on-write: untouched shards are shared by
         reference, postings deltas merge into any already-built CSR
         bit-for-bit with a from-scratch rebuild.
      2. **refresh** — ``core.index.refresh_appended`` infers vectors
         for the new docs with the *frozen* model (paced by
         ``yield_s`` so serving threads never stall behind more than
         one inference dispatch), re-signs and re-centroids only the
         touched shards, and returns a fresh index sharing the
         stack's ``GenerationClock``.
      3. **placement** — if the append spilled new shards, the host
         group's placement extends in place (old shards keep their
         hosts; the placement generation bumps).
      4. **publish** — the engine's/stack's corpus+index refs swap
         (RCU: in-flight batches keep the refs they captured at
         entry), and only *then* does the content generation bump, so
         a racing reader can at worst stamp a fresh answer with the
         old generation — it can never serve a stale answer under the
         new one.

    ``start()`` drives ``step`` from a background thread polling
    ``source``; ``close()`` is idempotent and joins the thread."""

    def __init__(self, stack: "ServingStack", model, pv_cfg, *,
                 source: Optional[Callable[[int], Any]] = None,
                 refresh_docs: int = 64, refresh_interval_s: float = 0.25,
                 infer_steps: int = 50,
                 shard_tokens: Optional[int] = None,
                 yield_s: float = 0.002):
        self._stack = stack
        self._model = model
        self._pv_cfg = pv_cfg
        self._source = source
        self._refresh_docs = int(refresh_docs)
        self._refresh_interval_s = float(refresh_interval_s)
        self._infer_steps = int(infer_steps)
        self._shard_tokens = shard_tokens
        self._yield_s = float(yield_s)
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.errors: List[str] = []
        self.stats = dict(steps=0, docs_appended=0, swaps=0,
                          shards_added=0)

    # ------------------------------------------------------------------
    def step(self, docs_tokens) -> dict:
        """Append ``docs_tokens`` (a list of token arrays) and publish
        the new generation; returns a record of what changed.  Safe to
        call concurrently with serving; serialized against itself."""
        with self._lock:
            stack = self._stack
            engine = stack.engine
            corpus, index = engine.corpus, engine.index
            new_corpus, new_ids, affected = corpus.append_documents(
                docs_tokens, shard_tokens=self._shard_tokens)
            self.stats["steps"] += 1
            if len(new_ids) == 0:
                return dict(appended=0, new_shards=0,
                            generation=stack.clock.current().record())
            new_index = refresh_appended(
                index, new_corpus, self._model, self._pv_cfg,
                docs_tokens, affected, infer_steps=self._infer_steps,
                infer_pause_s=self._yield_s)
            grown = new_corpus.n_shards - corpus.n_shards
            if grown and hasattr(stack.executor, "set_placement"):
                stack.executor.set_placement(
                    stack.executor.placement.extend(new_corpus.n_shards))
            # RCU publish: refs first (one atomic store — a racing
            # batch can never capture a torn pair), generation second
            # (see class docstring for why this order is the safe one)
            engine.swap_world(new_corpus, new_index)
            stack.corpus, stack.index = new_corpus, new_index
            gen = stack.clock.bump_content()
            self.stats["docs_appended"] += int(len(new_ids))
            self.stats["swaps"] += 1
            self.stats["shards_added"] += int(grown)
            return dict(appended=int(len(new_ids)), new_shards=int(grown),
                        generation=gen.record())

    # ------------------------------------------------------------------
    def start(self) -> None:
        """Start the background polling thread (needs ``source``)."""
        if self._source is None:
            raise ValueError("Ingestor.start() needs an ingest_source")
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run, name="ingestor", daemon=True)
        self._thread.start()

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                docs = self._source(self._refresh_docs)
                if docs:
                    self.step(list(docs))
            except Exception as e:  # noqa: BLE001 - surfaced in record()
                self.errors.append(f"{type(e).__name__}: {e}")
                break
            self._stop.wait(self._refresh_interval_s)

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def close(self) -> None:
        """Idempotent: stop and join the polling thread."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None

    def record(self) -> dict:
        """JSON-ready ingest counters + the stack's generation."""
        return dict(
            running=self.running,
            refresh_docs=self._refresh_docs,
            refresh_interval_s=self._refresh_interval_s,
            generation=self._stack.clock.current().record(),
            errors=list(self.errors),
            **{k: int(v) for k, v in self.stats.items()})


@dataclasses.dataclass
class ServingStack:
    """The wired layers, by name.  ``window``/``controller``/
    ``planner``/``cache``/``fleet``/``ingestor`` are None when not
    configured; ``executor``, ``engine`` and ``clock`` always exist.

    ``clock`` is the stack's single generation authority: the
    executor's placement swaps and the ingestor's content swaps both
    mint through it, and ``generation`` is the current composite."""
    config: ServeConfig
    corpus: Any
    index: Any
    executor: Any
    engine: QueryBatch
    clock: GenerationClock = dataclasses.field(
        default_factory=GenerationClock)
    controller: Optional[WindowController] = None
    planner: Optional[RatePlanner] = None
    cache: Optional[SemanticQueryCache] = None
    window: Optional[BatchWindow] = None
    fleet: Optional[FleetManager] = None
    ingestor: Optional[Ingestor] = None

    @property
    def generation(self) -> Generation:
        """The stack's current (placement, content) generation."""
        return self.clock.current()

    def close(self) -> None:
        """Idempotent bottom-up shutdown: stop the ingest writer, then
        drain the window, then stop the executor pool(s)."""
        if self.ingestor is not None:
            self.ingestor.close()
        if self.window is not None:
            self.window.close()
        self.executor.close()

    def __enter__(self) -> "ServingStack":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def build_serving_stack(corpus, index, config: Optional[ServeConfig] = None,
                        **overrides) -> ServingStack:
    """Wire the full serving stack from one config.

    ``config`` may be a ready ``ServeConfig``; keyword overrides are
    applied on top (``build_serving_stack(c, i, hosts=2, cache=True)``
    is the short form).  See ``ServeConfig`` for the knobs."""
    cfg = config or ServeConfig()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    # one generation authority per stack: the executor's placement
    # axis and the index's content axis mint through the same clock
    clock = GenerationClock()
    if index is not None:
        index.use_clock(clock)

    if cfg.hosts >= 2:
        placement = PlacementMap.blocked(corpus.n_shards, cfg.hosts,
                                         n_replicas=cfg.replicas)
        executor = HostGroupExecutor(
            placement,
            workers_per_host=max(1, cfg.workers // cfg.hosts),
            balanced=cfg.balanced,
            allow_partial=cfg.allow_partial,
            host_fault_hook=cfg.host_fault_hook,
            fault_hook=cfg.fault_hook,
            adaptive_workers=cfg.adaptive_workers,
            max_retries=cfg.max_retries,
            clock=clock)
    else:
        executor = ShardTaskExecutor(
            workers=cfg.workers,
            fault_hook=cfg.fault_hook,
            adaptive_workers=cfg.adaptive_workers,
            allow_partial=cfg.allow_partial,
            max_retries=cfg.max_retries)

    controller = None
    if cfg.window and cfg.adaptive:
        controller = WindowController(cfg.controller_config
                                      or ControllerConfig())

    planner = None
    if cfg.planner:
        planner = RatePlanner(corpus.n_shards, controller=controller,
                              config=cfg.planner_config)

    cache = None
    if cfg.cache:
        cache = SemanticQueryCache(cfg.cache_config)

    engine = QueryBatch(corpus, index, executor=executor,
                        method=cfg.method, confidence=cfg.confidence,
                        planner=planner, ci=cfg.ci, cache=cache)

    window = None
    if cfg.window:
        window = BatchWindow(engine, cfg.rate,
                             max_batch=cfg.max_batch,
                             max_delay_s=cfg.max_delay_s,
                             controller=controller,
                             max_pending=cfg.max_pending,
                             rng=np.random.default_rng(cfg.seed))

    fleet = None
    if cfg.fleet:
        fleet = FleetManager(executor, warm_fn=cfg.warm_fn)

    stack = ServingStack(config=cfg, corpus=corpus, index=index,
                         executor=executor, engine=engine, clock=clock,
                         controller=controller, planner=planner,
                         cache=cache, window=window, fleet=fleet)

    if cfg.ingest:
        stack.ingestor = Ingestor(
            stack, cfg.ingest_model, cfg.ingest_pv_cfg,
            source=cfg.ingest_source,
            refresh_docs=cfg.refresh_docs,
            refresh_interval_s=cfg.refresh_interval_s,
            infer_steps=cfg.ingest_infer_steps,
            shard_tokens=cfg.ingest_shard_tokens,
            yield_s=cfg.ingest_yield_s)
        if cfg.ingest_source is not None:
            stack.ingestor.start()

    return stack
