"""Serving driver: batched prefill + decode with a KV/SSM cache.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import (
    decode_state_shardings,
    make_decode_step,
    make_prefill_step,
    params_shardings,
)
from repro.models import model as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh()
    max_len = args.prompt_len + args.gen + 8
    import dataclasses
    cfg = dataclasses.replace(cfg, max_seq_len=max(cfg.max_seq_len, max_len))

    with mesh:
        key = jax.random.PRNGKey(0)
        params = M.init_params(cfg, key)
        prompts = jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
        enc = None
        if cfg.is_encdec:
            frames = jax.random.normal(
                key, (args.batch, cfg.encoder_seq, cfg.d_model))
            enc = M.encode(params, frames, cfg)
        elif cfg.family == "vlm":
            enc = jax.random.normal(
                key, (args.batch, cfg.vision_tokens, cfg.d_model))

        state = M.init_decode_state(cfg, args.batch, max_len, enc=enc)
        prefill_fn = jax.jit(make_prefill_step(cfg))
        decode_fn = jax.jit(make_decode_step(cfg))

        t0 = time.time()
        logits, state = prefill_fn(params, prompts, state)
        logits.block_until_ready()
        t_prefill = time.time() - t0

        tok = jnp.argmax(logits, axis=-1)[:, None]
        out_tokens = [tok]
        t0 = time.time()
        for i in range(args.gen - 1):
            logits, state = decode_fn(params, tok, state)
            if args.temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits / args.temperature, axis=-1)[:, None]
            else:
                tok = jnp.argmax(logits, axis=-1)[:, None]
            out_tokens.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0

        gen = np.asarray(jnp.concatenate(out_tokens, axis=1))
        print(f"[serve] arch={cfg.name} batch={args.batch} "
              f"prefill {args.prompt_len} tok in {t_prefill*1e3:.0f}ms; "
              f"decode {args.gen} tok in {t_decode*1e3:.0f}ms "
              f"({args.batch*(args.gen-1)/max(t_decode,1e-9):.0f} tok/s)")
        print(f"[serve] first sequence: {gen[0][:16].tolist()} ...")


if __name__ == "__main__":
    main()
