"""Input shape cells + abstract input specs for the dry-run.

Every (architecture x shape) cell from the assignment maps here to a
step kind + ShapeDtypeStruct inputs (no allocation — the full configs
are only ever exercised abstractly; smoke tests use reduced configs).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_is_supported(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """DESIGN.md Sec. 6 skip policy."""
    cell = SHAPES[shape]
    if cell.name == "long_500k" and not cfg.supports_long_context:
        return False, ("full-attention decode state would be a 500k KV "
                       "cache; sub-quadratic archs only (DESIGN.md Sec 6)")
    return True, ""


def enc_input_spec(cfg: ModelConfig, batch: int, dtype):
    if cfg.is_encdec:
        return jax.ShapeDtypeStruct((batch, cfg.encoder_seq, cfg.d_model),
                                    dtype)
    if cfg.family == "vlm":
        return jax.ShapeDtypeStruct((batch, cfg.vision_tokens, cfg.d_model),
                                    dtype)
    return None


def train_input_specs(cfg: ModelConfig, shape: str) -> Dict[str, jax.ShapeDtypeStruct]:
    cell = SHAPES[shape]
    b, s = cell.global_batch, cell.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
    }
    enc = enc_input_spec(cfg, b, cfg.dtypes.compute_dtype)
    if enc is not None:
        specs["enc_inputs"] = enc
    return specs


def serve_token_spec(cfg: ModelConfig, shape: str):
    cell = SHAPES[shape]
    if cell.kind == "prefill":
        return jax.ShapeDtypeStruct((cell.global_batch, cell.seq_len),
                                    jnp.int32)
    return jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32)


def effective_max_len(cfg: ModelConfig, shape: str) -> int:
    return SHAPES[shape].seq_len


def microbatches_for(cfg: ModelConfig, shape: str) -> int:
    """Gradient-accumulation depth for train cells: keeps live
    activations per microbatch bounded.  Wider models get smaller
    microbatches (napkin: live bytes ~ tokens_mb * d_model * c; holding
    tokens_mb * d_model ~ 2^26 keeps the per-device residual + attention
    temp under a few GB at 256-way sharding)."""
    if SHAPES[shape].kind != "train":
        return 1
    cell = SHAPES[shape]
    tokens = cell.global_batch * cell.seq_len
    if cfg.family == "moe" and cfg.n_experts >= 64:
        target = 1 << 14   # maverick: dispatch + expert-grad temps
    elif cfg.d_model >= 4096:
        target = 1 << 15
    elif cfg.d_model >= 2048:
        target = 1 << 16
    else:
        target = 1 << 17
    per_mb = max(1, tokens // target)
    mb = min(cell.global_batch, per_mb)
    # per-microbatch batch must stay >= 32 (pod x data = 2 x 16) or the
    # batch dim stops dividing the mesh and activations replicate
    # (measured: qwen train_4k 14 -> 29 GiB at mb=32, per-mb batch 8)
    mb = min(mb, max(1, cell.global_batch // 32))
    # choose a divisor of global_batch
    while cell.global_batch % mb:
        mb -= 1
    return max(1, mb)
