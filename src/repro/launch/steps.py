"""pjit-able step functions + their sharding trees.

``make_train_step`` builds loss -> grad -> (microbatched accumulation)
-> AdamW update; ``make_prefill_step`` / ``make_decode_step`` wrap the
serving entry points.  ``sharding trees`` map every argument/output to
NamedShardings derived from the logical rules, so launch code never
hand-writes PartitionSpecs per architecture.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import logical_to_mesh_spec
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optimizer.adamw import AdamWConfig, OptState, adamw_init, adamw_update
from repro.optimizer.schedules import cosine_warmup_schedule


# ----------------------------------------------------------------------
# sharding trees
# ----------------------------------------------------------------------
def batch_axes(mesh: Mesh, global_batch: int) -> Tuple[str, ...]:
    """Largest prefix of (pod, data) whose product divides the batch."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    chosen: list = []
    prod = 1
    for a in axes:
        size = mesh.shape[a]
        if global_batch % (prod * size) == 0:
            chosen.append(a)
            prod *= size
    return tuple(chosen)


def legalize_sharding(sharding: NamedSharding,
                      shape: Tuple[int, ...]) -> NamedSharding:
    """pjit *argument* shardings must divide each dimension exactly
    (unlike internal with_sharding_constraints, which GSPMD pads).  Drop
    mesh axes that don't divide — e.g. kv_heads=8 on a 16-way model
    axis, or whisper's odd vocab 51865 — leaving that dim replicated.
    The §Perf log tracks where this costs us."""
    mesh = sharding.mesh
    spec = sharding.spec
    new = []
    for i, dim in enumerate(shape):
        ax = spec[i] if i < len(spec) else None
        if ax is None:
            new.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        keep = []
        prod = 1
        for a in axes:
            size = mesh.shape[a]
            if dim % (prod * size) == 0:
                keep.append(a)
                prod *= size
        new.append(tuple(keep) if len(keep) > 1
                   else (keep[0] if keep else None))
    return NamedSharding(mesh, P(*new))


def legalize_tree(shardings, abstract):
    return jax.tree_util.tree_map(
        lambda sh, ab: legalize_sharding(sh, ab.shape)
        if isinstance(sh, NamedSharding) else sh,
        shardings, abstract)


def params_shardings(cfg: ModelConfig, mesh: Mesh, serve: bool = False):
    """Parameter shardings.  ``serve=True`` drops the FSDP axis: with no
    optimizer state to shard, replicating params over `data` removes the
    per-layer all-gathers from every decode step (measured 251 MB x 12
    gathers/step on qwen decode_32k) at a small HBM cost."""
    from repro.distributed.sharding import set_rules
    axes_tree = M.logical_axes(cfg)
    if serve:
        with set_rules({"fsdp": None}):
            raw = jax.tree_util.tree_map(
                lambda ax: NamedSharding(mesh, logical_to_mesh_spec(ax, mesh)),
                axes_tree, is_leaf=lambda x: isinstance(x, tuple))
    else:
        raw = jax.tree_util.tree_map(
            lambda ax: NamedSharding(mesh, logical_to_mesh_spec(ax, mesh)),
            axes_tree, is_leaf=lambda x: isinstance(x, tuple))
    return legalize_tree(raw, abstract_params(cfg))


def opt_state_shardings(cfg: ModelConfig, mesh: Mesh):
    p_sh = params_shardings(cfg, mesh)
    return OptState(
        step=NamedSharding(mesh, P()),
        m=p_sh, v=p_sh)


def batch_shardings(cfg: ModelConfig, mesh: Mesh, global_batch: int,
                    with_enc: bool):
    ba = batch_axes(mesh, global_batch)
    spec2 = NamedSharding(mesh, P(ba if ba else None, None))
    out = {"tokens": spec2, "labels": spec2, "mask": spec2}
    if with_enc:
        out["enc_inputs"] = NamedSharding(mesh, P(ba if ba else None,
                                                  None, None))
    return out


def decode_state_shardings(cfg: ModelConfig, mesh: Mesh,
                           state_abstract: M.DecodeState,
                           global_batch: int) -> M.DecodeState:
    """Sharding tree matching a DecodeState: batch over (pod, data), kv
    heads / ssm heads / d_inner over model, everything else replicated."""
    ba = batch_axes(mesh, global_batch)
    b_ax = ba if ba else None

    def kv_spec(arr):
        # Seq-sharding the cache over "model" (context parallelism):
        # kv_heads (8, 5, 12...) rarely divide a 16-way model axis, but
        # the 32k/500k cache seq always does — this is what takes the
        # decode-cell KV from replicated (48 GiB/chip) to 3 GiB/chip.
        if arr.ndim == 6:    # [G, per, B, S, KH, hd] (vlm / moe groups)
            return P(None, None, b_ax, "model", None, None)
        return P(None, b_ax, "model", None, None)   # [L, B, S, KH, hd]

    kv = None
    if state_abstract.kv is not None:
        kv = jax.tree_util.tree_map(
            lambda a: legalize_sharding(
                NamedSharding(mesh, kv_spec(a)), a.shape),
            state_abstract.kv)
    ssm = None
    if state_abstract.ssm is not None:
        st, cv = state_abstract.ssm
        ssm = (legalize_sharding(
                   NamedSharding(mesh, P(None, b_ax, "model", None, None)),
                   st.shape),
               legalize_sharding(
                   NamedSharding(mesh, P(None, b_ax, None, "model")),
                   cv.shape))
    pos = (NamedSharding(mesh, P(None))
           if state_abstract.pos is not None else None)
    enc = None
    if state_abstract.enc is not None:
        enc = legalize_sharding(NamedSharding(mesh, P(b_ax, None, None)),
                                state_abstract.enc.shape)
    return M.DecodeState(kv=kv, ssm=ssm, pos=pos,
                         length=NamedSharding(mesh, P()), enc=enc)


# ----------------------------------------------------------------------
# step functions
# ----------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    microbatches: int = 1, total_steps: int = 10000,
                    warmup_steps: int = 200,
                    accum_dtype=None):
    """Returns train_step(params, opt_state, batch) -> (params,
    opt_state, metrics).  ``accum_dtype``: gradient-accumulator dtype
    across microbatches (default fp32; bf16 halves the accumulator for
    very large models — fine at mb<=64 summation depth)."""
    acc_dt = accum_dtype or jnp.float32

    def train_step(params, opt_state, batch):
        if microbatches <= 1:
            loss, grads = jax.value_and_grad(M.loss_fn)(params, batch, cfg)
        else:
            def reshape(x):
                b = x.shape[0]
                return x.reshape((microbatches, b // microbatches)
                                 + x.shape[1:])
            mbs = jax.tree_util.tree_map(reshape, batch)

            def body(acc, mb):
                l, g = jax.value_and_grad(M.loss_fn)(params, mb, cfg)
                acc = jax.tree_util.tree_map(
                    lambda a, gg: a + gg.astype(a.dtype), acc, g)
                return acc, l
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            grads, losses = jax.lax.scan(body, zeros, mbs)
            grads = jax.tree_util.tree_map(
                lambda g: g / microbatches, grads)
            loss = losses.mean()
        lr_scale = cosine_warmup_schedule(
            opt_state.step, warmup_steps=warmup_steps,
            total_steps=total_steps)
        params, opt_state, metrics = adamw_update(
            params, grads, opt_state, opt_cfg, lr_scale)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, tokens, state):
        return M.prefill(params, tokens, cfg, state)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, token, state):
        return M.decode_step(params, token, cfg, state)
    return decode_step


def abstract_params(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocation."""
    return jax.eval_shape(
        functools.partial(M.init_params, cfg), jax.random.PRNGKey(0))


def abstract_opt_state(cfg: ModelConfig, opt_cfg: AdamWConfig):
    aparams = abstract_params(cfg)
    return jax.eval_shape(functools.partial(adamw_init, cfg=opt_cfg),
                          aparams)


def abstract_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                          with_enc: bool):
    def build():
        enc = None
        if with_enc:
            t = cfg.encoder_seq if cfg.is_encdec else cfg.vision_tokens
            enc = jnp.zeros((batch, t, cfg.d_model),
                            cfg.dtypes.compute_dtype)
        return M.init_decode_state(cfg, batch, max_len, enc=enc)
    return jax.eval_shape(build)
