"""Training driver: config-driven, fault-tolerant, mesh-agnostic.

Runs on whatever devices exist (1 CPU in dev, a pod slice in prod):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --steps 200 --batch 8 --seq 256 --smoke

Features exercised end-to-end here: sharded params/optimizer via the
logical rules, microbatch gradient accumulation, checkpoint/restart
(resumes from the latest committed step), similarity-driven data
sampling (--similarity-prompt), loss logging.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.corpus import SyntheticCorpusConfig, generate_text_corpus
from repro.data.pipeline import LMBatchPipeline, PrefetchIterator, SimilaritySampler
from repro.data.store import ShardedCorpus
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import (
    batch_shardings,
    make_train_step,
    opt_state_shardings,
    params_shardings,
)
from repro.models import model as M
from repro.optimizer.adamw import AdamWConfig, adamw_init


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--n-docs", type=int, default=2000)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--similarity-prompt", type=int, nargs="*", default=None,
                    help="word ids; shards are pps-sampled toward them")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    import dataclasses
    cfg = dataclasses.replace(cfg, max_seq_len=max(cfg.max_seq_len, args.seq))
    mesh = make_host_mesh()
    opt_cfg = AdamWConfig(lr=args.lr, state_dtype=cfg.dtypes.opt_state)

    # ---------------- data -------------------------------------------
    ccfg = SyntheticCorpusConfig(
        n_docs=args.n_docs,
        vocab_size=min(cfg.vocab_size, 8192), n_topics=16)
    docs, _ = generate_text_corpus(ccfg)
    corpus = ShardedCorpus.from_documents(docs, ccfg.vocab_size)
    shard_order = None
    if args.similarity_prompt:
        # EmApprox as a training-data curriculum (DESIGN.md Sec. 4)
        from repro.core.index import build_index
        from repro.core.lsh import LSHConfig
        from repro.core.pv_dbow import PVDBOWConfig, train_pv_dbow
        pv_cfg = PVDBOWConfig(dim=32, steps=300)
        index = build_index(corpus, train_pv_dbow(corpus, pv_cfg),
                            LSHConfig(bits=128),
                            temperature=pv_cfg.temperature)
        probs = index.shard_probabilities(args.similarity_prompt)
        shard_order = SimilaritySampler(probs).draw_epoch_order()
        print(f"[train] similarity sampling over {corpus.n_shards} shards")
    pipeline = LMBatchPipeline(corpus, args.batch, args.seq,
                               shard_order=shard_order)

    # ---------------- state ------------------------------------------
    with mesh:
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        opt_state = adamw_init(params, opt_cfg)
        step_fn = jax.jit(
            make_train_step(cfg, opt_cfg, microbatches=args.microbatches,
                            total_steps=args.steps),
            in_shardings=(params_shardings(cfg, mesh),
                          opt_state_shardings(cfg, mesh),
                          batch_shardings(cfg, mesh, args.batch,
                                          cfg.is_encdec or cfg.family == "vlm")),
            out_shardings=(params_shardings(cfg, mesh),
                           opt_state_shardings(cfg, mesh), None),
        )

        start_step = 0
        ckpt = None
        if args.ckpt_dir:
            ckpt = CheckpointManager(args.ckpt_dir)
            restored = ckpt.restore_latest((params, opt_state))
            if restored[0] is not None:
                start_step, (params, opt_state) = restored
                print(f"[train] resumed from step {start_step}")

        # ---------------- loop ---------------------------------------
        it = PrefetchIterator(iter(_batch_stream(pipeline, cfg)), depth=2)
        t0 = time.time()
        tokens_seen = 0
        for step in range(start_step, args.steps):
            batch = next(it)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            tokens_seen += batch["tokens"].size
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                gn = float(metrics["grad_norm"])
                tps = tokens_seen / max(time.time() - t0, 1e-9)
                print(f"[train] step {step} loss {loss:.4f} "
                      f"gnorm {gn:.3f} tok/s {tps:,.0f}", flush=True)
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, (params, opt_state))
        if ckpt:
            ckpt.save(args.steps, (params, opt_state))
            ckpt.wait()
        print(f"[train] done: {args.steps} steps, "
              f"{tokens_seen:,} tokens, {time.time()-t0:.1f}s")


def _batch_stream(pipeline: LMBatchPipeline, cfg):
    epoch = 0
    while True:
        yielded = False
        for b in pipeline.iter_epoch(epoch):
            batch = {k: jnp.asarray(v) for k, v in b.items()}
            if cfg.is_encdec:
                batch["enc_inputs"] = jnp.zeros(
                    (b["tokens"].shape[0], cfg.encoder_seq, cfg.d_model),
                    cfg.dtypes.compute_dtype)
            elif cfg.family == "vlm":
                batch["enc_inputs"] = jnp.zeros(
                    (b["tokens"].shape[0], cfg.vision_tokens, cfg.d_model),
                    cfg.dtypes.compute_dtype)
            yielded = True
            yield batch
        epoch += 1
        if not yielded:
            raise RuntimeError("corpus too small for one batch")


if __name__ == "__main__":
    main()
