import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax ---------------------------------
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: the
production mesh is built from 512 placeholder host devices; every cell's
step function must .lower().compile() under its sharding trees.
memory_analysis() proves per-device fit, cost_analysis() + the HLO
collective scan feed the roofline (EXPERIMENTS.md).

Resumable: one JSON per cell under --out; existing cells are skipped
unless --force.

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod both] [--out results/dryrun]
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Dict

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.launch import specs as S
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh
from repro.optimizer.adamw import AdamWConfig

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _array_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _ARRAY_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum output bytes of every collective op in the optimized HLO.

    '-done' ops are skipped ('-start' already carries the shape); counts
    are per-module-execution (the scan body's collectives appear once in
    HLO but execute L times — we scale by trip count when the op sits
    inside a while loop by counting it once per textual occurrence,
    which matches how XLA unrolls cost_analysis; the roofline notes
    this)."""
    stats: Dict[str, Dict[str, float]] = {
        c: {"count": 0, "bytes": 0.0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        for coll in _COLLECTIVES:
            # match "= <shapes> <coll>(" or "<coll>-start("
            m = re.search(rf"=\s+(.+?)\s+{coll}(-start)?\(", s)
            if m:
                stats[coll]["count"] += 1
                stats[coll]["bytes"] += _array_bytes(m.group(1))
                break
    return stats


def while_trip_counts(hlo_text: str) -> int:
    """Best-effort: max trip count among while loops (layer scan)."""
    trips = [int(t) for t in
             re.findall(r"trip_count=\"?(\d+)", hlo_text)]
    return max(trips, default=1)


def _probe_cfg(cfg, k: int):
    """Reduced-depth, unrolled-variant config for cost probes."""
    import dataclasses
    if cfg.family == "vlm" and cfg.cross_attn_every > 0:
        n = k * cfg.cross_attn_every
    elif cfg.family == "moe" and cfg.moe_every > 1:
        n = k * cfg.moe_every
    else:
        n = k
    repl = dict(n_layers=n, scan_layers=False)
    if cfg.is_encdec:
        repl["encoder_layers"] = k
    return dataclasses.replace(cfg, **repl)


def _layer_units(cfg) -> int:
    """How many probe units the full model has (layers / groups)."""
    if cfg.family == "vlm" and cfg.cross_attn_every > 0:
        return cfg.n_layers // cfg.cross_attn_every
    if cfg.family == "moe" and cfg.moe_every > 1:
        return cfg.n_layers // cfg.moe_every
    return cfg.n_layers


def _compile_cell(cfg, shape: str, mesh, microbatches: int):
    """Lower + compile one step for cfg on mesh; returns compiled."""
    cfg = _cell_cfg(cfg, shape)
    cell = S.SHAPES[shape]
    opt_cfg = AdamWConfig(state_dtype=cfg.dtypes.opt_state)
    with_enc = cfg.is_encdec or cfg.family == "vlm"
    with mesh:
        if cell.kind == "train":
            import jax.numpy as jnp
            accum = jnp.bfloat16 if (cfg.family == "moe" and
                                     cfg.n_experts >= 64) else None
            step = ST.make_train_step(cfg, opt_cfg,
                                      microbatches=microbatches,
                                      accum_dtype=accum)
            aparams = ST.abstract_params(cfg)
            aopt = ST.abstract_opt_state(cfg, opt_cfg)
            p_sh = ST.params_shardings(cfg, mesh)
            o_sh = ST.opt_state_shardings(cfg, mesh)
            b_sh = ST.batch_shardings(cfg, mesh, cell.global_batch, with_enc)
            abatch = S.train_input_specs(cfg, shape)
            jitted = jax.jit(step,
                             in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1))
            return jitted.lower(aparams, aopt, abatch).compile()
        max_len = S.effective_max_len(cfg, shape)
        astate = ST.abstract_decode_state(cfg, cell.global_batch,
                                          max_len, with_enc)
        st_sh = ST.decode_state_shardings(cfg, mesh, astate,
                                          cell.global_batch)
        p_sh = ST.params_shardings(cfg, mesh)
        aparams = ST.abstract_params(cfg)
        tok = S.serve_token_spec(cfg, shape)
        ba = ST.batch_axes(mesh, cell.global_batch)
        tok_sh = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(ba if ba else None, None))
        fn = (ST.make_prefill_step(cfg) if cell.kind == "prefill"
              else ST.make_decode_step(cfg))
        jitted = jax.jit(fn, in_shardings=(p_sh, tok_sh, st_sh),
                         out_shardings=(None, st_sh),
                         donate_argnums=(2,))
        return jitted.lower(aparams, tok, astate).compile()


def _extract_costs(compiled) -> Dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    colls = collective_stats(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": sum(c["bytes"] for c in colls.values()),
        "colls": colls,
    }


def cost_probe(cfg, shape: str, mesh) -> Dict:
    """Two-point depth probe: compile unrolled depth-1 and depth-2
    variants, reconstruct total = outer + units * per_layer.  Needed
    because cost_analysis counts while-loop (scan) bodies once."""
    probes = {}
    for k in (1, 2):
        c = _compile_cell(_probe_cfg(cfg, k), shape, mesh, microbatches=1)
        probes[k] = _extract_costs(c)
    units = _layer_units(cfg)
    out = {}
    for key in ("flops", "bytes", "coll_bytes"):
        # clamp: XLA occasionally optimizes depth-2 harder than depth-1
        # (negative marginal); fall back to attributing everything as
        # per-layer in that case
        per_unit = max(probes[2][key] - probes[1][key], 0.0)
        outer = max(probes[1][key] - per_unit, 0.0)
        out[f"{key}_per_layer_unit"] = per_unit
        out[f"{key}_outer"] = outer
        out[f"{key}_total"] = outer + units * per_unit
    out["units"] = units
    out["colls_probe1"] = probes[1]["colls"]
    out["colls_probe2"] = probes[2]["colls"]
    return out


def _cell_cfg(cfg, shape: str):
    """Per-shape config adjustments: chunked (flash-style) attention for
    long-sequence prefill so scores never materialize at [S, S], and
    bf16 weights for serving (standard deployment: no optimizer, no
    master copy — halves weight HBM and removes the per-step cast)."""
    import dataclasses
    if S.SHAPES[shape].kind == "prefill" and S.SHAPES[shape].seq_len >= 8192:
        cfg = dataclasses.replace(cfg, attn_impl="chunked")
    if S.SHAPES[shape].kind in ("prefill", "decode"):
        cfg = dataclasses.replace(
            cfg, dtypes=dataclasses.replace(cfg.dtypes, params="bfloat16"))
    return cfg


def run_cell(arch: str, shape: str, multi_pod: bool,
             verbose: bool = True) -> Dict:
    cfg = get_config(arch)
    ok, reason = S.cell_is_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": reason}

    t0 = time.time()
    cfg = _cell_cfg(cfg, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = S.SHAPES[shape]
    opt_cfg = AdamWConfig(state_dtype=cfg.dtypes.opt_state)
    with_enc = cfg.is_encdec or cfg.family == "vlm"

    with mesh:
        if cell.kind == "train":
            import jax.numpy as jnp
            mb = S.microbatches_for(cfg, shape)
            accum = jnp.bfloat16 if (cfg.family == "moe" and
                                     cfg.n_experts >= 64) else None
            step = ST.make_train_step(cfg, opt_cfg, microbatches=mb,
                                      accum_dtype=accum)
            aparams = ST.abstract_params(cfg)
            aopt = ST.abstract_opt_state(cfg, opt_cfg)
            p_sh = ST.params_shardings(cfg, mesh)
            o_sh = ST.opt_state_shardings(cfg, mesh)
            b_sh = ST.batch_shardings(cfg, mesh, cell.global_batch, with_enc)
            abatch = S.train_input_specs(cfg, shape)
            jitted = jax.jit(step,
                             in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(aparams, aopt, abatch)
        else:
            max_len = S.effective_max_len(cfg, shape)
            astate = ST.abstract_decode_state(cfg, cell.global_batch,
                                              max_len, with_enc)
            st_sh = ST.decode_state_shardings(cfg, mesh, astate,
                                              cell.global_batch)
            p_sh = ST.params_shardings(cfg, mesh)
            aparams = ST.abstract_params(cfg)
            tok = S.serve_token_spec(cfg, shape)
            ba = ST.batch_axes(mesh, cell.global_batch)
            tok_sh = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec(ba if ba else None, None))
            fn = (ST.make_prefill_step(cfg) if cell.kind == "prefill"
                  else ST.make_decode_step(cfg))
            jitted = jax.jit(fn, in_shardings=(p_sh, tok_sh, st_sh),
                             out_shardings=(None, st_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(aparams, tok, astate)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    colls = collective_stats(hlo)
    trip = while_trip_counts(hlo)

    result = {
        "arch": arch, "shape": shape,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "n_devices": int(np.prod(list(
            make_production_mesh(multi_pod=multi_pod).shape.values()))),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops": float(cost.get("flops", -1.0)),
        "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes":
                int(getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "collectives": colls,
        "collective_bytes_total": sum(c["bytes"] for c in colls.values()),
        "scan_trip_count": trip,
        "params_estimate": cfg.param_count_estimate(),
        "active_params_estimate": cfg.active_param_count_estimate(),
    }
    if cell.kind == "train":
        result["microbatches"] = S.microbatches_for(cfg, shape)

    # two-point depth probe for exact totals (scan bodies count once in
    # cost_analysis); only on the single-pod mesh — the roofline table is
    # single-pod per the spec, and multi-pod reuses shape-identical math
    if not multi_pod:
        t_probe = time.time()
        result["probe"] = cost_probe(cfg, shape, mesh)
        result["probe_s"] = round(time.time() - t_probe, 1)

    if verbose:
        extra = ""
        if "probe" in result:
            extra = (f" probe_flops={result['probe']['flops_total']:.3e}"
                     f" probe_coll={result['probe']['coll_bytes_total']:.3e}")
        print(f"  flops(raw)={result['flops']:.3e} "
              f"temp={result['memory']['temp_bytes']/2**30:.2f}GiB "
              f"compile={t_compile:.0f}s{extra}", flush=True)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(S.SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch.replace('-', '_')}__{shape}__" \
                      f"{'multi' if mp else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    try:
                        with open(path) as f:
                            prev = json.load(f)
                    except Exception:  # noqa: BLE001
                        prev = {}
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[skip existing] {tag}")
                        continue  # errors are retried
                print(f"[cell] {tag}", flush=True)
                try:
                    res = run_cell(arch, shape, mp)
                except Exception as e:  # noqa: BLE001
                    failures += 1
                    res = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "error", "error": str(e),
                           "traceback": traceback.format_exc()[-2000:]}
                    print(f"  ERROR: {e}", flush=True)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
