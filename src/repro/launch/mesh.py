"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS before the first jax call.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — DP
    across pods, FSDP within a pod, TP/EP on model."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has (1 CPU device in the dev container) laid
    out as a (data, model) mesh — lets the same pjit code paths run in
    tests."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_placement_mesh(n_hosts: int, *, model: int = 1):
    """Abstract (data, model) mesh describing an ``n_hosts``-wide data
    axis *without touching device state* — the serving runtime's
    ``PlacementMap.from_mesh`` reads shard residency off it, so a
    simulated multi-host topology (tests, ``--hosts N`` benches on one
    machine) and a real pod deployment configure placement the same
    way: swap this for ``make_production_mesh()`` and nothing else
    changes."""
    from jax.sharding import AbstractMesh
    return AbstractMesh((("data", int(n_hosts)), ("model", int(model))))
