"""Launchers: production mesh, multi-pod dry-run, training, serving.

``serve_stack`` is the serving facade: ``ServeConfig`` names every
serving knob once and ``build_serving_stack`` wires executor ->
cache -> planner -> engine -> controller -> window -> fleet in one
call."""
from repro.launch.serve_stack import (  # noqa: F401
    ServeConfig,
    ServingStack,
    build_serving_stack,
)
