"""Mixture-of-Experts MLP with capacity-based dense dispatch.

Top-k routing (llama4 configs use top-1) implemented with one-hot
dispatch/combine einsums — the GSPMD-friendly formulation: with experts
sharded over the ``model`` axis the dispatch einsum lowers to an
all-to-all, which is exactly the communication pattern EP wants.  Tokens
over capacity are dropped (residual passes through), standard for
capacity-factor MoE.

The router's softmax-gated top-1 sparsity is the same softmax-gated
selection structure as the paper's phi_s sampling — one picks experts
for a token, the other picks shards for a query (DESIGN.md Sec. 5).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_constraint


MAX_DISPATCH_GROUP = 4096


def moe_apply(p: dict, x: jax.Array, cfg) -> jax.Array:
    """x: [B, S, d_model] -> [B, S, d_model].

    Dispatch runs in groups of <= MAX_DISPATCH_GROUP tokens: the one-hot
    dispatch tensor is [G, g, E, c] with c ~ cf*g*k/E, i.e. O(T*g*1.25)
    elements instead of the O(T^2 * 1.25) a single global dispatch would
    cost (which is 43 TB at maverick's train_4k shape — measured napkin,
    not a guess).  Groups are an established capacity granularity
    (Switch/GShard use per-device groups)."""
    bsz, s, d = x.shape
    e = cfg.n_experts
    k = cfg.top_k
    tokens = x.reshape(bsz * s, d)
    n_tok = tokens.shape[0]
    g_size = min(MAX_DISPATCH_GROUP, n_tok)
    # pad to a whole number of groups
    pad = (-n_tok) % g_size
    if pad:
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    n_groups = tokens.shape[0] // g_size
    tg = tokens.reshape(n_groups, g_size, d)
    capacity = max(1, int(cfg.capacity_factor * g_size * k / e))

    logits = jnp.einsum("gtd,de->gte", tg, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)            # [G, t, k]

    # position of each token within its expert's queue (per group)
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)    # [G, t, k, E]
    flat = onehot.reshape(n_groups, g_size * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(
        n_groups, g_size, k, e)
    pos = (pos_in_expert * onehot).sum(-1)                     # [G, t, k]
    keep = pos < capacity
    gate_vals = gate_vals * keep

    dtype = x.dtype
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity + 1,
                            dtype=dtype)[..., :capacity]       # [G, t, k, c]
    disp = jnp.einsum("gtke,gtkc->gtec", onehot.astype(dtype), pos_oh)
    comb = jnp.einsum("gtke,gtkc,gtk->gtec", onehot.astype(jnp.float32),
                      pos_oh.astype(jnp.float32),
                      gate_vals.astype(jnp.float32)).astype(dtype)

    # route tokens to experts: [E, G, c, d] (all-to-all under EP sharding)
    xe = jnp.einsum("gtec,gtd->egcd", disp, tg)
    xe = shard_constraint(xe, "experts", None, None, "d_model")
    gg = jnp.einsum("egcd,edf->egcf", xe, p["w_gate"])
    uu = jnp.einsum("egcd,edf->egcf", xe, p["w_up"])
    h = jax.nn.silu(gg) * uu
    h = shard_constraint(h, "experts", None, None, "d_ff")
    ye = jnp.einsum("egcf,efd->egcd", h, p["w_down"])
    out = jnp.einsum("gtec,egcd->gtd", comb, ye)
    out = out.reshape(-1, d)
    if pad:
        out = out[:n_tok]
    return out.reshape(bsz, s, d)


def moe_aux_loss(p: dict, x: jax.Array, cfg) -> jax.Array:
    """Load-balancing auxiliary loss (Switch-style f_i * P_i)."""
    tokens = x.reshape(-1, x.shape[-1])
    logits = jnp.einsum("td,de->te", tokens, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts, dtype=jnp.float32), axis=0)
    prob_mean = probs.mean(axis=0)
    return cfg.n_experts * jnp.sum(frac * prob_mean)
