"""Unified model configuration for the 10 assigned architectures."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
           "float16": jnp.float16}


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    """Per-tensor-class dtypes (memory policy, DESIGN.md Sec. 7)."""
    params: str = "float32"
    compute: str = "bfloat16"
    kv_cache: str = "bfloat16"
    # optimizer second/first moments; bf16 halves optimizer HBM, the
    # distributed-optimization trick maverick-400b needs to fit 512x16GB
    opt_state: str = "float32"

    @property
    def params_dtype(self):
        return _DTYPES[self.params]

    @property
    def compute_dtype(self):
        return _DTYPES[self.compute]

    @property
    def kv_cache_dtype(self):
        return _DTYPES[self.kv_cache]

    @property
    def opt_state_dtype(self):
        return _DTYPES[self.opt_state]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                  # query heads (0 for pure ssm)
    n_kv_heads: int               # GQA kv heads
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    qkv_bias: bool = False        # qwen2.5 uses bias on QKV
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25
    # 1 = every layer is MoE (scout, HF interleave_moe_layer_step=1);
    # 2 = alternating dense/MoE (maverick) — this is what makes maverick
    # ~400B total rather than ~773B.
    moe_every: int = 1

    # --- SSM (Mamba2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2           # d_inner = expand * d_model
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_dim: int = 4

    # --- hybrid (Hymba): per-layer parallel attn + ssm heads ---
    # fraction of d_inner given to ssm vs attention is fixed 50/50 here
    sliding_window: int = 0       # 0 = full attention

    # --- enc-dec (Whisper backbone) ---
    encoder_layers: int = 0       # >0 means enc-dec; frontend is a stub
    encoder_seq: int = 1500       # whisper 30s @ 50Hz after conv stub

    # --- VLM (Llama-3.2-vision backbone) ---
    cross_attn_every: int = 0     # insert a cross-attn layer every N layers
    vision_tokens: int = 1601     # stub patch-embedding count (1 tile)

    # --- training / serving behavior ---
    max_seq_len: int = 8192
    dtypes: DTypePolicy = dataclasses.field(default_factory=DTypePolicy)
    # remat ("none" | "full" | "selective"): activation checkpointing
    # policy applied to the scanned layer body
    remat: str = "selective"
    # scan over layers keeps HLO size O(1) in depth; turn off to let XLA
    # see all layers (bigger compile, more fusion freedom)
    scan_layers: bool = True
    # attention implementation: "dense" (materialized scores) or
    # "chunked" (flash-style lazy softmax over KV chunks)
    attn_impl: str = "dense"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def supports_long_context(self) -> bool:
        """True if decode state is O(1)-ish in sequence length (DESIGN.md
        Sec. 6 long_500k policy): SSM and sliding-window hybrids."""
        return self.family == "ssm" or (
            self.family == "hybrid" and self.sliding_window > 0)

    def param_count_estimate(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D and
        memory napkin math; exact count comes from the param tree)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "audio", "vlm", "hybrid"):
            attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            per_layer += attn
        if self.family == "moe":
            # moe_every interleaving: 1/moe_every of layers are MoE, the
            # rest are dense
            moe_frac = 1.0 / self.moe_every
            per_layer += moe_frac * self.n_experts * 3 * d * ff
            per_layer += (1 - moe_frac) * 3 * d * ff
        elif self.family in ("dense", "audio", "vlm"):
            per_layer += 3 * d * ff
        elif self.family == "hybrid":
            per_layer += 3 * d * ff
        if self.family in ("ssm", "hybrid"):
            di = self.d_inner
            per_layer += d * 2 * di + di * d + di * self.ssm_state * 2 // max(self.ssm_heads, 1)
        total = emb + self.n_layers * per_layer
        if self.is_encdec:
            total += self.encoder_layers * (4 * d * d + 3 * d * ff)
        if self.cross_attn_every:
            n_cross = self.n_layers // self.cross_attn_every
            total += n_cross * (4 * d * d)
        return int(total)

    def active_param_count_estimate(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if self.family != "moe":
            return self.param_count_estimate()
        d, ff = self.d_model, self.d_ff
        total = self.param_count_estimate()
        n_moe_layers = self.n_layers // self.moe_every
        moe_all = n_moe_layers * self.n_experts * 3 * d * ff
        moe_active = n_moe_layers * self.top_k * 3 * d * ff
        return int(total - moe_all + moe_active)
