"""Per-family transformer blocks: ParamDefs + apply functions.

Every block comes in one apply function usable for training (full
sequence, no cache) and serving (with KV/SSM state).  Blocks take the
*per-layer* param dict; model.py stacks them along a leading "layers"
axis and scans.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import ParamDef, gelu_mlp, rms_norm, swiglu
from repro.distributed.sharding import shard_constraint


# ----------------------------------------------------------------------
# ParamDefs
# ----------------------------------------------------------------------
def attn_defs(cfg) -> dict:
    d, q, kv = cfg.d_model, cfg.q_dim, cfg.kv_dim
    out = {
        "wq": ParamDef((d, q), ("fsdp", "q_dim")),
        "wk": ParamDef((d, kv), ("fsdp", "kv_dim")),
        "wv": ParamDef((d, kv), ("fsdp", "kv_dim")),
        "wo": ParamDef((q, d), ("q_dim", "fsdp")),
    }
    if cfg.qkv_bias:
        out.update({
            "bq": ParamDef((q,), ("q_dim",), init="zeros"),
            "bk": ParamDef((kv,), ("kv_dim",), init="zeros"),
            "bv": ParamDef((kv,), ("kv_dim",), init="zeros"),
        })
    return out


def mlp_defs(cfg, gelu: bool = False) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    if gelu:
        return {
            "w_in": ParamDef((d, ff), ("fsdp", "d_ff")),
            "b_in": ParamDef((ff,), ("d_ff",), init="zeros"),
            "w_out": ParamDef((ff, d), ("d_ff", "fsdp")),
            "b_out": ParamDef((d,), ("d_model",), init="zeros"),
        }
    return {
        "w_gate": ParamDef((d, ff), ("fsdp", "d_ff")),
        "w_up": ParamDef((d, ff), ("fsdp", "d_ff")),
        "w_down": ParamDef((ff, d), ("d_ff", "fsdp")),
    }


def moe_defs(cfg) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamDef((d, e), ("d_model", None)),
        "w_gate": ParamDef((e, d, ff), ("experts", "fsdp", None)),
        "w_up": ParamDef((e, d, ff), ("experts", "fsdp", None)),
        "w_down": ParamDef((e, ff, d), ("experts", None, "fsdp")),
    }


def ssm_defs(cfg) -> dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    proj_out = 2 * di + 2 * n + h
    return {
        "in_proj": ParamDef((d, proj_out), ("fsdp", "d_inner")),
        "conv_w": ParamDef((cfg.conv_dim, di), (None, "d_inner"), scale=0.5),
        "dt_bias": ParamDef((h,), ("ssm_heads",), init="zeros"),
        "a_log": ParamDef((h,), ("ssm_heads",), init="zeros"),
        "d_skip": ParamDef((h,), ("ssm_heads",), init="ones"),
        "out_proj": ParamDef((di, d), ("d_inner", "fsdp")),
    }


def cross_defs(cfg) -> dict:
    d, q, kv = cfg.d_model, cfg.q_dim, cfg.kv_dim
    return {
        "wq": ParamDef((d, q), ("fsdp", "q_dim")),
        "wk": ParamDef((d, kv), ("fsdp", "kv_dim")),
        "wv": ParamDef((d, kv), ("fsdp", "kv_dim")),
        "wo": ParamDef((q, d), ("q_dim", "fsdp")),
        "gate": ParamDef((), (), init="zeros"),
    }


def block_defs(cfg, kind: str) -> dict:
    """kind: dense | moe | ssm | hybrid | cross | encoder."""
    def norm():
        return ParamDef((cfg.d_model,), ("d_model",), init="ones")
    if kind == "ssm":
        return {"norm": norm(), "ssm": ssm_defs(cfg)}
    if kind == "cross":
        return {"norm1": norm(), "cross": cross_defs(cfg),
                "norm2": norm(), "mlp": mlp_defs(cfg)}
    if kind == "encoder":
        return {"norm1": norm(), "attn": attn_defs(cfg),
                "norm2": norm(), "mlp": mlp_defs(cfg, gelu=True)}
    if kind == "dec_cross":   # whisper decoder layer: self + cross + mlp
        return {"norm1": norm(), "attn": attn_defs(cfg),
                "norm2": norm(), "cross": cross_defs(cfg),
                "norm3": norm(), "mlp": mlp_defs(cfg, gelu=True)}
    out = {"norm1": norm(), "attn": attn_defs(cfg), "norm2": norm()}
    if kind == "moe":
        out["moe"] = moe_defs(cfg)
    elif kind == "hybrid":
        out["ssm"] = ssm_defs(cfg)
        out["mlp"] = mlp_defs(cfg)
        out["mix"] = ParamDef((2,), (None,), init="ones")
    elif kind == "dense":
        out["mlp"] = mlp_defs(cfg)
    else:
        raise ValueError(kind)
    return out


# ----------------------------------------------------------------------
# apply
# ----------------------------------------------------------------------
def apply_block(
    p: dict,
    x: jax.Array,
    cfg,
    kind: str,
    *,
    positions: jax.Array,
    cache: Optional[attn_mod.KVCache] = None,
    ssm_state: Optional[ssm_mod.SSMState] = None,
    enc: Optional[jax.Array] = None,
    causal: bool = True,
):
    """Returns (x_out, new_cache, new_ssm_state, aux_loss)."""
    new_cache, new_state = None, None
    zero = jnp.zeros((), jnp.float32)
    if kind == "ssm":
        h = rms_norm(x, p["norm"], cfg.norm_eps)
        y, new_state = ssm_mod.ssm_apply(p["ssm"], h, cfg, ssm_state)
        return x + y, None, new_state, zero

    if kind == "cross":
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        y = attn_mod.cross_attention_apply(p["cross"], h, enc, cfg=cfg)
        x = x + jnp.tanh(p["cross"]["gate"]) * y
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        return x + swiglu(h, **p["mlp"]), None, None, zero

    if kind == "encoder":
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        y, _ = attn_mod.attention_apply(
            p["attn"], h, cfg=cfg, positions=positions, causal=False,
            use_rope=False)
        x = x + y
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        return x + gelu_mlp(h, p["mlp"]["w_in"], p["mlp"]["b_in"],
                            p["mlp"]["w_out"], p["mlp"]["b_out"]), None, None, zero

    if kind == "dec_cross":
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        y, new_cache = attn_mod.attention_apply(
            p["attn"], h, cfg=cfg, positions=positions, cache=cache,
            causal=causal, use_rope=False)
        x = x + y
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + attn_mod.cross_attention_apply(p["cross"], h, enc, cfg=cfg)
        h = rms_norm(x, p["norm3"], cfg.norm_eps)
        return (x + gelu_mlp(h, p["mlp"]["w_in"], p["mlp"]["b_in"],
                             p["mlp"]["w_out"], p["mlp"]["b_out"]),
                new_cache, None, zero)

    # dense / moe / hybrid share the attention sublayer
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    window = cfg.sliding_window if kind == "hybrid" else 0
    y, new_cache = attn_mod.attention_apply(
        p["attn"], h, cfg=cfg, positions=positions, cache=cache,
        causal=causal, window=window)
    if kind == "hybrid":
        ys, new_state = ssm_mod.ssm_apply(p["ssm"], h, cfg, ssm_state)
        mix = jax.nn.softmax(p["mix"].astype(jnp.float32))
        y = mix[0] * y.astype(jnp.float32) + mix[1] * ys.astype(jnp.float32)
        y = y.astype(x.dtype)
    x = x + y
    x = shard_constraint(x, "batch", "seq", "d_model")
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    aux = zero
    if kind == "moe":
        x = x + moe_mod.moe_apply(p["moe"], h, cfg)
        aux = moe_mod.moe_aux_loss(p["moe"], h, cfg)
    else:
        x = x + swiglu(h, **p["mlp"])
    return x, new_cache, new_state, aux
