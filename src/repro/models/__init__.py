"""Model zoo: the 10 assigned architectures as one composable family.

All models share a single ModelConfig surface and three entry points:
  * ``init_params`` (works under jax.eval_shape for the dry-run),
  * ``train_step_fn``  (next-token loss, grads, optimizer update),
  * ``prefill_fn`` / ``decode_step_fn`` (KV-cache serving).

Families: dense transformer (GQA/RoPE/QKV-bias), MoE (top-1 capacity
dispatch), SSM (Mamba2 SSD), hybrid (Hymba parallel attn+SSM), enc-dec
audio backbone (Whisper, stub frontend), VLM (Llama-3.2-vision backbone,
stub patch embeddings, interleaved cross-attention).
"""
from repro.models.config import ModelConfig, DTypePolicy  # noqa: F401
from repro.models.model import (  # noqa: F401
    init_params,
    forward_train,
    loss_fn,
    init_decode_state,
    prefill,
    decode_step,
)
