"""Top-level model assembly: init, train forward, prefill, decode.

Layers are stacked along a leading "layers" axis and consumed with
``jax.lax.scan`` so HLO size (and compile time) is O(1) in depth — that
is what makes 80 dry-run compiles of 30-48-layer models tractable.  The
VLM's heterogeneous stack scans over *groups* (N-1 dense + 1 cross
layer) so no gated-FLOP waste is introduced.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_constraint
from repro.models import blocks
from repro.models.attention import KVCache, cache_pos_update
from repro.models.config import ModelConfig
from repro.models.layers import (
    ParamDef,
    logical_axes_tree,
    materialize,
    rms_norm,
)
from repro.models.ssm import SSMState


# ----------------------------------------------------------------------
# parameter trees
# ----------------------------------------------------------------------
def _stack_defs(defs, n: int):
    return jax.tree_util.tree_map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.logical_axes,
                           d.init, d.scale),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def _layer_kind(cfg: ModelConfig) -> str:
    return {"dense": "dense", "moe": "moe", "ssm": "ssm",
            "hybrid": "hybrid", "audio": "dec_cross", "vlm": "dense"}[cfg.family]


def param_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab_size
    out: Dict[str, Any] = {
        "tok_emb": ParamDef((v, d), ("vocab", "fsdp")),
        "final_norm": ParamDef((d,), ("d_model",), init="ones"),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = ParamDef((d, v), ("fsdp", "vocab"))

    kind = _layer_kind(cfg)
    if cfg.family == "vlm" and cfg.cross_attn_every > 0:
        n_groups = cfg.n_layers // cfg.cross_attn_every
        plain_per = cfg.cross_attn_every - 1
        out["groups"] = {
            "plain": _stack_defs(_stack_defs(blocks.block_defs(cfg, "dense"),
                                             plain_per), n_groups),
            "cross": _stack_defs(blocks.block_defs(cfg, "cross"), n_groups),
        }
    elif cfg.family == "moe" and cfg.moe_every > 1:
        # interleaved dense/MoE (maverick): groups of (moe_every-1 dense
        # + 1 moe), dense first
        n_groups = cfg.n_layers // cfg.moe_every
        dense_per = cfg.moe_every - 1
        out["groups"] = {
            "plain": _stack_defs(_stack_defs(blocks.block_defs(cfg, "dense"),
                                             dense_per), n_groups),
            "moe": _stack_defs(blocks.block_defs(cfg, "moe"), n_groups),
        }
    else:
        out["layers"] = _stack_defs(blocks.block_defs(cfg, kind), cfg.n_layers)

    if cfg.is_encdec:
        out["encoder"] = _stack_defs(blocks.block_defs(cfg, "encoder"),
                                     cfg.encoder_layers)
        out["enc_final_norm"] = ParamDef((d,), ("d_model",), init="ones")
        out["dec_pos_emb"] = ParamDef((cfg.max_seq_len, d), (None, "fsdp"),
                                      scale=0.02)
    return out


def init_params(cfg: ModelConfig, key: jax.Array):
    return materialize(param_defs(cfg), key, cfg.dtypes.params_dtype)


def logical_axes(cfg: ModelConfig):
    return logical_axes_tree(param_defs(cfg))


# ----------------------------------------------------------------------
# forward (training / no-cache)
# ----------------------------------------------------------------------
def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        policy = jax.checkpoint_policies.nothing_saveable
    else:
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint(fn, policy=policy)


def _cast_tree(params, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, params)


def _scan_apply(body, x, stacked, cfg: ModelConfig, remat: bool = False):
    """jax.lax.scan over the stacked layer axis, or a Python unroll when
    cfg.scan_layers is False (used by the dry-run cost probes: XLA's
    cost_analysis counts while-loop bodies ONCE, so exact FLOP totals
    need an unrolled compile at small depth)."""
    fn = _maybe_remat(body, cfg) if remat else body
    if cfg.scan_layers:
        return jax.lax.scan(fn, x, stacked)
    n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    ys = []
    for i in range(n):
        sl = jax.tree_util.tree_map(lambda a: a[i], stacked)
        x, y = fn(x, sl)
        ys.append(y)
    if ys and ys[0] is not None:
        ys_stacked = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    else:
        ys_stacked = None
    return x, ys_stacked


def _run_encoder(params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Whisper encoder over stub frame embeddings [B, T, d]."""
    x = frames
    positions = jnp.arange(x.shape[1])

    def body(carry, layer_params):
        y, _, _, _ = blocks.apply_block(layer_params, carry, cfg, "encoder",
                                        positions=positions, causal=False)
        return y, None

    x, _ = _scan_apply(body, x, params["encoder"], cfg, remat=True)
    return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def _forward_impl(
    params,
    tokens: jax.Array,
    cfg: ModelConfig,
    enc_inputs: Optional[jax.Array],
) -> Tuple[jax.Array, jax.Array]:
    compute = cfg.dtypes.compute_dtype
    cparams = _cast_tree(params, compute)
    b, s = tokens.shape
    x = cparams["tok_emb"][tokens]
    x = shard_constraint(x, "batch", "seq", "d_model")
    positions = jnp.arange(s)

    enc = None
    if cfg.is_encdec:
        enc = _run_encoder(cparams, enc_inputs.astype(compute), cfg)
        x = x + cparams["dec_pos_emb"][:s][None]
    elif cfg.family == "vlm":
        enc = enc_inputs.astype(compute)

    kind = _layer_kind(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    if cfg.family == "vlm" and cfg.cross_attn_every > 0:
        def group_body(carry, gp):
            def plain_body(c, lp):
                y, _, _, _ = blocks.apply_block(lp, c, cfg, "dense",
                                                positions=positions)
                return y, None
            h, _ = _scan_apply(plain_body, carry, gp["plain"], cfg)
            h, _, _, _ = blocks.apply_block(gp["cross"], h, cfg, "cross",
                                            positions=positions, enc=enc)
            return h, None
        x, _ = _scan_apply(group_body, x, cparams["groups"], cfg, remat=True)
    elif cfg.family == "moe" and cfg.moe_every > 1:
        def group_body(carry, gp):
            def plain_body(c, lp):
                y, _, _, _ = blocks.apply_block(lp, c, cfg, "dense",
                                                positions=positions)
                return y, None
            h, _ = _scan_apply(plain_body, carry, gp["plain"], cfg)
            h, _, _, aux = blocks.apply_block(gp["moe"], h, cfg, "moe",
                                              positions=positions)
            return h, aux
        x, auxs = _scan_apply(group_body, x, cparams["groups"], cfg,
                              remat=True)
        aux_total = auxs.sum()
    else:
        def body(carry, lp):
            y, _, _, aux = blocks.apply_block(lp, carry, cfg, kind,
                                              positions=positions, enc=enc)
            return y, aux
        x, auxs = _scan_apply(body, x, cparams["layers"], cfg, remat=True)
        aux_total = auxs.sum()

    x = rms_norm(x, cparams["final_norm"], cfg.norm_eps)
    head = (cparams["tok_emb"].T if cfg.tie_embeddings
            else cparams["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return shard_constraint(logits, "batch", "seq", "vocab"), aux_total


def forward(
    params,
    tokens: jax.Array,                 # [B, S] int32
    cfg: ModelConfig,
    *,
    enc_inputs: Optional[jax.Array] = None,   # audio frames / vision embeds
) -> jax.Array:
    """Full-sequence causal forward -> logits [B, S, vocab]."""
    logits, _ = _forward_impl(params, tokens, cfg, enc_inputs)
    return logits


def loss_fn(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            aux_coef: float = 0.01) -> jax.Array:
    """Masked next-token cross-entropy (+ MoE load-balance aux loss)."""
    logits, aux = _forward_impl(params, batch["tokens"], cfg,
                                batch.get("enc_inputs"))
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None],
                               axis=-1)[..., 0]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    if cfg.family == "moe":
        loss = loss + aux_coef * aux
    return loss


forward_train = forward  # alias used by smoke tests


# ----------------------------------------------------------------------
# serving: prefill + decode
# ----------------------------------------------------------------------
class DecodeState(NamedTuple):
    kv: Optional[Tuple[jax.Array, jax.Array]]   # stacked [L, B, S, KH, hd]
    ssm: Optional[Tuple[jax.Array, jax.Array]]  # stacked state/conv
    pos: Optional[jax.Array]                     # [S_cache] ring positions
    length: jax.Array                            # [] int32
    enc: Optional[jax.Array] = None              # encoder/vision context


def _cache_seq_len(cfg: ModelConfig, max_len: int) -> int:
    if cfg.sliding_window > 0:
        return min(max_len, cfg.sliding_window)
    return max_len


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      enc: Optional[jax.Array] = None) -> DecodeState:
    dt = cfg.dtypes.kv_cache_dtype
    kv, ssm, pos = None, None, None
    if cfg.family == "vlm" and cfg.cross_attn_every > 0:
        n_groups = cfg.n_layers // cfg.cross_attn_every
        plain_per = cfg.cross_attn_every - 1
        k = jnp.zeros((n_groups, plain_per, batch, max_len,
                       cfg.n_kv_heads, cfg.head_dim), dt)
        kv = (k, jnp.zeros_like(k))
        pos = jnp.full((max_len,), -1, jnp.int32)
    elif cfg.family == "moe" and cfg.moe_every > 1:
        n_groups = cfg.n_layers // cfg.moe_every
        dense_per = cfg.moe_every - 1
        kp = jnp.zeros((n_groups, dense_per, batch, max_len,
                        cfg.n_kv_heads, cfg.head_dim), dt)
        km = jnp.zeros((n_groups, batch, max_len,
                        cfg.n_kv_heads, cfg.head_dim), dt)
        kv = {"plain": (kp, jnp.zeros_like(kp)),
              "moe": (km, jnp.zeros_like(km))}
        pos = jnp.full((max_len,), -1, jnp.int32)
    elif cfg.family != "ssm":
        s_len = _cache_seq_len(cfg, max_len)
        k = jnp.zeros((cfg.n_layers, batch, s_len, cfg.n_kv_heads,
                       cfg.head_dim), dt)
        kv = (k, jnp.zeros_like(k))
        pos = jnp.full((s_len,), -1, jnp.int32)
    if cfg.family in ("ssm", "hybrid"):
        st = jnp.zeros((cfg.n_layers, batch, cfg.ssm_heads,
                        cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
        cv = jnp.zeros((cfg.n_layers, batch, cfg.conv_dim - 1, cfg.d_inner),
                       dt)
        ssm = (st, cv)
    return DecodeState(kv=kv, ssm=ssm, pos=pos,
                       length=jnp.zeros((), jnp.int32), enc=enc)


def _forward_cached(params, tokens, cfg: ModelConfig, state: DecodeState):
    """Shared prefill/decode body: runs S tokens against the caches."""
    compute = cfg.dtypes.compute_dtype
    cparams = _cast_tree(params, compute)
    b, s = tokens.shape
    x = cparams["tok_emb"][tokens]
    x = shard_constraint(x, "batch", "seq", "d_model")
    positions = state.length + jnp.arange(s)
    enc = state.enc
    if enc is not None:
        enc = enc.astype(compute)
    if cfg.is_encdec:
        x = x + jax.lax.dynamic_slice_in_dim(
            cparams["dec_pos_emb"], state.length, s, axis=0)[None]

    kind = _layer_kind(cfg)
    new_pos = (cache_pos_update(state.pos, state.length, s)
               if state.pos is not None else None)

    if cfg.family == "vlm" and cfg.cross_attn_every > 0:
        k_all, v_all = state.kv

        def group_body(carry, xs):
            gp, kg, vg = xs

            def plain_body(c, xs2):
                lp, kl, vl = xs2
                cache = KVCache(kl, vl, state.pos, state.length)
                y, nc, _, _ = blocks.apply_block(
                    lp, c, cfg, "dense", positions=positions, cache=cache)
                return y, (nc.k, nc.v)
            h, (nk, nv) = _scan_apply(plain_body, carry,
                                      (gp["plain"], kg, vg), cfg)
            h, _, _, _ = blocks.apply_block(gp["cross"], h, cfg, "cross",
                                            positions=positions, enc=enc)
            return h, (nk, nv)
        x, (new_k, new_v) = _scan_apply(group_body, x,
                                        (cparams["groups"], k_all, v_all), cfg)
        new_state = DecodeState((new_k, new_v), None, new_pos,
                                state.length + s, state.enc)
    elif cfg.family == "moe" and cfg.moe_every > 1:
        kp, vp = state.kv["plain"]
        km, vm = state.kv["moe"]

        def group_body(carry, xs):
            gp, kpl, vpl, kml, vml = xs

            def plain_body(c, xs2):
                lp, kl, vl = xs2
                cache = KVCache(kl, vl, state.pos, state.length)
                y, nc, _, _ = blocks.apply_block(
                    lp, c, cfg, "dense", positions=positions, cache=cache)
                return y, (nc.k, nc.v)
            h, (nkp, nvp) = _scan_apply(plain_body, carry,
                                        (gp["plain"], kpl, vpl), cfg)
            cache = KVCache(kml, vml, state.pos, state.length)
            h, nc, _, _ = blocks.apply_block(gp["moe"], h, cfg, "moe",
                                             positions=positions, cache=cache)
            return h, (nkp, nvp, nc.k, nc.v)
        x, (nkp, nvp, nkm, nvm) = _scan_apply(
            group_body, x, (cparams["groups"], kp, vp, km, vm), cfg)
        new_state = DecodeState({"plain": (nkp, nvp), "moe": (nkm, nvm)},
                                None, new_pos, state.length + s, state.enc)
    elif cfg.family == "ssm":
        st_all, cv_all = state.ssm

        def body(carry, xs):
            lp, st, cv = xs
            y, _, new_ssm, _ = blocks.apply_block(
                lp, carry, cfg, "ssm", positions=positions,
                ssm_state=SSMState(st, cv))
            return y, (new_ssm.state, new_ssm.conv)
        x, (nst, ncv) = _scan_apply(body, x,
                                    (cparams["layers"], st_all, cv_all), cfg)
        new_state = DecodeState(None, (nst, ncv), None,
                                state.length + s, state.enc)
    elif cfg.family == "hybrid":
        k_all, v_all = state.kv
        st_all, cv_all = state.ssm

        def body(carry, xs):
            lp, kl, vl, st, cv = xs
            cache = KVCache(kl, vl, state.pos, state.length)
            y, nc, new_ssm, _ = blocks.apply_block(
                lp, carry, cfg, "hybrid", positions=positions,
                cache=cache, ssm_state=SSMState(st, cv))
            return y, (nc.k, nc.v, new_ssm.state, new_ssm.conv)
        x, (nk, nv, nst, ncv) = _scan_apply(
            body, x, (cparams["layers"], k_all, v_all, st_all, cv_all), cfg)
        new_state = DecodeState((nk, nv), (nst, ncv), new_pos,
                                state.length + s, state.enc)
    else:
        k_all, v_all = state.kv
        kind2 = "dec_cross" if cfg.is_encdec else kind

        def body(carry, xs):
            lp, kl, vl = xs
            cache = KVCache(kl, vl, state.pos, state.length)
            y, nc, _, _ = blocks.apply_block(
                lp, carry, cfg, kind2, positions=positions,
                cache=cache, enc=enc)
            return y, (nc.k, nc.v)
        x, (nk, nv) = _scan_apply(body, x, (cparams["layers"], k_all, v_all), cfg)
        new_state = DecodeState((nk, nv), None, new_pos,
                                state.length + s, state.enc)

    x = rms_norm(x, cparams["final_norm"], cfg.norm_eps)
    x_last = x[:, -1, :]
    head = (cparams["tok_emb"].T if cfg.tie_embeddings else cparams["lm_head"])
    logits = x_last @ head
    return shard_constraint(logits, "batch", "vocab"), new_state


def prefill(params, tokens: jax.Array, cfg: ModelConfig,
            state: DecodeState):
    """Process the prompt; returns (last-token logits, filled state)."""
    if cfg.is_encdec and state.enc is None:
        raise ValueError("enc-dec prefill needs encoder output in state.enc")
    return _forward_cached(params, tokens, cfg, state)


def decode_step(params, token: jax.Array, cfg: ModelConfig,
                state: DecodeState):
    """One decode step. token: [B, 1] -> (logits [B, vocab], new state)."""
    return _forward_cached(params, token, cfg, state)


def encode(params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Public encoder entry (whisper): stub frames -> encoder states."""
    cparams = _cast_tree(params, cfg.dtypes.compute_dtype)
    return _run_encoder(cparams, frames.astype(cfg.dtypes.compute_dtype), cfg)
