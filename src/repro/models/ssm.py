"""Mamba2 SSD (state-space duality) layer — training scan + O(1) decode.

Implements the SSD recurrence (Dao & Gu 2024, arXiv:2405.21060) in its
chunked form: within a chunk the quadratic "attention-like" dual form
runs on the MXU; across chunks a small state [heads, head_dim, state]
carries the recurrence.  This is the TPU-native adaptation: the CUDA
kernel's warp-level scan becomes a jax.lax.scan over chunk states with
dense intra-chunk einsums (MXU food), per the hardware-adaptation rule.

Simplifications vs the full Mamba2 block (recorded in DESIGN.md):
scalar-per-head A (as in the paper), single B/C group, depthwise conv
on x only.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_constraint


class SSMState(NamedTuple):
    state: jax.Array       # [B, H, hd, N] inter-chunk SSD state
    conv: jax.Array        # [B, conv_dim-1, d_inner] depthwise conv tail


def init_ssm_state(batch: int, cfg, dtype) -> SSMState:
    return SSMState(
        state=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                         cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_dim - 1, cfg.d_inner), dtype),
    )


def _split_proj(p, x, cfg):
    """in_proj -> (z gate [.., d_inner], x [.., d_inner], B [.., N],
    C [.., N], dt [.., H])."""
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z, xin, b, c, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    return z, xin, b, c, dt


def _conv1d(xin: jax.Array, w: jax.Array, tail: Optional[jax.Array]):
    """Causal depthwise conv over seq.  w: [conv_dim, d_inner].
    Returns (y, new_tail)."""
    kdim = w.shape[0]
    if tail is None:
        pad = jnp.zeros((xin.shape[0], kdim - 1, xin.shape[2]), xin.dtype)
    else:
        pad = tail.astype(xin.dtype)
    xp = jnp.concatenate([pad, xin], axis=1)          # [B, S+k-1, di]
    y = sum(xp[:, i: i + xin.shape[1], :] * w[i] for i in range(kdim))
    new_tail = xp[:, xp.shape[1] - (kdim - 1):, :]
    return jax.nn.silu(y), new_tail


def ssd_chunked(
    xin: jax.Array,       # [B, S, H, hd]  (post conv+silu, reshaped)
    dt: jax.Array,        # [B, S, H]      softplus'd step sizes
    a_log: jax.Array,     # [H]            log(-A)
    b: jax.Array,         # [B, S, N]
    c: jax.Array,         # [B, S, N]
    chunk: int,
    init_state: Optional[jax.Array] = None,   # [B, H, hd, N]
    unroll: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """SSD forward. Returns (y [B,S,H,hd], final_state [B,H,hd,N])."""
    bsz, s, h, hd = xin.shape
    n = b.shape[-1]
    nc = (s + chunk - 1) // chunk
    pad = nc * chunk - s
    if pad:
        xin = jnp.pad(xin, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    a = -jnp.exp(a_log.astype(jnp.float32))                   # [H], a < 0
    dt32 = dt.astype(jnp.float32)
    da = dt32 * a[None, None, :]                              # [B, S', H]
    # reshape to chunks
    xin_c = xin.reshape(bsz, nc, chunk, h, hd)
    dt_c = dt32.reshape(bsz, nc, chunk, h)
    da_c = da.reshape(bsz, nc, chunk, h)
    b_c = b.reshape(bsz, nc, chunk, n).astype(jnp.float32)
    c_c = c.reshape(bsz, nc, chunk, n).astype(jnp.float32)

    cum = jnp.cumsum(da_c, axis=2)                            # [B,nc,L,H]
    seg_total = cum[:, :, -1, :]                              # [B,nc,H]

    def chunk_body(state, inp):
        xin_i, dt_i, da_i, cum_i, tot_i, b_i, c_i = inp
        # intra-chunk dual (attention-like) term
        # L[s,t] = exp(cum[s] - cum[t]) for s >= t
        rel = cum_i[:, :, None, :] - cum_i[:, None, :, :]      # [B,L,L,H]
        causal = jnp.tril(jnp.ones((rel.shape[1], rel.shape[1]), bool))
        # mask BEFORE exp: exp of the (large positive) acausal entries
        # overflows and where()'s backward turns 0 * inf into NaN
        rel = jnp.where(causal[None, :, :, None], rel, -1e30)
        gamma = jnp.exp(rel)
        cb = jnp.einsum("bln,btn->blt", c_i, b_i)              # [B,L,L]
        w = cb[:, :, :, None] * gamma                          # [B,L,L,H]
        xdt = xin_i.astype(jnp.float32) * dt_i[..., None]      # [B,L,H,hd]
        y_intra = jnp.einsum("blth,bthd->blhd", w, xdt)
        # inter-chunk: contribution of carried state
        decay_in = jnp.exp(cum_i)                              # [B,L,H]
        y_inter = jnp.einsum("bln,bhdn,blh->blhd", c_i, state, decay_in)
        # state update: state' = exp(tot) * state + sum_t exp(tot-cum_t) * x_t dt_t b_t^T
        decay_out = jnp.exp(tot_i[:, None, :] - cum_i)         # [B,L,H]
        ds = jnp.einsum("blh,blhd,bln->bhdn", decay_out, xdt, b_i)
        new_state = jnp.exp(tot_i)[:, :, None, None] * state + ds
        return new_state, y_intra + y_inter

    state0 = (init_state if init_state is not None
              else jnp.zeros((bsz, h, hd, n), jnp.float32))
    inputs = (
        xin_c.swapaxes(0, 1), dt_c.swapaxes(0, 1), da_c.swapaxes(0, 1),
        cum.swapaxes(0, 1), seg_total.swapaxes(0, 1),
        b_c.swapaxes(0, 1), c_c.swapaxes(0, 1),
    )
    final_state, y = jax.lax.scan(chunk_body, state0, inputs,
                                  unroll=nc if unroll else 1)
    y = y.swapaxes(0, 1).reshape(bsz, nc * chunk, h, hd)[:, :s]
    return y.astype(xin.dtype), final_state


def ssm_apply(
    p: dict,
    x: jax.Array,            # [B, S, d_model]
    cfg,
    state: Optional[SSMState] = None,
) -> Tuple[jax.Array, Optional[SSMState]]:
    """Full Mamba2 mixer.  With ``state`` the call is incremental
    (prefill appends S tokens; decode S=1) and returns the new state."""
    bsz, s, _ = x.shape
    z, xin, b, c, dt = _split_proj(p, x, cfg)
    xin = shard_constraint(xin, "batch", "seq", "d_inner")
    xin, new_conv = _conv1d(xin, p["conv_w"],
                            state.conv if state is not None else None)
    dt = jax.nn.softplus(dt + p["dt_bias"])
    h, hd = cfg.ssm_heads, cfg.ssm_head_dim
    xin_h = xin.reshape(bsz, s, h, hd)
    y, new_state = ssd_chunked(
        xin_h, dt, p["a_log"], b, c, cfg.ssm_chunk,
        init_state=state.state if state is not None else None,
        unroll=not cfg.scan_layers)
    y = y + xin_h * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, cfg.d_inner)
    y = y * jax.nn.silu(z)                       # gated output
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if state is not None:
        return out, SSMState(new_state, new_conv)
    return out, None
