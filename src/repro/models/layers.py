"""Shared layers + the parameter-definition machinery.

Parameters are declared as ``ParamDef``s (shape, logical sharding axes,
initializer).  ``init_params`` materializes them (or produces abstract
ShapeDtypeStructs under ``jax.eval_shape`` for the dry-run);
``logical_axes_tree`` returns the same pytree filled with logical-axis
tuples so the launcher can derive NamedShardings without touching model
code.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import shard_constraint


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | small_normal
    scale: float = 1.0

    def initializer(self, key: jax.Array, dtype) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        fan_in = self.shape[0] if len(self.shape) >= 2 else max(self.shape[-1], 1)
        std = self.scale / np.sqrt(fan_in)
        return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(dtype)


ParamTree = Dict  # nested dict of ParamDef / arrays


def materialize(defs: ParamTree, key: jax.Array, dtype) -> ParamTree:
    """Turn a tree of ParamDefs into arrays (jit/eval_shape friendly)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    vals = [d.initializer(k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def logical_axes_tree(defs: ParamTree) -> ParamTree:
    return jax.tree_util.tree_map(
        lambda d: d.logical_axes, defs,
        is_leaf=lambda x: isinstance(x, ParamDef))


# ----------------------------------------------------------------------
# normalization / activations
# ----------------------------------------------------------------------
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g) * u
    h = shard_constraint(h, "batch", "seq", "d_ff")
    return jnp.einsum("...f,fd->...d", h, w_down)


def gelu_mlp(x: jax.Array, w_in: jax.Array, b_in: jax.Array,
             w_out: jax.Array, b_out: jax.Array) -> jax.Array:
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, w_in) + b_in)
    h = shard_constraint(h, "batch", "seq", "d_ff")
    return jnp.einsum("...f,fd->...d", h, w_out) + b_out


# ----------------------------------------------------------------------
# rotary position embeddings
# ----------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq] int32."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                  # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., :, None, :]                     # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
